"""Per-engine mutation overlay: uncompressed triple delta over a grammar.

The ITR grammar is a *static* compression of a triple set — inserting or
deleting one triple would invalidate digram counts, rule bodies, and the
succinct encoding all at once. Instead of recompressing on every write,
each :class:`~repro.core.query.TripleQueryEngine` carries a
:class:`DeltaOverlay`: a small uncompressed buffer of inserted triples
(kept CSR-sorted by (s, p, o)) plus a tombstone set of deleted *base*
triples. Queries stay exact under mutation because the engine merges the
overlay into every result at execution time:

* edges answered by the compressed grammar that match a tombstone are
  filtered out (rank-2 edges only — node-label hyperedges of ITR+ are
  never triples and never tombstoned);
* inserted triples matching the pattern are appended.

Both steps are vectorized over the whole unique-pattern batch (a
``(n_queries, delta_size)`` broadcast for inserts, one row-set membership
pass for tombstones), so overlay cost scales with the delta — which is
bounded: once ``delta.size`` exceeds the engine's budget
(``ITR_DELTA_BUDGET``, see :func:`resolve_delta_budget`), the engine
recompresses base+delta into a fresh grammar and the overlay empties.
The overlay is the write path; RePair stays the storage format.

Set semantics: the logical triple set is ``(base - tombstones) + inserts``
with the invariants that inserts are never present in the visible base and
tombstones always are. The engine enforces them with a membership query
before each mutation batch, so re-inserting a deleted triple just drops
its tombstone, and deleting an overlay insert just drops the buffered row
— ``size`` counts real divergence from the compressed base.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.hypergraph import _ragged_take

_EMPTY_ROWS = np.zeros((0, 3), dtype=np.int64)

# default rebuild budget: overlay rows tolerated before auto-recompression
DEFAULT_DELTA_BUDGET = 4096

# ITR_DELTA_BUDGET spellings that disable auto-rebuild entirely
_OFF_SPELLINGS = ("off", "none", "never", "disable", "disabled")


def resolve_delta_budget(value=None) -> int | None:
    """Resolve a delta-rebuild budget to ``int`` (threshold) or ``None``
    (auto-rebuild disabled; only explicit ``rebuild()`` recompresses).

    ``value=None`` reads ``ITR_DELTA_BUDGET``: a non-negative integer is
    the threshold (``0`` = recompress after every mutation batch);
    ``off``/``none``/``never`` or any negative integer disables
    auto-rebuild; unset/empty/unparsable falls back to
    :data:`DEFAULT_DELTA_BUDGET`. An explicit ``value`` follows the same
    rules without touching the environment.
    """
    if value is None:
        env = os.environ.get("ITR_DELTA_BUDGET", "").strip().lower()
        if not env:
            return DEFAULT_DELTA_BUDGET
        if env in _OFF_SPELLINGS:
            return None
        try:
            value = int(env)
        except ValueError:
            return DEFAULT_DELTA_BUDGET
    value = int(value)
    return None if value < 0 else value


def as_triple_rows(triples) -> np.ndarray:
    """Validate + canonicalize a mutation batch: ``(n, 3)`` int64 rows,
    non-negative ids, deduplicated and sorted (mutations have set
    semantics, so duplicate rows in one batch are one mutation)."""
    rows = np.asarray(triples, dtype=np.int64)
    if rows.ndim != 2 or rows.shape[1] != 3:
        raise ValueError(f"expected (n, 3) triple rows, got shape {rows.shape}")
    if len(rows) and rows.min() < 0:
        raise ValueError("triple ids must be non-negative (-1 means 'unbound' "
                         "in query patterns, not in data)")
    return np.unique(rows, axis=0) if len(rows) else _EMPTY_ROWS


def rows_in(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-wise set membership: bool[len(a)], True where row a[i] occurs in b."""
    if len(a) == 0 or len(b) == 0:
        return np.zeros(len(a), dtype=bool)
    both = np.concatenate([b, a])
    uniq, inv = np.unique(both, axis=0, return_inverse=True)
    inv = inv.reshape(-1)
    in_b = np.zeros(len(uniq), dtype=bool)
    in_b[inv[: len(b)]] = True
    return in_b[inv[len(b):]]


class DeltaOverlay:
    """Uncompressed (inserts, tombstones) delta over a compressed triple set.

    Pure data structure: the engine decides *what* is an insert vs a
    resurrection (see module docstring); the overlay stores rows, answers
    patterns over its insert buffer, and rewrites batch results.
    """

    __slots__ = ("_inserts", "_tombstones")

    def __init__(self):
        self._inserts = _EMPTY_ROWS
        self._tombstones = _EMPTY_ROWS

    # -- introspection ---------------------------------------------------
    @property
    def inserts(self) -> np.ndarray:
        """Buffered inserted triples, CSR-sorted by (s, p, o). Read-only."""
        return self._inserts

    @property
    def tombstones(self) -> np.ndarray:
        """Deleted base triples, sorted. Read-only."""
        return self._tombstones

    @property
    def n_inserts(self) -> int:
        return len(self._inserts)

    @property
    def n_tombstones(self) -> int:
        return len(self._tombstones)

    @property
    def size(self) -> int:
        """Total divergence from the compressed base (rows buffered either
        way) — the quantity ``ITR_DELTA_BUDGET`` bounds."""
        return len(self._inserts) + len(self._tombstones)

    @property
    def is_empty(self) -> bool:
        return self.size == 0

    def clear(self) -> None:
        self._inserts = _EMPTY_ROWS
        self._tombstones = _EMPTY_ROWS

    def load_rows(self, inserts: np.ndarray, tombstones: np.ndarray) -> None:
        """Restore persisted overlay state (the snapshot load path).

        The rows must already be canonical — each side sorted, deduped,
        disjoint from the other, with the module invariants (inserts not
        in the visible base, tombstones in it) guaranteed by whoever
        persisted them; they are adopted as-is. Read-only (mmap) arrays
        are fine: the overlay never mutates its buffers in place.
        """
        self._inserts = np.asarray(inserts, dtype=np.int64).reshape(-1, 3)
        self._tombstones = np.asarray(tombstones, dtype=np.int64).reshape(-1, 3)

    # -- mutation --------------------------------------------------------
    def insert_rows(self, rows: np.ndarray) -> int:
        """Record insertions of `rows`, which the caller has verified are
        NOT currently visible. Tombstoned rows are resurrected (tombstone
        dropped); the rest join the sorted insert buffer."""
        if len(rows) == 0:
            return 0
        tombed = rows_in(rows, self._tombstones)
        if tombed.any():
            self._tombstones = self._tombstones[
                ~rows_in(self._tombstones, rows[tombed])]
        fresh = rows[~tombed]
        if len(fresh):
            merged = np.concatenate([self._inserts, fresh])
            self._inserts = merged[np.lexsort(merged.T[::-1])]
        return len(rows)

    def delete_rows(self, rows: np.ndarray) -> int:
        """Record deletions of `rows`, which the caller has verified ARE
        currently visible. Overlay inserts are simply un-buffered; base
        rows gain a tombstone."""
        if len(rows) == 0:
            return 0
        buffered = rows_in(rows, self._inserts)
        if buffered.any():
            self._inserts = self._inserts[~rows_in(self._inserts, rows[buffered])]
        base = rows[~buffered]
        if len(base):
            merged = np.concatenate([self._tombstones, base])
            self._tombstones = merged[np.lexsort(merged.T[::-1])]
        return len(rows)

    # -- query-side ------------------------------------------------------
    def apply(self, triples: np.ndarray) -> np.ndarray:
        """Logical triple set: `triples` (the decompressed base) minus
        tombstones plus the insert buffer. Base duplicates survive."""
        out = np.asarray(triples, dtype=np.int64).reshape(-1, 3)
        if len(self._tombstones):
            out = out[~rows_in(out, self._tombstones)]
        if len(self._inserts):
            out = np.concatenate([out, self._inserts])
        return out

    def merge_batch(self, res, s: np.ndarray, p: np.ndarray, o: np.ndarray):
        """Rewrite one executed unique-pattern batch under the overlay.

        `res` is the engine's ``(qids, labels, nodes_flat, offsets)``
        result over the compressed base; `s`/`p`/`o` are the aligned
        pattern columns (-1 = unbound). Tombstoned rank-2 edges are
        dropped, then each query gains its matching inserted triples as
        appended rank-2 edges. Returns the same tuple shape.
        """
        qids, labels, nodes, offsets = res
        ranks = np.diff(offsets)
        tombs = self._tombstones
        if len(tombs) and len(labels):
            starts = offsets[:-1]
            t_idx = np.flatnonzero(ranks == 2)
            # cheap 1-D prefilter before the row-wise membership test:
            # rows_in sorts full (s, p, o) rows, which on an unselective
            # result (a ?P? scan is ~10^5-10^6 edges) would cost a
            # 3-column lexsort per executed batch even for one tombstone.
            # Subject-column isin narrows that to edges sharing a
            # tombstoned subject — typically a handful.
            if len(t_idx):
                cand = np.isin(nodes[starts[t_idx]], tombs[:, 0])
                t_idx = t_idx[cand]
            if len(t_idx):
                edge_rows = np.stack(
                    [nodes[starts[t_idx]], labels[t_idx],
                     nodes[starts[t_idx] + 1]], axis=1)
                dead = rows_in(edge_rows, tombs)
                if dead.any():
                    keep = np.ones(len(labels), dtype=bool)
                    keep[t_idx[dead]] = False
                    idx = np.flatnonzero(keep)
                    ranks = np.diff(offsets)[idx]
                    take = _ragged_take(offsets, idx, ranks)
                    qids, labels, nodes = qids[idx], labels[idx], nodes[take]
                    offsets = np.concatenate(
                        [[0], np.cumsum(ranks)]).astype(np.int64)
        ins = self._inserts
        if len(ins):
            # (n_queries, n_inserts) broadcast: delta is budget-bounded,
            # so this stays a small dense mask even for wide batches
            match = ((s[:, None] < 0) | (ins[None, :, 0] == s[:, None])) \
                & ((p[:, None] < 0) | (ins[None, :, 1] == p[:, None])) \
                & ((o[:, None] < 0) | (ins[None, :, 2] == o[:, None]))
            qi, ri = np.nonzero(match)
            if len(qi):
                add_nodes = np.empty(2 * len(ri), dtype=np.int64)
                add_nodes[0::2] = ins[ri, 0]
                add_nodes[1::2] = ins[ri, 2]
                qids = np.concatenate([qids, qi])
                labels = np.concatenate([labels, ins[ri, 1]])
                nodes = np.concatenate([nodes, add_nodes])
                offsets = np.concatenate(
                    [offsets,
                     offsets[-1] + 2 * np.arange(1, len(ri) + 1, dtype=np.int64)])
        return qids, labels, nodes, offsets
