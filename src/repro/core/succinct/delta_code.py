"""Elias gamma / delta universal codes over uint32 word streams.

The encoder is vectorized: per-value code words (<= 64 bits each) are OR-
scattered into the output word array with at most three word touches per
code. The decoder walks the bitstream through one arbitrary-precision
integer (CPython big-int bit ops are C-speed), which is plenty for the
rule-decode path — rules are decoded once at load time and memoized.

Codes encode x >= 1; callers encoding values >= 0 shift by one.
"""
from __future__ import annotations

import numpy as np


def _bit_length(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) + 1 for x >= 1, vectorized."""
    x = x.astype(np.uint64)
    out = np.zeros(x.shape, dtype=np.int64)
    cur = x.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        ge = cur >= (np.uint64(1) << np.uint64(shift))
        out += np.where(ge, shift, 0)
        cur = np.where(ge, cur >> np.uint64(shift), cur)
    return out + 1


def _gamma_parts(x: np.ndarray):
    """Return (code_as_uint64, length_bits) for gamma(x), LSB-first layout.

    gamma(x) = (N zeros) then reversed? We use the LSB-first convention:
    the decoder reads unary zeros, a terminating 1, then N payload bits
    (LSB first). Code = [0]*N + [1] + low N bits of x.
    Bit i of the returned integer is the i-th bit written to the stream.
    """
    x = x.astype(np.uint64)
    n = _bit_length(x) - 1  # payload bits
    # bit layout: positions 0..n-1 zeros, position n one, n+1..2n payload
    payload = x - (np.uint64(1) << n.astype(np.uint64))  # strip leading 1
    code = (np.uint64(1) << n.astype(np.uint64)) | (payload << (n + 1).astype(np.uint64))
    return code, 2 * n + 1


def gamma_encode(values: np.ndarray) -> tuple[np.ndarray, int]:
    values = np.asarray(values, dtype=np.uint64)
    if np.any(values < 1):
        raise ValueError("gamma code requires values >= 1")
    codes, lengths = _gamma_parts(values)
    return _pack_codes(codes, lengths)


def delta_encode(values: np.ndarray) -> tuple[np.ndarray, int]:
    """Elias delta: gamma(bitlen(x)) followed by the bitlen(x)-1 payload bits."""
    values = np.asarray(values, dtype=np.uint64)
    if values.size == 0:
        return np.zeros(0, dtype=np.uint32), 0
    if np.any(values < 1):
        raise ValueError("delta code requires values >= 1")
    nbits = _bit_length(values)  # L = N + 1
    g_code, g_len = _gamma_parts(nbits.astype(np.uint64))
    payload_len = nbits - 1
    payload = values - (np.uint64(1) << payload_len.astype(np.uint64))
    code = g_code | (payload << g_len.astype(np.uint64))
    total_len = g_len + payload_len
    if np.any(total_len > 64):
        raise ValueError("delta codes over 64 bits unsupported (value too large)")
    return _pack_codes(code, total_len)


def _pack_codes(codes: np.ndarray, lengths: np.ndarray) -> tuple[np.ndarray, int]:
    """OR-scatter LSB-first codes into a uint32 word array."""
    lengths = np.asarray(lengths, dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(lengths)])
    total_bits = int(offsets[-1])
    n_words = (total_bits + 31) // 32 + 2  # slack for the 3-word writes
    words = np.zeros(n_words, dtype=np.uint64)
    starts = offsets[:-1]
    w0 = starts >> 5
    s = (starts & 31).astype(np.uint64)
    lo64 = (codes << s).astype(np.uint64)  # wraps mod 2^64 == low 64 bits
    hi = np.where(s > 0, codes >> (np.uint64(64) - s), np.uint64(0))
    np.bitwise_or.at(words, w0, lo64 & np.uint64(0xFFFFFFFF))
    np.bitwise_or.at(words, w0 + 1, lo64 >> np.uint64(32))
    np.bitwise_or.at(words, w0 + 2, hi & np.uint64(0xFFFFFFFF))
    out = words[: (total_bits + 31) // 32].astype(np.uint32)
    return out, total_bits


class _BitReader:
    """Sequential bit reader over packed words using one big int."""

    def __init__(self, words: np.ndarray, n_bits: int):
        self.big = int.from_bytes(np.ascontiguousarray(words, dtype="<u4").tobytes(), "little")
        self.n_bits = n_bits
        self.pos = 0

    def read_unary_zeros(self) -> int:
        z = 0
        big, pos = self.big, self.pos
        while not (big >> pos) & 1:
            z += 1
            pos += 1
            if pos > self.n_bits:
                raise ValueError("ran off bitstream in unary read")
        self.pos = pos + 1  # consume terminating 1
        return z

    def read_bits(self, k: int) -> int:
        v = (self.big >> self.pos) & ((1 << k) - 1)
        self.pos += k
        return v


def gamma_decode(words: np.ndarray, n_bits: int, count: int) -> np.ndarray:
    r = _BitReader(words, n_bits)
    out = np.empty(count, dtype=np.uint64)
    for i in range(count):
        n = r.read_unary_zeros()
        out[i] = (1 << n) | r.read_bits(n)
    return out


def delta_decode(words: np.ndarray, n_bits: int, count: int) -> np.ndarray:
    r = _BitReader(words, n_bits)
    out = np.empty(count, dtype=np.uint64)
    for i in range(count):
        n = r.read_unary_zeros()
        nbits = (1 << n) | r.read_bits(n)  # = bit length L of the value
        payload = r.read_bits(int(nbits) - 1)
        out[i] = (1 << (int(nbits) - 1)) | payload
    return out
