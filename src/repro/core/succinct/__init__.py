"""Succinct data structures used by the ITR encoder/decoder and query engine.

All structures report `size_in_bytes()` so compression benchmarks account the
true serialized footprint, and expose numpy-side query paths (the hot batched
paths additionally have Pallas kernels in `repro.kernels`).
"""
from repro.core.succinct.bitvector import (
    BitVector,
    get_rank_backend,
    pack_bits,
    set_rank_backend,
    unpack_bits,
)
from repro.core.succinct.elias_fano import EliasFano
from repro.core.succinct.delta_code import (
    delta_decode,
    delta_encode,
    gamma_decode,
    gamma_encode,
)
from repro.core.succinct.k2tree import K2Tree

__all__ = [
    "BitVector",
    "get_rank_backend",
    "set_rank_backend",
    "pack_bits",
    "unpack_bits",
    "EliasFano",
    "delta_encode",
    "delta_decode",
    "gamma_encode",
    "gamma_decode",
    "K2Tree",
]
