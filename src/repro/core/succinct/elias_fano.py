"""Quasi-succinct Elias–Fano encoding of monotone non-decreasing sequences.

Used by ITR for the sorted list of per-edge label IDs in the start graph
(paper §Succinct Encoding, citing Vigna [12]). Supports O(1) `access` via
select1 on the upper-bits bitvector and O(log) `rank_leq` / predecessor.
"""
from __future__ import annotations

import numpy as np

from repro.core.succinct.bitvector import BitVector


class EliasFano:
    def __init__(self, values: np.ndarray, universe: int | None = None):
        values = np.asarray(values, dtype=np.int64)
        if len(values) and np.any(np.diff(values) < 0):
            raise ValueError("EliasFano requires a non-decreasing sequence")
        if len(values) and values[0] < 0:
            raise ValueError("EliasFano requires non-negative values")
        self.n = int(len(values))
        self.universe = int(universe if universe is not None else (values[-1] + 1 if self.n else 1))
        if self.n and self.universe <= int(values[-1]):
            # a universe that cannot hold the largest value would silently
            # mis-split the high/low bits and decode garbage on access
            raise ValueError(
                f"EliasFano universe {self.universe} too small for max value "
                f"{int(values[-1])} (need universe > max value)"
            )
        n = max(self.n, 1)
        self.l = max(0, int(np.floor(np.log2(max(self.universe, 1) / n))) if self.universe > n else 0)
        low_mask = (1 << self.l) - 1
        self._lows = (values & low_mask).astype(np.uint64) if self.l > 0 else np.zeros(self.n, dtype=np.uint64)
        highs = (values >> self.l).astype(np.int64)
        # upper bitvector: for item i, a 1 at position highs[i] + i
        n_upper = self.n + (int(highs[-1]) if self.n else 0) + 1
        self._upper = BitVector.from_positions(highs + np.arange(self.n), n_upper)
        # packed low bits
        self._low_words, self._low_bits = self._pack_lows()

    @classmethod
    def from_parts(cls, n: int, universe: int, l: int, lows: np.ndarray,
                   upper_words: np.ndarray, upper_n: int,
                   low_words: np.ndarray, low_bits: int) -> "EliasFano":
        """Reconstruct from persisted internals (the snapshot load path) —
        no re-derivation of the split or re-packing of the low bits."""
        self = cls.__new__(cls)
        self.n = int(n)
        self.universe = int(universe)
        self.l = int(l)
        self._lows = np.asarray(lows, dtype=np.uint64)
        self._upper = BitVector.from_words(upper_words, upper_n)
        self._low_words = np.asarray(low_words, dtype=np.uint32)
        self._low_bits = int(low_bits)
        return self

    def _pack_lows(self):
        if self.l == 0 or self.n == 0:
            return np.zeros(0, dtype=np.uint32), 0
        total_bits = self.n * self.l
        starts = np.arange(self.n, dtype=np.int64) * self.l
        w0 = starts >> 5
        s = (starts & 31).astype(np.uint64)
        lo64 = (self._lows << s).astype(np.uint64)
        # pack into 32-bit lanes via 64-bit scatter
        words32 = np.zeros(total_bits // 32 + 3, dtype=np.uint64)
        hi = np.where(s > 0, self._lows >> (np.uint64(64) - s), np.uint64(0))
        np.bitwise_or.at(words32, w0, lo64 & np.uint64(0xFFFFFFFF))
        np.bitwise_or.at(words32, w0 + 1, lo64 >> np.uint64(32))
        np.bitwise_or.at(words32, w0 + 2, hi & np.uint64(0xFFFFFFFF))
        return words32[: (total_bits + 31) // 32].astype(np.uint32), total_bits

    def _low(self, i: np.ndarray) -> np.ndarray:
        if self.l == 0:
            return np.zeros(np.shape(i), dtype=np.int64)
        i = np.asarray(i, dtype=np.int64)
        starts = i * self.l
        w0 = starts >> 5
        s = (starts & 31).astype(np.uint64)
        w = self._low_words
        lo = w[w0].astype(np.uint64)
        mid = np.where(w0 + 1 < len(w), w[np.minimum(w0 + 1, len(w) - 1)], 0).astype(np.uint64)
        merged = lo | (mid << np.uint64(32))
        return ((merged >> s) & np.uint64((1 << self.l) - 1)).astype(np.int64)

    def access(self, i) -> np.ndarray:
        """values[i]; accepts scalars or arrays."""
        i_arr = np.asarray(i, dtype=np.int64)
        high = self._upper.select1(i_arr) - i_arr
        return (high << self.l) | self._low(i_arr)

    def to_numpy(self) -> np.ndarray:
        if self.n == 0:
            return np.zeros(0, dtype=np.int64)
        return self.access(np.arange(self.n))

    def rank_leq(self, x: int) -> int:
        """Number of stored values <= x (binary search on access)."""
        lo, hi = 0, self.n
        while lo < hi:
            mid = (lo + hi) // 2
            if int(self.access(mid)) <= x:
                lo = mid + 1
            else:
                hi = mid
        return lo

    def size_in_bytes(self) -> int:
        return self._upper.size_in_bytes() + self._low_words.nbytes + 16
