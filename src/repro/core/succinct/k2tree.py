"""k²-tree (Brisaboa et al. [7]) over a sparse 0/1 matrix, built from COO.

ITR uses k²-trees twice: for the node×edge *incidence matrix* of the start
graph, and for the NT (nonterminal × terminal-label) reachability matrix of
the triple-query engine.

Layout note: the classic structure concatenates all internal levels into one
bitmap T plus a leaf bitmap L and navigates with a single rank. We keep one
BitVector per level (identical total bit count, plus one pointer per level);
child block of the j-th set bit of level t is block j of level t+1. This
keeps construction fully vectorized (digit-radix sort per level) and row/
column expansion a simple per-level frontier sweep.
"""
from __future__ import annotations

import numpy as np

from repro.core.succinct.bitvector import BitVector


class K2Tree:
    def __init__(self, rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int, k: int = 2):
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if rows.size:
            if rows.min() < 0 or rows.max() >= n_rows or cols.min() < 0 or cols.max() >= n_cols:
                raise ValueError("point out of bounds")
        self.n_rows, self.n_cols, self.k = int(n_rows), int(n_cols), int(k)
        side = max(n_rows, n_cols, 1)
        h = 1
        while k**h < side:
            h += 1
        self.h = h
        self.side = k**h
        self.n_points = 0
        self.levels: list[BitVector] = []
        self._build(rows, cols)

    @classmethod
    def from_levels(cls, n_rows: int, n_cols: int, k: int, h: int,
                    n_points: int, level_words: list, level_bits: list) -> "K2Tree":
        """Reconstruct from persisted per-level bitvector words (the
        snapshot load path): no COO radix build, only rank-index
        recomputation inside each :meth:`BitVector.from_words`."""
        from repro.core.succinct.bitvector import BitVector as _BV

        self = cls.__new__(cls)
        self.n_rows, self.n_cols, self.k = int(n_rows), int(n_cols), int(k)
        self.h = int(h)
        self.side = self.k ** self.h
        self.n_points = int(n_points)
        if len(level_words) != self.h and not (len(level_words) == 1
                                               and n_points == 0):
            raise ValueError(
                f"{len(level_words)} levels for a height-{self.h} k2-tree")
        self.levels = [_BV.from_words(w, int(nb))
                       for w, nb in zip(level_words, level_bits)]
        return self

    def _build(self, rows: np.ndarray, cols: np.ndarray):
        k, k2, h = self.k, self.k * self.k, self.h
        if rows.size == 0:
            self.levels = [BitVector(np.zeros(k2, dtype=np.uint8))]
            return
        # dedup points
        flat = rows * self.n_cols + cols
        flat = np.unique(flat)
        rows = flat // self.n_cols
        cols = flat % self.n_cols
        self.n_points = len(flat)

        # child digit of each point at each level
        childs = np.empty((h, len(rows)), dtype=np.int64)
        for t in range(h):
            scale = k ** (h - 1 - t)
            childs[t] = (rows // scale % k) * k + (cols // scale % k)

        levels = []
        keys = np.zeros(len(rows), dtype=np.int64)  # node key at current level (root=0)
        for t in range(h):
            pair = keys * k2 + childs[t]
            uniq_keys, key_idx = np.unique(keys, return_inverse=True)
            uniq_pair = np.unique(pair)
            bits = np.zeros(len(uniq_keys) * k2, dtype=np.uint8)
            # position of each set child bit: parent's index in level order * k2 + child
            parent_of_pair = np.searchsorted(uniq_keys, uniq_pair // k2)
            bits[parent_of_pair * k2 + uniq_pair % k2] = 1
            levels.append(BitVector(bits))
            # next level node key = rank of (key,child) among set bits == index in uniq_pair
            keys = np.searchsorted(uniq_pair, pair)
        self.levels = levels

    # ---------------- queries ----------------
    # The row/col expansion is *batched*: many fixed coordinates traverse the
    # tree together, level-synchronously, carrying a query-id column; each
    # level issues ONE vectorized rank1 over the concatenated child bit
    # positions (the k²-tree hot op — routable to the Pallas kernel via
    # `repro.core.succinct.bitvector.set_rank_backend`).

    def access(self, r: int, c: int) -> int:
        k, k2 = self.k, self.k * self.k
        block = 0
        for t in range(self.h):
            scale = k ** (self.h - 1 - t)
            child = (r // scale % k) * k + (c // scale % k)
            bitpos = block * k2 + child
            if bitpos >= self.levels[t].n or not int(self.levels[t].access(bitpos)):
                return 0
            block = int(self.levels[t].rank1(bitpos))
        return 1

    def row(self, r: int) -> np.ndarray:
        """All columns c with M[r, c] = 1, without decompressing the matrix."""
        return self._lines(np.array([r], dtype=np.int64), axis=0)[1]

    def col(self, c: int) -> np.ndarray:
        """All rows r with M[r, c] = 1."""
        return self._lines(np.array([c], dtype=np.int64), axis=1)[1]

    def rows_many(self, rs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched row expansion: one traversal for many rows.

        Returns (idx, cols): query rs[idx[i]] has a 1 at column cols[i];
        pairs are sorted by (idx, col). Out-of-range rows yield no pairs.
        """
        return self._lines(rs, axis=0)

    def cols_many(self, cs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched column expansion; see :meth:`rows_many`."""
        return self._lines(cs, axis=1)

    def _lines(self, fixed: np.ndarray, axis: int) -> tuple[np.ndarray, np.ndarray]:
        k, k2 = self.k, self.k * self.k
        fixed = np.asarray(fixed, dtype=np.int64)
        limit_fixed = self.n_rows if axis == 0 else self.n_cols
        limit_free = self.n_cols if axis == 0 else self.n_rows
        ok = (fixed >= 0) & (fixed < limit_fixed)
        qids = np.flatnonzero(ok).astype(np.int64)
        fvals = fixed[qids]
        blocks = np.zeros(len(qids), dtype=np.int64)
        prefixes = np.zeros(len(qids), dtype=np.int64)  # free-axis coordinate prefix
        free = np.arange(k, dtype=np.int64)
        empty = (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        for t in range(self.h):
            if len(blocks) == 0:
                return empty
            scale = k ** (self.h - 1 - t)
            fixed_digit = fvals // scale % k
            # candidate children: fixed axis digit fixed, free axis digit 0..k-1
            if axis == 0:  # row query: row digit fixed, col digit free
                child = fixed_digit[:, None] * k + free[None, :]
            else:  # col query: col digit fixed, row digit free
                child = free[None, :] * k + fixed_digit[:, None]
            bitpos = (blocks[:, None] * k2 + child).reshape(-1)
            new_prefix = (prefixes[:, None] * k + free[None, :]).reshape(-1)
            new_qids = np.repeat(qids, k)
            new_fvals = np.repeat(fvals, k)
            lv = self.levels[t]
            valid = bitpos < lv.n
            setbit = np.zeros(len(bitpos), dtype=bool)
            if valid.any():
                setbit[valid] = lv.access(bitpos[valid]).astype(bool)
            bitpos = bitpos[setbit]
            qids, fvals, prefixes = new_qids[setbit], new_fvals[setbit], new_prefix[setbit]
            if t < self.h - 1:
                blocks = lv.rank1(bitpos)  # one batched rank per level
            else:
                keep = prefixes < limit_free
                qids, coords = qids[keep], prefixes[keep]
                order = np.lexsort((coords, qids))
                return qids[order], coords[order]
        return empty

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=np.uint8)
        r_idx, cols = self.rows_many(np.arange(self.n_rows, dtype=np.int64))
        out[r_idx, cols] = 1
        return out

    def size_in_bytes(self) -> int:
        return sum(lv.size_in_bytes() for lv in self.levels) + 8 * len(self.levels)
