"""k²-tree (Brisaboa et al. [7]) over a sparse 0/1 matrix, built from COO.

ITR uses k²-trees twice: for the node×edge *incidence matrix* of the start
graph, and for the NT (nonterminal × terminal-label) reachability matrix of
the triple-query engine.

Layout note: the classic structure concatenates all internal levels into one
bitmap T plus a leaf bitmap L and navigates with a single rank. We keep one
BitVector per level (identical total bit count, plus one pointer per level);
child block of the j-th set bit of level t is block j of level t+1. This
keeps construction fully vectorized (digit-radix sort per level) and row/
column expansion a simple per-level frontier sweep.
"""
from __future__ import annotations

import numpy as np

from repro.core.succinct.bitvector import BitVector


class K2Tree:
    def __init__(self, rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int, k: int = 2):
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if rows.size:
            if rows.min() < 0 or rows.max() >= n_rows or cols.min() < 0 or cols.max() >= n_cols:
                raise ValueError("point out of bounds")
        self.n_rows, self.n_cols, self.k = int(n_rows), int(n_cols), int(k)
        side = max(n_rows, n_cols, 1)
        h = 1
        while k**h < side:
            h += 1
        self.h = h
        self.side = k**h
        self.n_points = 0
        self.levels: list[BitVector] = []
        self._build(rows, cols)

    def _build(self, rows: np.ndarray, cols: np.ndarray):
        k, k2, h = self.k, self.k * self.k, self.h
        if rows.size == 0:
            self.levels = [BitVector(np.zeros(k2, dtype=np.uint8))]
            return
        # dedup points
        flat = rows * self.n_cols + cols
        flat = np.unique(flat)
        rows = flat // self.n_cols
        cols = flat % self.n_cols
        self.n_points = len(flat)

        # child digit of each point at each level
        childs = np.empty((h, len(rows)), dtype=np.int64)
        for t in range(h):
            scale = k ** (h - 1 - t)
            childs[t] = (rows // scale % k) * k + (cols // scale % k)

        levels = []
        keys = np.zeros(len(rows), dtype=np.int64)  # node key at current level (root=0)
        for t in range(h):
            pair = keys * k2 + childs[t]
            uniq_keys, key_idx = np.unique(keys, return_inverse=True)
            uniq_pair = np.unique(pair)
            bits = np.zeros(len(uniq_keys) * k2, dtype=np.uint8)
            # position of each set child bit: parent's index in level order * k2 + child
            parent_of_pair = np.searchsorted(uniq_keys, uniq_pair // k2)
            bits[parent_of_pair * k2 + uniq_pair % k2] = 1
            levels.append(BitVector(bits))
            # next level node key = rank of (key,child) among set bits == index in uniq_pair
            keys = np.searchsorted(uniq_pair, pair)
        self.levels = levels

    # ---------------- queries ----------------
    def access(self, r: int, c: int) -> int:
        k, k2 = self.k, self.k * self.k
        block = 0
        for t in range(self.h):
            scale = k ** (self.h - 1 - t)
            child = (r // scale % k) * k + (c // scale % k)
            bitpos = block * k2 + child
            if bitpos >= self.levels[t].n or not int(self.levels[t].access(bitpos)):
                return 0
            block = int(self.levels[t].rank1(bitpos))
        return 1

    def row(self, r: int) -> np.ndarray:
        """All columns c with M[r, c] = 1, without decompressing the matrix."""
        return self._line(r, axis=0)

    def col(self, c: int) -> np.ndarray:
        """All rows r with M[r, c] = 1."""
        return self._line(c, axis=1)

    def _line(self, fixed: int, axis: int) -> np.ndarray:
        k, k2 = self.k, self.k * self.k
        blocks = np.array([0], dtype=np.int64)
        prefixes = np.array([0], dtype=np.int64)  # free-axis coordinate prefix
        for t in range(self.h):
            if len(blocks) == 0:
                return np.zeros(0, dtype=np.int64)
            scale = k ** (self.h - 1 - t)
            fixed_digit = fixed // scale % k
            # candidate children: fixed axis digit fixed, free axis digit 0..k-1
            free = np.arange(k, dtype=np.int64)
            if axis == 0:  # row query: row digit fixed, col digit free
                child = fixed_digit * k + free
            else:  # col query: col digit fixed, row digit free
                child = free * k + fixed_digit
            bitpos = (blocks[:, None] * k2 + child[None, :]).reshape(-1)
            new_prefix = (prefixes[:, None] * k + free[None, :]).reshape(-1)
            lv = self.levels[t]
            valid = bitpos < lv.n
            setbit = np.zeros(len(bitpos), dtype=bool)
            if valid.any():
                setbit[valid] = lv.access(bitpos[valid]).astype(bool)
            bitpos, new_prefix = bitpos[setbit], new_prefix[setbit]
            if t < self.h - 1:
                blocks = lv.rank1(bitpos)
                prefixes = new_prefix
            else:
                limit = self.n_cols if axis == 0 else self.n_rows
                return np.sort(new_prefix[new_prefix < limit])
        return np.zeros(0, dtype=np.int64)

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=np.uint8)
        for r in range(self.n_rows):
            out[r, self.row(r)] = 1
        return out

    def size_in_bytes(self) -> int:
        return sum(lv.size_in_bytes() for lv in self.levels) + 8 * len(self.levels)
