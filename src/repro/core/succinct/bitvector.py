"""Rank/select bitvector over packed uint32 words.

Bit `i` lives at word `i // 32`, bit position `i % 32` (LSB-first). Rank is
O(1) via per-word exclusive prefix popcounts (a 1/32 space overhead,
accounted separately so size reports can include or exclude the index);
select is O(log W) via searchsorted over the prefix array.

Construction is fully vectorized numpy; queries have both scalar and batched
(numpy array) entry points. Batched `rank1` can additionally be routed
through the Pallas kernel (`repro.kernels.bitvec_rank`) — the TPU query
path — via :func:`set_rank_backend`; numpy remains the fallback (and the
parity oracle for the kernel: `tests/test_succinct.py`).
"""
from __future__ import annotations

import os
import warnings

import numpy as np

_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)

# rank backend: "numpy" (default) or "pallas" (Pallas kernel; interpret mode
# off-TPU). Batches below _PALLAS_MIN_BATCH always take the numpy path —
# kernel dispatch overhead dominates tiny queries. Once the kernel fails
# (missing jax, lowering error) the process sticks to numpy (_PALLAS_BROKEN).
_RANK_BACKEND = os.environ.get("ITR_RANK_BACKEND", "numpy")
if _RANK_BACKEND not in ("numpy", "pallas"):
    warnings.warn(f"ITR_RANK_BACKEND={_RANK_BACKEND!r} unknown; using numpy")
    _RANK_BACKEND = "numpy"
_PALLAS_MIN_BATCH = 32
_PALLAS_BROKEN = False


def set_rank_backend(name: str) -> str:
    """Select the batched-rank backend ("numpy" | "pallas"); returns the old one."""
    global _RANK_BACKEND, _PALLAS_BROKEN
    if name not in ("numpy", "pallas"):
        raise ValueError(f"unknown rank backend {name!r}")
    old, _RANK_BACKEND = _RANK_BACKEND, name
    if name == "pallas":
        _PALLAS_BROKEN = False  # explicit re-opt-in retries the kernel once
    return old


def get_rank_backend() -> str:
    return _RANK_BACKEND


def popcount32(words: np.ndarray) -> np.ndarray:
    """Vectorized popcount of uint32 words (SWAR)."""
    w = words.astype(np.uint32, copy=True)
    w = w - ((w >> np.uint32(1)) & _M1)
    w = (w & _M2) + ((w >> np.uint32(2)) & _M2)
    w = (w + (w >> np.uint32(4))) & _M4
    with np.errstate(over="ignore"):  # SWAR multiply wraps by design
        return ((w * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.int64)


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a bool/0-1 array into uint32 words (LSB-first)."""
    bits = np.asarray(bits, dtype=np.uint8)
    n = len(bits)
    n_words = (n + 31) // 32
    padded = np.zeros(n_words * 32, dtype=np.uint8)
    padded[:n] = bits
    lanes = padded.reshape(n_words, 32).astype(np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    return (lanes << shifts).sum(axis=1, dtype=np.uint32)


def unpack_bits(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Inverse of pack_bits."""
    shifts = np.arange(32, dtype=np.uint32)
    lanes = (words[:, None] >> shifts) & np.uint32(1)
    return lanes.reshape(-1)[:n_bits].astype(np.uint8)


class BitVector:
    """Immutable bitvector with O(1) rank1 and O(log) select1."""

    def __init__(self, bits: np.ndarray):
        bits = np.asarray(bits, dtype=np.uint8)
        self.n = int(len(bits))
        self.words = pack_bits(bits)
        pc = popcount32(self.words)
        # word_ranks[w] = number of 1s strictly before word w
        self.word_ranks = np.concatenate([[0], np.cumsum(pc)]).astype(np.int64)
        self.n_ones = int(self.word_ranks[-1])
        self._jax_words = None  # lazy device copies for the Pallas rank path

    @classmethod
    def from_positions(cls, positions: np.ndarray, n: int) -> "BitVector":
        bits = np.zeros(n, dtype=np.uint8)
        if len(positions):
            bits[np.asarray(positions, dtype=np.int64)] = 1
        return cls(bits)

    @classmethod
    def from_words(cls, words: np.ndarray, n: int) -> "BitVector":
        """Reconstruct from already-packed words (the snapshot load path):
        only the rank index is recomputed — no unpack/repack round-trip.
        `words` may be a read-only mmap view; it is never written to."""
        self = cls.__new__(cls)
        self.n = int(n)
        self.words = np.asarray(words, dtype=np.uint32)
        if len(self.words) != (self.n + 31) // 32:
            raise ValueError(
                f"{len(self.words)} words cannot back {self.n} bits")
        pc = popcount32(self.words)
        self.word_ranks = np.concatenate([[0], np.cumsum(pc)]).astype(np.int64)
        self.n_ones = int(self.word_ranks[-1])
        self._jax_words = None
        return self

    def __len__(self) -> int:
        return self.n

    def access(self, i) -> np.ndarray:
        i = np.asarray(i, dtype=np.int64)
        return ((self.words[i >> 5] >> (i & 31).astype(np.uint32)) & np.uint32(1)).astype(np.uint8)

    def rank1(self, i) -> np.ndarray:
        """Number of set bits in [0, i). Accepts scalars or arrays; i in [0, n]."""
        i = np.asarray(i, dtype=np.int64)
        if (_RANK_BACKEND == "pallas" and not _PALLAS_BROKEN
                and i.ndim == 1 and i.size >= _PALLAS_MIN_BATCH):
            out = self._rank1_pallas(i)
            if out is not None:
                return out
        return self._rank1_numpy(i)

    def _rank1_numpy(self, i: np.ndarray) -> np.ndarray:
        w = i >> 5
        rem = (i & 31).astype(np.uint32)
        mask = np.where(rem == 0, np.uint32(0), (np.uint32(1) << rem) - np.uint32(1))
        # i == n with n % 32 == 0 indexes one-past-last word; guard it.
        wordvals = self.words[np.minimum(w, len(self.words) - 1)] if len(self.words) else np.zeros_like(w, dtype=np.uint32)
        partial = popcount32(np.where(w < len(self.words), wordvals & mask, np.uint32(0)))
        return self.word_ranks[np.minimum(w, len(self.word_ranks) - 1)] + partial

    def _rank1_pallas(self, i: np.ndarray) -> np.ndarray | None:
        """Batched rank via the Pallas kernel; None on failure (numpy fallback).

        Words are padded with one trailing zero word so i == n (one past the
        last bit) indexes in-bounds; the exclusive prefix `word_ranks` already
        has W+1 entries and lines up with the padded words.
        """
        global _PALLAS_BROKEN
        try:
            import jax.numpy as jnp

            from repro.kernels.ops import bitvec_rank as _kernel_rank

            if self._jax_words is None:
                self._jax_words = jnp.asarray(
                    np.concatenate([self.words, np.zeros(1, np.uint32)]))
                self._jax_ranks = jnp.asarray(self.word_ranks.astype(np.int32))
            out = _kernel_rank(self._jax_words, self._jax_ranks,
                               jnp.asarray(i.astype(np.int32)))
            return np.asarray(out).astype(np.int64)
        except Exception as e:  # missing jax backend, lowering failure, ...
            _PALLAS_BROKEN = True  # don't re-pay the failed attempt per call
            warnings.warn(f"pallas rank backend unavailable ({e!r}); using numpy")
            return None

    def rank0(self, i) -> np.ndarray:
        i = np.asarray(i, dtype=np.int64)
        return i - self.rank1(i)

    def select1(self, j) -> np.ndarray:
        """Position of the j-th (0-based) set bit. Accepts scalars or arrays."""
        j = np.asarray(j, dtype=np.int64)
        if np.any(j >= self.n_ones) or np.any(j < 0):
            raise IndexError("select1 argument out of range")
        # word containing the (j+1)-th one:
        w = np.searchsorted(self.word_ranks, j, side="right") - 1
        within = (j - self.word_ranks[w]).astype(np.int64)
        # scan bits of word w for the `within`-th set bit (vectorized over 32 lanes)
        words = self.words[w]
        shifts = np.arange(32, dtype=np.uint32)
        lanes = ((np.atleast_1d(words)[:, None] >> shifts) & np.uint32(1)).astype(np.int64)
        cum = np.cumsum(lanes, axis=1) - lanes  # ones strictly before each lane
        hit = (lanes == 1) & (cum == np.atleast_1d(within)[:, None])
        pos_in_word = hit.argmax(axis=1)
        out = (np.atleast_1d(w) << 5) + pos_in_word
        return out[0] if j.ndim == 0 else out

    def size_in_bytes(self, include_rank_index: bool = True) -> int:
        base = self.words.nbytes
        if include_rank_index:
            # production layout: one 32-bit cumulative count per 8 words (256 bits)
            base += 4 * ((len(self.words) + 7) // 8)
        return base

    def to_numpy(self) -> np.ndarray:
        return unpack_bits(self.words, self.n)
