"""Succinct encoding of an SL-HR grammar (paper §Succinct Encoding).

Start graph: edges sorted by label id; the monotone label sequence is
Elias–Fano coded; the node×edge incidence matrix (dedup'd) is a k²-tree;
per-edge *index-functions* — π_e mapping connection-type m to the position
of e[m] in the duplicate-free sorted node list ζ_e — are deduplicated,
δ-coded once each, and referenced by δ-coded per-edge ids. Loops are thereby
absorbed without extra rules (paper §Handling loops).

Rules: right-hand sides only, in nonterminal order (topological after
prune), each as δ(#edges) then per edge δ(label+1) δ(node+1)*rank(label).
Rule ranks are recovered as max(node)+1 (every external occurs in the RHS).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.grammar import Grammar, Rule
from repro.core.hypergraph import Hypergraph, LabelTable
from repro.core.succinct import EliasFano, K2Tree, delta_decode, delta_encode


@dataclass
class EncodedGrammar:
    n_nodes: int
    n_edges: int
    n_terminals: int
    terminal_ranks: np.ndarray
    label_ef: EliasFano          # sorted per-edge label ids
    incidence: K2Tree            # rows = nodes, cols = edges (sorted order)
    fn_stream: tuple[np.ndarray, int]   # δ stream of unique index-functions
    fn_lengths: np.ndarray       # rank of each unique index-function
    n_fns: int
    edge_fn_stream: tuple[np.ndarray, int]  # δ stream of per-edge fn ids (+1)
    rule_stream: tuple[np.ndarray, int]     # δ stream of all rule bodies
    rule_symbol_count: int       # total δ symbols in rule_stream
    n_rules: int
    names: list[str] | None = None

    # ------------------------------------------------------------------
    def size_in_bytes(self, include_dictionary: bool = False) -> int:
        total = 8 * 4  # header counts
        total += (len(self.terminal_ranks) * 2 + 7) // 8 or 1
        total += self.label_ef.size_in_bytes()
        total += self.incidence.size_in_bytes()
        total += (self.fn_stream[1] + 7) // 8
        total += (self.edge_fn_stream[1] + 7) // 8
        total += (self.rule_stream[1] + 7) // 8
        if include_dictionary and self.names is not None:
            total += sum(len(s) + 1 for s in self.names)
        return total

    def decode(self) -> Grammar:
        labels = self.label_ef.to_numpy()
        # unique index-functions
        fn_vals = delta_decode(*self.fn_stream, int(self.fn_lengths.sum()) + self.n_fns)
        fns, pos = [], 0
        for _ in range(self.n_fns):
            rank = int(fn_vals[pos]) - 1 + 1  # δ(rank) stored as rank (>=1)
            pi = fn_vals[pos + 1 : pos + 1 + rank].astype(np.int64) - 1
            fns.append(pi)
            pos += 1 + rank
        fn_ids = delta_decode(*self.edge_fn_stream, self.n_edges).astype(np.int64) - 1
        # reconstruct edges: zeta from ONE batched incidence-column traversal
        # (all edges at once), nodes = zeta[pi] as a flat ragged gather
        eidx, zeta_flat = self.incidence.cols_many(np.arange(self.n_edges, dtype=np.int64))
        zeta_counts = np.bincount(eidx, minlength=self.n_edges).astype(np.int64)
        zeta_starts = np.cumsum(zeta_counts) - zeta_counts
        fn_flat = np.concatenate(fns) if fns else np.zeros(0, np.int64)
        fn_lens = np.asarray(self.fn_lengths, dtype=np.int64)
        fn_starts = np.cumsum(fn_lens) - fn_lens
        ranks = fn_lens[fn_ids] if self.n_edges else np.zeros(0, np.int64)
        ends = np.cumsum(ranks)
        slot = np.arange(int(ranks.sum()), dtype=np.int64) - np.repeat(ends - ranks, ranks)
        pi_vals = fn_flat[np.repeat(fn_starts[fn_ids], ranks) + slot]
        flat = zeta_flat[np.repeat(zeta_starts, ranks) + pi_vals]
        offsets = np.concatenate([[0], ends]).astype(np.int64)
        start = Hypergraph(self.n_nodes, labels.astype(np.int64), flat, offsets)

        # rules
        vals = delta_decode(*self.rule_stream, self.rule_symbol_count).astype(np.int64)
        ranks = list(self.terminal_ranks)
        rules: dict[int, Rule] = {}
        pos = 0
        for i in range(self.n_rules):
            lbl = self.n_terminals + i
            n_e = int(vals[pos]); pos += 1
            r_labels, r_nodes = [], []
            for _ in range(n_e):
                el = int(vals[pos]) - 1; pos += 1
                r = int(ranks[el])
                nds = vals[pos : pos + r] - 1; pos += r
                r_labels.append(el)
                r_nodes.append(np.asarray(nds, dtype=np.int64))
            rank = int(max(n.max() for n in r_nodes)) + 1
            ranks.append(rank)
            rhs = Hypergraph.from_edges(rank, list(zip(r_labels, [n.tolist() for n in r_nodes])))
            rules[lbl] = Rule(lbl, rank, rhs)
        table = LabelTable(np.asarray(ranks, dtype=np.int64), self.n_terminals, self.names)
        return Grammar(table, start, rules)


def encode(grammar: Grammar) -> EncodedGrammar:
    g = grammar
    start, table = g.start, g.table
    order = np.argsort(start.labels, kind="stable")
    start = start.gather_edges(order)
    labels_sorted = start.labels

    # incidence matrix points (deduplicated by the k2 builder)
    ranks = start.ranks()
    edge_ids = np.repeat(np.arange(start.n_edges, dtype=np.int64), ranks)
    incidence = K2Tree(start.nodes_flat, edge_ids, max(start.n_nodes, 1), max(start.n_edges, 1))

    # index-functions
    fn_dict: dict[tuple, int] = {}
    fn_list: list[np.ndarray] = []
    per_edge = np.zeros(start.n_edges, dtype=np.int64)
    for e in range(start.n_edges):
        nodes = start.edge_nodes(e)
        zeta = np.unique(nodes)
        pi = np.searchsorted(zeta, nodes)
        key = tuple(pi.tolist())
        if key not in fn_dict:
            fn_dict[key] = len(fn_list)
            fn_list.append(pi)
        per_edge[e] = fn_dict[key]
    fn_symbols = []
    fn_lengths = np.array([len(pi) for pi in fn_list], dtype=np.int64)
    for pi in fn_list:
        fn_symbols.append(len(pi))           # δ(rank)
        fn_symbols.extend((pi + 1).tolist())  # δ(π(m)+1)
    fn_stream = delta_encode(np.asarray(fn_symbols if fn_symbols else [], dtype=np.uint64))
    edge_fn_stream = delta_encode((per_edge + 1).astype(np.uint64))

    # rules in label order (renumbered grammars are topological)
    rule_labels = sorted(g.rules.keys())
    assert rule_labels == list(range(table.n_terminals, table.n_terminals + len(rule_labels)))
    symbols = []
    for lbl in rule_labels:
        rhs = g.rules[lbl].rhs
        symbols.append(rhs.n_edges)
        for j in range(rhs.n_edges):
            symbols.append(int(rhs.labels[j]) + 1)
            symbols.extend((rhs.edge_nodes(j) + 1).tolist())
    rule_stream = delta_encode(np.asarray(symbols if symbols else [], dtype=np.uint64))

    return EncodedGrammar(
        n_nodes=start.n_nodes,
        n_edges=start.n_edges,
        n_terminals=table.n_terminals,
        terminal_ranks=table.ranks[: table.n_terminals].copy(),
        label_ef=EliasFano(labels_sorted, universe=int(table.n_labels)),
        incidence=incidence,
        fn_stream=fn_stream,
        fn_lengths=fn_lengths,
        n_fns=len(fn_list),
        edge_fn_stream=edge_fn_stream,
        rule_stream=rule_stream,
        rule_symbol_count=len(symbols),
        n_rules=len(rule_labels),
        names=table.names,
    )
