"""Straight-line hyperedge-replacement (SL-HR) grammars and expansion.

A rule ``A -> G_A`` has a right-hand side whose nodes ``0..rank(A)-1`` are
the formal parameters (digram-born rules reference only external nodes —
see DESIGN.md); expanding an edge ``A(v0..vk)`` maps RHS node ``j`` to
``vj``. Expansion is vectorized per (rule, rhs-edge): all edges sharing a
nonterminal label are instantiated with one gather.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.hypergraph import Hypergraph, LabelTable


@dataclass
class Rule:
    label: int  # nonterminal label id
    rank: int
    rhs: Hypergraph  # n_nodes == rank; all nodes are external parameters

    def validate(self, table: LabelTable):
        assert table.ranks[self.label] == self.rank
        assert self.rhs.n_nodes == self.rank
        self.rhs.validate(table)
        if self.rhs.n_edges:
            # every external parameter must occur in the RHS (decode relies on it)
            assert np.array_equal(np.unique(self.rhs.nodes_flat), np.arange(self.rank))


@dataclass
class Grammar:
    table: LabelTable
    start: Hypergraph
    rules: dict[int, Rule] = field(default_factory=dict)  # label -> rule

    # ------------------------------------------------------------------
    def validate(self):
        self.start.validate(self.table)
        for lbl, rule in self.rules.items():
            assert lbl == rule.label and lbl >= self.table.n_terminals
            rule.validate(self.table)
        assert self._topological_order() is not None, "grammar must be non-recursive"

    def _topological_order(self) -> list[int] | None:
        """Rule labels in dependency order (used rules first); None if cyclic."""
        deps = {
            lbl: {int(x) for x in np.unique(r.rhs.labels) if int(x) in self.rules}
            for lbl, r in self.rules.items()
        }
        order, done = [], set()
        while len(order) < len(deps):
            progress = False
            for lbl, ds in deps.items():
                if lbl not in done and ds <= done:
                    order.append(lbl)
                    done.add(lbl)
                    progress = True
            if not progress:
                return None
        return order

    # ------------------------------------------------------------------
    def expand_once(self, graph: Hypergraph) -> tuple[Hypergraph, bool]:
        """Replace every nonterminal edge by its instantiated RHS (one level)."""
        is_nt = np.isin(graph.labels, list(self.rules.keys())) if self.rules else np.zeros(graph.n_edges, bool)
        if not is_nt.any():
            return graph, False
        keep = graph.select(~is_nt)
        new_labels, new_flat, new_ranks = [], [], []
        nt_graph = graph.select(is_nt)
        for lbl in np.unique(nt_graph.labels):
            rule = self.rules[int(lbl)]
            sel = nt_graph.labels == lbl
            n_sel = int(sel.sum())
            node_mat = nt_graph.nodes_flat[
                nt_graph.offsets[:-1][sel][:, None] + np.arange(rule.rank)[None, :]
            ]  # (n_sel, rank)
            rhs = rule.rhs
            rhs_ranks = rhs.ranks()
            for j in range(rhs.n_edges):
                params = rhs.edge_nodes(j)  # indices into externals
                new_labels.append(np.full(n_sel, rhs.labels[j], dtype=np.int64))
                new_flat.append(node_mat[:, params].reshape(-1))
                new_ranks.append(np.full(n_sel, rhs_ranks[j], dtype=np.int64))
        out = keep.concat_edges(
            np.concatenate(new_labels),
            np.concatenate(new_flat) if new_flat else np.zeros(0, np.int64),
            np.concatenate(new_ranks),
        )
        return out, True

    def decompress(self) -> Hypergraph:
        g = self.start
        changed = True
        guard = 0
        while changed:
            g, changed = self.expand_once(g)
            guard += 1
            assert guard <= len(self.rules) + 2, "expansion did not terminate"
        return g

    # ------------------------------------------------------------------
    def size_units(self) -> int:
        """Integer-unit grammar size (drives the RePair stop condition)."""
        total = self.start.size_units()
        for r in self.rules.values():
            total += 1 + r.rhs.size_units()  # 1 unit rule header
        return total

    def nt_generates(self) -> np.ndarray:
        """bool[n_rules_labels, n_terminals]: A (transitively) emits label t.

        Rows indexed by (label - n_terminals) for present rule labels.
        """
        T = self.table.n_terminals
        n_nt = (max(self.rules.keys()) - T + 1) if self.rules else 0
        gen = np.zeros((n_nt, T), dtype=bool)
        order = self._topological_order()
        assert order is not None
        for lbl in order:
            rhs = self.rules[lbl].rhs
            row = gen[lbl - T]
            for x in np.unique(rhs.labels):
                x = int(x)
                if x < T:
                    row[x] = True
                else:
                    row |= gen[x - T]
        return gen

    # ------------------------------------------------------------------
    def prune(self) -> "Grammar":
        """String-RePair Prune adapted to graphs: inline rules used once,
        drop unused rules, renumber nonterminals in topological order."""
        g = self
        while True:
            usage = g._usage_counts()
            once = [lbl for lbl, c in usage.items() if c == 1]
            unused = [lbl for lbl, c in usage.items() if c == 0]
            if not once and not unused:
                break
            g = g._inline_and_drop(set(once), set(unused))
        return g._renumber()

    def _usage_counts(self) -> dict[int, int]:
        usage = {lbl: 0 for lbl in self.rules}
        for labels in [self.start.labels] + [r.rhs.labels for r in self.rules.values()]:
            uniq, cnt = np.unique(labels, return_counts=True)
            for u, c in zip(uniq.tolist(), cnt.tolist()):
                if u in usage:
                    usage[u] += int(c)
        return usage

    def _inline_and_drop(self, once: set, unused: set) -> "Grammar":
        sub = Grammar(self.table, self.start, {l: r for l, r in self.rules.items() if l not in unused})

        def inline(graph: Hypergraph) -> Hypergraph:
            if not once:
                return graph
            # once-rules may nest (A's RHS uses B, both used once): expand to
            # fixpoint so no dangling reference to a dropped rule survives
            partial = Grammar(self.table, graph,
                              {l: self.rules[l] for l in once if l in self.rules})
            changed = True
            while changed and partial.rules:
                graph, changed = partial.expand_once(graph)
            return graph

        # expand_once on the full graph would expand all NTs; restrict by
        # building a grammar containing only the inlined rules.
        new_start = inline(sub.start)
        new_rules = {}
        for lbl, r in sub.rules.items():
            if lbl in once:
                continue
            new_rules[lbl] = Rule(lbl, r.rank, inline(r.rhs))
        return Grammar(self.table, new_start, new_rules)

    def _renumber(self) -> "Grammar":
        T = self.table.n_terminals
        order = self._topological_order()
        assert order is not None
        mapping = {lbl: T + i for i, lbl in enumerate(order)}
        # vectorized lookup table — sequential masked assignment would
        # corrupt labels when old/new id ranges overlap
        lut = np.arange(self.table.n_labels, dtype=np.int64)
        for old, new in mapping.items():
            lut[old] = new

        def remap(graph: Hypergraph) -> Hypergraph:
            labels = lut[graph.labels] if graph.n_edges else graph.labels.copy()
            return Hypergraph(graph.n_nodes, labels, graph.nodes_flat.copy(), graph.offsets.copy())

        new_ranks = np.concatenate(
            [self.table.ranks[:T], [self.rules[lbl].rank for lbl in order]]
        ).astype(np.int64)
        table = LabelTable(new_ranks, T, self.table.names)
        rules = {
            mapping[lbl]: Rule(mapping[lbl], self.rules[lbl].rank, remap(self.rules[lbl].rhs))
            for lbl in order
        }
        return Grammar(table, remap(self.start), rules)
