"""The ITR RePair loop: count -> replace mfd -> update count -> prune.

Replacement is a vectorized emulation of the paper's left-to-right pointer
scan: per node, candidate edges are classed by which digram side(s) they can
serve (A = side-0 only, C = side-1 only, B = both), greedily paired
A×C, then leftovers×B, then B×B — a maximal matching at each node — and
cross-node conflicts (an edge proposed at two nodes) are resolved by pair
priority over a few rounds. Loops (e1 == e2) are never paired, matching the
paper's `e1 != e2` requirement.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.digram import (
    DigramCounter,
    incidences,
    split_digram,
    split_it,
)
from repro.core.grammar import Grammar, Rule
from repro.core.hypergraph import Hypergraph, LabelTable


@dataclass
class RepairConfig:
    max_rank: int = 32          # bound on new nonterminal rank (gRePair-style guard)
    cap: int | None = 64        # per-node distinct incidence-type cap (None = exact)
    selection: str = "count"    # "count" = paper's mfd; "savings" = beyond-paper
    max_iters: int | None = None
    prune: bool = True
    min_count: int | None = None  # if set, replace while count >= min_count
                                  # (overrides the unit-savings stop criterion)


@dataclass
class RepairStats:
    iterations: int = 0
    replaced_occurrences: int = 0
    rules_created: int = 0
    initial_size_units: int = 0
    final_size_units: int = 0


def compress(
    graph: Hypergraph, table: LabelTable, config: RepairConfig | None = None
) -> tuple[Grammar, RepairStats]:
    """Run ITR compression; returns (grammar, stats). Inputs are not mutated."""
    config = config or RepairConfig()
    table = table.copy()
    graph = graph.copy()
    stats = RepairStats(initial_size_units=graph.size_units())
    counter = DigramCounter(graph, table, cap=config.cap)
    it_offsets = table.it_offsets()  # stable under label append
    rules: dict[int, Rule] = {}
    skip: set[int] = set()

    while config.max_iters is None or stats.iterations < config.max_iters:
        picked = _select_digram(counter, table, it_offsets, skip, config)
        if picked is None:
            break
        key, _count = picked
        it1, it2 = split_digram(key)
        a1, m1 = split_it(it1, it_offsets)
        a2, m2 = split_it(it2, it_offsets)
        r1, r2 = int(table.ranks[a1]), int(table.ranks[a2])

        e1s, e2s = _find_occurrences(graph, a1, m1, a2, m2, it1 == it2)
        if len(e1s) == 0:
            skip.add(key)  # count is positive but only self-pairs exist
            continue

        new_label = table.add_label(r1 + r2 - 1)
        it_offsets = table.it_offsets()
        rules[new_label] = _make_rule(new_label, a1, m1, r1, a2, m2, r2)
        graph, removed_inc, added_inc = _replace(
            graph, table, e1s, e2s, a1, m1, r1, a2, m2, r2, new_label
        )
        counter.apply_delta(removed_inc, added_inc)
        stats.iterations += 1
        stats.replaced_occurrences += len(e1s)
        stats.rules_created += 1

    grammar = Grammar(table, graph, rules)
    if config.prune:
        grammar = grammar.prune()
    stats.final_size_units = grammar.size_units()
    return grammar, stats


# ----------------------------------------------------------------------
def _savings(count: int, r1: int, r2: int) -> int:
    # each replaced occurrence trades edges of cost (1+r1)+(1+r2) for one of
    # cost (1 + r1+r2-1): gain 2 units; the rule costs 3 + r1 + r2 units.
    return 2 * count - (3 + r1 + r2)


def _select_digram(counter, table, it_offsets, skip, config):
    """Pick the next digram per config.selection; None = stop."""
    if config.selection == "count":
        while True:
            best = counter.pop_best(skip)
            if best is None:
                return None
            key, cnt = best
            it1, it2 = split_digram(key)
            a1, _ = split_it(it1, it_offsets)
            a2, _ = split_it(it2, it_offsets)
            r1, r2 = int(table.ranks[a1]), int(table.ranks[a2])
            if r1 + r2 - 1 > config.max_rank:
                skip.add(key)
                continue
            if config.min_count is not None:
                if cnt < config.min_count:
                    return None
            elif _savings(cnt, r1, r2) <= 0:
                return None  # paper: stop when the mfd no longer shrinks the grammar
            return key, cnt
    elif config.selection == "savings":
        # scan candidates in count order; savings <= 2*cnt - 5, so we can
        # stop scanning once that bound cannot beat the best found. Each
        # candidate is popped off the heap (peek_pop) so the next one is
        # visible, and all are returned via push_back when the scan ends.
        popped = []
        best_key, best_score, best_cnt = None, 0, 0
        while True:
            item = counter.peek_pop(skip)
            if item is None:
                break
            key, cnt = item
            popped.append(item)
            if 2 * cnt - 5 <= best_score:
                break
            it1, it2 = split_digram(key)
            a1, _ = split_it(it1, it_offsets)
            a2, _ = split_it(it2, it_offsets)
            r1, r2 = int(table.ranks[a1]), int(table.ranks[a2])
            if r1 + r2 - 1 > config.max_rank:
                skip.add(key)
                continue
            score = _savings(cnt, r1, r2)
            if score > best_score:
                best_key, best_score, best_cnt = key, score, cnt
        for key, cnt in popped:
            counter.push_back(key, cnt)
        if best_key is None or best_score <= 0:
            return None
        return best_key, best_cnt
    raise ValueError(f"unknown selection {config.selection}")


# ----------------------------------------------------------------------
def _find_occurrences(graph, a1, m1, a2, m2, same_it):
    """Greedy maximal set of non-overlapping occurrences; returns (e1s, e2s)."""
    labels = graph.labels
    starts = graph.offsets[:-1]
    if same_it:
        cand = np.flatnonzero(labels == a1)
        v = graph.nodes_flat[starts[cand] + m1]
        order = np.lexsort((cand, v))
        cand, v = cand[order], v[order]
        # pair consecutive edges within each node group
        grp_start = np.concatenate([[True], v[1:] != v[:-1]])
        idx_in_grp = np.arange(len(v)) - np.maximum.accumulate(np.where(grp_start, np.arange(len(v)), 0))
        is_first = (idx_in_grp % 2 == 0) & (np.arange(len(v)) + 1 < len(v))
        partner_same_node = np.zeros(len(v), bool)
        partner_same_node[:-1] = v[:-1] == v[1:]
        take = is_first & partner_same_node
        e1s = cand[np.flatnonzero(take)]
        e2s = cand[np.flatnonzero(take) + 1]
        return e1s, e2s

    avail = np.ones(graph.n_edges, dtype=bool)
    out1, out2 = [], []
    for _round in range(64):
        c1 = np.flatnonzero((labels == a1) & avail)
        c2 = np.flatnonzero((labels == a2) & avail)
        if len(c1) == 0 or len(c2) == 0:
            break
        v1 = graph.nodes_flat[starts[c1] + m1]
        v2 = graph.nodes_flat[starts[c2] + m2]
        p1, p2 = _propose_pairs(c1, v1, c2, v2)
        if len(p1) == 0:
            break
        # cross-node conflict resolution: keep the lowest-priority pair per edge
        pid = np.arange(len(p1), dtype=np.int64)
        min_pid = np.full(graph.n_edges, len(p1), dtype=np.int64)
        np.minimum.at(min_pid, p1, pid)
        np.minimum.at(min_pid, p2, pid)
        keep = (min_pid[p1] == pid) & (min_pid[p2] == pid)
        kept1, kept2 = p1[keep], p2[keep]
        if len(kept1) == 0:
            break
        out1.append(kept1)
        out2.append(kept2)
        avail[kept1] = False
        avail[kept2] = False
        if keep.all():
            break  # nothing was dropped; no edge left to retry
    if not out1:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(out1), np.concatenate(out2)


def _propose_pairs(c1, v1, c2, v2):
    """Per-node greedy pairing of side-0 (c1@v1) and side-1 (c2@v2) candidates."""
    # class rows: (node, edge, side-bit); merge edges appearing on both sides at a node
    nodes = np.concatenate([v1, v2])
    edges = np.concatenate([c1, c2])
    bits = np.concatenate([np.ones(len(c1), np.int64), np.full(len(c2), 2, np.int64)])
    key = nodes * (edges.max() + 1) + edges
    uk, inv = np.unique(key, return_inverse=True)
    flag = np.zeros(len(uk), dtype=np.int64)
    np.bitwise_or.at(flag, inv, bits)
    u_nodes = uk // (edges.max() + 1)
    u_edges = uk % (edges.max() + 1)
    # class: A=1 (side0 only), C=2 (side1 only), B=3 (both); sort (node, class, edge)
    order = np.lexsort((u_edges, flag, u_nodes))
    u_nodes, u_edges, flag = u_nodes[order], u_edges[order], flag[order]

    grp_start = np.flatnonzero(np.concatenate([[True], u_nodes[1:] != u_nodes[:-1]]))
    grp_end = np.concatenate([grp_start[1:], [len(u_nodes)]])
    # per-node segment offsets of classes A(1), C(2), B(3) — classes are
    # contiguous within a node group because we sorted by flag
    a_cnt = np.zeros(len(grp_start), np.int64)
    c_cnt = np.zeros(len(grp_start), np.int64)
    b_cnt = np.zeros(len(grp_start), np.int64)
    gidx = np.repeat(np.arange(len(grp_start)), grp_end - grp_start)
    np.add.at(a_cnt, gidx, flag == 1)
    np.add.at(c_cnt, gidx, flag == 2)
    np.add.at(b_cnt, gidx, flag == 3)
    a_off = grp_start
    c_off = grp_start + a_cnt
    b_off = c_off + c_cnt

    p_ac = np.minimum(a_cnt, c_cnt)
    rem_a = a_cnt - p_ac
    rem_c = c_cnt - p_ac
    p_ab = np.minimum(rem_a, b_cnt)
    p_bc = np.minimum(rem_c, b_cnt - p_ab)
    p_bb = (b_cnt - p_ab - p_bc) // 2

    def ragged(offsets_l, counts, offsets_r, counts_r=None, stride_l=1, stride_r=1, base_r=0):
        tot = int(counts.sum())
        if tot == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        i = np.arange(tot, dtype=np.int64) - np.repeat(np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
        left = np.repeat(offsets_l, counts) + stride_l * i
        right = np.repeat(offsets_r, counts) + stride_r * i + base_r
        return left, right

    l_ac, r_ac = ragged(a_off, p_ac, c_off)
    l_ab, r_ab = ragged(a_off + p_ac, p_ab, b_off)            # A leftover × B(as side1)
    l_bc, r_bc = ragged(b_off, p_bc, c_off + p_ac)            # B(as side0) × C leftover
    bb_start = b_off + p_ab + p_bc
    l_bb, r_bb = ragged(bb_start, p_bb, bb_start, stride_l=2, stride_r=2, base_r=1)

    left = np.concatenate([l_ac, l_ab, l_bc, l_bb])
    right = np.concatenate([r_ac, r_ab, r_bc, r_bb])
    return u_edges[left], u_edges[right]


# ----------------------------------------------------------------------
def _others(rank: int, m: int) -> np.ndarray:
    return np.array([x for x in range(rank) if x != m], dtype=np.int64)


def _make_rule(new_label, a1, m1, r1, a2, m2, r2) -> Rule:
    """B -> { a1(params), a2(params) } with shared node = external 0."""
    new_rank = r1 + r2 - 1
    p1 = np.zeros(r1, dtype=np.int64)
    p1[_others(r1, m1)] = np.arange(1, r1)
    p2 = np.zeros(r2, dtype=np.int64)
    p2[_others(r2, m2)] = np.arange(r1, r1 + r2 - 1)
    rhs = Hypergraph.from_edges(new_rank, [(a1, p1.tolist()), (a2, p2.tolist())])
    return Rule(new_label, new_rank, rhs)


def _replace(graph, table, e1s, e2s, a1, m1, r1, a2, m2, r2, new_label):
    """Swap matched edge pairs for new_label hyperedges; return incidence deltas."""
    starts = graph.offsets[:-1]
    mat1 = graph.nodes_flat[starts[e1s][:, None] + np.arange(r1)[None, :]]
    mat2 = graph.nodes_flat[starts[e2s][:, None] + np.arange(r2)[None, :]]
    shared = mat1[:, m1]
    new_mat = np.concatenate(
        [shared[:, None], mat1[:, _others(r1, m1)], mat2[:, _others(r2, m2)]], axis=1
    )

    removed = np.zeros(graph.n_edges, dtype=bool)
    removed[e1s] = True
    removed[e2s] = True
    removed_graph = graph.select(removed)
    rem_inc = incidences(removed_graph, table)

    new_rank = r1 + r2 - 1
    kept = graph.select(~removed)
    n_new = len(e1s)
    out = kept.concat_edges(
        np.full(n_new, new_label, dtype=np.int64),
        new_mat.reshape(-1),
        np.full(n_new, new_rank, dtype=np.int64),
    )
    it_offsets = table.it_offsets()
    add_nodes = new_mat.reshape(-1)
    add_its = np.tile(it_offsets[new_label] + np.arange(new_rank), n_new)
    return out, rem_inc, (add_nodes, add_its)
