"""ITR core — the paper's contribution: Incidence-Type RePair graph
compression with a succinct encoding that answers triple queries fast."""
from repro.core.hypergraph import Hypergraph, LabelTable
from repro.core.delta import DeltaOverlay, resolve_delta_budget
from repro.core.digram import DigramCounter, digram_counts, digram_key, incidences
from repro.core.grammar import Grammar, Rule
from repro.core.repair import RepairConfig, RepairStats, compress
from repro.core.encode import EncodedGrammar, encode
from repro.core.flatten import FlatGrammar, FrontierArena, concat_ragged
from repro.core.bgp import (
    BGPResult,
    SelectivityStats,
    TriplePattern,
    execute_bgp,
    parse_bgp,
    plan_bgp,
)
from repro.core.query import QueryResultView, TripleQueryEngine, query_oracle
from repro.core.result_cache import CacheStats, QueryResultCache, ShardCacheView
from repro.core.itr_plus import attach_node_labels, strip_node_labels
from repro.core.term_dict import StringSpace, TermDict, resolve_dict_block

__all__ = [
    "Hypergraph",
    "LabelTable",
    "DeltaOverlay",
    "resolve_delta_budget",
    "DigramCounter",
    "digram_counts",
    "digram_key",
    "incidences",
    "Grammar",
    "Rule",
    "RepairConfig",
    "RepairStats",
    "compress",
    "EncodedGrammar",
    "encode",
    "FlatGrammar",
    "FrontierArena",
    "concat_ragged",
    "TripleQueryEngine",
    "QueryResultView",
    "QueryResultCache",
    "CacheStats",
    "ShardCacheView",
    "query_oracle",
    "BGPResult",
    "SelectivityStats",
    "TriplePattern",
    "execute_bgp",
    "parse_bgp",
    "plan_bgp",
    "attach_node_labels",
    "strip_node_labels",
    "StringSpace",
    "TermDict",
    "resolve_dict_block",
]
