"""ITR+ — frequent node labels become terminal hyperedges of rank 1.

`x(v)` states node v carries label x: the dictionary stores one entry per
*distinct* label instead of one RDF representation per labeled node, and
rank-1 edges participate in digram replacement, so repeated (node label ×
edge label) subgraphs compress into single nonterminals (paper §ITR+).
"""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph import Hypergraph, LabelTable


def attach_node_labels(
    graph: Hypergraph, table: LabelTable, node_labels: np.ndarray
) -> tuple[Hypergraph, LabelTable, int]:
    """Append rank-1 edges `x(v)` for every labeled node.

    node_labels: int64[n_nodes], -1 = unlabeled; values are indices into a
    node-label alphabet appended to the terminal labels. Returns
    (graph+, table+, first_node_label_id).
    """
    node_labels = np.asarray(node_labels, dtype=np.int64)
    assert len(node_labels) == graph.n_nodes
    n_label_kinds = int(node_labels.max()) + 1 if (node_labels >= 0).any() else 0
    base = table.n_terminals
    new_ranks = np.concatenate([table.ranks[:base], np.ones(n_label_kinds, dtype=np.int64), table.ranks[base:]])
    # terminal block grows; nonterminal ids (if any) shift by n_label_kinds
    assert base == table.n_labels, "attach node labels before compression"
    new_table = LabelTable(new_ranks, base + n_label_kinds, table.names)

    labeled = np.flatnonzero(node_labels >= 0)
    lab_edges_labels = base + node_labels[labeled]
    new_graph = graph.concat_edges(
        lab_edges_labels.astype(np.int64),
        labeled.astype(np.int64),
        np.ones(len(labeled), dtype=np.int64),
    )
    return new_graph, new_table, base


def strip_node_labels(
    graph: Hypergraph, first_label_id: int, n_label_kinds: int
) -> tuple[Hypergraph, np.ndarray]:
    """Inverse of attach: split rank-1 label edges back into node_labels."""
    ranks = graph.ranks()
    is_label_edge = (
        (graph.labels >= first_label_id)
        & (graph.labels < first_label_id + n_label_kinds)
        & (ranks == 1)
    )
    node_labels = np.full(graph.n_nodes, -1, dtype=np.int64)
    lab = graph.select(is_label_edge)
    node_labels[lab.nodes_flat] = lab.labels - first_label_id
    return graph.select(~is_label_edge), node_labels


def dictionary_cost_itr(node_label_strings: list[str], n_labeled_nodes: int, avg_node_repr: int = 24) -> int:
    """ITR stores one RDF representation per labeled node (paper: |V| entries)."""
    return n_labeled_nodes * avg_node_repr


def dictionary_cost_itr_plus(node_label_strings: list[str]) -> int:
    """ITR+ stores only the distinct label strings."""
    return sum(len(s) + 1 for s in node_label_strings)
