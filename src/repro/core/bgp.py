"""Basic graph pattern (BGP) join queries over the batched triple engine.

A BGP is a conjunction of triple patterns sharing named variables —
``?x worksFor ?y . ?y locatedIn Berlin`` — the unit of real RDF query
loads. This module is the join layer on top of the existing single-pattern
machinery ("Compressed k2-Triples" evaluates the same shapes over k²-trees
with sideways information passing; here the substrate is the
grammar-compressed engine):

* **Pattern model** — :func:`parse_bgp` accepts either the string form
  above (integer ids for constants, ``?name`` for variables, patterns
  separated by ``.``) or a list of ``(s, p, o)`` triples whose terms are
  ints or ``?name`` strings. There is no term dictionary yet (ROADMAP
  item 1), so bare strings are rejected rather than silently misread.
* **Selectivity stats** — :class:`SelectivityStats` holds per-predicate
  cardinalities and distinct subject/object counts, computed once per
  engine build from the flattened CSR arrays *without decompressing*:
  per-rule terminal-label counts propagate bottom-up through the rule
  bodies (RePair bodies only reference earlier rules), and start-graph
  edges sum their rules' counts. The stats order joins; they never gate
  correctness.
* **Planner** — :func:`plan_bgp` greedily picks the next pattern with the
  lowest estimated cardinality given the variables already solved,
  preferring patterns connected to the solved set so cartesian products
  only happen when the BGP truly is disconnected.
* **Executor** — :func:`execute_bgp` maintains a *binding table* (one
  int64 column per solved variable) and, per planned step, joins one
  pattern in through a ``batch_fn`` with the `query_batch_view` signature
  (the engine itself, or the sharded service's flush path — which brings
  micro-batch dedup, the shared cache, shard routing, and replica
  dispatch along for free). Two step modes, both joins on id arrays:

  - **bind-join** (selective steps): the distinct bound-variable combos
    are substituted into concrete (S,P,O) patterns and shipped as ONE
    batch — owned patterns stay on their shard; the returned id columns
    merge back through the unique-inverse mapping (a hash join keyed by
    combo id).
  - **scan + hash-join** (unselective steps, when the combo count exceeds
    the pattern's constants-only estimate): the pattern runs once with
    only constants bound and the candidate columns merge against the
    binding table with a vectorized sort/searchsorted equi-join
    (:func:`_join_indices`).

  An empty intermediate table short-circuits the remaining patterns; a
  variable repeated within one pattern (``?x ?p ?x``) filters candidate
  rows for equality before joining.

Results are a :class:`BGPResult`: variables in first-appearance order,
binding rows lexicographically sorted — deterministic, so whole-BGP
results can be cached and compared byte-for-byte across executions.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.core.flatten import _ragged_arange
from repro.core.hypergraph import _ragged_take

_EMPTY = np.zeros(0, dtype=np.int64)

# bind-join fan-out floor: below this many distinct bound-variable combos a
# step always binds (the batch is cheap and dedup/cache absorb repeats);
# above it the combo count competes against the pattern's constants-only
# cardinality estimate and the step may switch to scan + hash-join
_BIND_FANOUT = 64


@dataclass(frozen=True)
class TriplePattern:
    """One (s, p, o) pattern: each term an int constant or a ``?var`` name."""

    s: int | str
    p: int | str
    o: int | str

    @property
    def terms(self) -> tuple:
        return (self.s, self.p, self.o)

    def variables(self) -> list[str]:
        """Variable names in slot order (repeats kept)."""
        return [t for t in self.terms if isinstance(t, str)]

    def __str__(self) -> str:
        return " ".join(str(t) for t in self.terms)


def _parse_term(tok):
    if isinstance(tok, TriplePattern):
        raise TypeError("pattern given where a term was expected")
    if isinstance(tok, str):
        tok = tok.strip()
        if tok.startswith("?"):
            if len(tok) < 2:
                raise ValueError("variable needs a name: bare '?'")
            return tok
        try:
            val = int(tok)
        except ValueError:
            raise ValueError(
                f"term {tok!r} is neither an integer id nor a ?variable "
                "(string terms need the term dictionary, not built yet)"
            ) from None
        tok = val
    if isinstance(tok, (int, np.integer)):
        val = int(tok)
        if val < 0:
            raise ValueError(f"constant ids must be >= 0, got {val}")
        return val
    raise TypeError(f"unsupported pattern term: {tok!r}")


def parse_bgp(bgp) -> list[TriplePattern]:
    """Normalize a BGP into a list of :class:`TriplePattern`.

    Accepts the string form (``"?x 0 ?y . ?y 1 17"`` — whitespace-split
    terms, ``.``-separated patterns) or an iterable of 3-term patterns
    (``TriplePattern`` instances pass through). Every term must be a
    non-negative int id or a ``?name`` variable; an empty BGP is an error.
    """
    if isinstance(bgp, TriplePattern):
        return [bgp]
    if isinstance(bgp, str):
        parts = [part.strip() for part in bgp.split(".")]
        patterns: list = [part.split() for part in parts if part]
    else:
        patterns = list(bgp)
    out: list[TriplePattern] = []
    for pat in patterns:
        if isinstance(pat, TriplePattern):
            out.append(pat)
            continue
        terms = tuple(pat)
        if len(terms) != 3:
            raise ValueError(f"triple pattern needs 3 terms, got {terms!r}")
        out.append(TriplePattern(*(_parse_term(t) for t in terms)))
    if not out:
        raise ValueError("empty BGP: at least one triple pattern required")
    return out


def bgp_variables(patterns: list[TriplePattern]) -> list[str]:
    """Variable names in first-appearance order — the result column order."""
    seen: dict[str, None] = {}
    for pat in patterns:
        for v in pat.variables():
            seen.setdefault(v, None)
    return list(seen)


def canonical_bgp(patterns: list[TriplePattern]) -> str:
    """Stable text form with variables renamed by first occurrence, so two
    BGPs identical up to variable names share one cache key. Pattern
    *order* is part of the key (join order never changes the result set,
    but canonicalizing away the order would require a graph-isomorphism
    pass for no serving win)."""
    names: dict[str, int] = {}
    parts = []
    for pat in patterns:
        toks = []
        for t in pat.terms:
            if isinstance(t, str):
                toks.append(f"?{names.setdefault(t, len(names))}")
            else:
                toks.append(str(t))
        parts.append(" ".join(toks))
    return " . ".join(parts)


def bgp_cache_key(patterns: list[TriplePattern]) -> tuple[int, int, int]:
    """Digest a canonicalized BGP into the (S, P, O) int slots of the
    shared result cache. The three ints are always <= -2, so a key can
    never collide with a real pattern key (those use values >= -1); the
    generation component of the cache key is supplied by the cache itself,
    which is what makes the merged-namespace generation a whole-BGP
    invalidation vector."""
    digest = hashlib.blake2b(canonical_bgp(patterns).encode(),
                             digest_size=24).digest()
    return tuple(-2 - (int.from_bytes(digest[8 * i:8 * i + 8], "big") >> 2)
                 for i in range(3))


class BGPResult:
    """Bindings of a BGP: ``vars`` (first-appearance order) x ``rows``.

    ``rows`` is a read-only ``(n_bindings, n_vars)`` int64 array in
    lexicographic row order — deterministic across executions, shard
    counts, and partition strategies, so results compare byte-for-byte.
    """

    __slots__ = ("vars", "rows")

    def __init__(self, variables, rows: np.ndarray):
        self.vars = tuple(variables)
        self.rows = rows

    def __len__(self) -> int:
        return len(self.rows)

    def tuples(self) -> list[tuple]:
        """Binding rows as plain int tuples (test/oracle comparison form)."""
        return [tuple(int(v) for v in row) for row in self.rows]

    def bindings(self) -> list[dict]:
        """Binding rows as var -> id dicts."""
        return [dict(zip(self.vars, row)) for row in self.tuples()]

    def __repr__(self) -> str:
        return f"BGPResult(vars={self.vars}, n={len(self.rows)})"


def encode_result_entry(result: BGPResult):
    """A :class:`BGPResult` in the cache's ``(labels, nodes_flat,
    offsets)`` entry shape: one 'edge' per binding row (labels all zero,
    nodes = the row values, fixed rank = n_vars), so whole-BGP results
    ride the existing :class:`~repro.core.result_cache.QueryResultCache`
    budgets unchanged. Inverse: :func:`decode_result_entry`."""
    n, k = result.rows.shape
    labels = np.zeros(n, dtype=np.int64)
    nodes = np.ascontiguousarray(result.rows, dtype=np.int64).reshape(-1)
    offsets = np.arange(n + 1, dtype=np.int64) * k
    return labels, nodes, offsets


def decode_result_entry(entry, variables) -> BGPResult:
    labels, nodes, _ = entry
    k = len(tuple(variables))
    n = len(labels)
    rows = nodes.reshape(n, k) if k else np.zeros((n, 0), dtype=np.int64)
    rows.flags.writeable = False
    return BGPResult(variables, rows)


# -- selectivity statistics ---------------------------------------------------
@dataclass
class SelectivityStats:
    """Join-ordering statistics of one engine's compressed base.

    ``pred_card[p]`` is the exact number of base edges labeled ``p``,
    computed from the flattened CSR arrays alone: per-rule terminal-label
    counts propagate bottom-up through the rule bodies, then each start
    edge contributes its own label or its rule's counts. ``n_subjects`` /
    ``n_objects`` are distinct-value counts over the terminal start edges'
    first/second slots plus every nonterminal edge's attachment nodes (an
    upper bound — expansions can only place attachment nodes, so nothing
    is missed). The mutation overlay is deliberately ignored: it is
    bounded by the rebuild budget, and stats only order joins.
    """

    total: int
    pred_card: np.ndarray
    n_subjects: int
    n_objects: int

    @classmethod
    def from_csr(cls, labels, ranks, nodes_flat, offsets, flat,
                 n_terminals: int) -> "SelectivityStats":
        T = int(n_terminals)
        R = flat.n_rules
        counts = np.zeros((R, T), dtype=np.int64)
        for slot in range(R):
            body = flat.edge_labels[
                flat.edge_offsets[slot]:flat.edge_offsets[slot + 1]]
            terms = body[body < T]
            if len(terms) and T:
                counts[slot] += np.bincount(terms, minlength=T)
            nts = body[body >= T]
            if len(nts):
                child = flat.rule_index[nts]
                if bool(np.any(child >= slot)):
                    raise ValueError(
                        "rule bodies must reference earlier rules "
                        "(RePair output is bottom-up ordered)")
                counts[slot] += counts[child].sum(axis=0)

        labels = np.asarray(labels, dtype=np.int64)
        ranks = np.asarray(ranks, dtype=np.int64)
        offsets = np.asarray(offsets, dtype=np.int64)
        is_term = labels < T
        pred_card = np.bincount(labels[is_term], minlength=T).astype(np.int64) \
            if T else np.zeros(0, dtype=np.int64)
        nt_idx = np.flatnonzero(~is_term)
        if len(nt_idx) and R:
            pred_card += counts[flat.rule_index[labels[nt_idx]]].sum(axis=0)

        starts = offsets[:-1]
        t2 = is_term & (ranks >= 2)
        subs = nodes_flat[starts[t2]] if t2.any() else _EMPTY
        objs = nodes_flat[starts[t2] + 1] if t2.any() else _EMPTY
        att = nodes_flat[_ragged_take(offsets, nt_idx, ranks[nt_idx])] \
            if len(nt_idx) else _EMPTY
        return cls(total=int(pred_card.sum()), pred_card=pred_card,
                   n_subjects=max(1, len(np.unique(np.concatenate([subs, att])))),
                   n_objects=max(1, len(np.unique(np.concatenate([objs, att])))))

    @classmethod
    def merge(cls, parts) -> "SelectivityStats":
        """Tier-level stats: per-shard sums (distinct-count sums
        overestimate under ``predicate_hash``, where one subject spans
        shards — an acceptable bias for ordering joins)."""
        parts = list(parts)
        if not parts:
            return cls(0, np.zeros(0, dtype=np.int64), 1, 1)
        T = max(len(p.pred_card) for p in parts)
        pred = np.zeros(T, dtype=np.int64)
        for p in parts:
            pred[:len(p.pred_card)] += p.pred_card
        return cls(total=int(sum(p.total for p in parts)), pred_card=pred,
                   n_subjects=sum(p.n_subjects for p in parts),
                   n_objects=sum(p.n_objects for p in parts))

    def estimate(self, s_bound: bool, p: int | None, o_bound: bool) -> float:
        """Expected matches of one pattern under independence: predicate
        cardinality (or the full edge count for a free/variable P), divided
        by the distinct subject/object counts per bound slot."""
        if p is not None:
            p = int(p)
            card = float(self.pred_card[p]) \
                if 0 <= p < len(self.pred_card) else 0.0
        else:
            card = float(self.total)
        if s_bound:
            card /= max(1, self.n_subjects)
        if o_bound:
            card /= max(1, self.n_objects)
        return card


def pattern_cost(pattern: TriplePattern, bound, stats) -> float:
    """Estimated matches of `pattern` once the variables in `bound` carry
    concrete values. With no stats, falls back to counting free slots."""
    s, p, o = pattern.terms
    s_bound = not isinstance(s, str) or s in bound
    o_bound = not isinstance(o, str) or o in bound
    if stats is None:
        free = sum(1 for b in (s_bound, not isinstance(p, str) or p in bound,
                               o_bound) if not b)
        return float(1000 ** free)
    if not isinstance(p, str):
        return stats.estimate(s_bound, p, o_bound)
    if p in bound:  # concrete at run time, unknown now: average predicate
        card = stats.total / max(1, len(stats.pred_card))
        if s_bound:
            card /= max(1, stats.n_subjects)
        if o_bound:
            card /= max(1, stats.n_objects)
        return card
    return stats.estimate(s_bound, None, o_bound)


def plan_bgp(patterns: list[TriplePattern], stats=None) -> list[int]:
    """Greedy variable-elimination order (pattern indices).

    Start from the pattern with the lowest constants-only estimate; then
    repeatedly take the cheapest pattern *given the solved variables*,
    restricted to patterns sharing a solved variable whenever any exists —
    a cartesian step only happens when the remaining BGP is disconnected
    from everything solved so far. Ties break on pattern index, so plans
    are deterministic.
    """
    remaining = list(range(len(patterns)))
    bound: set[str] = set()
    order: list[int] = []
    while remaining:
        best = None
        best_key = None
        for i in remaining:
            pat = patterns[i]
            connected = not bound or \
                any(v in bound for v in pat.variables()) or \
                not pat.variables()
            key = (not connected, pattern_cost(pat, bound, stats), i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        order.append(best)
        remaining.remove(best)
        bound.update(patterns[best].variables())
    return order


# -- execution ----------------------------------------------------------------
def _join_indices(left: np.ndarray, right: np.ndarray):
    """Vectorized equi-join of two key matrices on all columns.

    Returns aligned ``(li, ri)`` index arrays: every pair with
    ``left[li[k]] == right[ri[k]]`` row-wise, grouped by left row. One
    shared `np.unique` assigns both sides integer key codes (the hash),
    then a sort + `searchsorted` merge emits the pairs — no Python loop.
    """
    n = len(left)
    both = np.concatenate([left, right], axis=0)
    _, codes = np.unique(both, axis=0, return_inverse=True)
    codes = codes.reshape(-1)
    lcode, rcode = codes[:n], codes[n:]
    order = np.argsort(rcode, kind="stable")
    rsorted = rcode[order]
    lo = np.searchsorted(rsorted, lcode, side="left")
    hi = np.searchsorted(rsorted, lcode, side="right")
    cnt = hi - lo
    li = np.repeat(np.arange(n, dtype=np.int64), cnt)
    ri = order[np.repeat(lo, cnt) + _ragged_arange(cnt)]
    return li, ri


def _var_positions(pattern: TriplePattern) -> dict[str, list[int]]:
    pos: dict[str, list[int]] = {}
    for slot, t in enumerate(pattern.terms):
        if isinstance(t, str):
            pos.setdefault(t, []).append(slot)
    return pos


def _entry_candidates(entry, want_slots: list[int],
                      check_pos: list[list[int]]) -> np.ndarray:
    """Candidate id columns from one result entry.

    Keeps only rank-2 edges (triples), applies in-pattern repeated-variable
    equality over each slot group in `check_pos` (slot 0 = subject,
    1 = predicate/label, 2 = object), and returns the surviving rows'
    values at `want_slots` as an ``(m, len(want_slots))`` matrix.
    """
    labels, nodes, offsets = entry
    ranks = np.diff(offsets)
    keep = ranks == 2
    lab = labels[keep]
    starts = offsets[:-1][keep]
    cols = (nodes[starts] if len(lab) else _EMPTY, lab,
            nodes[starts + 1] if len(lab) else _EMPTY)
    mask = np.ones(len(lab), dtype=bool)
    for slots in check_pos:
        for extra in slots[1:]:
            mask &= cols[slots[0]] == cols[extra]
    if not mask.all():
        cols = tuple(c[mask] for c in cols)
    m = len(cols[1])
    if not want_slots:
        return np.zeros((m, 0), dtype=np.int64)
    return np.stack([cols[slot] for slot in want_slots], axis=1)


def execute_bgp(patterns, batch_fn, stats=None, order=None) -> BGPResult:
    """Evaluate a BGP through a batched single-pattern executor.

    `batch_fn(s, p, o)` takes aligned int64 columns (-1 = unbound) and
    returns a :class:`~repro.core.query.QueryResultView` — pass
    ``engine.query_batch_view`` or the sharded service's flush hook; every
    sub-pattern batch then inherits that path's dedup, caching, shard
    routing, and locking. `stats` orders the join (:func:`plan_bgp`) and
    arbitrates bind-join vs scan+hash-join per step; `order` overrides the
    planner with an explicit pattern-index order.

    The binding table starts as the single empty binding and each step
    joins one pattern in; when it empties, the remaining patterns are
    never executed (the result is already known empty).
    """
    patterns = parse_bgp(patterns)
    out_vars = bgp_variables(patterns)
    if order is None:
        order = plan_bgp(patterns, stats)
    elif sorted(order) != list(range(len(patterns))):
        raise ValueError(f"order must permute range({len(patterns)}), "
                         f"got {order!r}")
    solved: list[str] = []
    rows = np.zeros((1, 0), dtype=np.int64)
    for i in order:
        rows, solved = _join_step(rows, solved, patterns[i], batch_fn, stats)
        if len(rows) == 0:
            break
    if len(rows) == 0:
        final = np.zeros((0, len(out_vars)), dtype=np.int64)
    else:
        perm = [solved.index(v) for v in out_vars]
        final = rows[:, perm] if perm else rows[:, :0]
        if len(final) and final.shape[1]:
            final = final[np.lexsort(final.T[::-1])]
        final = np.ascontiguousarray(final)
    final.flags.writeable = False
    return BGPResult(out_vars, final)


def _join_step(rows: np.ndarray, solved: list[str], pattern: TriplePattern,
               batch_fn, stats):
    """Join one pattern into the binding table; returns (rows, solved)."""
    var_pos = _var_positions(pattern)
    bound_vars = [v for v in solved if v in var_pos]
    new_vars = [v for v in var_pos if v not in solved]
    new_slots = [var_pos[v][0] for v in new_vars]
    n = len(rows)

    if not bound_vars:
        # first step, or a genuinely disconnected pattern: one scan, then
        # a cross product against the table (n == 1 empty binding at start)
        cols = [np.asarray([t if not isinstance(t, str) else -1
                            for t in pattern.terms], dtype=np.int64)]
        view = batch_fn(cols[0][:1], cols[0][1:2], cols[0][2:3])
        cand = _entry_candidates(view.entry(0), new_slots,
                                 list(var_pos.values()))
        m = len(cand)
        out = np.concatenate(
            [np.repeat(rows, m, axis=0), np.tile(cand, (n, 1))], axis=1) \
            if n * m else np.zeros((0, len(solved) + len(new_vars)), np.int64)
        return out, solved + new_vars

    key_cols = [solved.index(v) for v in bound_vars]
    table_keys = rows[:, key_cols]
    combos, inv = np.unique(table_keys, axis=0, return_inverse=True)
    inv = inv.reshape(-1)
    u = len(combos)
    # bind-join pays per distinct combo (a point pattern each, plus a
    # per-entry merge); scan+hash pays one est_const-row fetch plus a
    # vectorized join. Bind only when the combo count is small in absolute
    # terms or tiny relative to the scan — near parity the scan's single
    # batched fetch wins on constant factors.
    est_const = pattern_cost(pattern, frozenset(), stats) \
        if stats is not None else None
    threshold = _BIND_FANOUT if est_const is None \
        else max(_BIND_FANOUT, est_const / 8.0)

    if u > threshold:
        # scan + hash-join: run the pattern once with constants only, then
        # merge-join candidate columns against the table on the bound vars
        cols = np.asarray([t if not isinstance(t, str) else -1
                           for t in pattern.terms], dtype=np.int64)
        view = batch_fn(cols[:1], cols[1:2], cols[2:3])
        want = [var_pos[v][0] for v in bound_vars] + new_slots
        cand = _entry_candidates(view.entry(0), want, list(var_pos.values()))
        li, ri = _join_indices(table_keys, cand[:, :len(bound_vars)])
        out = np.concatenate([rows[li], cand[ri][:, len(bound_vars):]], axis=1)
        return out, solved + new_vars

    # bind-join: one concrete pattern per distinct bound-variable combo,
    # shipped as a single batch (dedup/cache/shard routing downstream);
    # the unique-inverse is the hash that joins results back to table rows
    sub = np.empty((3, u), dtype=np.int64)
    for slot, t in enumerate(pattern.terms):
        if isinstance(t, str):
            sub[slot] = combos[:, bound_vars.index(t)] \
                if t in bound_vars else -1
        else:
            sub[slot] = t
    view = batch_fn(sub[0], sub[1], sub[2])
    # repeated-variable checks only cover FREE groups here: bound and
    # constant slots were substituted, so the executor enforced them
    check = [slots for v, slots in var_pos.items()
             if v in new_vars and len(slots) > 1]
    per_entry = [_entry_candidates(e, new_slots, check) for e in view.entries]
    combo_entry = view.qid_entry
    combo_counts = np.array([len(per_entry[int(combo_entry[j])])
                             for j in range(u)], dtype=np.int64)
    if int(combo_counts.sum()) == 0:
        return np.zeros((0, len(solved) + len(new_vars)), np.int64), \
            solved + new_vars
    cand_all = np.concatenate([per_entry[int(combo_entry[j])]
                               for j in range(u)], axis=0)
    combo_starts = np.cumsum(combo_counts) - combo_counts
    cnt = combo_counts[inv]
    take = np.repeat(combo_starts[inv], cnt) + _ragged_arange(cnt)
    out = np.concatenate(
        [np.repeat(rows, cnt, axis=0), cand_all[take]], axis=1)
    return out, solved + new_vars
