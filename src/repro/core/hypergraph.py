"""Array-based hypergraph with labeled (hyper)edges, per the paper's model.

A hypergraph is ``G = (V, E)`` with ``V = {0..p}`` and edges ``e = a(v0..vk)``
where ``a`` is a ranked label and duplicates among the ``vi`` are allowed
(loops). We store edges in struct-of-arrays form:

  labels[e]                -> label id of edge e
  nodes_flat / offsets[e]  -> node tuple of edge e (ragged)

Label ranks live in a :class:`LabelTable`; all edges of a label share its
rank (paper assumption). Terminal labels occupy ids ``0..n_terminals-1``;
nonterminals introduced by compression are appended after.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class LabelTable:
    ranks: np.ndarray  # int64[n_labels]
    n_terminals: int
    names: list[str] | None = None  # dictionary strings for terminals

    @classmethod
    def terminals(cls, ranks, names=None) -> "LabelTable":
        ranks = np.asarray(ranks, dtype=np.int64)
        return cls(ranks=ranks, n_terminals=len(ranks), names=names)

    @property
    def n_labels(self) -> int:
        return len(self.ranks)

    def is_terminal(self, label) -> np.ndarray:
        return np.asarray(label) < self.n_terminals

    def add_label(self, rank: int) -> int:
        """Append a nonterminal label; returns its id."""
        self.ranks = np.concatenate([self.ranks, [rank]])
        return len(self.ranks) - 1

    def it_offsets(self) -> np.ndarray:
        """Incidence-type id of (label a, connection m) is it_offsets[a] + m."""
        return np.concatenate([[0], np.cumsum(self.ranks)]).astype(np.int64)

    def copy(self) -> "LabelTable":
        return LabelTable(self.ranks.copy(), self.n_terminals, self.names)


@dataclass
class Hypergraph:
    n_nodes: int
    labels: np.ndarray      # int64[E]
    nodes_flat: np.ndarray  # int64[sum ranks]
    offsets: np.ndarray     # int64[E+1]

    @classmethod
    def from_edges(cls, n_nodes: int, edges: list[tuple[int, list[int]]]) -> "Hypergraph":
        """edges: list of (label, [v0..vk])."""
        labels = np.array([e[0] for e in edges], dtype=np.int64)
        tuples = [np.asarray(e[1], dtype=np.int64) for e in edges]
        offsets = np.concatenate([[0], np.cumsum([len(t) for t in tuples])]).astype(np.int64)
        nodes_flat = np.concatenate(tuples) if tuples else np.zeros(0, dtype=np.int64)
        return cls(n_nodes, labels, nodes_flat, offsets)

    @classmethod
    def from_triples(cls, triples: np.ndarray, n_nodes: int) -> "Hypergraph":
        """triples: int64[n, 3] rows (s, p, o) -> rank-2 edges p(s, o)."""
        triples = np.asarray(triples, dtype=np.int64)
        labels = triples[:, 1].copy()
        nodes_flat = triples[:, [0, 2]].reshape(-1).copy()
        offsets = np.arange(len(triples) + 1, dtype=np.int64) * 2
        return cls(n_nodes, labels, nodes_flat, offsets)

    @property
    def n_edges(self) -> int:
        return len(self.labels)

    def ranks(self) -> np.ndarray:
        return np.diff(self.offsets)

    def edge_nodes(self, e: int) -> np.ndarray:
        return self.nodes_flat[self.offsets[e]:self.offsets[e + 1]]

    def edge_tuples(self) -> list[tuple[int, tuple[int, ...]]]:
        """Python-friendly view (tests / small graphs only)."""
        return [
            (int(self.labels[e]), tuple(int(v) for v in self.edge_nodes(e)))
            for e in range(self.n_edges)
        ]

    def canonical_multiset(self) -> set:
        """Multiset of edges as a set of (label, nodes, multiplicity) triples."""
        from collections import Counter

        cnt = Counter(self.edge_tuples())
        return {(lbl, nd, c) for (lbl, nd), c in cnt.items()}

    def validate(self, table: LabelTable | None = None) -> None:
        assert len(self.offsets) == self.n_edges + 1
        assert self.offsets[0] == 0 and self.offsets[-1] == len(self.nodes_flat)
        if self.n_edges:
            assert self.nodes_flat.min() >= 0 and (self.n_nodes == 0 or self.nodes_flat.max() < self.n_nodes)
        if table is not None and self.n_edges:
            assert np.array_equal(self.ranks(), table.ranks[self.labels]), "edge arity != label rank"

    def size_units(self) -> int:
        """Integer-unit size model: 1 (label) + rank per edge (Maneth-style)."""
        return int(self.n_edges + len(self.nodes_flat))

    def select(self, mask: np.ndarray) -> "Hypergraph":
        """Subgraph with edges where mask is True (nodes untouched)."""
        idx = np.flatnonzero(mask)
        return self.gather_edges(idx)

    def gather_edges(self, idx: np.ndarray) -> "Hypergraph":
        ranks = self.ranks()
        new_labels = self.labels[idx]
        new_ranks = ranks[idx]
        new_offsets = np.concatenate([[0], np.cumsum(new_ranks)]).astype(np.int64)
        # ragged gather of node tuples
        take = _ragged_take(self.offsets, idx, new_ranks)
        return Hypergraph(self.n_nodes, new_labels, self.nodes_flat[take], new_offsets)

    def concat_edges(self, labels: np.ndarray, nodes_flat: np.ndarray, ranks: np.ndarray) -> "Hypergraph":
        new_labels = np.concatenate([self.labels, labels])
        new_flat = np.concatenate([self.nodes_flat, nodes_flat])
        new_offsets = np.concatenate([self.offsets, self.offsets[-1] + np.cumsum(ranks)]).astype(np.int64)
        return Hypergraph(self.n_nodes, new_labels, new_flat, new_offsets)

    def copy(self) -> "Hypergraph":
        return Hypergraph(self.n_nodes, self.labels.copy(), self.nodes_flat.copy(), self.offsets.copy())


def _ragged_take(offsets: np.ndarray, idx: np.ndarray, out_ranks: np.ndarray) -> np.ndarray:
    """Flat indices selecting the node tuples of edges `idx`."""
    total = int(out_ranks.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = offsets[idx]
    out_offsets = np.concatenate([[0], np.cumsum(out_ranks)]).astype(np.int64)
    pos = np.arange(total, dtype=np.int64) - np.repeat(out_offsets[:-1], out_ranks)
    return np.repeat(starts, out_ranks) + pos
