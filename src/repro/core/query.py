"""Triple queries on the compressed grammar (paper §Answering triple queries).

Patterns: any subset of (S, P, O) bound. Case analysis per the paper:

* S or O bound  -> decompress one row of the start graph's incidence-matrix
  k²-tree (no full decompression) to seed the worklist with incident edges.
* only P bound  -> seed with start-graph edges labeled P (binary search on
  the Elias–Fano label list) plus edges of every nonterminal A whose NT
  matrix row says A can generate P.
* nothing bound -> all start edges (equivalent to decompression).

The worklist expands a nonterminal edge only if its attachment nodes can
still contain bound S/O and NT[label, P] holds — pruned expansion is what
makes queries fast on the grammar.
"""
from __future__ import annotations

import numpy as np

from repro.core.encode import EncodedGrammar, encode
from repro.core.grammar import Grammar
from repro.core.succinct import K2Tree


class TripleQueryEngine:
    """Query engine over a grammar + its succinct encoding."""

    def __init__(self, grammar: Grammar, encoded: EncodedGrammar | None = None):
        self.grammar = grammar
        self.encoded = encoded if encoded is not None else encode(grammar)
        self.T = grammar.table.n_terminals
        self.ranks = grammar.table.ranks
        # NT reachability matrix, k²-compressed (paper: matrix NT)
        gen = grammar.nt_generates()
        if gen.size:
            r, c = np.nonzero(gen)
            self.nt_k2 = K2Tree(r, c, gen.shape[0], gen.shape[1])
        else:
            self.nt_k2 = None
        self._nt_rows: dict[int, set] = {}
        # decoded rule bodies (label, params) per nonterminal, memoized arrays
        self._rules = {
            lbl: [(int(r.rhs.labels[j]), r.rhs.edge_nodes(j)) for j in range(r.rhs.n_edges)]
            for lbl, r in grammar.rules.items()
        }
        # per-edge start-graph reconstruction caches; materialized once as
        # python lists so the per-query hot loop does O(1) lookups instead
        # of numpy slicing per edge (paper-side hillclimb, EXPERIMENTS §Perf)
        self._start_sorted = grammar.start.gather_edges(np.argsort(grammar.start.labels, kind="stable"))
        self._sorted_labels = self._start_sorted.labels
        g = self._start_sorted
        self._edge_cache = [
            (int(g.labels[j]), g.nodes_flat[g.offsets[j]:g.offsets[j + 1]])
            for j in range(g.n_edges)
        ]

    # -- helpers --------------------------------------------------------
    def _nt_generates(self, label: int, p: int) -> bool:
        if self.nt_k2 is None:
            return False
        row = self._nt_rows.get(label)
        if row is None:
            row = set(self.nt_k2.row(label - self.T).tolist())
            self._nt_rows[label] = row
        return p in row

    def _edge(self, j: int) -> tuple[int, np.ndarray]:
        """Sorted-start edge j (pre-reconstructed at load)."""
        return self._edge_cache[j]

    def _edges_with_label(self, label: int) -> np.ndarray:
        lo = np.searchsorted(self._sorted_labels, label, side="left")
        hi = np.searchsorted(self._sorted_labels, label, side="right")
        return np.arange(lo, hi, dtype=np.int64)

    def _row_edges(self, node: int) -> np.ndarray:
        """Edges incident to `node` via one k²-tree row decompression."""
        if node < 0 or node >= self.encoded.incidence.n_rows:
            return np.zeros(0, dtype=np.int64)
        return self.encoded.incidence.row(node)

    # -- main entry ------------------------------------------------------
    def query(self, s: int | None, p: int | None, o: int | None) -> list[tuple]:
        """Return matching terminal edges as (label, (v0..vk)) tuples."""
        if s is not None or o is not None:
            r = s if s is not None else o
            seeds = [self._edge(int(j)) for j in self._row_edges(int(r))]
        elif p is not None:
            seeds = [self._edge(int(j)) for j in self._edges_with_label(int(p))]
            for lbl in self._rules:
                if self._nt_generates(lbl, int(p)):
                    seeds.extend(self._edge(int(j)) for j in self._edges_with_label(lbl))
        else:
            g = self._start_sorted
            seeds = [(int(g.labels[j]), g.edge_nodes(j)) for j in range(g.n_edges)]

        out: list[tuple] = []
        z = list(seeds)
        while z:
            label, nodes = z.pop()
            if label >= self.T:  # nonterminal
                if s is not None and s not in nodes:
                    continue
                if o is not None and o not in nodes:
                    continue
                if p is not None and not self._nt_generates(label, p):
                    continue
                for child_label, params in self._rules[label]:
                    z.append((child_label, nodes[params]))
            else:
                if self._matches(label, nodes, s, p, o):
                    out.append((label, tuple(int(v) for v in nodes)))
        return out

    @staticmethod
    def _matches(label, nodes, s, p, o) -> bool:
        if p is not None and label != p:
            return False
        if s is not None and (len(nodes) < 1 or nodes[0] != s):
            return False
        if o is not None and (len(nodes) < 2 or nodes[1] != o):
            return False
        return True

    # -- convenience -----------------------------------------------------
    def neighbors_out(self, v: int) -> np.ndarray:
        """v ? ? -> distinct objects (outgoing neighborhood)."""
        res = self.query(v, None, None)
        return np.unique(np.array([e[1][1] for e in res if len(e[1]) >= 2], dtype=np.int64))

    def neighbors_in(self, v: int) -> np.ndarray:
        """? ? v -> distinct subjects (incoming neighborhood)."""
        res = self.query(None, None, v)
        return np.unique(np.array([e[1][0] for e in res if len(e[1]) >= 2], dtype=np.int64))


def query_oracle(graph, s, p, o) -> list[tuple]:
    """Reference: scan the uncompressed hypergraph (tests/benchmarks)."""
    out = []
    for e in range(graph.n_edges):
        label = int(graph.labels[e])
        nodes = graph.edge_nodes(e)
        if TripleQueryEngine._matches(label, nodes, s, p, o):
            out.append((label, tuple(int(v) for v in nodes)))
    return out
