"""Triple queries on the compressed grammar (paper §Answering triple queries).

Patterns: any subset of (S, P, O) bound. Case analysis per the paper:

* S or O bound  -> decompress one row of the start graph's incidence-matrix
  k²-tree (no full decompression) to seed the frontier with incident edges.
* only P bound  -> seed with start-graph edges labeled P (binary search on
  the Elias–Fano label list) plus edges of every nonterminal A whose NT
  matrix row says A can generate P.
* nothing bound -> all start edges (equivalent to decompression).

Execution is *batched and level-synchronous*: `query_batch` runs many
(S,P,O) patterns in one frontier by carrying a query-id column. Each
iteration expands ALL nonterminal edges at once through the flattened
grammar's CSR gathers (`repro.core.flatten`), applies the S/O-containment
and NT[label,P] prunes as boolean masks, and partitions terminals into a
preallocated result arena (`FrontierArena`) that is reused across calls.
Seeding uses the k²-tree's batched multi-row expansion, so one traversal
serves every S/O-bound query in the batch — pruned expansion plus batching
is what makes queries fast on the grammar.

The serving path is cache- and width-aware:

* a cross-request :class:`QueryResultCache` (LRU over (S,P,O) patterns,
  with a dedicated ``?P?`` segment) turns repeats *across* micro-batches
  into gathers — streaming dedup, not just in-batch dedup;
* cache-missing work narrower than the engine's measured crossover width
  routes to the per-query `query_scalar` worklist when every pattern is
  selective (S or O bound) — tiny frontiers pay more in numpy per-level
  overhead than the worklist pays in Python. The width is calibrated at
  engine build and overridable via ``ITR_QUERY_CROSSOVER``.

`query` is a batch of one; `query_scalar` keeps the seed per-query Python
worklist as the parity/benchmark reference.

The engine is also the *write* surface: `insert_triples`/`delete_triples`
record mutations in an uncompressed :class:`~repro.core.delta.DeltaOverlay`
(insert buffer + tombstone set) that every executed batch merges in, so
queries stay exact while the grammar itself is untouched. Once the overlay
outgrows the engine's budget (``ITR_DELTA_BUDGET``), `rebuild` recompresses
base+delta through the RePair pipeline and atomically swaps the engine's
internals; the cross-request cache is generation-bumped on every mutation
so stale entries can never be served.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.delta import DeltaOverlay, as_triple_rows, resolve_delta_budget
from repro.core.encode import EncodedGrammar, encode
from repro.core.flatten import FlatGrammar, FrontierArena, _ragged_arange, concat_ragged
from repro.core.grammar import Grammar
from repro.core.hypergraph import _ragged_take
from repro.core.result_cache import QueryResultCache
from repro.core.succinct import K2Tree
from repro.persist.crash import crash_point

_EMPTY = np.zeros(0, dtype=np.int64)

# sentinel: "create a default QueryResultCache unless disabled by env"
_DEFAULT_CACHE = object()

# sentinel: "resolve the delta budget from ITR_DELTA_BUDGET"
_DEFAULT_BUDGET = object()

# calibration cap: scalar routing never extends past this batch width
_MAX_CROSSOVER = 8


class QueryResultView:
    """Batch results as qid -> *shared* per-pattern entry arrays.

    The materialized batch layout (`query_batch_arrays`) replicates each
    duplicated pattern's full result per query id — for warm repeated
    ``?P?`` traffic that replication IS the cost floor. A view instead
    holds one ``(labels, nodes_flat, offsets)`` entry per *unique* pattern
    plus the qid -> entry mapping; duplicates share the same backing
    arrays with zero copies. All arrays are read-only (they may alias live
    cache entries). `materialize()` is the escape hatch back to the flat
    ``(qids, labels, nodes_flat, offsets)`` layout.
    """

    __slots__ = ("entries", "qid_entry")

    def __init__(self, entries: list, qid_entry: np.ndarray):
        self.entries = entries                       # one per unique pattern
        self.qid_entry = np.asarray(qid_entry, dtype=np.int64)

    @property
    def n_queries(self) -> int:
        return len(self.qid_entry)

    def entry(self, qid: int):
        """(labels, nodes_flat, offsets) of query `qid` — shared, read-only."""
        return self.entries[int(self.qid_entry[qid])]

    def result_counts(self) -> np.ndarray:
        """Matching-edge count per query id (duplicates counted per qid)."""
        per_entry = np.array([len(e[0]) for e in self.entries], dtype=np.int64)
        return per_entry[self.qid_entry] if len(per_entry) else \
            np.zeros(self.n_queries, dtype=np.int64)

    def total_results(self) -> int:
        return int(self.result_counts().sum())

    def entry_tuples(self, index: int) -> list[tuple]:
        """Entry `index` as (label, (v0..vk)) tuples (built per entry, so
        duplicate qids can share ONE list instead of converting each)."""
        labels, nodes, offsets = self.entries[index]
        return [(int(labels[j]), tuple(int(v) for v in nodes[offsets[j]:offsets[j + 1]]))
                for j in range(len(labels))]

    def tuples(self, qid: int) -> list[tuple]:
        return self.entry_tuples(int(self.qid_entry[qid]))

    def tuple_lists(self) -> list[tuple]:
        """Per-qid (label, nodes) result sequences, built ONCE per unique
        pattern — duplicate qids share one *immutable tuple* (mutating a
        shared list would silently corrupt the sibling ticket's answer;
        a tuple fails loudly). This is the service flush path."""
        shared: list = [None] * len(self.entries)
        out: list[tuple] = []
        for ei in self.qid_entry:
            ei = int(ei)
            if shared[ei] is None:
                shared[ei] = tuple(self.entry_tuples(ei))
            out.append(shared[ei])
        return out

    def materialize(self):
        """Escape hatch back to the flat batch layout.

        Returns ``(qids, labels, nodes_flat, offsets)`` with every
        duplicate pattern's results replicated per query id — identical
        layout and content to `query_batch_arrays`. This re-pays exactly
        the replication cost the view exists to avoid, so call it only at
        boundaries that require the flat form (legacy consumers, array
        serialization); duplicate-heavy warm traffic should stay on the
        view's shared entries.
        """
        counts = np.array([len(e[0]) for e in self.entries], dtype=np.int64)
        u_l, u_n, u_o = concat_ragged(self.entries)
        return _replicate_sorted(u_l, u_n, np.diff(u_o), u_o, counts, self.qid_entry)

    @staticmethod
    def empty() -> "QueryResultView":
        """Zero-query view (the empty-flush no-op result)."""
        return QueryResultView([], np.zeros(0, dtype=np.int64))

    @staticmethod
    def concat(views: list["QueryResultView"]) -> "QueryResultView":
        """Stack views over consecutive qid ranges (micro-batch chunks)."""
        entries: list = []
        qid_chunks = []
        for v in views:
            qid_chunks.append(v.qid_entry + len(entries))
            entries.extend(v.entries)
        qid_entry = np.concatenate(qid_chunks) if qid_chunks else _EMPTY
        return QueryResultView(entries, qid_entry)


def _freeze_entry(entry):
    """Mark an entry's arrays read-only: view entries are shared across
    duplicate qids (and may back cache entries), so in-place mutation must
    raise instead of silently corrupting a sibling's answer."""
    for a in entry:
        a.flags.writeable = False
    return entry


def _env_flag(name: str, default: bool) -> bool:
    v = os.environ.get(name, "").strip().lower()
    if not v:
        return default
    return v not in ("0", "off", "false", "no")


class TripleQueryEngine:
    """Query engine over a grammar + its succinct encoding.

    `cache` is the cross-request result cache (pass ``None`` to disable,
    or your own :class:`QueryResultCache` — or a
    :class:`~repro.core.result_cache.ShardCacheView` of a shared tier, as
    the sharded service does — to share/size it; the default is
    engine-private and can be switched off with ``ITR_RESULT_CACHE=0``).
    `crossover` is the batch width at/below which cache-missing selective
    patterns run on the scalar worklist instead of the frontier (``None``
    = read ``ITR_QUERY_CROSSOVER`` or calibrate at build; ``0`` = always
    use the frontier).
    `delta_budget` bounds the mutation overlay before :meth:`rebuild`
    recompresses automatically (default: read ``ITR_DELTA_BUDGET``;
    ``None`` = never auto-rebuild, ``0`` = recompress after every
    mutation batch — see :func:`repro.core.delta.resolve_delta_budget`).
    `config` is the :class:`~repro.core.repair.RepairConfig` rebuilds
    recompress with — pass the one the grammar was built with, or
    budget-triggered auto-rebuilds would silently fall back to default
    compression parameters.
    """

    def __init__(self, grammar: Grammar, encoded: EncodedGrammar | None = None,
                 cache=_DEFAULT_CACHE, crossover: int | None = None,
                 delta_budget=_DEFAULT_BUDGET, config=None):
        self.grammar = grammar
        self.encoded = encoded if encoded is not None else encode(grammar)
        self.T = grammar.table.n_terminals
        self.ranks = grammar.table.ranks
        # NT reachability matrix, k²-compressed (paper: matrix NT)
        gen = grammar.nt_generates()
        if gen.size:
            r, c = np.nonzero(gen)
            self.nt_k2 = K2Tree(r, c, gen.shape[0], gen.shape[1])
        else:
            self.nt_k2 = None
        self._nt_rows: dict[int, set] = {}
        # flattened grammar: CSR rule bodies + NT bitsets for batch expansion
        self.flat = FlatGrammar.from_grammar(grammar)
        # start graph in label-sorted order, struct-of-arrays (frontier seeds
        # and expansions are pure gathers over these)
        self._start_sorted = grammar.start.gather_edges(
            np.argsort(grammar.start.labels, kind="stable"))
        g = self._start_sorted
        self._sorted_labels = g.labels
        self._sorted_ranks = g.ranks()
        self._sorted_offsets = g.offsets
        self._sorted_nodes = g.nodes_flat
        # decoded rule bodies for the scalar reference path
        self._rules = {
            lbl: [(int(r.rhs.labels[j]), r.rhs.edge_nodes(j)) for j in range(r.rhs.n_edges)]
            for lbl, r in grammar.rules.items()
        }
        self._edge_cache = [
            (int(g.labels[j]), g.nodes_flat[g.offsets[j]:g.offsets[j + 1]])
            for j in range(g.n_edges)
        ]
        # result arena: shared across frontier levels, reused across calls
        self._arena = FrontierArena()
        if cache is _DEFAULT_CACHE:
            cache = QueryResultCache() if _env_flag("ITR_RESULT_CACHE", True) else None
        self.cache: QueryResultCache | None = cache
        self.crossover = self._calibrate_crossover() if crossover is None else int(crossover)
        # mutation overlay: uncompressed (inserts, tombstones) delta merged
        # into every executed batch; bounded by the rebuild budget
        self.delta = DeltaOverlay()
        self._base_edges: int | None = None  # lazy |base triples| cache
        self.config = config  # RepairConfig reused by rebuilds
        if delta_budget is _DEFAULT_BUDGET:
            self.delta_budget = resolve_delta_budget()
        else:  # explicit None = auto-rebuild off; ints resolve (neg = off)
            self.delta_budget = None if delta_budget is None \
                else resolve_delta_budget(delta_budget)
        self.rebuild_count = 0
        self._select_stats = None  # lazy SelectivityStats (see selectivity())
        self.term_dict = None  # optional TermDict (attach_term_dict)

    @classmethod
    def from_state(cls, grammar: Grammar, encoded: EncodedGrammar,
                   flat: FlatGrammar, *, crossover: int, cache=_DEFAULT_CACHE,
                   delta_budget: int | None = None, config=None,
                   base_edges: int | None = None,
                   rebuild_count: int = 0) -> "TripleQueryEngine":
        """Reconstruct an engine from prebuilt parts — the snapshot load
        path. No RePair, no `encode`, no `FlatGrammar.from_grammar`, no
        crossover calibration: everything expensive arrives precomputed.

        `grammar.start` must be in label-sorted edge order (the order
        `encoded.incidence` indexes and snapshots persist); `crossover`
        and `delta_budget` are the already-resolved stored values. The
        overlay starts empty — callers restore it via
        :meth:`~repro.core.delta.DeltaOverlay.load_rows`. Attribute
        assignments mirror ``__init__`` one-for-one; keep the two in sync.
        """
        self = cls.__new__(cls)
        self.grammar = grammar
        self.encoded = encoded
        self.T = grammar.table.n_terminals
        self.ranks = grammar.table.ranks
        # NT k²-tree from the flat bitsets instead of grammar.nt_generates()
        # (identical content: flat rows are label-T slots, and encode
        # guarantees rule labels are contiguous)
        if flat.nt_gen.size:
            r, c = np.nonzero(flat.nt_gen)
            self.nt_k2 = K2Tree(r, c, flat.nt_gen.shape[0], flat.nt_gen.shape[1])
        else:
            self.nt_k2 = None
        self._nt_rows = {}
        self.flat = flat
        g = grammar.start
        if g.n_edges and bool(np.any(np.diff(g.labels) < 0)):
            raise ValueError("from_state needs a label-sorted start graph")
        self._start_sorted = g
        self._sorted_labels = g.labels
        self._sorted_ranks = g.ranks()
        self._sorted_offsets = g.offsets
        self._sorted_nodes = g.nodes_flat
        self._rules = {
            lbl: [(int(r.rhs.labels[j]), r.rhs.edge_nodes(j))
                  for j in range(r.rhs.n_edges)]
            for lbl, r in grammar.rules.items()
        }
        self._edge_cache = [
            (int(g.labels[j]), g.nodes_flat[g.offsets[j]:g.offsets[j + 1]])
            for j in range(g.n_edges)
        ]
        self._arena = FrontierArena()
        if cache is _DEFAULT_CACHE:
            cache = QueryResultCache() if _env_flag("ITR_RESULT_CACHE", True) else None
        self.cache = cache
        self.crossover = int(crossover)
        self.delta = DeltaOverlay()
        self._base_edges = None if base_edges is None else int(base_edges)
        self.config = config
        self.delta_budget = None if delta_budget is None \
            else resolve_delta_budget(delta_budget)
        self.rebuild_count = int(rebuild_count)
        self._select_stats = None
        self.term_dict = None
        return self

    # -- crossover calibration -------------------------------------------
    def _calibrate_crossover(self) -> int:
        """Measured batch width at/below which the scalar worklist beats a
        frontier of the same width on a selective probe. A frontier of one
        pays numpy per-level overhead on arrays of length ~1; the worklist
        pays per-edge Python — which side wins depends on the grammar, so
        measure it on this one instead of hardcoding."""
        env = os.environ.get("ITR_QUERY_CROSSOVER", "").strip()
        if env:
            try:
                return max(0, int(env))
            except ValueError:
                pass
        g = self._start_sorted
        if g.n_edges == 0 or len(g.nodes_flat) == 0:
            return 1
        probe = int(g.nodes_flat[0])
        s1 = np.array([probe], dtype=np.int64)
        u1 = np.full(1, -1, dtype=np.int64)
        t_scalar = t_batch = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            self.query_scalar(probe, None, None)
            t_scalar = min(t_scalar, time.perf_counter() - t0)
            t0 = time.perf_counter()
            self._run_batch_unique(s1, u1, u1)
            t_batch = min(t_batch, time.perf_counter() - t0)
        if t_scalar <= 0:
            return 1
        return int(np.clip(t_batch / t_scalar, 0, _MAX_CROSSOVER))

    # -- helpers --------------------------------------------------------
    def _nt_generates(self, label: int, p: int) -> bool:
        if self.nt_k2 is None:
            return False
        row = self._nt_rows.get(label)
        if row is None:
            row = set(self.nt_k2.row(label - self.T).tolist())
            self._nt_rows[label] = row
        return p in row

    def _edge(self, j: int) -> tuple[int, np.ndarray]:
        """Sorted-start edge j (pre-reconstructed at load)."""
        return self._edge_cache[j]

    def _edges_with_label(self, label: int) -> np.ndarray:
        lo = np.searchsorted(self._sorted_labels, label, side="left")
        hi = np.searchsorted(self._sorted_labels, label, side="right")
        return np.arange(lo, hi, dtype=np.int64)

    def _row_edges(self, node: int) -> np.ndarray:
        """Edges incident to `node` via one k²-tree row decompression."""
        if node < 0 or node >= self.encoded.incidence.n_rows:
            return np.zeros(0, dtype=np.int64)
        return self.encoded.incidence.row(node)

    # -- batched seeding -------------------------------------------------
    def _seed_batch(self, s: np.ndarray, p: np.ndarray, o: np.ndarray):
        """Start-graph edge ids seeding each query; returns (qids, edge_ids)."""
        nq = len(s)
        all_qids, all_eids = [], []

        so = (s >= 0) | (o >= 0)
        so_q = np.flatnonzero(so)
        if so_q.size:
            nodes = np.where(s[so_q] >= 0, s[so_q], o[so_q])
            idx, eids = self.encoded.incidence.rows_many(nodes)
            all_qids.append(so_q[idx])
            all_eids.append(eids)

        p_only = ~so & (p >= 0)
        p_q = np.flatnonzero(p_only)
        if p_q.size:
            pq = p[p_q]
            # seed labels: the terminal P itself + every NT generating P
            seed_labels = [pq]
            owners = [p_q]
            valid = (pq >= 0) & (pq < self.T)
            if self.flat.n_rules and valid.any():
                ntmask = self.flat.nt_gen[:, np.clip(pq, 0, self.T - 1)].T  # (nq, R)
                ntmask &= valid[:, None]
                qi, ri = np.nonzero(ntmask)
                seed_labels.append(self.flat.rule_labels[ri])
                owners.append(p_q[qi])
            lbls = np.concatenate(seed_labels)
            own = np.concatenate(owners)
            lo = np.searchsorted(self._sorted_labels, lbls, side="left")
            hi = np.searchsorted(self._sorted_labels, lbls, side="right")
            counts = hi - lo
            all_eids.append(np.repeat(lo, counts) + _ragged_arange(counts))
            all_qids.append(np.repeat(own, counts))

        open_q = np.flatnonzero(~so & (p < 0))
        if open_q.size:
            E = len(self._sorted_labels)
            all_eids.append(np.tile(np.arange(E, dtype=np.int64), len(open_q)))
            all_qids.append(np.repeat(open_q, E))

        if not all_qids:
            return _EMPTY, _EMPTY
        return np.concatenate(all_qids), np.concatenate(all_eids)

    # -- batched frontier ------------------------------------------------
    def _run_batch(self, s: np.ndarray, p: np.ndarray, o: np.ndarray):
        """Cache-aware batch execution.

        Duplicate (S,P,O) patterns in the batch — common under real traffic
        and dominant for the unselective ?P?/??? patterns — are executed
        once and their results replicated per query id at the end. With a
        result cache attached the dedup is *streaming*: unique patterns are
        first looked up in the cross-request cache, only the misses run
        (through the frontier, or the scalar worklist below the crossover
        width), and their results are inserted for future batches.

        Returns result arrays (qids, labels, nodes_flat, offsets) of the
        matching terminal edges, ragged, unordered across queries. The
        arrays may share memory with cache entries — treat as read-only.
        """
        cache = self.cache
        n = len(s)
        if cache is None:
            # cache-less path stays entry-free: splitting per unique query
            # just to re-concatenate would copy every result once for
            # nothing when the batch has no duplicates
            if n > 1:  # dedup never helps a batch of one
                key = np.stack([s, p, o], axis=1)
                uniq, inv = np.unique(key, axis=0, return_inverse=True)
                if len(uniq) < n:
                    u_res = self._execute_unique(uniq[:, 0], uniq[:, 1], uniq[:, 2])
                    return _replicate_results(u_res, inv.reshape(-1))
            return self._execute_unique(s, p, o)

        # cached execution IS the view path; materialize replicates per qid
        view = self._run_batch_view(s, p, o)
        if view.n_queries == 1:  # hot serving path: alias the entry, no gather
            labels, nodes, offsets = view.entries[0]
            return np.zeros(len(labels), dtype=np.int64), labels, nodes, offsets
        return view.materialize()

    def _execute_unique(self, s: np.ndarray, p: np.ndarray, o: np.ndarray):
        """Crossover dispatch: tiny all-selective batches take the scalar
        worklist; everything else takes the level-synchronous frontier.
        Every execution path funnels through here, so this is also where
        the mutation overlay is merged in (tombstoned base edges dropped,
        matching inserted triples appended) — views, caches, and the
        sharded tier all see post-overlay results."""
        w = len(s)
        if 0 < w <= self.crossover and bool(np.all((s >= 0) | (o >= 0))):
            res = self._run_scalar_batch(s, p, o)
        else:
            res = self._run_batch_unique(s, p, o)
        if not self.delta.is_empty:
            res = self.delta.merge_batch(res, s, p, o)
        return res

    def _run_scalar_batch(self, s: np.ndarray, p: np.ndarray, o: np.ndarray):
        """Per-query worklist over a tiny batch, frontier-shaped results."""
        qids: list[int] = []
        labels: list[int] = []
        ranks: list[int] = []
        nodes: list[int] = []
        for i in range(len(s)):
            res = self.query_scalar(int(s[i]) if s[i] >= 0 else None,
                                    int(p[i]) if p[i] >= 0 else None,
                                    int(o[i]) if o[i] >= 0 else None)
            for lbl, nd in res:
                qids.append(i)
                labels.append(lbl)
                ranks.append(len(nd))
                nodes.extend(nd)
        if not labels:
            return _EMPTY, _EMPTY, _EMPTY, np.zeros(1, dtype=np.int64)
        offsets = np.concatenate([[0], np.cumsum(ranks)]).astype(np.int64)
        return (np.asarray(qids, dtype=np.int64), np.asarray(labels, dtype=np.int64),
                np.asarray(nodes, dtype=np.int64), offsets)

    def _run_batch_unique(self, s: np.ndarray, p: np.ndarray, o: np.ndarray):
        qids, eids = self._seed_batch(s, p, o)
        labels = self._sorted_labels[eids]
        ranks = self._sorted_ranks[eids]
        take = _ragged_take(self._sorted_offsets, eids, ranks)
        nodes = self._sorted_nodes[take]
        offsets = np.concatenate([[0], np.cumsum(ranks)]).astype(np.int64)

        arena = self._arena  # engine-owned result arena, reused across calls
        arena.reset()
        guard = 0
        while len(labels):
            guard += 1
            assert guard <= self.flat.n_rules + 2, "frontier expansion did not terminate"
            is_nt = labels >= self.T

            # terminals: match filter -> arena (one slice-assign per level)
            t_sel = ~is_nt
            if t_sel.any():
                tl, tn, to, (tq,) = _ragged_select(labels, nodes, offsets, t_sel, qids)
                tr = np.diff(to)
                first = _slot(tn, to, tr, 0)
                second = _slot(tn, to, tr, 1)
                sq, pq, oq = s[tq], p[tq], o[tq]
                match = (pq < 0) | (tl == pq)
                match &= (sq < 0) | ((tr >= 1) & (first == sq))
                match &= (oq < 0) | ((tr >= 2) & (second == oq))
                if match.any():
                    midx = np.flatnonzero(match)
                    mranks = tr[midx]
                    take = _ragged_take(to, midx, mranks)
                    arena.push(tq[midx], tl[midx], mranks, tn[take])

            if not is_nt.any():
                break
            # nonterminals: S/O-containment and NT[label,P] prunes as masks
            nl, nn, no, (nq,) = _ragged_select(labels, nodes, offsets, is_nt, qids)
            nr = np.diff(no)
            sq, pq, oq = s[nq], p[nq], o[nq]
            keep = np.ones(len(nl), dtype=bool)
            if (sq >= 0).any():
                keep &= (sq < 0) | _contains(nn, no, nr, sq)
            if (oq >= 0).any():
                keep &= (oq < 0) | _contains(nn, no, nr, oq)
            if (pq >= 0).any():
                valid_p = (pq >= 0) & (pq < self.T)
                gen = self.flat.generates(nl, np.clip(pq, 0, max(self.T - 1, 0)))
                keep &= (pq < 0) | (valid_p & gen)
            if not keep.any():
                break
            el, en, eo, (eq,) = _ragged_select(nl, nn, no, keep, nq)
            labels, nodes, offsets, (qids,) = self.flat.expand(el, en, eo, eq)

        return arena.finish()

    # -- main entries ----------------------------------------------------
    def query_batch_arrays(self, s_arr, p_arr, o_arr):
        """Array-native batch query. -1 (or None) marks an unbound slot.

        Returns (qids, labels, nodes_flat, offsets): matching terminal edge
        i belongs to query qids[i], has label labels[i] and node tuple
        nodes_flat[offsets[i]:offsets[i+1]]. Treat the arrays as
        READ-ONLY: with a result cache attached, single-query results
        alias live cache entries (they are marked non-writeable, so an
        in-place mutation raises instead of corrupting future answers).
        """
        s, p, o = _normalize_batch(s_arr, p_arr, o_arr)
        return self._run_batch(s, p, o)

    def query_batch_view(self, s_arr, p_arr, o_arr) -> QueryResultView:
        """Batch query returning a :class:`QueryResultView`: one shared
        entry per unique pattern, qid -> entry mapping, no per-duplicate
        materialization. This is the serving path for duplicate-heavy
        traffic (warm repeated ``?P?`` batches stop paying the replication
        cost floor); `.materialize()` recovers the flat array layout."""
        s, p, o = _normalize_batch(s_arr, p_arr, o_arr)
        return self._run_batch_view(s, p, o)

    def _run_batch_view(self, s: np.ndarray, p: np.ndarray, o: np.ndarray) -> QueryResultView:
        """Cache-aware execution producing per-unique-pattern entries.

        Same streaming-dedup discipline as `_run_batch` — look unique
        patterns up in the cross-request cache, execute only the misses,
        insert their split results — but duplicates share entries instead
        of being replicated into a flat batch.
        """
        cache = self.cache
        n = len(s)
        if n == 1:  # hot serving path: no stack/unique/split machinery
            hit = cache.lookup(s[0], p[0], o[0]) if cache is not None else None
            if hit is None:
                _, r_l, r_n, r_o = self._execute_unique(s, p, o)
                hit = _freeze_entry((r_l, r_n, r_o))
                if cache is not None:
                    cache.insert(s[0], p[0], o[0], hit)
            return QueryResultView([hit], np.zeros(1, dtype=np.int64))
        key = np.stack([s, p, o], axis=1)
        uniq, inv = np.unique(key, axis=0, return_inverse=True)
        inv = inv.reshape(-1)
        nu = len(uniq)
        entries: list = [None] * nu
        miss: list[int] = []
        for i in range(nu):
            hit = cache.lookup(uniq[i, 0], uniq[i, 1], uniq[i, 2]) \
                if cache is not None else None
            if hit is None:
                miss.append(i)
            else:
                entries[i] = hit
        if miss:
            mi = np.asarray(miss, dtype=np.int64)
            fresh = self._execute_unique(uniq[mi, 0], uniq[mi, 1], uniq[mi, 2])
            for j, entry in enumerate(_split_per_query(fresh, len(mi))):
                i = int(mi[j])
                entries[i] = _freeze_entry(entry)  # shared across duplicate
                if cache is not None:              # qids even when uncached
                    cache.insert(uniq[i, 0], uniq[i, 1], uniq[i, 2], entry)
        return QueryResultView(entries, inv)

    def query_batch(self, s_arr, p_arr, o_arr) -> list[list[tuple]]:
        """Batch query returning, per query, (label, (v0..vk)) tuples —
        identical contents to `query_scalar`/`query_oracle` per query."""
        s, p, o = _normalize_batch(s_arr, p_arr, o_arr)
        r_q, r_l, r_n, r_o = self._run_batch(s, p, o)
        results: list[list[tuple]] = [[] for _ in range(len(s))]
        order = np.argsort(r_q, kind="stable")
        for i in order:
            q = int(r_q[i])
            results[q].append(
                (int(r_l[i]), tuple(int(v) for v in r_n[r_o[i]:r_o[i + 1]])))
        return results

    def query(self, s: int | None, p: int | None, o: int | None) -> list[tuple]:
        """Return matching terminal edges as (label, (v0..vk)) tuples."""
        # cache-less selective single query below the crossover: the scalar
        # worklist already produces tuples — skip the array round-trip
        # (only while the overlay is empty: query_scalar is base-only)
        if self.cache is None and self.crossover >= 1 and self.delta.is_empty \
                and (s is not None or o is not None):
            return self.query_scalar(s, p, o)
        return self.query_batch([s], [p], [o])[0]

    def query_scalar(self, s: int | None, p: int | None, o: int | None) -> list[tuple]:
        """Seed-era per-query Python worklist over the COMPRESSED BASE only.

        Not the query path — `query`/`query_batch*` are (they batch,
        cache, and merge the mutation overlay). This survives as (a) the
        parity oracle tests compare the batched frontier against, (b) the
        pre-batching baseline benchmarks report speedups over, and (c) the
        executor the crossover dispatch routes tiny selective batches to.
        It deliberately ignores `delta`: overlay merging happens once per
        executed batch in `_execute_unique`, above this level.
        """
        if s is not None or o is not None:
            r = s if s is not None else o
            seeds = [self._edge(int(j)) for j in self._row_edges(int(r))]
        elif p is not None:
            seeds = [self._edge(int(j)) for j in self._edges_with_label(int(p))]
            for lbl in self._rules:
                if self._nt_generates(lbl, int(p)):
                    seeds.extend(self._edge(int(j)) for j in self._edges_with_label(lbl))
        else:
            g = self._start_sorted
            seeds = [(int(g.labels[j]), g.edge_nodes(j)) for j in range(g.n_edges)]

        out: list[tuple] = []
        z = list(seeds)
        while z:
            label, nodes = z.pop()
            if label >= self.T:  # nonterminal
                if s is not None and s not in nodes:
                    continue
                if o is not None and o not in nodes:
                    continue
                if p is not None and not self._nt_generates(label, p):
                    continue
                for child_label, params in self._rules[label]:
                    z.append((child_label, nodes[params]))
            else:
                if self._matches(label, nodes, s, p, o):
                    out.append((label, tuple(int(v) for v in nodes)))
        return out

    @staticmethod
    def _matches(label, nodes, s, p, o) -> bool:
        if p is not None and label != p:
            return False
        if s is not None and (len(nodes) < 1 or nodes[0] != s):
            return False
        if o is not None and (len(nodes) < 2 or nodes[1] != o):
            return False
        return True

    # -- mutation --------------------------------------------------------
    def insert_triples(self, triples) -> int:
        """Insert (s, p, o) rows; returns how many were actually new.

        Rows already visible (in the base and not tombstoned, or already
        buffered) are no-ops; rows matching a tombstone are resurrected.
        Predicates must be terminal labels of this grammar; node ids may
        extend past the base graph (the node universe grows at the next
        rebuild). Any applied mutation bumps the result cache's generation
        and, once the overlay exceeds `delta_budget`, triggers an
        automatic :meth:`rebuild`.
        """
        rows = as_triple_rows(triples)
        if len(rows):
            if int(rows[:, 1].max()) >= self.T:
                raise ValueError(
                    f"predicate ids must be < {self.T} (terminal labels); "
                    f"got {int(rows[:, 1].max())}")
            if bool(np.any(self.ranks[rows[:, 1]] != 2)):
                raise ValueError(
                    "predicates must be rank-2 terminal labels (ITR+ "
                    "node-label terminals are not triple predicates)")
            rows = rows[~self._exists_rows(rows)]
        applied = self.delta.insert_rows(rows)
        self._after_mutation(applied)
        return applied

    def delete_triples(self, triples) -> int:
        """Delete (s, p, o) rows; returns how many were actually present.

        Deleting an overlay insert un-buffers it; deleting a base triple
        tombstones it; deleting an absent triple is a no-op. Cache
        generation and the rebuild budget are handled as in
        :meth:`insert_triples`.
        """
        rows = as_triple_rows(triples)
        if len(rows):
            rows = rows[self._exists_rows(rows)]
        applied = self.delta.delete_rows(rows)
        self._after_mutation(applied)
        return applied

    def contains_triples(self, triples) -> np.ndarray:
        """bool per (s, p, o) row: is it currently visible on THIS engine
        (base minus tombstones plus inserts)? Row-aligned with the input
        (no dedup/sort) and cache-detached — the probe the sharded tier
        uses to keep partitions disjoint while a migration is in flight.
        """
        rows = np.asarray(triples, dtype=np.int64)
        if rows.size == 0:
            return np.zeros(0, dtype=bool)
        if rows.ndim != 2 or rows.shape[1] != 3:
            raise ValueError(
                f"expected (n, 3) triple rows, got shape {rows.shape}")
        return self._exists_rows(rows)

    @property
    def base_edges(self) -> int:
        """Triple count of the compressed base — the live-load signal
        rebalancing reads (`live = base_edges + inserts - tombstones`).
        Lazily decompressed once per grammar and cached: mutations only
        touch the overlay, and a rebuild swaps in a fresh (uncounted)
        engine state. Requires a pure triple grammar, like
        :meth:`base_triples`."""
        if self._base_edges is None:
            self._base_edges = len(self.base_triples())
        return self._base_edges

    def _exists_rows(self, rows: np.ndarray) -> np.ndarray:
        """bool per row: is this triple currently visible (base minus
        tombstones plus inserts)? Runs one cache-detached batch query —
        membership probes must not pollute the cross-request cache with
        entries the mutation is about to invalidate."""
        cache, self.cache = self.cache, None
        try:
            view = self._run_batch_view(rows[:, 0], rows[:, 1], rows[:, 2])
        finally:
            self.cache = cache
        return view.result_counts() > 0

    def _after_mutation(self, applied: int) -> None:
        if not applied:
            return
        if self.cache is not None:
            self.cache.bump_generation()
        if self.delta_budget is not None and self.delta.size > self.delta_budget:
            self.rebuild()

    def base_triples(self) -> np.ndarray:
        """The compressed base as (n, 3) rows — requires a pure triple
        grammar (every decompressed edge rank-2; ITR+ node-label
        hyperedges cannot be expressed as triples)."""
        g = self.grammar.decompress()
        if len(g.labels) and not bool(np.all(g.ranks() == 2)):
            raise ValueError("base graph has non-triple (rank != 2) edges; "
                             "triple mutation/rebuild needs a pure triple set")
        starts = g.offsets[:-1]
        return np.stack(
            [g.nodes_flat[starts], g.labels, g.nodes_flat[starts + 1]], axis=1) \
            if len(g.labels) else np.zeros((0, 3), dtype=np.int64)

    def current_triples(self) -> np.ndarray:
        """The logical triple set: decompressed base with the overlay
        applied (tombstones removed, inserts appended)."""
        return self.delta.apply(self.base_triples())

    # -- BGP joins -------------------------------------------------------
    def selectivity(self):
        """Join-ordering stats (per-predicate cardinalities, distinct
        subject/object counts) computed once per build from the flattened
        CSR arrays — no decompression. Lazily cached; `rebuild()` swaps
        the whole engine state, so the next call recomputes for the new
        grammar. The mutation overlay is ignored: it is bounded by the
        rebuild budget and stats only order joins, never gate answers."""
        if self._select_stats is None:
            from repro.core.bgp import SelectivityStats
            self._select_stats = SelectivityStats.from_csr(
                self._sorted_labels, self._sorted_ranks, self._sorted_nodes,
                self._sorted_offsets, self.flat, self.T)
        return self._select_stats

    def query_bgp(self, patterns):
        """Evaluate a basic graph pattern — a conjunction of triple
        patterns with shared `?var` terms, e.g. ``"?x 0 ?y . ?y 1 17"`` —
        and return a :class:`~repro.core.bgp.BGPResult`. Joins are planned
        by `selectivity()` and each step runs through `query_batch_view`,
        so sub-patterns get the batched frontier + result cache for free."""
        from repro.core.bgp import execute_bgp
        return execute_bgp(patterns, self.query_batch_view, self.selectivity())

    # -- string-term surfaces (require an attached TermDict) --------------
    def attach_term_dict(self, term_dict) -> None:
        """Attach a :class:`~repro.core.term_dict.TermDict` so this engine
        can answer string-term queries (`query_strings`,
        `query_bgp_strings`). The dictionary survives `rebuild`."""
        self.term_dict = term_dict

    def _require_term_dict(self):
        if self.term_dict is None:
            raise ValueError(
                "no term dictionary attached — call attach_term_dict() "
                "(or ingest through repro.data.ingest, which attaches one)")
        return self.term_dict

    def query_strings(self, s: str | None, p: str | None, o: str | None):
        """Answer one (S, P, O) pattern with *term strings*: each slot is a
        term or ``None`` (unbound). Terms resolve to ids once, here at the
        boundary; a bound term the dictionary has never seen short-circuits
        to ``[]`` without executing. Returns ``(s, p, o)`` term triples."""
        td = self._require_term_dict()
        from repro.core.term_dict import resolve_string_triple
        s_id, p_id, o_id, known = resolve_string_triple(td, s, p, o)
        if not known:
            return []
        out = []
        for label, nodes in self.query(s_id, p_id, o_id):
            if len(nodes) != 2:
                raise ValueError(
                    f"string queries need rank-2 edges, got rank {len(nodes)}")
            out.append((td.node_term(nodes[0]), td.pred_term(label),
                        td.node_term(nodes[1])))
        return out

    def query_bgp_strings(self, patterns) -> list[dict]:
        """`query_bgp` with string terms: patterns are (s, p, o) tuples of
        ``?var`` names / constant term strings. Unknown constants
        short-circuit to ``[]``. Returns ``[{var: term}, ...]`` binding
        rows (deterministic `BGPResult` order)."""
        td = self._require_term_dict()
        from repro.core.term_dict import bgp_result_to_terms, resolve_string_bgp
        id_patterns, pred_vars, known = resolve_string_bgp(td, patterns)
        if not known:
            return []
        return bgp_result_to_terms(td, self.query_bgp(id_patterns), pred_vars)

    def rebuild(self, config=None) -> bool:
        """Recompress base+delta into a fresh grammar and swap it in.

        The full RePair pipeline runs on the overlay-applied triple set
        with `config` (default: the config this engine was built with);
        every derived structure (succinct encoding, flattened CSR, NT
        k²-tree, arena, crossover) is rebuilt, then the engine's
        attributes are replaced in one ``__dict__`` update — the engine
        is never observable in a partially-rebuilt state *between* method
        calls. The engine is NOT thread-safe, though: a query executing
        concurrently with the swap can read attributes from both sides of
        it; serialize rebuilds against queries externally. The attached
        cache view survives the swap and gets a generation bump. Returns
        True if a rebuild ran (False when the overlay is empty).
        """
        if self.delta.is_empty:
            return False
        from repro.core.hypergraph import Hypergraph, LabelTable
        from repro.core.repair import compress

        config = config if config is not None else self.config
        triples = self.current_triples()
        n_nodes = self.grammar.start.n_nodes
        if len(triples):
            n_nodes = max(n_nodes, int(triples[:, [0, 2]].max()) + 1)
        table = LabelTable.terminals(self.grammar.table.ranks[:self.T].copy(),
                                     names=self.grammar.table.names)
        grammar, _ = compress(Hypergraph.from_triples(triples, n_nodes), table,
                              config)
        fresh = TripleQueryEngine(grammar, cache=self.cache,
                                  crossover=self.crossover,
                                  delta_budget=self.delta_budget,
                                  config=config)
        fresh._base_edges = len(triples)  # the new base IS these rows
        rebuilds = self.rebuild_count + 1
        term_dict = self.term_dict  # survives the swap, like the cache view
        # a kill here loses only memory: the swap below never touches disk,
        # so recovery replays snapshot + WAL and re-reaches this state
        crash_point("engine.rebuild")
        self.__dict__.update(fresh.__dict__)
        self.rebuild_count = rebuilds
        self.term_dict = term_dict
        if self.cache is not None:
            self.cache.bump_generation()
        return True

    # -- convenience -----------------------------------------------------
    def neighbors_out_batch(self, vs) -> list[np.ndarray]:
        """Per v: distinct objects (outgoing neighborhood), one batch.

        View-backed: duplicate vs share one distinct-node computation and
        one (read-only) result array instead of per-duplicate copies."""
        vs = self._sanitize_nodes(vs)
        view = self._run_batch_view(
            vs, np.full(len(vs), -1, np.int64), np.full(len(vs), -1, np.int64))
        per_entry = [_entry_distinct_slot(e, 1) for e in view.entries]
        return [per_entry[i] for i in view.qid_entry]

    def neighbors_in_batch(self, vs) -> list[np.ndarray]:
        """Per v: distinct subjects (incoming neighborhood), one batch."""
        vs = self._sanitize_nodes(vs)
        view = self._run_batch_view(
            np.full(len(vs), -1, np.int64), np.full(len(vs), -1, np.int64), vs)
        per_entry = [_entry_distinct_slot(e, 0) for e in view.entries]
        return [per_entry[i] for i in view.qid_entry]

    def _sanitize_nodes(self, vs) -> np.ndarray:
        """Negative node ids would read as 'unbound' — remap them to an
        out-of-range row so they yield empty results instead."""
        vs = np.asarray(vs, dtype=np.int64)
        return np.where(vs < 0, self.encoded.incidence.n_rows, vs)

    def neighbors_out(self, v: int) -> np.ndarray:
        """v ? ? -> distinct objects (outgoing neighborhood)."""
        return self.neighbors_out_batch([v])[0]

    def neighbors_in(self, v: int) -> np.ndarray:
        """? ? v -> distinct subjects (incoming neighborhood)."""
        return self.neighbors_in_batch([v])[0]


# ----------------------------------------------------------------------
def _normalize_batch(s_arr, p_arr, o_arr) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """None/-1-sentinel columns -> aligned int64 arrays with -1 = unbound."""
    if s_arr is None and p_arr is None and o_arr is None:
        raise ValueError(
            "at least one of s/p/o must be an array — with all three None the "
            "batch size is unknown (for all-unbound queries pass [None] * n)")
    cols = []
    n = max(len(c) for c in (s_arr, p_arr, o_arr) if c is not None)
    for c in (s_arr, p_arr, o_arr):
        if c is None:
            cols.append(np.full(n, -1, dtype=np.int64))
        else:
            cols.append(np.array([-1 if v is None else int(v) for v in c], dtype=np.int64)
                        if isinstance(c, (list, tuple)) else np.asarray(c, dtype=np.int64))
    s, p, o = cols
    assert len(s) == len(p) == len(o), "query columns must be aligned"
    return s, p, o


def _ragged_select(labels, nodes, offsets, mask, *payload):
    """Select edges where mask holds from a ragged (labels, nodes, offsets)
    batch; payload columns are filtered alongside."""
    idx = np.flatnonzero(mask)
    ranks = np.diff(offsets)[idx]
    take = _ragged_take(offsets, idx, ranks)
    new_offsets = np.concatenate([[0], np.cumsum(ranks)]).astype(np.int64)
    return labels[idx], nodes[take], new_offsets, tuple(c[idx] for c in payload)


def _slot(nodes, offsets, ranks, m: int) -> np.ndarray:
    """nodes[offsets[e] + m] per edge, -1 where rank <= m (no branch)."""
    pos = offsets[:-1] + m
    safe = np.minimum(pos, max(len(nodes) - 1, 0))
    vals = nodes[safe] if len(nodes) else np.full(len(ranks), -1, np.int64)
    return np.where(ranks > m, vals, -1)


def _contains(nodes, offsets, ranks, targets) -> np.ndarray:
    """Per edge e: does target[e] occur among its nodes? (segment any)"""
    n_edges = len(ranks)
    seg = np.repeat(np.arange(n_edges, dtype=np.int64), ranks)
    hits = nodes == np.repeat(targets, ranks)
    return np.bincount(seg[hits], minlength=n_edges).astype(bool)


def _split_per_query(res, nq: int) -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Split batch result arrays into per-query (labels, nodes, offsets)
    cache entries: one stable sort by query id, then slicing. Entries are
    COPIES — a view would pin the whole batch's backing buffer for the
    lifetime of the cache entry, defeating the cache's edge budget."""
    r_q, r_l, r_n, r_o = res
    order = np.argsort(r_q, kind="stable")
    labels = r_l[order]
    ranks = np.diff(r_o)[order]
    nodes = r_n[_ragged_take(r_o, order, ranks)]
    offsets = np.concatenate([[0], np.cumsum(ranks)]).astype(np.int64)
    bounds = np.concatenate([[0], np.cumsum(np.bincount(r_q, minlength=nq))]).astype(np.int64)
    out = []
    for i in range(nq):
        e0, e1 = bounds[i], bounds[i + 1]
        n0 = offsets[e0]
        out.append((labels[e0:e1].copy(), nodes[n0:offsets[e1]].copy(),
                    offsets[e0:e1 + 1] - n0))
    return out


def _replicate_results(u_res, inv: np.ndarray):
    """Map result arrays of deduped queries back to the full batch: original
    query q receives a copy of unique-query inv[q]'s results (all gathers)."""
    u_q, u_l, u_n, u_o = u_res
    n_uniq = int(inv.max()) + 1 if len(inv) else 0
    order = np.argsort(u_q, kind="stable")
    u_q, u_l = u_q[order], u_l[order]
    u_ranks = np.diff(u_o)[order]
    take = _ragged_take(u_o, order, u_ranks)
    u_n = u_n[take]
    u_o = np.concatenate([[0], np.cumsum(u_ranks)]).astype(np.int64)
    counts = np.bincount(u_q, minlength=n_uniq)
    return _replicate_sorted(u_l, u_n, u_ranks, u_o, counts, inv)


def _replicate_sorted(u_l, u_n, u_ranks, u_o, counts, inv: np.ndarray):
    """Replication core for unique results already grouped in unique-query
    order (the cache-assembly path lands here directly — no argsort, no
    pre-gather): `counts[u]` edges per unique query, `inv[q]` = the unique
    query whose results original query q receives."""
    starts = np.cumsum(counts) - counts
    out_counts = counts[inv]
    eidx = np.repeat(starts[inv], out_counts) + _ragged_arange(out_counts)
    r_q = np.repeat(np.arange(len(inv), dtype=np.int64), out_counts)
    r_l = u_l[eidx]
    ranks = u_ranks[eidx]
    r_n = u_n[_ragged_take(u_o, eidx, ranks)]
    r_o = np.concatenate([[0], np.cumsum(ranks)]).astype(np.int64)
    return r_q, r_l, r_n, r_o


def _entry_distinct_slot(entry, slot: int) -> np.ndarray:
    """Distinct node at tuple position `slot` within one result entry.
    Read-only: duplicate queries share this array, so an in-place mutation
    must fail loudly instead of corrupting the sibling's result."""
    _, nodes, offsets = entry
    ranks = np.diff(offsets)
    vals = _slot(nodes, offsets, ranks, slot)
    out = np.unique(vals[ranks > slot])
    out.flags.writeable = False
    return out


def query_oracle(graph, s, p, o) -> list[tuple]:
    """Reference: scan the uncompressed hypergraph (tests/benchmarks)."""
    out = []
    for e in range(graph.n_edges):
        label = int(graph.labels[e])
        nodes = graph.edge_nodes(e)
        if TripleQueryEngine._matches(label, nodes, s, p, o):
            out.append((label, tuple(int(v) for v in nodes)))
    return out
