"""Incidence-types, digrams, and the paper's approximate occurrence counting.

An incidence-type is ``(label a, connection-type m)`` with ``m < rank(a)``;
it is flattened to the integer id ``it_offsets[a] + m``. A digram is an
unordered pair of incidence-types, flattened to ``min(it1,it2) << 32 | max``.

Counting follows the paper exactly: a single scan builds
``c : V × IT -> N`` (a segment count), and the per-node digram score is
``min(c(v,i1), c(v,i2))`` for ``i1 != i2`` and ``c(v,i1) // 2`` for
``i1 == i2``, summed over nodes. Two implementations:

* :func:`digram_counts` — full vectorized recount (sort + segment ops);
  this is the TPU-native formulation (see `repro.kernels.digram_count`
  for the Pallas version of the pairwise stage).
* :class:`DigramCounter` — the paper's *Update Count* step: after a
  replacement only the touched nodes' contributions are recomputed.
  Tests assert it matches the full recount after every iteration.

``cap`` bounds the number of distinct incidence-types considered per node
(top-`cap` by count); nodes beyond it contribute only their most frequent
types. This is the one deviation from the paper (documented in DESIGN.md
§3); ``cap=None`` disables it and is used in the parity tests.
"""
from __future__ import annotations

import heapq
from collections import defaultdict

import numpy as np

from repro.core.hypergraph import Hypergraph, LabelTable

DIGRAM_SHIFT = 32
_MASK32 = (1 << 32) - 1


def digram_key(it1: int, it2: int) -> int:
    lo, hi = (it1, it2) if it1 <= it2 else (it2, it1)
    return (lo << DIGRAM_SHIFT) | hi


def split_digram(key: int) -> tuple[int, int]:
    return key >> DIGRAM_SHIFT, key & _MASK32


def split_it(it: int, it_offsets: np.ndarray) -> tuple[int, int]:
    """Inverse of it_offsets[label] + m -> (label, m)."""
    label = int(np.searchsorted(it_offsets, it, side="right") - 1)
    return label, int(it - it_offsets[label])


def incidences(graph: Hypergraph, table: LabelTable) -> tuple[np.ndarray, np.ndarray]:
    """(node, incidence_type_id) for every edge slot; one scan over edges."""
    ranks = graph.ranks()
    it_offsets = table.it_offsets()
    pos = np.arange(len(graph.nodes_flat), dtype=np.int64) - np.repeat(graph.offsets[:-1], ranks)
    its = np.repeat(it_offsets[graph.labels], ranks) + pos
    return graph.nodes_flat, its


def node_it_counts(graph: Hypergraph, table: LabelTable):
    """The mapping c : V × IT -> N as parallel arrays (v, it, count), sorted."""
    nodes, its = incidences(graph, table)
    n_it = int(table.it_offsets()[-1])
    key = nodes * n_it + its
    uk, cnts = np.unique(key, return_counts=True)
    return uk // n_it, uk % n_it, cnts.astype(np.int64)


def digram_counts(
    graph: Hypergraph, table: LabelTable, cap: int | None = 64
) -> tuple[np.ndarray, np.ndarray]:
    """Full recount. Returns (digram_keys, counts), counts > 0, unsorted."""
    v, it, cnts = node_it_counts(graph, table)
    if len(v) == 0:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    order = np.lexsort((-cnts, v))
    v, it, cnts = v[order], it[order], cnts[order]
    starts = np.flatnonzero(np.concatenate([[True], v[1:] != v[:-1]]))
    sizes = np.diff(np.concatenate([starts, [len(v)]]))
    if cap is not None:
        rank_in_group = np.arange(len(v)) - np.repeat(starts, sizes)
        keep = rank_in_group < cap
        v, it, cnts = v[keep], it[keep], cnts[keep]
        starts = np.flatnonzero(np.concatenate([[True], v[1:] != v[:-1]]))
        sizes = np.diff(np.concatenate([starts, [len(v)]]))

    all_keys, all_cv = [], []
    for d in np.unique(sizes):
        g_starts = starts[sizes == d]
        idx = g_starts[:, None] + np.arange(d)[None, :]
        its_m = it[idx]  # (G, d)
        cnt_m = cnts[idx]
        ii, jj = np.triu_indices(int(d))
        it1, it2 = its_m[:, ii], its_m[:, jj]
        c1, c2 = cnt_m[:, ii], cnt_m[:, jj]
        cv = np.where(ii == jj, c1 // 2, np.minimum(c1, c2))
        lo = np.minimum(it1, it2)
        hi = np.maximum(it1, it2)
        keys = (lo.astype(np.int64) << DIGRAM_SHIFT) | hi.astype(np.int64)
        mask = cv > 0
        all_keys.append(keys[mask])
        all_cv.append(cv[mask])
    if not all_keys:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    keys = np.concatenate(all_keys)
    cv = np.concatenate(all_cv)
    uk, inv = np.unique(keys, return_inverse=True)
    sums = np.zeros(len(uk), dtype=np.int64)
    np.add.at(sums, inv, cv)
    return uk, sums


class DigramCounter:
    """Incremental digram counts (paper's Count + Update Count steps).

    Maintains per-node incidence-type histograms and the global digram
    count table; replacement notifies it with the removed/added incidence
    lists and only the touched nodes are recomputed. A lazy max-heap
    serves "most frequent digram" queries.
    """

    def __init__(self, graph: Hypergraph, table: LabelTable, cap: int | None = 64):
        self.cap = cap
        self.node_hist: dict[int, dict[int, int]] = defaultdict(dict)
        self.pair_counts: dict[int, int] = defaultdict(int)
        self._heap: list[tuple[int, int]] = []
        v, it, cnts = node_it_counts(graph, table)
        starts = np.flatnonzero(np.concatenate([[True], v[1:] != v[:-1]])) if len(v) else np.zeros(0, np.int64)
        bounds = np.concatenate([starts, [len(v)]]).astype(np.int64)
        it_l, cnt_l = it.tolist(), cnts.tolist()
        v_l = v.tolist()
        for gi in range(len(starts)):
            s, e = int(bounds[gi]), int(bounds[gi + 1])
            self.node_hist[v_l[s]] = dict(zip(it_l[s:e], cnt_l[s:e]))
        for node in self.node_hist:
            self._apply_contrib(node, +1)
        for key, cnt in self.pair_counts.items():
            heapq.heappush(self._heap, (-cnt, key))

    # -- per-node contributions ------------------------------------------
    def _node_items(self, node: int):
        items = self.node_hist.get(node)
        if not items:
            return ()
        if self.cap is not None and len(items) > self.cap:
            return sorted(items.items(), key=lambda kv: -kv[1])[: self.cap]
        return tuple(items.items())

    def _apply_contrib(self, node: int, sign: int, touch: set | None = None):
        items = self._node_items(node)
        n = len(items)
        pc = self.pair_counts
        for i in range(n):
            it1, c1 = items[i]
            half = c1 // 2
            if half:
                k = (it1 << DIGRAM_SHIFT) | it1
                pc[k] += sign * half
                if touch is not None:
                    touch.add(k)
            for j in range(i + 1, n):
                it2, c2 = items[j]
                cv = c1 if c1 < c2 else c2
                if cv:
                    k = digram_key(it1, it2)
                    pc[k] += sign * cv
                    if touch is not None:
                        touch.add(k)

    # -- update after replacement ----------------------------------------
    def apply_delta(self, removed: tuple[np.ndarray, np.ndarray], added: tuple[np.ndarray, np.ndarray]):
        """removed/added: (nodes, its) incidence arrays of deleted/new edges."""
        rem_v, rem_it = removed
        add_v, add_it = added
        affected = set(np.unique(np.concatenate([rem_v, add_v])).tolist())
        touched: set = set()
        for node in affected:
            self._apply_contrib(node, -1, touched)
        # apply histogram deltas
        for v_arr, it_arr, sign in ((rem_v, rem_it, -1), (add_v, add_it, +1)):
            for v, it in zip(v_arr.tolist(), it_arr.tolist()):
                h = self.node_hist[v]
                nv = h.get(it, 0) + sign
                if nv:
                    h[it] = nv
                else:
                    h.pop(it, None)
        for node in affected:
            self._apply_contrib(node, +1, touched)
        for k in touched:
            c = self.pair_counts.get(k, 0)
            if c > 0:
                heapq.heappush(self._heap, (-c, k))
            elif c == 0:
                self.pair_counts.pop(k, None)

    def peek_pop(self, skip: set | None = None) -> tuple[int, int] | None:
        """Pop the current best (digram_key, count) OFF the heap, or None.

        The returned entry is *removed*; callers scanning candidates (e.g.
        the "savings" selection) must return it via :meth:`push_back` when
        done. Lazy-deletion max-heap: stale entries (count changed since
        push) are reinserted at their current count; digrams in `skip`
        (e.g. excluded by the max-rank bound) are dropped permanently.
        """
        while self._heap:
            negc, key = heapq.heappop(self._heap)
            cur = self.pair_counts.get(key, 0)
            if cur <= 0 or (skip is not None and key in skip):
                continue
            if cur != -negc:
                heapq.heappush(self._heap, (-cur, key))
                continue
            return key, cur
        return None

    def push_back(self, key: int, count: int) -> None:
        """Return an entry obtained from :meth:`peek_pop` to the heap."""
        heapq.heappush(self._heap, (-count, key))

    def pop_best(self, skip: set | None = None) -> tuple[int, int] | None:
        """(digram_key, count) with the highest current count, or None.
        Non-destructive: the entry stays on the heap for future queries."""
        item = self.peek_pop(skip)
        if item is not None:
            self.push_back(*item)
        return item

    def as_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        items = [(k, c) for k, c in self.pair_counts.items() if c > 0]
        if not items:
            return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
        keys = np.array([k for k, _ in items], dtype=np.int64)
        cnts = np.array([c for _, c in items], dtype=np.int64)
        order = np.argsort(keys)
        return keys[order], cnts[order]
