"""Compressed term dictionary: RDF term strings <-> dense integer ids.

The engines and services speak dense int64 ids; real RDF speaks IRIs,
blank nodes, and literals. This module is the bridge (ROADMAP item 1,
following the dictionary+index co-design of "Compressed Indexes for Fast
Search of Semantic Data" and the dictionary-encoded input assumed by the
HDT / k2-triples baselines):

* **Front-coded base** — the immutable side of a :class:`StringSpace`
  holds its terms sorted, in blocks of ``block`` strings: each block head
  is stored whole, every other term stores only ``(lcp, suffix)`` against
  its predecessor. All suffix bytes live in one contiguous ``uint8`` blob;
  the byte offset of each block head is indexed with
  :class:`~repro.core.succinct.elias_fano.EliasFano`, so ``term_to_id`` is
  a binary search over block heads plus one in-block walk
  (O(log n_blocks + block)) and ``id_to_term`` decodes exactly one block
  prefix (O(block)).
* **Append tail** — the mutable side is a plain list + dict for terms
  minted after the base was built (streaming ingestion). Ids are dense and
  stable: base terms keep their build-time ids, appended terms extend the
  id space. ``compacted()`` re-front-codes everything *without changing any
  id* — safe to run before a snapshot.
* **Two spaces** — a :class:`TermDict` holds separate node and predicate
  spaces, mirroring the engines' separate id universes.

Sorting is by Unicode code point; UTF-8 byte order preserves it, so the
in-block comparisons run on encoded bytes directly.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.succinct.elias_fano import EliasFano

DEFAULT_BLOCK = 16


def resolve_dict_block(value=None) -> int:
    """Front-coding block size: explicit argument > ``ITR_DICT_BLOCK`` >
    default 16. Values below 2 clamp to 2 (a block of 1 stores every term
    whole); unset/unparsable falls back to the default."""
    if value is not None:
        return max(2, int(value))
    raw = os.environ.get("ITR_DICT_BLOCK", "").strip()
    if not raw:
        return DEFAULT_BLOCK
    try:
        return max(2, int(raw))
    except ValueError:
        return DEFAULT_BLOCK


def _lcp(a: bytes, b: bytes) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class StringSpace:
    """One term space: front-coded immutable base + mutable append tail."""

    def __init__(self, block: int | None = None):
        self.block = resolve_dict_block(block)
        self.n_base = 0
        self._blob = np.zeros(0, dtype=np.uint8)
        self._suffix_lens = np.zeros(0, dtype=np.int32)
        self._lcps = np.zeros(0, dtype=np.int32)
        self._block_ef = EliasFano(np.zeros(0, dtype=np.int64))
        # permutations between sorted position and public id (None = the
        # build-time terms were already sorted, so position == id)
        self._ids = None
        self._pos_of_id = None
        self._extra: list[str] = []
        self._extra_index: dict[str, int] = {}

    # -- construction ---------------------------------------------------
    @classmethod
    def from_terms(cls, terms, block: int | None = None) -> "StringSpace":
        """Build with ``terms[i]`` assigned id ``i``. Terms must be unique."""
        self = cls(block)
        terms = list(terms)
        if not terms:
            return self
        order = sorted(range(len(terms)), key=lambda i: terms[i])
        for a, b in zip(order, order[1:]):
            if terms[a] == terms[b]:
                raise ValueError(f"duplicate term: {terms[a]!r}")
        self.n_base = len(terms)
        if order != list(range(len(terms))):
            self._ids = np.array(order, dtype=np.int64)
            self._pos_of_id = np.empty(len(terms), dtype=np.int64)
            self._pos_of_id[self._ids] = np.arange(len(terms), dtype=np.int64)
        chunks = []
        suffix_lens = np.empty(len(terms), dtype=np.int32)
        lcps = np.empty(len(terms), dtype=np.int32)
        prev = b""
        for pos, idx in enumerate(order):
            enc = terms[idx].encode("utf-8")
            cut = 0 if pos % self.block == 0 else _lcp(prev, enc)
            chunks.append(enc[cut:])
            lcps[pos] = cut
            suffix_lens[pos] = len(enc) - cut
            prev = enc
        self._blob = np.frombuffer(b"".join(chunks), dtype=np.uint8).copy()
        self._suffix_lens = suffix_lens
        self._lcps = lcps
        self._block_ef = self._build_block_ef()
        return self

    def _build_block_ef(self) -> EliasFano:
        if self.n_base == 0:
            return EliasFano(np.zeros(0, dtype=np.int64))
        offsets = np.zeros(self.n_base, dtype=np.int64)
        np.cumsum(self._suffix_lens[:-1], out=offsets[1:])
        heads = offsets[:: self.block]
        return EliasFano(heads, universe=int(self._blob.nbytes) + 1)

    # -- lookups --------------------------------------------------------
    def __len__(self) -> int:
        return self.n_base + len(self._extra)

    def _head(self, b: int) -> bytes:
        """Decoded bytes of block ``b``'s head term."""
        off = int(self._block_ef.access(b))
        return self._blob[off: off + int(self._suffix_lens[b * self.block])].tobytes()

    def _walk_block(self, b: int, stop_pos: int | None = None):
        """Yield ``(pos, decoded_bytes)`` for block ``b`` up to *stop_pos*."""
        start = b * self.block
        end = min(start + self.block, self.n_base)
        off = int(self._block_ef.access(b))
        cur = b""
        for pos in range(start, end):
            ln = int(self._suffix_lens[pos])
            cur = cur[: int(self._lcps[pos])] + self._blob[off: off + ln].tobytes()
            off += ln
            yield pos, cur
            if stop_pos is not None and pos >= stop_pos:
                return

    def _base_pos(self, enc: bytes) -> int | None:
        """Sorted position of an encoded term in the base, or None."""
        if self.n_base == 0:
            return None
        n_blocks = (self.n_base + self.block - 1) // self.block
        lo, hi = 0, n_blocks
        while lo < hi:  # last block whose head <= enc
            mid = (lo + hi) // 2
            if self._head(mid) <= enc:
                lo = mid + 1
            else:
                hi = mid
        b = lo - 1
        if b < 0:
            return None
        for pos, cur in self._walk_block(b):
            if cur == enc:
                return pos
            if cur > enc:
                return None
        return None

    def term_to_id(self, term: str) -> int | None:
        pos = self._base_pos(term.encode("utf-8"))
        if pos is not None:
            return int(self._ids[pos]) if self._ids is not None else pos
        return self._extra_index.get(term)

    def id_to_term(self, i: int) -> str:
        i = int(i)
        if i < 0 or i >= len(self):
            raise IndexError(f"term id {i} out of range (have {len(self)})")
        if i >= self.n_base:
            return self._extra[i - self.n_base]
        pos = int(self._pos_of_id[i]) if self._pos_of_id is not None else i
        for p, cur in self._walk_block(pos // self.block, stop_pos=pos):
            if p == pos:
                return cur.decode("utf-8")
        raise AssertionError("unreachable: position not found in its block")

    # -- appends --------------------------------------------------------
    def add_terms(self, terms) -> np.ndarray:
        """Mint ids for *terms* (existing terms keep theirs); returns the
        int64 id array, in input order."""
        out = np.empty(len(terms), dtype=np.int64)
        for j, term in enumerate(terms):
            known = self.term_to_id(term)
            if known is None:
                known = len(self)
                self._extra.append(term)
                self._extra_index[term] = known
            out[j] = known
        return out

    @property
    def n_extra(self) -> int:
        return len(self._extra)

    def terms_in_id_order(self) -> list[str]:
        return [self.id_to_term(i) for i in range(len(self))]

    def compacted(self, block: int | None = None) -> "StringSpace":
        """Everything front-coded, every id preserved."""
        return StringSpace.from_terms(
            self.terms_in_id_order(), block if block is not None else self.block
        )

    def size_in_bytes(self) -> int:
        base = (self._blob.nbytes + self._suffix_lens.nbytes + self._lcps.nbytes
                + self._block_ef.size_in_bytes())
        if self._ids is not None:
            base += self._ids.nbytes + self._pos_of_id.nbytes
        # tail: utf-8 payload plus a conservative per-entry pointer estimate
        tail = sum(len(t.encode("utf-8")) for t in self._extra) + 16 * len(self._extra)
        return base + tail

    # -- persistence ----------------------------------------------------
    def to_arrays(self):
        """``(meta, arrays)`` capturing the full state (base + tail). The
        block-offset Elias–Fano index is derived from ``suffix_lens`` on
        load, so it is not persisted."""
        extra_enc = [t.encode("utf-8") for t in self._extra]
        extra_offsets = np.zeros(len(extra_enc) + 1, dtype=np.int64)
        if extra_enc:
            np.cumsum([len(e) for e in extra_enc], out=extra_offsets[1:])
        meta = {
            "block": int(self.block),
            "n_base": int(self.n_base),
            "identity_ids": self._ids is None,
            "n_extra": len(self._extra),
        }
        arrays = {
            "blob": self._blob,
            "suffix_lens": self._suffix_lens,
            "lcps": self._lcps,
            "ids": (self._ids if self._ids is not None
                    else np.zeros(0, dtype=np.int64)),
            "extra_blob": np.frombuffer(b"".join(extra_enc), dtype=np.uint8).copy(),
            "extra_offsets": extra_offsets,
        }
        return meta, arrays

    @classmethod
    def from_arrays(cls, meta, arrays) -> "StringSpace":
        self = cls(int(meta["block"]))
        self.n_base = int(meta["n_base"])
        self._blob = np.asarray(arrays["blob"], dtype=np.uint8)
        self._suffix_lens = np.asarray(arrays["suffix_lens"], dtype=np.int32)
        self._lcps = np.asarray(arrays["lcps"], dtype=np.int32)
        if not meta["identity_ids"]:
            self._ids = np.asarray(arrays["ids"], dtype=np.int64)
            self._pos_of_id = np.empty(self.n_base, dtype=np.int64)
            self._pos_of_id[self._ids] = np.arange(self.n_base, dtype=np.int64)
        self._block_ef = self._build_block_ef()
        blob = np.asarray(arrays["extra_blob"], dtype=np.uint8).tobytes()
        offs = np.asarray(arrays["extra_offsets"], dtype=np.int64)
        self._extra = [blob[offs[j]: offs[j + 1]].decode("utf-8")
                       for j in range(int(meta["n_extra"]))]
        self._extra_index = {t: self.n_base + j for j, t in enumerate(self._extra)}
        return self


class TermDict:
    """Node + predicate term spaces with bidirectional dense-id lookup."""

    def __init__(self, nodes: StringSpace, preds: StringSpace):
        self.nodes = nodes
        self.preds = preds

    @classmethod
    def empty(cls, block: int | None = None) -> "TermDict":
        return cls(StringSpace(block), StringSpace(block))

    @classmethod
    def from_terms(cls, node_terms, pred_terms, block: int | None = None) -> "TermDict":
        return cls(StringSpace.from_terms(node_terms, block),
                   StringSpace.from_terms(pred_terms, block))

    # -- lookups --------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_preds(self) -> int:
        return len(self.preds)

    def node_id(self, term: str):
        return self.nodes.term_to_id(term)

    def pred_id(self, term: str):
        return self.preds.term_to_id(term)

    def node_term(self, i: int) -> str:
        return self.nodes.id_to_term(i)

    def pred_term(self, i: int) -> str:
        return self.preds.id_to_term(i)

    def add_node_terms(self, terms) -> np.ndarray:
        return self.nodes.add_terms(terms)

    def add_pred_terms(self, terms) -> np.ndarray:
        return self.preds.add_terms(terms)

    def compacted(self) -> "TermDict":
        return TermDict(self.nodes.compacted(), self.preds.compacted())

    def size_in_bytes(self) -> int:
        return self.nodes.size_in_bytes() + self.preds.size_in_bytes()

    def bytes_per_term(self) -> float:
        n = self.n_nodes + self.n_preds
        return self.size_in_bytes() / n if n else 0.0

    def to_arrays(self):
        """``(meta, arrays)`` over both spaces, keys prefixed ``nodes_`` /
        ``preds_`` — the persistence shape `persist/snapshot.py` writes."""
        meta, arrays = {}, {}
        for prefix, space in (("nodes", self.nodes), ("preds", self.preds)):
            m, a = space.to_arrays()
            meta[prefix] = m
            for k, v in a.items():
                arrays[f"{prefix}_{k}"] = v
        return meta, arrays

    @classmethod
    def from_arrays(cls, meta, arrays) -> "TermDict":
        spaces = {}
        for prefix in ("nodes", "preds"):
            sub = {k[len(prefix) + 1:]: v for k, v in arrays.items()
                   if k.startswith(prefix + "_")}
            spaces[prefix] = StringSpace.from_arrays(meta[prefix], sub)
        return cls(spaces["nodes"], spaces["preds"])


# -- string-pattern resolution (shared by engine + services) ------------------

def _is_var(term) -> bool:
    return isinstance(term, str) and term.startswith("?")


def resolve_string_triple(td: TermDict, s, p, o):
    """Map one string (S, P, O) pattern to ids. ``None`` stays unbound;
    any bound term unknown to the dictionary returns ``known=False`` so the
    caller can short-circuit to an empty result without touching shards.
    Returns ``(s_id, p_id, o_id, known)``."""
    ids = []
    for term, space in ((s, td.nodes), (p, td.preds), (o, td.nodes)):
        if term is None:
            ids.append(None)
            continue
        if not isinstance(term, str):
            raise TypeError(f"string pattern terms must be str or None, got {term!r}")
        i = space.term_to_id(term)
        if i is None:
            return None, None, None, False
        ids.append(i)
    return ids[0], ids[1], ids[2], True


def resolve_string_bgp(td: TermDict, patterns):
    """Map string-term BGP patterns to id-term patterns.

    *patterns* is one ``(s, p, o)`` tuple or a list of them; each term is a
    ``?var`` name or a constant term string (int ids also pass through).
    Returns ``(id_patterns, pred_vars, known)`` where *pred_vars* is the
    set of variables bound in predicate position (their binding ids decode
    through the predicate space) and ``known=False`` means some constant is
    absent from the dictionary — the BGP can have no answers.
    """
    if patterns and isinstance(patterns[0], (str, int, np.integer)):
        patterns = [patterns]
    id_patterns = []
    pred_vars, node_vars = set(), set()
    known = True
    for pat in patterns:
        if len(pat) != 3:
            raise ValueError(f"BGP patterns are (s, p, o) triples, got {pat!r}")
        out = []
        for slot, term in enumerate(pat):
            is_pred = slot == 1
            if _is_var(term):
                (pred_vars if is_pred else node_vars).add(term)
                out.append(term)
            elif isinstance(term, (int, np.integer)):
                out.append(int(term))
            elif isinstance(term, str):
                i = td.pred_id(term) if is_pred else td.node_id(term)
                if i is None:
                    known = False
                    i = 0  # placeholder; caller short-circuits on known=False
                out.append(i)
            else:
                raise TypeError(f"unsupported string BGP term: {term!r}")
        id_patterns.append(tuple(out))
    both = pred_vars & node_vars
    if both:
        raise ValueError(
            f"variable(s) {sorted(both)} appear in both predicate and "
            "subject/object positions; predicate and node id spaces are "
            "disjoint, so their bindings cannot decode to one term"
        )
    return id_patterns, pred_vars, known


def bgp_result_to_terms(td: TermDict, result, pred_vars) -> list[dict]:
    """A :class:`~repro.core.bgp.BGPResult` as ``[{var: term}, ...]`` —
    predicate-position variables decode through the predicate space."""
    decode = [td.pred_term if v in pred_vars else td.node_term
              for v in result.vars]
    return [
        {v: decode[j](row[j]) for j, v in enumerate(result.vars)}
        for row in result.rows
    ]
