"""Cross-request result cache for triple-pattern queries.

Serving traffic repeats patterns across micro-batches, not just within
one: the same hot entities are looked up by many requests, and dashboards
re-issue the same ``?P?`` scans every refresh. In-batch dedup (PR 1) only
collapses duplicates inside a single frontier; this module makes dedup
*streaming* — an LRU keyed by the (S, P, O) pattern holds each pattern's
result arrays so a repeat anywhere in the engine's lifetime is a gather,
not a frontier traversal.

Two segments share the budget accounting but evict independently:

* **general** — every pattern with S or O bound (and the open ``???``).
* **predicate** — patterns binding only P. ``?P?`` scans enumerate a
  large slice of the graph, so one burst of selective point lookups would
  otherwise evict exactly the entries that are most expensive to rebuild.
  Giving them their own LRU keeps unique-predicate-heavy traffic warm
  without riding on in-batch dedup alone.

Entries are numpy triples ``(labels, nodes_flat, offsets)`` — the same
ragged layout the batch engine produces — and are treated as immutable by
both the cache and the engine.

The cache doubles as the **shared tier** of the sharded serving stack
(``repro.serve.sharded``): entries are keyed by ``(generation, shard,
S, P, O)``, so one instance can back many per-partition engines without
cross-shard collisions. :meth:`shard_view` returns a shard-bound adapter
with the engine-facing ``lookup``/``insert``/``stats`` surface, and
:meth:`bump_generation` is the invalidation hook the mutation path leans
on: every applied ``insert_triples``/``delete_triples`` (and every
grammar rebuild) bumps exactly the mutated shard's generation, making
its entries unreachable (and purging them eagerly so they stop consuming
the edge budgets) while every other shard's warm entries survive.

Segment routing is computed from the *pattern* alone, never the shard or
generation: a shard-qualified ``?P?`` entry still lands in the predicate
segment, so bursts of point lookups from any number of shards cannot
evict it past the segment's own budget floor.

The cache is **thread-safe**: every operation (lookup, insert,
generation bump, clear) runs under one internal lock, because the shared
tier is hit concurrently by every reader thread of a
:class:`~repro.serve.sharded.ShardedTripleService` flush — the LRU
``move_to_end`` on lookup makes even reads mutating. Entries themselves
are immutable (read-only numpy arrays), so returning them outside the
lock is safe. See ``docs/CONCURRENCY.md``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

# one cached pattern: (labels, nodes_flat, offsets), offsets has len+1 rows
CacheEntry = tuple[np.ndarray, np.ndarray, np.ndarray]

_EMPTY = np.zeros(0, dtype=np.int64)
_EMPTY_OFF = np.zeros(1, dtype=np.int64)

EMPTY_ENTRY: CacheEntry = (_EMPTY, _EMPTY, _EMPTY_OFF)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    inserts: int = 0
    oversize_skips: int = 0
    predicate_hits: int = 0  # subset of `hits` served by the ?P? segment

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def snapshot(self) -> "CacheStats":
        return CacheStats(self.hits, self.misses, self.evictions,
                          self.inserts, self.oversize_skips, self.predicate_hits)


class _LruSegment:
    """One LRU: bounded by entry count and by total cached result edges."""

    def __init__(self, max_entries: int, max_edges: int):
        self.max_entries = int(max_entries)
        self.max_edges = int(max_edges)
        self.entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.edges = 0  # total result edges held

    def get(self, key: tuple) -> CacheEntry | None:
        val = self.entries.get(key)
        if val is not None:
            self.entries.move_to_end(key)
        return val

    def put(self, key: tuple, value: CacheEntry) -> int:
        """Insert (replacing any stale entry); returns evictions performed."""
        n_edges = len(value[0])
        old = self.entries.pop(key, None)
        if old is not None:
            self.edges -= len(old[0])
        self.entries[key] = value
        self.edges += n_edges
        evicted = 0
        while len(self.entries) > self.max_entries or \
                (self.edges > self.max_edges and len(self.entries) > 1):
            _, dropped = self.entries.popitem(last=False)
            self.edges -= len(dropped[0])
            evicted += 1
        return evicted

    def clear(self) -> None:
        self.entries.clear()
        self.edges = 0


@dataclass
class QueryResultCache:
    """LRU over (S, P, O) -> result arrays, with a ``?P?`` sub-cache.

    ``max_edges`` bounds the memory held per segment (in result edges, the
    unit both segments' entries are made of); a single result larger than
    ``max_entry_edges`` is never cached — one ``???`` materialization must
    not be able to flush the whole cache.
    """

    max_entries: int = 4096
    max_edges: int = 1 << 20
    predicate_entries: int = 512
    predicate_edges: int = 1 << 20
    max_entry_edges: int = 1 << 18
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self):
        self._general = _LruSegment(self.max_entries, self.max_edges)
        self._predicate = _LruSegment(self.predicate_entries, self.predicate_edges)
        self._generations: dict[int, int] = {}  # shard -> current generation
        # one lock over both segments + stats: lookups mutate LRU order, so
        # concurrent reader threads need exclusion even on the "read" path
        self._lock = threading.RLock()

    # -- routing ---------------------------------------------------------
    def _segment_key(self, s: int, p: int, o: int, shard: int):
        # segment routing depends on the PATTERN only — shard/generation
        # qualify the key but must never demote a ?P? entry to the general
        # segment (that would let point-lookup bursts evict it)
        is_pred = s < 0 and o < 0 and p >= 0
        gen = self._generations.get(shard, 0)
        return is_pred, (gen, int(shard), int(s), int(p), int(o))

    def _segment(self, is_pred: bool) -> _LruSegment:
        return self._predicate if is_pred else self._general

    # -- engine API ------------------------------------------------------
    def lookup(self, s: int, p: int, o: int, shard: int = -1) -> CacheEntry | None:
        with self._lock:
            is_pred, key = self._segment_key(s, p, o, shard)
            val = self._segment(is_pred).get(key)
            if val is None:
                self.stats.misses += 1
            else:
                self.stats.hits += 1
                if is_pred:
                    self.stats.predicate_hits += 1
            return val

    def insert(self, s: int, p: int, o: int, value: CacheEntry,
               shard: int = -1) -> None:
        for arr in value:  # entries may be returned to callers by reference:
            arr.flags.writeable = False  # fail loudly on in-place mutation
        with self._lock:
            if len(value[0]) > self.max_entry_edges:
                self.stats.oversize_skips += 1
                return
            is_pred, key = self._segment_key(s, p, o, shard)
            self.stats.evictions += self._segment(is_pred).put(key, value)
            self.stats.inserts += 1

    # -- shared-tier API -------------------------------------------------
    def shard_view(self, shard: int) -> "ShardCacheView":
        """Shard-bound adapter over this cache (the per-partition engines of
        a sharded service each get one, so they share budgets and stats
        without key collisions)."""
        return ShardCacheView(self, shard)

    def generation(self, shard: int = -1) -> int:
        with self._lock:
            return self._generations.get(shard, 0)

    def bump_generation(self, shard: int = -1) -> int:
        """Invalidate one shard's entries (the hook for graph mutability).

        The shard's generation is incremented — its old entries become
        unreachable immediately — and stale entries are purged eagerly so
        the edge budgets reflect live data, not garbage awaiting LRU churn.
        Other shards' warm entries are untouched. Returns the new generation.
        """
        with self._lock:
            gen = self._generations.get(shard, 0) + 1
            self._generations[shard] = gen
            for seg in (self._general, self._predicate):
                stale = [k for k in seg.entries if k[1] == shard and k[0] < gen]
                for k in stale:
                    seg.edges -= len(seg.entries.pop(k)[0])
            return gen

    # -- introspection ---------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._general.entries) + len(self._predicate.entries)

    @property
    def cached_edges(self) -> int:
        with self._lock:
            return self._general.edges + self._predicate.edges

    def clear(self) -> None:
        """Drop all entries (stats are kept; reassign `stats` to reset)."""
        with self._lock:
            self._general.clear()
            self._predicate.clear()


class ShardCacheView:
    """Engine-facing view of a shared :class:`QueryResultCache`, bound to
    one shard id.

    A :class:`~repro.core.query.TripleQueryEngine` only needs ``lookup`` /
    ``insert`` / ``stats`` / ``clear`` from its ``cache`` attribute; this
    adapter provides that surface while folding the shard id into every
    key, so P partition engines can share one LRU tier (one budget, one
    stats block, no collisions between shards' results for the same
    pattern).
    """

    __slots__ = ("cache", "shard")

    def __init__(self, cache: QueryResultCache, shard: int):
        self.cache = cache
        self.shard = int(shard)

    @property
    def stats(self) -> CacheStats:
        return self.cache.stats  # shared across all views

    def lookup(self, s: int, p: int, o: int) -> CacheEntry | None:
        return self.cache.lookup(s, p, o, shard=self.shard)

    def insert(self, s: int, p: int, o: int, value: CacheEntry) -> None:
        self.cache.insert(s, p, o, value, shard=self.shard)

    def generation(self) -> int:
        """This shard's current cache generation (mutations bump it; a
        warm entry from an older generation is unreachable by design)."""
        return self.cache.generation(self.shard)

    def bump_generation(self) -> int:
        return self.cache.bump_generation(self.shard)

    def clear(self) -> None:
        """Clears the WHOLE shared tier (benchmark hook); use
        :meth:`bump_generation` to invalidate just this shard."""
        self.cache.clear()

    def __len__(self) -> int:
        return len(self.cache)
