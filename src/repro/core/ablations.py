"""Ablations of ITR design decisions (paper §Handling loops, digram choice).

`loop_rule_transform` implements the alternative the paper REJECTS: every
loop edge (duplicate nodes, e.g. B(10,10,11)) is replaced by a fresh rule
`C -> B(0,0,1)` over deduplicated parameters (Figure 1 (c)/(e)). The paper
keeps loops and lets the index-function absorb the duplicates; the
benchmark shows the extra rules do not beat the index-function encoding —
reproducing the paper's measured conclusion.
"""
from __future__ import annotations

import numpy as np

from repro.core.grammar import Grammar, Rule
from repro.core.hypergraph import Hypergraph


def loop_rule_transform(grammar: Grammar) -> Grammar:
    """Replace every loop edge in the start graph by a loop-eliminating rule.

    Loop edges sharing (label, index-function signature) share one rule.
    Returns a new grammar whose start graph has no duplicate-node edges.
    """
    table = grammar.table.copy()
    start = grammar.start
    rules = dict(grammar.rules)

    new_labels, new_flat, new_ranks = [], [], []
    keep_mask = np.ones(start.n_edges, dtype=bool)
    loop_rules: dict[tuple, int] = {}

    for e in range(start.n_edges):
        nodes = start.edge_nodes(e)
        zeta = np.unique(nodes)
        if len(zeta) == len(nodes):
            continue  # not a loop
        pi = tuple(int(x) for x in np.searchsorted(zeta, nodes))
        key = (int(start.labels[e]), pi)
        if key not in loop_rules:
            lbl = table.add_label(len(zeta))
            rhs = Hypergraph.from_edges(len(zeta), [(key[0], list(pi))])
            rules[lbl] = Rule(lbl, len(zeta), rhs)
            loop_rules[key] = lbl
        keep_mask[e] = False
        new_labels.append(loop_rules[key])
        new_flat.append(zeta.astype(np.int64))
        new_ranks.append(len(zeta))

    if not new_labels:
        return Grammar(table, start.copy(), rules)
    kept = start.select(keep_mask)
    new_start = kept.concat_edges(
        np.asarray(new_labels, dtype=np.int64),
        np.concatenate(new_flat),
        np.asarray(new_ranks, dtype=np.int64),
    )
    out = Grammar(table, new_start, rules)
    return out._renumber()
