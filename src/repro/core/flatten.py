"""Flattened (array-native) view of an SL-HR grammar for batch queries.

The per-query worklist in the seed engine walked `grammar.rules` dicts and
Python lists — one attribute lookup and one tuple allocation per expanded
edge. For batch execution we flatten everything once, at engine build time,
into CSR arrays so that expanding *every* nonterminal edge of a frontier is
a handful of `np.repeat`/`np.take` gathers:

  rule_index[label]          -> dense rule slot (-1 for terminals/absent)
  edge_offsets[r:r+2]        -> slice of rule r's RHS edges
  edge_labels[j]             -> child label of RHS edge j
  param_offsets[j:j+2]       -> slice of edge j's parameter positions
  params[...]                -> indices into the parent edge's node tuple
  nt_gen[r, p]               -> rule r (transitively) emits terminal p
                                (the paper's NT matrix, decompressed from
                                its k²-tree into a dense bitset at build)

`expand` is the level-synchronous step: given a ragged frontier of
nonterminal edges (labels / nodes_flat / offsets) plus any number of
aligned per-edge payload columns (query ids), it instantiates all RHS
edges of all frontier edges in one shot.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.grammar import Grammar


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    """[0..counts[0]), [0..counts[1]), ... concatenated."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(counts)
    return np.arange(total, dtype=np.int64) - np.repeat(ends - counts, counts)


def concat_ragged(chunks) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Concatenate ragged ``(labels, nodes_flat, offsets)`` triples into one.

    This is the scatter-gather merge primitive: each shard answers a
    pattern with its own ragged result chunk, and the union over disjoint
    partitions is exactly their concatenation (no dedup needed). Also used
    by :meth:`repro.core.query.QueryResultView.materialize` to rebuild the
    flat batch layout from shared per-pattern entries.
    """
    chunks = [c for c in chunks if len(c[0])]
    if not chunks:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
                np.zeros(1, dtype=np.int64))
    if len(chunks) == 1:
        return chunks[0]
    labels = np.concatenate([c[0] for c in chunks])
    nodes = np.concatenate([c[1] for c in chunks])
    ranks = np.concatenate([np.diff(c[2]) for c in chunks])
    offsets = np.concatenate([[0], np.cumsum(ranks)]).astype(np.int64)
    return labels, nodes, offsets


class FrontierArena:
    """Preallocated, geometrically-grown buffers for ragged result batches.

    The frontier loop emits one chunk of matched terminal edges per level;
    collecting them in Python lists and concatenating at the end reallocates
    and copies every level's chunks again on every query. The arena instead
    slice-assigns each chunk into place, doubling capacity only when a chunk
    overflows it, so steady-state accumulation is one memcpy per level and
    zero allocations. One arena lives on the engine and is reused by every
    `query_batch_arrays` call; `finish()` returns right-sized copies, so the
    returned result arrays never alias the next query's scratch space.
    """

    def __init__(self, edge_cap: int = 1024, node_cap: int = 4096):
        self._q = np.empty(max(edge_cap, 1), dtype=np.int64)
        self._l = np.empty(max(edge_cap, 1), dtype=np.int64)
        self._r = np.empty(max(edge_cap, 1), dtype=np.int64)
        self._n = np.empty(max(node_cap, 1), dtype=np.int64)
        self.n_edges = 0
        self.n_nodes = 0

    @property
    def edge_capacity(self) -> int:
        return len(self._q)

    @property
    def node_capacity(self) -> int:
        return len(self._n)

    def reset(self) -> None:
        self.n_edges = 0
        self.n_nodes = 0

    @staticmethod
    def _grown(buf: np.ndarray, live: int, needed: int) -> np.ndarray:
        cap = len(buf)
        while cap < needed:
            cap *= 2
        new = np.empty(cap, dtype=np.int64)
        new[:live] = buf[:live]
        return new

    def push(self, qids: np.ndarray, labels: np.ndarray, ranks: np.ndarray,
             nodes: np.ndarray) -> None:
        """Append one chunk of edges (qids/labels/ranks aligned, nodes flat)."""
        ne = self.n_edges + len(labels)
        nn = self.n_nodes + len(nodes)
        if ne > len(self._q):
            self._q = self._grown(self._q, self.n_edges, ne)
            self._l = self._grown(self._l, self.n_edges, ne)
            self._r = self._grown(self._r, self.n_edges, ne)
        if nn > len(self._n):
            self._n = self._grown(self._n, self.n_nodes, nn)
        self._q[self.n_edges:ne] = qids
        self._l[self.n_edges:ne] = labels
        self._r[self.n_edges:ne] = ranks
        self._n[self.n_nodes:nn] = nodes
        self.n_edges = ne
        self.n_nodes = nn

    def finish(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Hand back the accumulated results and reset for the next query.

        Returns ``(qids, labels, nodes_flat, offsets)`` as right-sized
        COPIES of the live region — callers may hold them indefinitely
        (e.g. as cache entries) without pinning the arena's scratch
        buffers or racing the next `query_batch_arrays` call, which
        reuses this arena from offset zero.
        """
        ne, nn = self.n_edges, self.n_nodes
        offsets = np.zeros(ne + 1, dtype=np.int64)
        np.cumsum(self._r[:ne], out=offsets[1:])
        out = (self._q[:ne].copy(), self._l[:ne].copy(),
               self._n[:nn].copy(), offsets)
        self.reset()
        return out


@dataclass
class FlatGrammar:
    """CSR arrays for rule bodies + NT-reachability bitsets."""

    n_terminals: int
    rule_index: np.ndarray     # int64[n_labels]: label -> rule slot or -1
    rule_labels: np.ndarray    # int64[n_rules]: slot -> label
    edge_offsets: np.ndarray   # int64[n_rules+1]
    edge_labels: np.ndarray    # int64[total_rhs_edges]
    edge_ranks: np.ndarray     # int64[total_rhs_edges]
    param_offsets: np.ndarray  # int64[total_rhs_edges+1]
    params: np.ndarray         # int64[total_params]
    nt_gen: np.ndarray         # bool[n_rules, n_terminals]

    @property
    def n_rules(self) -> int:
        return len(self.rule_labels)

    # ------------------------------------------------------------------
    @classmethod
    def from_grammar(cls, grammar: Grammar) -> "FlatGrammar":
        T = grammar.table.n_terminals
        n_labels = grammar.table.n_labels
        rule_labels = np.array(sorted(grammar.rules.keys()), dtype=np.int64)
        rule_index = np.full(n_labels, -1, dtype=np.int64)
        rule_index[rule_labels] = np.arange(len(rule_labels))

        e_labels, e_ranks, p_chunks, e_counts = [], [], [], []
        for lbl in rule_labels:
            rhs = grammar.rules[int(lbl)].rhs
            e_counts.append(rhs.n_edges)
            e_labels.append(rhs.labels)
            e_ranks.append(rhs.ranks())
            p_chunks.append(rhs.nodes_flat)
        if rule_labels.size:
            edge_labels = np.concatenate(e_labels).astype(np.int64)
            edge_ranks = np.concatenate(e_ranks).astype(np.int64)
            params = np.concatenate(p_chunks).astype(np.int64)
        else:
            edge_labels = edge_ranks = params = np.zeros(0, dtype=np.int64)
        edge_offsets = np.concatenate([[0], np.cumsum(e_counts)]).astype(np.int64) \
            if e_counts else np.zeros(1, dtype=np.int64)
        param_offsets = np.concatenate([[0], np.cumsum(edge_ranks)]).astype(np.int64)

        # NT matrix rows, in rule-slot order (nt_generates rows are label-T)
        gen = grammar.nt_generates()
        if rule_labels.size:
            nt_gen = gen[rule_labels - T]
        else:
            nt_gen = np.zeros((0, T), dtype=bool)
        return cls(T, rule_index, rule_labels, edge_offsets, edge_labels,
                   edge_ranks, param_offsets, params, nt_gen)

    # ------------------------------------------------------------------
    _ARRAY_FIELDS = ("rule_index", "rule_labels", "edge_offsets",
                     "edge_labels", "edge_ranks", "param_offsets", "params",
                     "nt_gen")

    def to_arrays(self) -> dict[str, np.ndarray]:
        """The CSR as a flat name -> array dict — the snapshot wire form.
        (`nt_gen` stays 2-D bool; ``.npy`` serializes it natively.)"""
        return {name: getattr(self, name) for name in self._ARRAY_FIELDS}

    @classmethod
    def from_arrays(cls, n_terminals: int,
                    arrays: dict[str, np.ndarray]) -> "FlatGrammar":
        """Inverse of :meth:`to_arrays` — rebuilds the flat view with no
        per-rule Python loop (arrays may be read-only mmap views)."""
        return cls(int(n_terminals),
                   *(np.asarray(arrays[name]) for name in cls._ARRAY_FIELDS))

    # ------------------------------------------------------------------
    def generates(self, labels: np.ndarray, preds: np.ndarray) -> np.ndarray:
        """Vectorized NT[label, p]: does each (nonterminal label, terminal p)
        pair hold? Labels must be nonterminals with a rule slot."""
        if self.nt_gen.size == 0:
            return np.zeros(len(labels), dtype=bool)
        return self.nt_gen[self.rule_index[labels], preds]

    def expand(
        self,
        labels: np.ndarray,
        nodes_flat: np.ndarray,
        offsets: np.ndarray,
        *payload: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, tuple[np.ndarray, ...]]:
        """One frontier level: instantiate every RHS edge of every NT edge.

        labels/nodes_flat/offsets describe a ragged batch of nonterminal
        edges; payload columns (e.g. query ids) are carried to the children.
        Returns (child_labels, child_nodes_flat, child_offsets, payloads).
        """
        slots = self.rule_index[labels]
        counts = self.edge_offsets[slots + 1] - self.edge_offsets[slots]
        parent = np.repeat(np.arange(len(labels), dtype=np.int64), counts)
        # RHS edge id of each child: rule's edge slice, ragged
        rei = np.repeat(self.edge_offsets[slots], counts) + _ragged_arange(counts)
        child_labels = self.edge_labels[rei]
        child_ranks = self.edge_ranks[rei]
        # child node tuple = parent_nodes[rhs params]; all flat gathers
        pidx = np.repeat(self.param_offsets[rei], child_ranks) + _ragged_arange(child_ranks)
        parent_starts = offsets[:-1][parent]
        child_nodes = nodes_flat[np.repeat(parent_starts, child_ranks) + self.params[pidx]]
        child_offsets = np.concatenate([[0], np.cumsum(child_ranks)]).astype(np.int64)
        out_payload = tuple(col[parent] for col in payload)
        return child_labels, child_nodes, child_offsets, out_payload
