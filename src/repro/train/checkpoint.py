"""Sharded, atomic, async checkpointing with elastic restore.

Layout: <dir>/step_<N>/ containing manifest.json (pytree structure, shapes,
dtypes) + one .npy per leaf. Writes go to step_<N>.tmp and are committed
with a single atomic rename — a crash mid-save never corrupts the previous
checkpoint. `AsyncCheckpointer` snapshots to host (device_get) on the
training thread and writes on a worker thread, overlapping I/O with compute.

Restore is *elastic*: leaves are loaded as host numpy and re-placed under
whatever mesh/sharding the restoring job uses (`device_put` with the target
sharding), so a checkpoint taken on N chips restores onto M.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

# npy-serializable stand-ins for ml_dtypes types
_EXOTIC_VIEWS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        items.append((path, leaf))
    return items, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Blocking atomic save; returns the committed path."""
    items, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(items):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        dtype_name = str(arr.dtype)
        if dtype_name in _EXOTIC_VIEWS:  # bf16/fp8: npy can't serialize them
            np.save(os.path.join(tmp, fname), arr.view(_EXOTIC_VIEWS[dtype_name]))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape), "dtype": dtype_name}
        )
    manifest["treedef"] = jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int | None = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Load (tree, step). If `shardings` (a matching pytree of Sharding or
    PartitionSpec-resolved shardings) is given, leaves are placed with it —
    the elastic-remesh path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    leaves = []
    for rec in manifest["leaves"]:
        arr = np.load(os.path.join(path, rec["file"]))
        if rec["dtype"] in _EXOTIC_VIEWS:
            import ml_dtypes

            arr = arr.view(getattr(ml_dtypes, rec["dtype"]))
        leaves.append(arr)
    # rebuild the tree from paths (robust to treedef serialization versions)
    tree = _unflatten_from_paths([(rec["path"], leaf) for rec, leaf in
                                  zip(manifest["leaves"], leaves)])
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["step"]


def _unflatten_from_paths(items):
    root: dict = {}
    for path, leaf in items:
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return _listify(root)


def _listify(node):
    """Convert dicts whose keys are 0..n-1 back into lists."""
    if not isinstance(node, dict):
        return node
    out = {k: _listify(v) for k, v in node.items()}
    keys = list(out.keys())
    if keys and all(k.isdigit() for k in keys):
        idx = sorted(int(k) for k in keys)
        if idx == list(range(len(idx))):
            return [out[str(i)] for i in idx]
    return out


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training; keeps the last `keep` steps."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
