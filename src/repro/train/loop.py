"""The training loop: jitted step + checkpoint/restart + straggler hooks +
gradient compression, composed into a `Trainer` that the examples and the
multi-node driver (`repro.launch.train`) share.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax

from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.compression import CompressionConfig, compress_gradients, init_residual
from repro.train.fault_tolerance import FailureInjector, StragglerDetector
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    keep_checkpoints: int = 3
    log_every: int = 10
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    compression: CompressionConfig = field(default_factory=CompressionConfig)


class Trainer:
    """loss_fn(params, batch) -> scalar; data: iterator of batch pytrees."""

    def __init__(self, loss_fn: Callable, params: Any, cfg: TrainerConfig,
                 failure_injector: FailureInjector | None = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.params = params
        self.opt_state = init_opt_state(params, cfg.opt)
        self.residual = init_residual(params) if cfg.compression.codec != "none" else None
        self.step = 0
        self.straggler = StragglerDetector()
        self.injector = failure_injector
        self.ckpt = AsyncCheckpointer(cfg.checkpoint_dir, cfg.keep_checkpoints) \
            if cfg.checkpoint_dir else None
        self.metrics_log: list[dict] = []

        comp = cfg.compression

        def train_step(params, opt_state, residual, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            if comp.codec != "none":
                grads, residual, _ = compress_gradients(grads, residual, comp)
            params, opt_state, metrics = adamw_update(params, grads, opt_state, cfg.opt)
            return params, opt_state, residual, loss, metrics

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------- restart
    def maybe_restore(self) -> bool:
        if not self.cfg.checkpoint_dir:
            return False
        step = latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return False
        state, step = restore_checkpoint(self.cfg.checkpoint_dir, step)
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        if self.residual is not None and "residual" in state:
            self.residual = state["residual"]
        self.step = step
        return True

    def _save(self):
        if self.ckpt is None:
            return
        state = {"params": self.params, "opt_state": self.opt_state}
        if self.residual is not None:
            state["residual"] = self.residual
        self.ckpt.save(self.step, state)

    # ---------------------------------------------------------------- run
    def run(self, data: Iterator, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.cfg.total_steps
        end = self.step + steps
        while self.step < end:
            if self.injector and self.injector.failures_at(self.step):
                # failure event: drain in-flight checkpoint I/O so recovery
                # sees the last *committed* step, then surface the failure
                if self.ckpt is not None:
                    self.ckpt.wait()
                raise WorkerFailure(self.step)
            batch = next(data)
            t0 = time.monotonic()
            self.params, self.opt_state, self.residual, loss, metrics = self._step_fn(
                self.params, self.opt_state, self.residual, batch)
            loss = float(loss)
            dt = time.monotonic() - t0
            self.straggler.observe(0, dt)
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == end:
                rec = {"step": self.step, "loss": loss, "sec_per_step": dt,
                       "lr": float(metrics["lr"]), "grad_norm": float(metrics["grad_norm"])}
                self.metrics_log.append(rec)
            if self.cfg.checkpoint_dir and self.step % self.cfg.checkpoint_every == 0:
                self._save()
        if self.ckpt is not None:
            self._save()
            self.ckpt.wait()
        return self.metrics_log


class WorkerFailure(RuntimeError):
    def __init__(self, step):
        super().__init__(f"injected worker failure at step {step}")
        self.step = step
