"""Fault-tolerance runtime: straggler detection, failure handling policy,
elastic re-meshing. Hardware failures cannot be triggered in this container,
so the *mechanisms* are real (and unit-tested) while failure events are
injected through the `FailureInjector` used by tests and examples.

At 1000+ nodes the operative loop is: detect (heartbeat timeout or step-time
EWMA outlier) -> decide (evict / wait) -> recover (restore latest atomic
checkpoint onto the surviving device set via elastic restore, skipping
consumed data deterministically).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    """EWMA step-time monitor: a worker whose step time exceeds
    `threshold` × the fleet EWMA is flagged (then evicted or rebalanced)."""

    alpha: float = 0.1
    threshold: float = 2.0
    warmup_steps: int = 5
    _ewma: float | None = None
    _steps: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, worker_id: int, step_time_s: float) -> bool:
        self._steps += 1
        if self._ewma is None:
            self._ewma = step_time_s
            return False
        is_straggler = (
            self._steps > self.warmup_steps
            and step_time_s > self.threshold * self._ewma
        )
        if is_straggler:
            self.flagged.append((worker_id, step_time_s, self._ewma))
        else:
            # stragglers do not poison the fleet estimate
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time_s
        return is_straggler


@dataclass
class HeartbeatMonitor:
    """Tracks worker liveness; `dead_workers` after `timeout_s` of silence."""

    timeout_s: float = 30.0
    _last: dict = field(default_factory=dict)

    def beat(self, worker_id: int, now: float | None = None):
        self._last[worker_id] = time.monotonic() if now is None else now

    def dead_workers(self, now: float | None = None) -> list:
        now = time.monotonic() if now is None else now
        return [w for w, t in self._last.items() if now - t > self.timeout_s]


class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail worker w at
    step s. Stands in for the hardware events we cannot produce here."""

    def __init__(self, schedule: dict[int, list[int]] | None = None):
        self.schedule = schedule or {}

    def failures_at(self, step: int) -> list[int]:
        return self.schedule.get(step, [])


@dataclass
class ElasticPlan:
    """Re-mesh decision after losing nodes: the largest (data × model) grid
    that fits the survivors while keeping the model axis intact (TP degree
    must not change without resharding params — which elastic restore also
    supports, but keeping it avoids a full reshard)."""

    n_devices: int
    model_axis: int

    def new_mesh_shape(self) -> tuple[int, int]:
        data = self.n_devices // self.model_axis
        if data < 1:
            raise RuntimeError(
                f"cannot keep model={self.model_axis} with {self.n_devices} devices"
            )
        return (data, self.model_axis)


def data_skip_offset(step: int, global_batch: int) -> int:
    """Deterministic data-stream offset after restore: consumed samples are
    skipped exactly, so a restart never re-trains on seen batches."""
    return step * global_batch
