"""Training substrate: optimizer, loop, checkpoint/restart, fault tolerance,
gradient compression."""
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, schedule
from repro.train.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.compression import (
    CompressionConfig,
    compress_gradients,
    compress_int8,
    compress_topk,
    init_residual,
)
from repro.train.fault_tolerance import (
    ElasticPlan,
    FailureInjector,
    HeartbeatMonitor,
    StragglerDetector,
    data_skip_offset,
)
from repro.train.loop import Trainer, TrainerConfig, WorkerFailure
