"""Optimizers (pure JAX pytree transforms) with mixed-precision discipline:
bf16 compute params, fp32 master + moments (ZeRO-1-shardable — see
repro.distributed.sharding.zero1_spec).

`adamw` for dense params; `mixed_dlrm` applies plain SGD to embedding
tables (MLPerf practice — Adam moments on 178M-row tables would double the
table memory) and AdamW to the MLPs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    sgd_paths: tuple = ()  # path substrings optimized with plain SGD (no moments)


def _is_sgd(path: str, cfg: AdamWConfig) -> bool:
    return any(s in path for s in cfg.sgd_paths)


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k.key) if hasattr(k, "key") else str(k.idx) for k in kp) for kp, _ in flat]
    return paths, [v for _, v in flat], treedef


def schedule(step, cfg: AdamWConfig):
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params, cfg: AdamWConfig):
    paths, leaves, treedef = _paths(params)

    def moments(path, p):
        if _is_sgd(path, cfg):
            return None
        return jnp.zeros(p.shape, jnp.float32)

    # copy=True: for fp32 params astype would alias the param buffer, and an
    # aliased master breaks donation (same buffer donated twice)
    master = [jnp.array(p, dtype=jnp.float32, copy=True) for p in leaves]
    m = [moments(pa, p) for pa, p in zip(paths, leaves)]
    v = [moments(pa, p) for pa, p in zip(paths, leaves)]
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree_util.tree_unflatten(treedef, master),
        "m": jax.tree_util.tree_unflatten(treedef, m),
        "v": jax.tree_util.tree_unflatten(treedef, v),
    }


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    paths, g_leaves, treedef = _paths(grads)
    p_leaves = jax.tree.leaves(params)
    mast_leaves = jax.tree.leaves(opt_state["master"])
    m_leaves, _ = jax.tree_util.tree_flatten(opt_state["m"], is_leaf=lambda x: x is None)
    v_leaves, _ = jax.tree_util.tree_flatten(opt_state["v"], is_leaf=lambda x: x is None)

    new_p, new_mast, new_m, new_v = [], [], [], []
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    for path, p, g, mast, m, v in zip(paths, p_leaves, g_leaves, mast_leaves, m_leaves, v_leaves):
        gf = g.astype(jnp.float32) * clip
        if m is None:  # plain SGD leaf (embedding tables)
            upd = lr * gf
            nm, nv = None, None
        else:
            nm = cfg.b1 * m + (1 - cfg.b1) * gf
            nv = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
            upd = lr * (nm / b1c) / (jnp.sqrt(nv / b2c) + cfg.eps)
            if cfg.weight_decay and p.ndim >= 2:
                upd = upd + lr * cfg.weight_decay * mast
        nmast = mast - upd
        new_mast.append(nmast)
        new_p.append(nmast.astype(p.dtype))
        new_m.append(nm)
        new_v.append(nv)

    unf = partial(jax.tree_util.tree_unflatten, treedef)
    return (
        unf(new_p),
        {"step": step, "master": unf(new_mast), "m": unf(new_m), "v": unf(new_v)},
        {"lr": lr, "grad_norm": gnorm},
    )
