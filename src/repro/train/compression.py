"""Gradient compression with error feedback (distributed-optimization layer).

Two codecs, both pytree transforms applied before the gradient all-reduce:

* int8 quantization: per-tensor absmax scale, ~4× wire reduction vs fp32;
* top-k sparsification: keep the k largest-magnitude entries per tensor
  (values + int32 indices), Deep-Gradient-Compression style.

Both maintain an *error-feedback* residual (the un-transmitted remainder is
added back into the next step's gradient), which is what keeps convergence
intact — tests train a quadratic and a tiny transformer to verify.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------- int8
def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_int8(grads, residual):
    """Returns (wire_tree {q, scale}, decoded_grads, new_residual)."""

    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        dec = dequantize_int8(q, scale)
        return (q, scale), dec, gf - dec

    out = jax.tree.map(leaf, grads, residual)
    wire = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    dec = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    res = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    return wire, dec, res


# ---------------------------------------------------------------- top-k
def compress_topk(grads, residual, frac=0.01):
    """Keep ceil(frac·n) largest-|g| entries per tensor, with error feedback."""

    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        k = max(int(flat.shape[0] * frac), 1)
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        sel = flat[idx]
        dec = jnp.zeros_like(flat).at[idx].set(sel).reshape(gf.shape)
        return (sel, idx.astype(jnp.int32)), dec, gf - dec

    out = jax.tree.map(leaf, grads, residual)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    wire = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    dec = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    res = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return wire, dec, res


def wire_bytes(wire_tree) -> int:
    """Serialized size of the compressed representation."""
    total = 0
    for leaf in jax.tree.leaves(wire_tree):
        total += leaf.size * leaf.dtype.itemsize
    return total


@dataclass(frozen=True)
class CompressionConfig:
    codec: str = "none"      # none | int8 | topk
    topk_frac: float = 0.01


def compress_gradients(grads, residual, cfg: CompressionConfig):
    """Dispatch; returns (decoded_grads, new_residual, wire_bytes_factor)."""
    if cfg.codec == "none":
        return grads, residual, 1.0
    if cfg.codec == "int8":
        _, dec, res = compress_int8(grads, residual)
        return dec, res, 0.25
    if cfg.codec == "topk":
        _, dec, res = compress_topk(grads, residual, cfg.topk_frac)
        return dec, res, cfg.topk_frac * 2  # values + indices
    raise ValueError(cfg.codec)
