"""Graph partitioning for sharded triple serving.

A partition plan splits a triple set into P disjoint subgraphs, each of
which is compressed into its own grammar and served by its own
:class:`~repro.core.query.TripleQueryEngine`. Because the partitions are
disjoint, the exact answer to any (S,P,O) pattern is the concatenation of
the per-shard answers — no dedup, no overlap bookkeeping.

Two strategies, each with a different "owning" axis that lets the router
send selective patterns to a single shard:

* ``predicate_hash`` — vertical partitioning by predicate, the
  k²-Triples axis: every triple with predicate p lives in shard
  ``hash(p) % P``. Any pattern binding P is owned by one shard; patterns
  leaving P free (``S??``, ``??O``, ``???``) scatter-gather.
* ``node_range`` — horizontal partitioning by subject: node ids
  ``[0, n_nodes)`` are cut into P contiguous ranges and a triple lives in
  the shard owning its subject. Any pattern binding S is owned; ``?P?``,
  ``??O`` and ``???`` scatter-gather.

Plans are pure numpy and stateless — routing a million-pattern batch is
one vectorized pass (`route_batch`).

Placement and routing share one rule, which is what keeps the tier
correct under mutation: `route_triples` sends an inserted/deleted (s, p,
o) row to exactly the shard whose engine would answer an owned pattern
for it, so a shard's delta overlay never holds a triple another shard
would be asked about. Ids outside the planned universe (e.g. subjects
past the last `node_range` boundary, from inserts that grow the graph)
clip onto the last shard — again identically for placement and queries.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

STRATEGIES = ("predicate_hash", "node_range")

# Knuth multiplicative hash over 32-bit predicate ids: consecutive
# predicate ids (the common dictionary encoding) spread across shards
# instead of striping p % P onto correlated workloads.
_HASH_MULT = np.uint64(2654435761)
_HASH_MASK = np.uint64(0xFFFFFFFF)


def _hash_pred(p, n_shards: int):
    h = (np.asarray(p).astype(np.uint64) * _HASH_MULT) & _HASH_MASK
    return (h % np.uint64(n_shards)).astype(np.int64)


@dataclass(frozen=True)
class PartitionPlan:
    """Deterministic triple -> shard assignment + pattern routing rules.

    `pred_assign` (predicate_hash only) overrides the hash with an
    explicit predicate -> shard map — the form online rebalancing
    produces when it re-packs predicate groups onto shards by observed
    load. Absent, the Knuth hash is the assignment; either way placement
    and routing read the same function, so the build/mutation invariant
    survives a re-cut.
    """

    strategy: str
    n_shards: int
    n_nodes: int
    n_preds: int
    boundaries: np.ndarray | None = None   # node_range: int64[n_shards+1]
    pred_assign: np.ndarray | None = None  # predicate_hash: int64[n_preds]

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown partition strategy {self.strategy!r}; "
                f"expected one of {STRATEGIES}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.strategy == "node_range":
            b = self.boundaries
            if b is None or len(b) != self.n_shards + 1:
                raise ValueError(
                    "node_range plans need boundaries of length n_shards+1 "
                    "(build plans with make_plan)")
            if np.any(np.diff(b) < 0):
                raise ValueError("node_range boundaries must be non-decreasing")
        if self.pred_assign is not None:
            if self.strategy != "predicate_hash":
                raise ValueError(
                    "pred_assign only applies to predicate_hash plans")
            pa = np.asarray(self.pred_assign)
            if pa.shape != (self.n_preds,):
                raise ValueError(
                    f"pred_assign must have shape ({self.n_preds},), "
                    f"got {pa.shape}")
            if len(pa) and (int(pa.min()) < 0 or int(pa.max()) >= self.n_shards):
                raise ValueError(
                    f"pred_assign values must be shard ids in "
                    f"[0, {self.n_shards})")

    # -- triple placement ------------------------------------------------
    def triple_shards(self, triples: np.ndarray) -> np.ndarray:
        """Owning shard per (s, p, o) row."""
        triples = np.asarray(triples, dtype=np.int64)
        if self.strategy == "predicate_hash":
            return self._pred_shard(triples[:, 1])
        return self._node_shard(triples[:, 0])

    def _node_shard(self, nodes) -> np.ndarray:
        idx = np.searchsorted(self.boundaries, np.asarray(nodes, dtype=np.int64),
                              side="right") - 1
        return np.clip(idx, 0, self.n_shards - 1)

    def _pred_shard(self, preds) -> np.ndarray:
        preds = np.asarray(preds, dtype=np.int64)
        if self.pred_assign is not None:
            # ids at/above n_preds clamp onto the last predicate's shard —
            # the same clamp placement uses, so routing can never disagree
            return np.asarray(self.pred_assign, dtype=np.int64)[
                np.clip(preds, 0, self.n_preds - 1)]
        return _hash_pred(preds, self.n_shards)

    def pred_assignment(self) -> np.ndarray:
        """Explicit predicate -> shard map of a predicate_hash plan (the
        stored re-cut assignment, or the hash evaluated per predicate)."""
        if self.strategy != "predicate_hash":
            raise ValueError("pred_assignment() needs a predicate_hash plan")
        return self._pred_shard(np.arange(self.n_preds, dtype=np.int64)).copy()

    def route_triples(self, triples: np.ndarray) -> np.ndarray:
        """Owning shard per mutation row — the write-path routing surface.

        Identical to :meth:`triple_shards` (one placement rule for build
        and mutation, by construction), but validates the ``(n, 3)``
        shape so a malformed mutation batch fails here instead of
        landing rows on arbitrary shards. Zero-row batches of any empty
        shape (``[]`` included) are a valid no-op.
        """
        triples = np.asarray(triples, dtype=np.int64)
        if triples.size == 0:
            return np.zeros(0, dtype=np.int64)
        if triples.ndim != 2 or triples.shape[1] != 3:
            raise ValueError(
                f"expected (n, 3) triple rows, got shape {triples.shape}")
        return self.triple_shards(triples)

    # -- pattern routing -------------------------------------------------
    def route(self, s: int, p: int, o: int) -> int:
        """Owning shard of one pattern (-1 = scatter-gather all shards).

        Unbound slots are encoded as -1, matching the engine's batch
        convention.
        """
        if self.strategy == "predicate_hash":
            return int(self._pred_shard(p)) if p >= 0 else -1
        return int(self._node_shard(s)) if s >= 0 else -1

    def route_batch(self, s: np.ndarray, p: np.ndarray, o: np.ndarray) -> np.ndarray:
        """Vectorized `route` over aligned pattern columns (zero-length
        columns return an empty route array)."""
        s = np.asarray(s, dtype=np.int64)
        p = np.asarray(p, dtype=np.int64)
        if self.strategy == "predicate_hash":
            return np.where(p >= 0, self._pred_shard(np.maximum(p, 0)), -1)
        return np.where(s >= 0, self._node_shard(np.maximum(s, 0)), -1)


def plan_to_dict(plan: PartitionPlan) -> dict:
    """JSON-serializable form of a plan — the wire format service
    snapshots and WAL plan records use. Inverse: :func:`plan_from_dict`."""
    d = {"strategy": plan.strategy, "n_shards": int(plan.n_shards),
         "n_nodes": int(plan.n_nodes), "n_preds": int(plan.n_preds)}
    if plan.boundaries is not None:
        d["boundaries"] = [int(v) for v in plan.boundaries]
    if plan.pred_assign is not None:
        d["pred_assign"] = [int(v) for v in plan.pred_assign]
    return d


def plan_from_dict(d: dict) -> PartitionPlan:
    """Rebuild a plan from :func:`plan_to_dict` output (validation reruns
    in ``PartitionPlan.__post_init__``, so a corrupted record fails loudly
    instead of mis-routing rows)."""
    boundaries = d.get("boundaries")
    pred_assign = d.get("pred_assign")
    return PartitionPlan(
        d["strategy"], int(d["n_shards"]), int(d["n_nodes"]),
        int(d["n_preds"]),
        boundaries=None if boundaries is None
        else np.asarray(boundaries, dtype=np.int64),
        pred_assign=None if pred_assign is None
        else np.asarray(pred_assign, dtype=np.int64))


def plans_equal(a: PartitionPlan, b: PartitionPlan) -> bool:
    """Semantic plan equality (same routing for every row and pattern).

    Plans that round-trip through the WAL (`plan_from_dict`) are new
    objects, so identity alone cannot compare a primary's plan with a
    replica's replayed copy; the serialized form is the routing state."""
    return a is b or plan_to_dict(a) == plan_to_dict(b)


def make_plan(strategy: str, n_shards: int, n_nodes: int, n_preds: int,
              triples: np.ndarray | None = None) -> PartitionPlan:
    """Build a partition plan.

    `node_range` boundaries default to even node-id ranges; when `triples`
    are provided they are placed at subject-distribution *quantiles*
    instead — real RDF subjects concentrate in a prefix of the id space
    (objects hold literals/values), and even id ranges would park every
    triple in shard 0. Duplicate boundaries (skewed hot subjects) simply
    leave the middle shards empty.
    """
    if n_shards < 1:  # validate before boundary math (PartitionPlan re-checks)
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    boundaries = None
    if strategy == "node_range":
        hi = max(n_nodes, n_shards)
        subjects = np.asarray(triples, dtype=np.int64)[:, 0] \
            if triples is not None and len(triples) else None
        boundaries = subject_quantile_boundaries(subjects, n_shards, hi)
    return PartitionPlan(strategy, int(n_shards), int(n_nodes), int(n_preds),
                         boundaries)


def subject_quantile_boundaries(subjects, n_shards: int, hi: int) -> np.ndarray:
    """node_range boundary (re-)cut from an observed subject distribution.

    Boundaries sit at subject quantiles so each shard owns roughly the
    same number of triples regardless of how subjects cluster in the id
    space; with no observations (``subjects=None`` or empty) the cut
    falls back to even id ranges. This is the single boundary function —
    `make_plan` uses it at build and `repro.distributed.rebalance`
    re-runs it on live subjects to re-cut a skewed tier online.
    """
    if subjects is not None:
        subjects = np.asarray(subjects, dtype=np.int64)
    if subjects is None or len(subjects) == 0:
        boundaries = np.floor(
            np.arange(n_shards + 1) * hi / n_shards).astype(np.int64)
        boundaries[0], boundaries[-1] = 0, hi
        return boundaries
    subs = np.sort(subjects)
    cuts = subs[np.minimum(
        np.arange(1, n_shards) * len(subs) // n_shards, len(subs) - 1)]
    boundaries = np.concatenate([[0], np.maximum(cuts, 1), [hi]]).astype(np.int64)
    return np.maximum.accumulate(boundaries)


def diff_plans(old: PartitionPlan, new: PartitionPlan,
               triples: np.ndarray) -> np.ndarray:
    """Boolean mask per triple row: does its owning shard change from
    `old` to `new`? Zero rows diff to an empty mask. Diagnostic helper
    for inspecting a re-cut; the actual migration moves are computed in
    `repro.distributed.rebalance.plan_rebalance` against each engine's
    *physical* rows (robust to ids that clamped onto a boundary shard),
    not against where `old` says they should be."""
    triples = np.asarray(triples, dtype=np.int64).reshape(-1, 3)
    if len(triples) == 0:
        return np.zeros(0, dtype=bool)
    return old.triple_shards(triples) != new.triple_shards(triples)


def partition_triples(triples: np.ndarray, plan: PartitionPlan) -> list[np.ndarray]:
    """Split (n, 3) triples into per-shard subsets (global node/pred ids are
    kept, so shard results are directly mergeable and comparable)."""
    triples = np.asarray(triples, dtype=np.int64)
    if len(triples) == 0:
        return [triples[:0] for _ in range(plan.n_shards)]
    shards = plan.triple_shards(triples)
    order = np.argsort(shards, kind="stable")
    sorted_triples = triples[order]
    counts = np.bincount(shards, minlength=plan.n_shards)
    bounds = np.concatenate([[0], np.cumsum(counts)])
    return [sorted_triples[bounds[k]:bounds[k + 1]] for k in range(plan.n_shards)]
