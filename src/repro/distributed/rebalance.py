"""Online shard rebalancing: skew detection, plan re-cut, incremental moves.

Mutations skew a partitioned tier: `PartitionPlan` routes rows where the
*build-time* cut put their axis value, so a burst of inserts landing on one
shard keeps degrading every scatter-gather flush until something re-cuts
the plan. This module is that something, in three pieces the serving tier
(`repro.serve.sharded`) wires together:

* **Skew detection** — :func:`live_shard_edges` reads each engine's live
  triple count (compressed base + overlay inserts - tombstones, O(1) per
  shard after one lazy decompression) and :func:`measure_skew` condenses
  the counts to a ``max/mean`` ratio. The mutation path compares it to the
  env-tunable trigger ``ITR_REBALANCE_SKEW``
  (:func:`resolve_rebalance_skew`).
* **Plan re-cut** — :func:`plan_rebalance` computes a successor
  `PartitionPlan` from the live data: `node_range` boundaries are
  re-quantiled from the observed subjects
  (`partition.subject_quantile_boundaries`, the same function the build
  used) and `predicate_hash` groups are re-packed onto shards by greedy
  LPT over live per-predicate counts (:func:`balance_predicates`,
  materialized as the plan's explicit ``pred_assign``).
* **Migration bookkeeping** — :class:`RebalancePlan` carries the pending
  per-``(src, dst)`` row moves. Rows leave their source shard via
  tombstones and arrive through the destination's delta overlay (the
  PR 4 mutation path — no new write machinery), in bounded batches so a
  migration can be spread across serving calls. `discard` removes rows
  the caller deleted mid-flight so a later batch can never resurrect
  them.

Exactness across the whole dance rests on two invariants the service
enforces: every migrated batch applies arrive-then-depart inside one call
(partitions stay disjoint at every public boundary), and while moves are
pending the router only trusts single-shard ownership for patterns both
the outgoing and incoming plans route to the same shard — anything an
ownership change is still moving gets scatter-gathered, which is exact on
disjoint partitions no matter which side each row currently sits on.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.delta import rows_in
from repro.distributed.partition import (
    PartitionPlan,
    subject_quantile_boundaries,
)

_EMPTY_ROWS = np.zeros((0, 3), dtype=np.int64)

# default auto-trigger: rebalance when one shard holds 4x the mean load
DEFAULT_REBALANCE_SKEW = 4.0

# ITR_REBALANCE_SKEW spellings that disable the mutation-path auto-trigger
_OFF_SPELLINGS = ("off", "none", "never", "disable", "disabled")


def resolve_rebalance_skew(value=None) -> float | None:
    """Resolve the auto-rebalance trigger to ``float`` (skew threshold,
    >= 1) or ``None`` (auto-rebalancing disabled; only explicit
    ``rebalance(force=True)`` re-cuts).

    ``value=None`` reads ``ITR_REBALANCE_SKEW``: a number > 0 is the
    ``max/mean`` live-edge ratio at/above which the mutation path starts
    a rebalance (values below 1 clamp to 1.0 — skew can't go lower);
    ``off``/``none``/``never`` or any value <= 0 disables the trigger;
    unset/empty/unparsable falls back to :data:`DEFAULT_REBALANCE_SKEW`.
    An explicit `value` follows the same rules without touching the
    environment.
    """
    if value is None:
        env = os.environ.get("ITR_REBALANCE_SKEW", "").strip().lower()
        if not env:
            return DEFAULT_REBALANCE_SKEW
        if env in _OFF_SPELLINGS:
            return None
        try:
            value = float(env)
        except ValueError:
            return DEFAULT_REBALANCE_SKEW
    value = float(value)
    if value <= 0:
        return None
    return max(value, 1.0)


def live_shard_edges(engines) -> np.ndarray:
    """Live triple count per shard: compressed base edges plus overlay
    inserts minus tombstones — the quantity mutation actually skews.
    O(1) per shard (`TripleQueryEngine.base_edges` is cached), so the
    mutation path can afford it on every batch."""
    return np.array(
        [e.base_edges + e.delta.n_inserts - e.delta.n_tombstones
         for e in engines], dtype=np.int64)


def measure_skew(counts) -> float:
    """``max/mean`` shard load: 1.0 is perfectly balanced, ``n_shards``
    means one shard holds everything. Degenerate tiers (single shard,
    nothing stored) read as balanced."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if len(counts) <= 1 or total <= 0:
        return 1.0
    return float(int(counts.max()) * len(counts) / total)


def balance_predicates(pred_counts, n_shards: int, prior) -> np.ndarray:
    """Greedy LPT re-pack of predicate groups onto shards.

    Predicates in descending live-count order land on the least-loaded
    shard; ties keep the `prior` owner and zero-count predicates keep it
    unconditionally, so idle ids never churn shards for nothing. LPT's
    4/3 bound is plenty here — the floor is set by the largest single
    predicate, which vertical partitioning cannot split by construction.
    """
    counts = np.asarray(pred_counts, dtype=np.int64)
    assign = np.asarray(prior, dtype=np.int64).copy()
    if assign.shape != counts.shape:
        raise ValueError(
            f"prior assignment shape {assign.shape} != counts {counts.shape}")
    load = np.zeros(n_shards, dtype=np.int64)
    for p in np.argsort(-counts, kind="stable"):
        p = int(p)
        if counts[p] == 0:
            continue
        k = int(np.argmin(load))
        if load[int(assign[p])] == load[k]:
            k = int(assign[p])
        assign[p] = k
        load[k] += counts[p]
    return assign


class RebalancePlan:
    """One online re-cut: the successor plan plus pending migration rows.

    Built by :func:`plan_rebalance`; consumed by the sharded service. The
    contract the service relies on:

    * every pending row is physically on its ``src`` shard until a
      `take` batch migrates it (or `discard` drops it because the caller
      mutated it mid-flight);
    * `take` consumes moves front-to-back in bounded batches, splitting a
      move when the cap lands inside it, so migration cost per serving
      call is bounded by ``max_rows``;
    * once `done`, the successor `new_plan` routes exactly where every
      row now lives.
    """

    def __init__(self, old_plan: PartitionPlan, new_plan: PartitionPlan,
                 moves: list):
        self.old_plan = old_plan
        self.new_plan = new_plan
        self._moves = [
            (int(src), int(dst), np.asarray(rows, dtype=np.int64))
            for src, dst, rows in moves if len(rows)]
        #: rows this re-cut set out to migrate (fixed at plan time)
        self.total_rows = sum(len(r) for _, _, r in self._moves)

    @property
    def pending_rows(self) -> int:
        """Rows still waiting to migrate."""
        return sum(len(r) for _, _, r in self._moves)

    @property
    def done(self) -> bool:
        return not self._moves

    def pending_moves(self) -> list:
        """Snapshot of the pending (src, dst, rows) moves (read-only)."""
        return list(self._moves)

    def discard(self, rows: np.ndarray) -> int:
        """Drop `rows` from the pending moves; returns how many pending
        rows were dropped. The service calls this for every row deleted
        while the migration is in flight — a later `take` batch must not
        re-deliver (resurrect) a triple the user has since removed."""
        rows = np.asarray(rows, dtype=np.int64).reshape(-1, 3)
        if len(rows) == 0:
            return 0
        dropped = 0
        kept = []
        for src, dst, pending in self._moves:
            hit = rows_in(pending, rows)
            if hit.any():
                dropped += int(hit.sum())
                pending = pending[~hit]
            if len(pending):
                kept.append((src, dst, pending))
        self._moves = kept
        return dropped

    def take(self, max_rows: int | None = None) -> list:
        """Pop up to `max_rows` pending rows (``None`` = everything) as
        a list of (src, dst, rows) batches ready to apply."""
        budget = self.pending_rows if max_rows is None else max(0, int(max_rows))
        out = []
        while self._moves and budget > 0:
            src, dst, pending = self._moves[0]
            if len(pending) <= budget:
                out.append((src, dst, pending))
                budget -= len(pending)
                self._moves.pop(0)
            else:
                out.append((src, dst, pending[:budget]))
                self._moves[0] = (src, dst, pending[budget:])
                budget = 0
        return out


def plan_rebalance(plan: PartitionPlan, engines) -> RebalancePlan:
    """Re-cut `plan` from the engines' live triples; compute the moves.

    `node_range` re-quantiles the boundaries from the observed subjects;
    `predicate_hash` re-packs predicate groups by live count (LPT) into
    an explicit ``pred_assign``. The node universe grows to cover any
    inserted ids. Moves are computed against each engine's *actual* rows
    (overlay applied), not against where the old plan says they should
    be, so the migration is exact even for rows whose ids clamped onto a
    boundary shard.
    """
    per_shard = [e.current_triples() for e in engines]
    rows = np.concatenate(per_shard) if per_shard else _EMPTY_ROWS
    n_nodes = plan.n_nodes
    if len(rows):
        n_nodes = max(n_nodes, int(rows[:, [0, 2]].max()) + 1)
    if plan.strategy == "node_range":
        hi = max(n_nodes, plan.n_shards)
        boundaries = subject_quantile_boundaries(
            rows[:, 0] if len(rows) else None, plan.n_shards, hi)
        new_plan = PartitionPlan("node_range", plan.n_shards, n_nodes,
                                 plan.n_preds, boundaries=boundaries)
    else:
        counts = np.bincount(rows[:, 1], minlength=plan.n_preds) \
            if len(rows) else np.zeros(plan.n_preds, dtype=np.int64)
        assign = balance_predicates(counts, plan.n_shards,
                                    prior=plan.pred_assignment())
        new_plan = PartitionPlan("predicate_hash", plan.n_shards, n_nodes,
                                 plan.n_preds, pred_assign=assign)
    return RebalancePlan(plan, new_plan, _moves_for(new_plan, per_shard))


def _moves_for(new_plan: PartitionPlan, per_shard: list) -> list:
    """(src, dst, rows) moves turning the given physical placement into
    `new_plan`'s: for each shard, the rows the successor plan routes
    elsewhere."""
    moves = []
    for k, shard_rows in enumerate(per_shard):
        if len(shard_rows) == 0:
            continue
        dst = new_plan.triple_shards(shard_rows)
        for d in np.unique(dst):
            d = int(d)
            if d != k:
                moves.append((k, d, shard_rows[dst == d]))
    return moves


def migration_moves(new_plan: PartitionPlan, engines) -> list:
    """Pending (src, dst, rows) moves for an ALREADY-DECIDED successor
    plan, diffed against the engines' current physical rows.

    This is the WAL-replay / snapshot-restore path: a journaled
    ``rebalance_begin`` record (and a snapshot taken mid-migration) stores
    only the successor plan — the rows still waiting to move are exactly
    the ones the recovered engines hold on shards the plan routes
    elsewhere, so recomputing the diff reconstructs the in-flight
    migration without persisting row lists. Deterministic given engine
    state: replaying the same mutation history yields the same moves.
    """
    return _moves_for(new_plan, [e.current_triples() for e in engines])
