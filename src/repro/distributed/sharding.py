"""Logical-axis sharding: models annotate activations/params with *logical*
names; this module maps them onto the physical mesh axes actually present.

Rules follow the production layout in DESIGN.md §6:
  batch/tokens -> data (x pod)    heads/ffn/experts/vocab -> model (TP/EP)
  kv sequence  -> data (split-K decode)     edges/rows -> data+model (flattened)

`shard` silently drops an axis when the dimension is not divisible by the
mesh axis size (GSPMD would pad; we prefer explicit fallbacks) or when no
mesh is active (single-device tests/smoke runs are unconstrained).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# logical name -> physical mesh axis (or tuple for flattened sharding)
LOGICAL_RULES: dict[str, object] = {
    "batch": ("pod", "data"),   # pod axis (if present) is outer data-parallel
    "seq": None,                # sequence kept unsharded in-layer by default
    "kv_seq": ("data", "model"),  # long-context decode: split-K over free axes
    "seq_model": "model",       # context parallelism: train/prefill q-seq over TP
    "model_dim": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_cap": "data",       # MoE capacity dim over data — without this the
                                # expert GEMMs replicate across the data axis
                                # (§Perf B2: 16× redundant expert compute)
    "vocab": "model",
    "edges": ("data", "model"),  # GNN edge lists over the whole pod
    "nodes": ("data", "model"),
    "table_rows": ("data", "model"),  # DLRM embedding rows over all chips
    "wide_batch": ("pod", "data", "model"),  # DLRM batch over every chip
    "fields": None,
}


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not m.shape:
        return None
    return m


def logical_spec(names: tuple, shape: tuple | None = None) -> P:
    """Map logical dim names to a PartitionSpec valid on the active mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return P()
    axes_present = dict(mesh.shape)
    spec = []
    used = set()
    for i, name in enumerate(names):
        if name is None:
            spec.append(None)
            continue
        phys = LOGICAL_RULES.get(name)
        if phys is None:
            spec.append(None)
            continue
        cand = tuple(a for a in ((phys,) if isinstance(phys, str) else phys) if a in axes_present and a not in used)
        if not cand:
            spec.append(None)
            continue
        total = 1
        for a in cand:
            total *= axes_present[a]
        if shape is not None and shape[i] % total != 0:
            # try the largest single axis that divides instead
            cand = tuple(a for a in cand if shape[i] % axes_present[a] == 0)[:1]
            if not cand:
                spec.append(None)
                continue
        used.update(cand)
        spec.append(cand if len(cand) > 1 else cand[0])
    return P(*spec)


def shard(x, names: tuple):
    """with_sharding_constraint by logical names; no-op without a mesh.

    If no logical name maps to a usable mesh axis the constraint is skipped
    entirely (an all-None spec would *force replication*, which is worse
    than letting GSPMD propagate)."""
    if _active_mesh() is None:
        return x
    assert len(names) == x.ndim, f"{names} vs rank {x.ndim}"
    spec = logical_spec(names, x.shape)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------- params
def param_spec(path: str, shape: tuple) -> P:
    """Sharding spec for a parameter from its pytree path (TP layout)."""
    names = _param_logical(path, shape)
    return logical_spec(names, shape)


def _param_logical(path: str, shape: tuple) -> tuple:
    p = path.lower()
    n = len(shape)

    def pad(tail: tuple) -> tuple:
        return (None,) * (n - len(tail)) + tail  # leading dims = stacked layers

    if "embed" in p or "vocab_in" in p:
        return pad(("vocab", None)) if n >= 2 else (None,) * n
    if "w_vocab" in p or "lm_head" in p:
        return pad((None, "vocab"))
    if "table" in p:
        # hybrid table placement (production DLRM practice): small tables
        # replicate (local lookups, cheap dense grads); big tables row-shard.
        # Sharding a 3-row table 256 ways turns every lookup into a
        # full-batch masked all-reduce — measured 534 MB/step (§Perf C4).
        if n >= 2 and shape[0] < 100_000:
            return (None,) * n
        return pad(("table_rows", None))
    if "experts" in p or "w_gate_e" in p or "w_up_e" in p or "w_down_e" in p:
        if n >= 3:
            return pad(("experts", None, None))
        return (None,) * n
    if any(k in p for k in ("wq", "wk", "wv", "w_qkv")):
        return pad((None, "heads")) if n >= 2 else (None,) * n
    if "wo" in p:
        return pad(("heads", None)) if n >= 2 else (None,) * n
    if any(k in p for k in ("w_gate", "w_up", "w_in")):
        return pad((None, "ffn")) if n >= 2 else (None,) * n
    if any(k in p for k in ("w_down", "w_out")):
        return pad(("ffn", None)) if n >= 2 else (None,) * n
    return (None,) * n


def zero1_spec(spec: P, shape: tuple) -> P:
    """Optimizer-state spec: params spec + 'data' on the first free divisible
    axis (ZeRO-1 partitioning of m/v/master over the data axis)."""
    mesh = _active_mesh()
    if mesh is None:
        return spec
    axes_present = dict(mesh.shape)
    if "data" not in axes_present:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    flat_used = set()
    for e in entries:
        for a in (e,) if isinstance(e, str) else (e or ()):
            flat_used.add(a)
    if "data" in flat_used:
        return spec
    d = axes_present["data"]
    for i, e in enumerate(entries):
        if e is None and shape[i] % d == 0:
            entries[i] = "data"
            return P(*entries)
        if e is not None:
            # try composing data with the existing axis on this dim
            axes = (e,) if isinstance(e, str) else tuple(e)
            total = d
            for a in axes:
                total *= axes_present[a]
            if shape[i] % total == 0:
                entries[i] = tuple(axes) + ("data",)
                return P(*entries)
    return spec
