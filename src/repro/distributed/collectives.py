"""Collective-aware aggregation primitives.

`partitioned_segment_sum` is the §Perf-D GNN optimization: when the data
layer partitions edges by receiver block (receivers sorted, shard s owning
node rows [s·rows, (s+1)·rows)), message aggregation becomes a *local*
scatter per shard via shard_map — the plain `jax.ops.segment_sum` over
edge-sharded messages otherwise all-reduces the full (N, d) node aggregate
on every layer (measured: ~96 × 48 MB tuples per gatedgcn/minibatch step).

Contract: edges must be receiver-block-partitioned to match the flattened
mesh (the GraphStore/NeighborSampler `partition_edges` helpers provide
this); `validate_partitioning` checks it host-side in tests/loaders.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _shard_map(fn, **kwargs):
    """`jax.shard_map` (jax >= 0.6) or its experimental predecessor."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, **kwargs)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, **kwargs)


def _active_mesh():
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        try:
            m = get()
        except Exception:
            m = None
        if m is not None and m.shape:
            return m
    # pre-0.5 jax: the ambient mesh lives in the `with mesh:` context
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def partitioned_segment_sum(msgs, receivers, n_nodes: int):
    """Σ_{e: recv[e]=r} msgs[e] -> (n_nodes, d); local scatter per shard.

    Falls back to jax.ops.segment_sum when no mesh is active or shapes
    don't divide the device grid.
    """
    mesh = _active_mesh()
    if mesh is None:
        return jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)
    if msgs.ndim == 1:  # e.g. degree counts
        return partitioned_segment_sum(msgs[:, None], receivers, n_nodes)[:, 0]
    axes = tuple(mesh.axis_names)
    n_dev = 1
    for a in axes:
        n_dev *= dict(mesh.shape)[a]
    E = msgs.shape[0]
    if E % n_dev or n_nodes % n_dev or n_dev == 1:
        return jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)
    rows = n_nodes // n_dev

    sizes = dict(mesh.shape)

    def local(m_loc, r_loc):
        # linear device index over the flattened axes (row-major, matching
        # P(axes) edge sharding); built per-axis so it works on every jax
        # version — axis_index over a tuple of names is a newer addition
        dev = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            dev = dev * sizes[a] + jax.lax.axis_index(a)
        lo = dev * rows
        rel = r_loc - lo
        # contract: 0 <= rel < rows (receiver-partitioned edges); clip is a
        # safety net so violations corrupt locally instead of crashing
        rel = jnp.clip(rel, 0, rows - 1)
        return jax.ops.segment_sum(m_loc, rel, num_segments=rows)

    spec_e = P(axes) if len(axes) > 1 else P(axes[0])
    out = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(spec_e[0], None), spec_e),
        out_specs=P(spec_e[0], None),
    )(msgs, receivers)
    return out


def partition_edges(senders: np.ndarray, receivers: np.ndarray, n_nodes: int,
                    n_shards: int):
    """Host-side loader step: sort edges by receiver block and pad each
    shard's slice to equal length (padding edges point at the shard's first
    row with a sentinel sender -1 the caller masks).

    Returns (senders', receivers', pad_mask) each of length
    n_shards * max_per_shard.
    """
    rows = (n_nodes + n_shards - 1) // n_shards
    blk = receivers // rows
    order = np.argsort(blk, kind="stable")
    senders, receivers, blk = senders[order], receivers[order], blk[order]
    counts = np.bincount(blk, minlength=n_shards)
    per = int(counts.max()) if len(counts) else 1
    out_s = np.full(n_shards * per, -1, dtype=np.int64)
    out_r = np.empty(n_shards * per, dtype=np.int64)
    for s in range(n_shards):
        out_r[s * per:(s + 1) * per] = s * rows  # pad targets: shard-local row
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(len(receivers)) - np.repeat(starts, counts)
    idx = blk * per + pos
    out_s[idx] = senders
    out_r[idx] = receivers
    return out_s, out_r, out_s >= 0


def validate_partitioning(receivers: np.ndarray, n_nodes: int, n_shards: int) -> bool:
    rows = (n_nodes + n_shards - 1) // n_shards
    per = len(receivers) // n_shards
    blk = np.asarray(receivers) // rows
    want = np.repeat(np.arange(n_shards), per)
    return bool((blk == want).all())
