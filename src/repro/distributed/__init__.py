"""Distribution utilities: logical-axis sharding rules, collective helpers,
graph partitioning for the sharded serving tier, and online rebalancing."""
from repro.distributed.partition import (
    STRATEGIES,
    PartitionPlan,
    diff_plans,
    make_plan,
    partition_triples,
    subject_quantile_boundaries,
)
from repro.distributed.rebalance import (
    RebalancePlan,
    balance_predicates,
    live_shard_edges,
    measure_skew,
    plan_rebalance,
    resolve_rebalance_skew,
)
from repro.distributed.sharding import (
    LOGICAL_RULES,
    logical_spec,
    shard,
    param_spec,
    zero1_spec,
)

__all__ = [
    "LOGICAL_RULES",
    "logical_spec",
    "shard",
    "param_spec",
    "zero1_spec",
    "STRATEGIES",
    "PartitionPlan",
    "diff_plans",
    "make_plan",
    "partition_triples",
    "subject_quantile_boundaries",
    "RebalancePlan",
    "balance_predicates",
    "live_shard_edges",
    "measure_skew",
    "plan_rebalance",
    "resolve_rebalance_skew",
]
