"""Distribution utilities: logical-axis sharding rules and collective helpers."""
from repro.distributed.sharding import (
    LOGICAL_RULES,
    logical_spec,
    shard,
    param_spec,
    zero1_spec,
)

__all__ = ["LOGICAL_RULES", "logical_spec", "shard", "param_spec", "zero1_spec"]
