"""Distribution utilities: logical-axis sharding rules, collective helpers,
and graph partitioning for the sharded serving tier."""
from repro.distributed.partition import (
    STRATEGIES,
    PartitionPlan,
    make_plan,
    partition_triples,
)
from repro.distributed.sharding import (
    LOGICAL_RULES,
    logical_spec,
    shard,
    param_spec,
    zero1_spec,
)

__all__ = [
    "LOGICAL_RULES",
    "logical_spec",
    "shard",
    "param_spec",
    "zero1_spec",
    "STRATEGIES",
    "PartitionPlan",
    "make_plan",
    "partition_triples",
]
