"""Uncompressed N-Triples size model — denominator of the compression ratio."""
from __future__ import annotations

import numpy as np


def ntriples_size_bytes(
    triples: np.ndarray,
    node_repr_len: int = 24,
    pred_repr_len: int = 28,
) -> int:
    """Serialized `<s> <p> <o> .\n` size with IRI-length models matching the
    paper's converted inputs (all compressors read the same RDF file)."""
    n = len(triples)
    return n * (2 * node_repr_len + pred_repr_len + 6)
