"""HDT Bitmap-Triples baseline [10].

Triples sorted by (s, p, o). Layer 1: the distinct predicates of each
subject (sequence Sp + bitmap Bp whose 1s close each subject's run); layer
2: the objects of each (s, p) pair (sequence So + bitmap Bo). S-rooted
patterns are rank/select walks; O-rooted patterns scan (HDT needs its
optional OPS index for those, which the paper excluded from disk size).
"""
from __future__ import annotations

import numpy as np

from repro.core.succinct import BitVector


class HDTBitmapTriples:
    def __init__(self, triples: np.ndarray, n_nodes: int, n_preds: int):
        triples = np.asarray(triples, dtype=np.int64)
        triples = np.unique(triples[np.lexsort((triples[:, 2], triples[:, 1], triples[:, 0]))], axis=0)
        self.n_nodes, self.n_preds = int(n_nodes), int(n_preds)
        s, p, o = triples[:, 0], triples[:, 1], triples[:, 2]
        self.n_triples = len(triples)

        # layer 2: objects per (s,p) run
        sp_change = np.concatenate([[True], (s[1:] != s[:-1]) | (p[1:] != p[:-1])])
        self.So = o
        bo = np.zeros(len(o), dtype=np.uint8)
        run_ends = np.concatenate([np.flatnonzero(sp_change)[1:] - 1, [len(o) - 1]]) if len(o) else np.zeros(0, np.int64)
        bo[run_ends] = 1
        self.Bo = BitVector(bo)

        # layer 1: predicates per subject (one entry per (s,p) run)
        sp_idx = np.flatnonzero(sp_change)
        self.Sp = p[sp_idx]
        s_of_run = s[sp_idx]
        bp = np.zeros(len(sp_idx), dtype=np.uint8)
        s_change_end = np.concatenate(
            [np.flatnonzero(s_of_run[1:] != s_of_run[:-1]), [len(s_of_run) - 1]]
        ) if len(sp_idx) else np.zeros(0, np.int64)
        bp[s_change_end] = 1
        self.Bp = BitVector(bp)
        # subjects present, in order (for select into runs)
        self.subjects = np.unique(s)
        self._subj_pos = {int(v): i for i, v in enumerate(self.subjects)}

    # -- run lookups -----------------------------------------------------
    def _pred_run(self, subj: int) -> tuple[int, int]:
        """[lo, hi) range in Sp for subject subj."""
        i = self._subj_pos.get(int(subj))
        if i is None:
            return 0, 0
        lo = 0 if i == 0 else int(self.Bp.select1(i - 1)) + 1
        hi = int(self.Bp.select1(i)) + 1
        return lo, hi

    def _obj_run(self, sp_run_idx: int) -> tuple[int, int]:
        lo = 0 if sp_run_idx == 0 else int(self.Bo.select1(sp_run_idx - 1)) + 1
        hi = int(self.Bo.select1(sp_run_idx)) + 1
        return lo, hi

    def query(self, s: int | None, p: int | None, o: int | None) -> list[tuple]:
        out = []
        if s is not None:
            lo, hi = self._pred_run(s)
            for ri in range(lo, hi):
                pp = int(self.Sp[ri])
                if p is not None and pp != p:
                    continue
                olo, ohi = self._obj_run(ri)
                objs = self.So[olo:ohi]
                if o is not None:
                    j = np.searchsorted(objs, o)
                    if j < len(objs) and objs[j] == o:
                        out.append((pp, (int(s), int(o))))
                else:
                    out.extend((pp, (int(s), int(x))) for x in objs)
            return out
        # O-rooted / P-only patterns: scan runs (no OPS index)
        run_subject = self.subjects[self.Bp.rank1(np.arange(len(self.Sp)))] if len(self.Sp) else np.zeros(0, np.int64)
        for ri in range(len(self.Sp)):
            pp = int(self.Sp[ri])
            if p is not None and pp != p:
                continue
            ss = int(run_subject[ri])
            olo, ohi = self._obj_run(ri)
            objs = self.So[olo:ohi]
            if o is not None:
                j = np.searchsorted(objs, o)
                if j < len(objs) and objs[j] == o:
                    out.append((pp, (ss, int(o))))
            else:
                out.extend((pp, (ss, int(x))) for x in objs)
        return out

    def size_in_bytes(self) -> int:
        # sequences log-packed like HDT: ceil(log2) bits per element
        bits_p = max(1, int(np.ceil(np.log2(max(self.n_preds, 2)))))
        bits_o = max(1, int(np.ceil(np.log2(max(self.n_nodes, 2)))))
        seq = (len(self.Sp) * bits_p + len(self.So) * bits_o + 7) // 8
        subj = (len(self.subjects) * bits_o + 7) // 8
        return seq + subj + self.Bp.size_in_bytes() + self.Bo.size_in_bytes()
