"""Comparison baselines from the paper's evaluation (Table 1a).

* :class:`K2Triples` — Álvarez-García et al. [9]: one k²-tree per predicate
  over the subject×object matrix.
* :class:`HDTBitmapTriples` — Fernández et al. [10]: dictionary + the BT
  (Bitmap-Triples) structure: subject-sorted adjacency with predicate and
  object layers delimited by rank/select bitmaps.
* gRePair / RDFRePair are RePair variants; the paper's differentiators are
  the digram definition and the index-functions. We expose the honest
  ablation `loop_rules` mode (paper §Handling loops) in `repro.core` and a
  `grepair_digrams` restricted-shape mode for size comparisons rather than
  reimplementing the Scala/Java systems (see DESIGN.md §2).
"""
from repro.baselines.k2_triples import K2Triples
from repro.baselines.hdt_bt import HDTBitmapTriples
from repro.baselines.ntriples import ntriples_size_bytes

__all__ = ["K2Triples", "HDTBitmapTriples", "ntriples_size_bytes"]
