"""k²-Triples baseline [9]: a k²-tree per predicate over subject×object."""
from __future__ import annotations

import numpy as np

from repro.core.succinct import K2Tree


class K2Triples:
    def __init__(self, triples: np.ndarray, n_nodes: int, n_preds: int):
        triples = np.asarray(triples, dtype=np.int64)
        self.n_nodes, self.n_preds = int(n_nodes), int(n_preds)
        self.trees: list[K2Tree] = []
        for p in range(n_preds):
            sel = triples[:, 1] == p
            self.trees.append(K2Tree(triples[sel, 0], triples[sel, 2], n_nodes, n_nodes))

    def query(self, s: int | None, p: int | None, o: int | None) -> list[tuple]:
        preds = [p] if p is not None else range(self.n_preds)
        out = []
        for pp in preds:
            t = self.trees[pp]
            if s is not None and o is not None:
                if t.access(s, o):
                    out.append((pp, (s, o)))
            elif s is not None:
                out.extend((pp, (s, int(c))) for c in t.row(s))
            elif o is not None:
                out.extend((pp, (int(r), o)) for r in t.col(o))
            else:
                for r in range(self.n_nodes):
                    out.extend((pp, (r, int(c))) for c in t.row(r))
        return out

    def size_in_bytes(self) -> int:
        return sum(t.size_in_bytes() for t in self.trees) + 8 * self.n_preds
