"""yi-34b [arXiv:2403.04652]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 (llama-arch GQA)."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="yi-34b", n_layers=60, d_model=7168, n_heads=56,
        n_kv_heads=8, head_dim=128, d_ff=20480, vocab=64000,
        # 56 heads don't divide the 16-way TP axis: context-parallel
        # attention (q-seq over 'model') is the measured win (EXPERIMENTS §Perf A2)
        context_parallel=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="yi-reduced", n_layers=2, d_model=56, n_heads=7,
        n_kv_heads=1, head_dim=8, d_ff=128, vocab=256,
        dtype=jnp.float32, ce_chunk=16,
    )
