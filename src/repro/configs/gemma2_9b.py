"""gemma2-9b [arXiv:2408.00118]: 42L d_model=3584 16H (GQA kv=8) head_dim=256
d_ff=14336 vocab=256000; alternating 4096-local/global attention, attn
softcap 50, final softcap 30, post-norms."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-9b", n_layers=42, d_model=3584, n_heads=16,
        n_kv_heads=8, head_dim=256, d_ff=14336, vocab=256000,
        local_window=4096, attn_softcap=50.0, final_softcap=30.0,
        post_norms=True,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        local_window=8, attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, dtype=jnp.float32, ce_chunk=16,
    )
