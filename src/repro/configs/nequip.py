"""nequip [arXiv:2101.03164]: 5 layers, d_hidden=32, l_max=2, 8 radial basis
functions, cutoff 5 Å, E(3)-equivariant tensor products."""
from repro.models.gnn import NequIPConfig


def config() -> NequIPConfig:
    return NequIPConfig(n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0, name="nequip")


def reduced() -> NequIPConfig:
    return NequIPConfig(n_layers=2, d_hidden=8, l_max=2, n_rbf=4, cutoff=5.0, name="nequip-reduced")
