"""gatedgcn [arXiv:2003.00982]: 16 layers, d_hidden=70, gated aggregation."""
from repro.models.gnn import GatedGCNConfig


def config() -> GatedGCNConfig:
    return GatedGCNConfig(n_layers=16, d_hidden=70, name="gatedgcn")


def reduced() -> GatedGCNConfig:
    return GatedGCNConfig(n_layers=3, d_hidden=16, name="gatedgcn-reduced")
