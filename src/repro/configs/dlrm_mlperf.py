"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM over Criteo-1TB; 13 dense,
26 sparse fields, embed_dim=128, bot 13-512-256-128, top 1024-1024-512-256-1,
dot interaction."""
from repro.models.dlrm import DLRMConfig


def config() -> DLRMConfig:
    return DLRMConfig()


def reduced() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-reduced", embed_dim=16, bot_mlp=(32, 16), top_mlp=(32, 16, 1),
        compute_dtype="float32",
        row_counts=tuple([50, 20, 30, 10, 5, 3, 40, 8, 6, 25, 12, 9, 10, 7,
                          11, 13, 4, 14, 14, 21, 22, 23, 24, 12, 10, 35]),
    )
