"""meshgraphnet [arXiv:2010.03409]: 15 layers, d_hidden=128, sum aggregation,
2-layer MLPs."""
from repro.models.gnn import MeshGraphNetConfig


def config() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(n_layers=15, d_hidden=128, mlp_layers=2, name="meshgraphnet")


def reduced() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(n_layers=3, d_hidden=32, mlp_layers=2, name="mgn-reduced")
