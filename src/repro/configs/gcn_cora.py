"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, mean/sym aggregation."""
from repro.models.gnn import GCNConfig


def config() -> GCNConfig:
    return GCNConfig(n_layers=2, d_hidden=16, norm="sym", name="gcn-cora")


def reduced() -> GCNConfig:
    return GCNConfig(n_layers=2, d_hidden=8, norm="sym", name="gcn-reduced")
