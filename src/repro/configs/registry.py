"""Architecture registry: maps --arch ids to config constructors + shapes.

Every assigned architecture has its own module in repro.configs with
`config()` (exact published numbers) and `reduced()` (smoke-test scale).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | decode_long | serve | retrieval | full_graph | minibatch | molecule
    params: dict


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "full_graph",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "minibatch",
        dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
             fanouts=(15, 10), d_feat=602, n_classes=41)),
    "ogb_products": ShapeSpec(
        "ogb_products", "full_graph",
        dict(n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47)),
    "molecule": ShapeSpec(
        "molecule", "molecule",
        dict(n_nodes=30, n_edges=64, batch=128, d_feat=16)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1000000)),
}


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str          # lm | gnn | recsys
    module: str          # repro.configs.<module>
    shapes: dict = field(default_factory=dict)

    def config(self):
        return importlib.import_module(self.module).config()

    def reduced(self):
        return importlib.import_module(self.module).reduced()


ARCHS: dict[str, ArchSpec] = {
    a.arch_id: a
    for a in [
        ArchSpec("phi3.5-moe-42b-a6.6b", "lm", "repro.configs.phi35_moe", LM_SHAPES),
        ArchSpec("olmoe-1b-7b", "lm", "repro.configs.olmoe", LM_SHAPES),
        ArchSpec("qwen2-1.5b", "lm", "repro.configs.qwen2_1_5b", LM_SHAPES),
        ArchSpec("yi-34b", "lm", "repro.configs.yi_34b", LM_SHAPES),
        ArchSpec("gemma2-9b", "lm", "repro.configs.gemma2_9b", LM_SHAPES),
        ArchSpec("gatedgcn", "gnn", "repro.configs.gatedgcn", GNN_SHAPES),
        ArchSpec("meshgraphnet", "gnn", "repro.configs.meshgraphnet", GNN_SHAPES),
        ArchSpec("gcn-cora", "gnn", "repro.configs.gcn_cora", GNN_SHAPES),
        ArchSpec("nequip", "gnn", "repro.configs.nequip", GNN_SHAPES),
        ArchSpec("dlrm-mlperf", "recsys", "repro.configs.dlrm_mlperf", RECSYS_SHAPES),
    ]
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells():
    """Every (arch, shape) dry-run cell — 40 total."""
    return [(a, s) for a in ARCHS for s in ARCHS[a].shapes]
