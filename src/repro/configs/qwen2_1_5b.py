"""qwen2-1.5b [arXiv:2407.10671]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias, rope theta 1e6."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, head_dim=128, d_ff=8960, vocab=151936,
        qkv_bias=True, rope_theta=1e6,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-reduced", n_layers=2, d_model=48, n_heads=6,
        n_kv_heads=2, head_dim=8, d_ff=128, vocab=256,
        qkv_bias=True, dtype=jnp.float32, ce_chunk=16,
    )
