"""olmoe-1b-7b [arXiv:2409.02060]: 16L d_model=2048 16H (kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50304,
        n_experts=64, top_k=8,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=64, vocab=256,
        n_experts=8, top_k=2, moe_group=64, dtype=jnp.float32, ce_chunk=16,
    )
