from repro.roofline.analysis import (
    HW,
    collective_wire_bytes,
    model_flops,
    parse_collectives,
    roofline_terms,
)

__all__ = ["HW", "collective_wire_bytes", "model_flops", "parse_collectives", "roofline_terms"]
