"""Trip-count-aware cost extraction from optimized HLO text.

`compiled.cost_analysis()` counts every while-loop body ONCE, so scanned
models (layers, microbatches, CE chunks, attention chunks) are massively
undercounted (verified: scan(8) reports the same flops as scan(1)). This
module re-derives per-device flops / HBM bytes / collective wire bytes by
walking the HLO computation graph and multiplying loop bodies by their
`known_trip_count` backend_config (emitted by XLA for lax.scan loops).

Cost model (per top-level op line, post-fusion):
  dot            flops = 2 · |result| · contracted_size; bytes = result+operands
  fusion/other   flops ≈ |result| (elementwise estimate); bytes = result+operands
  dynamic-slice  bytes = 2·|result| (slice read + write, not the full operand)
  dyn-upd-slice / scatter / fusion-containing-DUS:
                 bytes = 2·Σ operands that are not the aliased full buffer
  while          cost = trip_count · (body + cond); carried tuple not counted
  get-tuple-element/tuple/bitcast/copy/parameter/constant: free

Collectives accumulate ring-model wire bytes (see analysis.py) and are
multiplied by enclosing trip counts like any other op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s+(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z]\d?[a-z0-9]*)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_COMPACT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([^}]*)\}")

FREE_OPS = {
    "get-tuple-element", "tuple", "bitcast", "parameter", "constant",
    "after-all", "iota", "partition-id", "replica-id",
}
COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"}


def _shape_info(type_str: str):
    """(total_elems, total_bytes, first_shape_dims) over all arrays in a type."""
    elems = bytes_ = 0
    first = None
    for m in _SHAPE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d] or [1]
        n = 1
        for d in dims:
            n *= d
        elems += n
        bytes_ += n * DTYPE_BYTES[dt]
        if first is None:
            first = dims
    return elems, bytes_ or 0, first or []


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str            # text after the '(' of the op call
    line: str


@dataclass
class Computation:
    name: str
    params: dict                      # %name -> type str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # %name -> result type str


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        if not line.strip():
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            name = hdr.group(2)
            params = {}
            for pm in re.finditer(r"([\w\.\-]+):\s*([^,)]+)", hdr.group(3)):
                params["%" + pm.group(1)] = pm.group(2)
            cur = Computation(name, params)
            comps[name] = cur
            if hdr.group(1):
                entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        op = Op(name=m.group(2), kind=m.group(4), result_type=m.group(3),
                rest=m.group(5), line=line)
        cur.ops.append(op)
        cur.symbols["%" + op.name] = op.result_type
    return comps, entry


def _operand_types(op: Op, comp: Computation, comps: dict) -> list[str]:
    # operands are the %names inside the call parens, before attribute list
    call_part = op.rest.split("),")[0]
    types = []
    for m in _OPERAND.finditer(call_part):
        nm = "%" + m.group(1)
        t = comp.symbols.get(nm) or comp.params.get(nm)
        if t:
            types.append(t)
    return types


def _dot_flops(op: Op, comp: Computation, comps: dict) -> float:
    r_elems, _, _ = _shape_info(op.result_type)
    ods = _operand_types(op, comp, comps)
    contracted = 1
    m = _LHS_CDIMS.search(op.line)
    if m and ods:
        _, _, lhs_dims = _shape_info(ods[0])
        for i in [int(x) for x in m.group(1).split(",") if x]:
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * r_elems * contracted


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_COMPACT.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _collective_wire(op: Op, n_devices: int) -> float:
    _, rbytes, _ = _shape_info(op.result_type)
    n = _group_size(op.line, n_devices)
    frac = (n - 1) / max(n, 1)
    if op.kind.startswith("all-gather"):
        return rbytes * frac
    if op.kind.startswith("all-reduce"):
        return 2 * rbytes * frac
    if op.kind.startswith("reduce-scatter"):
        return rbytes * (n - 1)
    if op.kind.startswith("all-to-all"):
        return rbytes * frac
    return float(rbytes)  # collective-permute


def _fusion_has_dus(op: Op, comps: dict) -> bool:
    m = _CALLS.search(op.line)
    if not m or m.group(1) not in comps:
        return False
    called = comps[m.group(1)]
    return any(o.kind in ("dynamic-update-slice", "scatter") for o in called.ops)


def _fusion_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """HBM traffic of a fusion: parameters consumed only through
    dynamic-slice/gather are charged at slice size (a scan body reading one
    layer of an (L, d, f) weight stack moves d·f bytes, not L·d·f); a
    dynamic-update-slice root is charged at update size."""
    m = _CALLS.search(op.line)
    _, r_bytes, _ = _shape_info(op.result_type)
    operand_types = _operand_types(op, comp, comps)
    if not m or m.group(1) not in comps:
        return r_bytes + sum(_shape_info(t)[1] for t in operand_types)
    called = comps[m.group(1)]
    # parameter index -> name
    param_ops = {}
    for o in called.ops:
        if o.kind == "parameter":
            pm = re.search(r"parameter\((\d+)\)", o.line)
            if pm:
                param_ops[int(pm.group(1))] = o.name
    total = 0.0
    for i, t in enumerate(operand_types):
        full = _shape_info(t)[1]
        pname = param_ops.get(i)
        if pname is None:
            total += full
            continue
        uses = [o for o in called.ops
                if re.search(r"%" + re.escape(pname) + r"\b", o.rest)]
        if uses and all(u.kind in ("dynamic-slice", "gather", "slice") for u in uses):
            total += sum(_shape_info(u.result_type)[1] for u in uses)
        else:
            total += full
    # result: DUS/scatter roots write the update, not the aliased buffer
    has_dus = any(o.kind in ("dynamic-update-slice", "scatter") for o in called.ops)
    if has_dus:
        small = [b for t in operand_types if (b := _shape_info(t)[1]) != r_bytes]
        total = min(total, 2 * (sum(small) if small else r_bytes))
    else:
        total += r_bytes
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        for k, v in other.collectives.items():
            d = self.collectives.setdefault(k, {"count": 0, "wire_bytes": 0.0})
            d["count"] += v["count"] * mult
            d["wire_bytes"] += v["wire_bytes"] * mult


def computation_cost(name: str, comps: dict, n_devices: int, memo: dict) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps[name]
    total = Cost()
    for op in comp.ops:
        base = op.kind.replace("-start", "").replace("-done", "")
        if op.kind in FREE_OPS or op.kind.endswith("-done"):
            continue
        if base in COLLECTIVES:
            wire = _collective_wire(op, n_devices)
            total.wire_bytes += wire
            d = total.collectives.setdefault(base, {"count": 0, "wire_bytes": 0.0})
            d["count"] += 1
            d["wire_bytes"] += wire
            _, rb, _ = _shape_info(op.result_type)
            total.bytes += 2 * rb
            continue
        if op.kind == "while":
            trip = 1
            m = _TRIP.search(op.line)
            if m:
                trip = int(m.group(1))
            body = _BODY.search(op.line)
            cond = _COND.search(op.line)
            if body and body.group(1) in comps:
                total.add(computation_cost(body.group(1), comps, n_devices, memo), trip)
            if cond and cond.group(1) in comps:
                total.add(computation_cost(cond.group(1), comps, n_devices, memo), trip)
            continue
        if op.kind in ("call", "conditional"):
            for m in re.finditer(r"(?:to_apply|branch_computations=\{)?%([\w\.\-]+)", op.rest):
                if m.group(1) in comps and m.group(1) != name:
                    total.add(computation_cost(m.group(1), comps, n_devices, memo), 1.0)
            continue

        r_elems, r_bytes, _ = _shape_info(op.result_type)
        if op.kind == "dot":
            total.flops += _dot_flops(op, comp, comps)
            ob = sum(_shape_info(t)[1] for t in _operand_types(op, comp, comps))
            total.bytes += r_bytes + ob
        elif op.kind in ("dynamic-slice", "gather", "slice"):
            total.bytes += 2 * r_bytes
        elif op.kind in ("dynamic-update-slice", "scatter"):
            ods = _operand_types(op, comp, comps)
            small = [b for t in ods if (b := _shape_info(t)[1]) != r_bytes]
            total.bytes += 2 * sum(small) if small else 2 * r_bytes
            total.flops += sum(_shape_info(t)[0] for t in ods if _shape_info(t)[1] != r_bytes)
        elif op.kind == "fusion":
            total.flops += r_elems  # elementwise estimate
            total.bytes += _fusion_bytes(op, comp, comps)
        else:
            total.flops += r_elems  # elementwise estimate
            ob = sum(_shape_info(t)[1] for t in _operand_types(op, comp, comps))
            total.bytes += r_bytes + ob
    memo[name] = total
    return total


def hlo_cost(text: str, n_devices: int) -> Cost:
    comps, entry = parse_hlo(text)
    if entry is None:
        return Cost()
    return computation_cost(entry, comps, n_devices, {})
