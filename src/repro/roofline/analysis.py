"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds-per-step:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = wire_bytes_per_device / ICI_bandwidth

`cost_analysis()` on the SPMD-partitioned module reports *per-device*
flops/bytes. Collective bytes are not in cost_analysis: we parse the
optimized HLO text, take each collective's result shape, and convert to
wire bytes with the standard ring models (group size N from
replica_groups):

  all-gather      result * (N-1)/N        reduce-scatter  input ≈ result*(N-1)
  all-reduce      2 * result * (N-1)/N    all-to-all      result * (N-1)/N
  collective-permute  result

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12     # bf16 per chip
    hbm_bw: float = 819e9          # bytes/s
    ici_bw: float = 50e9           # bytes/s per link (conservative single-link)


DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL = re.compile(
    r"=\s*(?P<rtype>.+?)\s+(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_COMPACT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt = m.group("dt")
        if dt not in DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_COMPACT.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-collective-type result bytes + modeled wire bytes (per device)."""
    out: dict[str, dict] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL.search(line)
        if not m:
            continue
        op = m.group("op")
        # async pairs: count -start, skip -done re-listing
        if "-done(" in line:
            continue
        rbytes = _shape_bytes(m.group("rtype"))
        n = _group_size(line, n_devices)
        frac = (n - 1) / max(n, 1)
        if op == "all-gather":
            wire = rbytes * frac
        elif op == "all-reduce":
            wire = 2 * rbytes * frac
        elif op == "reduce-scatter":
            wire = rbytes * (n - 1)
        elif op == "all-to-all":
            wire = rbytes * frac
        else:  # collective-permute
            wire = rbytes
        d = out.setdefault(op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += rbytes
        d["wire_bytes"] += wire
    return out


def collective_wire_bytes(parsed: dict) -> float:
    return float(sum(d["wire_bytes"] for d in parsed.values()))


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, hw: HW = HW()) -> dict:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = wire_bytes_per_dev / hw.ici_bw
    dominant = max(
        [("compute", compute), ("memory", memory), ("collective", collective)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_step_s": max(compute, memory, collective),
    }


# --------------------------------------------------------------- MODEL_FLOPS
def model_flops(arch_id: str, shape_name: str, meta: dict) -> float:
    """Analytic useful-work FLOPs per step (global, all chips).

    LM: 6·N_active·tokens for training (fwd+bwd), 2·N_active·tokens +
    attention for inference. GNN/DLRM: closed-form per published structure.
    """
    kind = meta["kind"]
    if meta["family"] == "lm":
        n_active = meta["n_active_params"]
        B, S = meta["global_batch"], meta["seq_len"]
        h_kv_dh = meta["n_heads"] * meta["head_dim"]
        if kind == "train":
            tokens = B * S
            attn = 6 * B * meta["n_layers"] * S * S * h_kv_dh  # fwd+bwd, causal halved
            return 6.0 * n_active * tokens + attn
        if kind == "prefill":
            tokens = B * S
            attn = 2 * B * meta["n_layers"] * S * S * h_kv_dh
            return 2.0 * n_active * tokens + attn
        # decode: one token over a seq_len cache
        attn = 4 * B * meta["n_layers"] * S * h_kv_dh
        return 2.0 * n_active * B + attn
    if meta["family"] == "gnn":
        n, e, d_f = meta["n_nodes"], meta["n_edges"], meta["d_feat"]
        L, d = meta["n_layers"], meta["d_hidden"]
        mults = {
            "gcn-cora": 2 * n * d_f * d + 2 * e * d + 2 * L * n * d * d,
            "gatedgcn": L * (10 * n * d * d + 8 * e * d),
            "meshgraphnet": L * (2 * 3 * d * d * e + 2 * 2 * d * d * n) * 2,
            "nequip": L * (e * (11 * d * 9 + 2 * 8 * 32 * d) + 2 * n * d * d * 3),
        }
        fwd = float(mults[arch_id])
        return 3.0 * fwd if kind in ("full_graph", "minibatch", "molecule") else fwd
    # dlrm
    B = meta.get("batch", 1)
    if kind == "retrieval":
        return 2.0 * meta["n_candidates"] * meta["embed_dim"]
    bot = 2 * (13 * 512 + 512 * 256 + 256 * 128)
    f = meta["n_fields"]
    inter = 2 * f * f * meta["embed_dim"]
    d_int = f * (f - 1) // 2 + meta["embed_dim"]
    top = 2 * (d_int * 1024 + 1024 * 1024 + 1024 * 512 + 512 * 256 + 256)
    fwd = B * float(bot + inter + top)
    return 3.0 * fwd if kind == "train" else fwd
