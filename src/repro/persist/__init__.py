"""Durability: engine snapshots, mutation write-ahead log, crash injection.

Submodules:

* `repro.persist.crash` — :func:`crash_point` injection hooks + the
  :class:`CrashInjector` test harness (no repro imports; safe to call
  from any layer).
* `repro.persist.snapshot` — versioned, checksummed, mmap-able on-disk
  engine snapshots (``save_snapshot`` / ``load_snapshot``).
* `repro.persist.wal` — framed, fsync-controlled write-ahead log with a
  truncation-tolerant reader.
* `repro.persist.service` — :class:`DurableShardedService`: the sharded
  serving tier wrapped with snapshot + WAL + replay recovery.

Attribute access is lazy (PEP 562): ``repro.core.query`` and
``repro.serve.sharded`` import ``repro.persist.crash`` for their
injection hooks, and an eager package import of ``snapshot``/``service``
(which import those same modules) would be circular.
"""
from __future__ import annotations

from repro.persist.crash import (  # noqa: F401  (dependency-free, safe eager)
    CrashInjector,
    CrashPoint,
    crash_point,
    inject_crashes,
)

_LAZY = {
    "save_snapshot": "repro.persist.snapshot",
    "load_snapshot": "repro.persist.snapshot",
    "SnapshotError": "repro.persist.snapshot",
    "WriteAheadLog": "repro.persist.wal",
    "read_wal_records": "repro.persist.wal",
    "DurableShardedService": "repro.persist.service",
}

__all__ = [
    "CrashInjector", "CrashPoint", "crash_point", "inject_crashes",
    *_LAZY,
]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
