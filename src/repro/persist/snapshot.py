"""Versioned, checksummed, mmap-able on-disk engine snapshots.

A built :class:`~repro.core.query.TripleQueryEngine` is expensive: RePair
compression, succinct encoding, grammar flattening, crossover calibration.
All of it is deterministic *data*, so cold start should be a read, not a
recomputation. A snapshot persists every array the engine's hot path
touches — flattened CSR rule arrays, the label-sorted start graph, the
k²-tree level bitvectors and Elias–Fano words of the succinct encoding,
the delta overlay, dictionaries and calibration scalars — each as its own
``.npy`` file, so :func:`load_snapshot` can hand the arrays back as
read-only ``np.load(mmap_mode="r")`` views: the OS pages in only what
queries actually touch, and N processes share one physical copy.

Layout of a snapshot directory::

    manifest.json      scalars + per-file crc32 checksums  (written LAST)
    <name>.npy         one file per array

The manifest doubles as the commit marker — a directory without a
parseable manifest is an aborted write, never a corrupt load. Writes are
crash-safe the same way `repro.train.checkpoint` is: everything lands in
``<path>.tmp`` and one ``os.rename`` publishes it; a kill mid-write
leaves a ``.tmp`` orphan and the previous snapshot intact. Checksums are
verified on load by default, so bit rot surfaces as a loud
:class:`SnapshotError` instead of silently wrong query answers.

Reconstruction is loop-free where it matters: the grammar's rule dict is
rebuilt by slicing the flattened CSR (no re-parse of δ-streams), and the
succinct structures are adopted word-for-word through their
``from_parts`` / ``from_levels`` constructors — no re-encoding, no
re-ranking beyond one cumsum per bitvector.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from dataclasses import asdict

import numpy as np

from repro.core.encode import EncodedGrammar
from repro.core.flatten import FlatGrammar
from repro.core.grammar import Grammar, Rule
from repro.core.hypergraph import Hypergraph, LabelTable
from repro.core.query import _DEFAULT_CACHE, TripleQueryEngine
from repro.core.repair import RepairConfig
from repro.core.succinct import EliasFano, K2Tree
from repro.persist.crash import crash_point

FORMAT_VERSION = 1

MANIFEST = "manifest.json"


class SnapshotError(RuntimeError):
    """A snapshot directory is unreadable: missing/unparseable manifest,
    missing arrays, checksum mismatch, or a format this code can't read."""


# -- saving ----------------------------------------------------------------

def save_snapshot(engine: TripleQueryEngine, path, *, atomic: bool = True) -> str:
    """Persist `engine` to the directory `path`; returns `path`.

    With ``atomic=True`` (default) the write goes through ``<path>.tmp``
    + ``os.rename``, replacing any existing snapshot only at the final
    instant; callers embedding engine snapshots inside their own staged
    directory (the sharded service) pass ``atomic=False`` to write in
    place. The delta overlay is persisted as-is — a snapshot is the full
    logical state, not just the compressed base.
    """
    path = os.fspath(path)
    if not atomic:
        _write_engine_dir(engine, path)
        return path
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    _write_engine_dir(engine, tmp)
    crash_point("snapshot.pre_commit")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    crash_point("snapshot.post_commit")
    return path


def _write_engine_dir(engine: TripleQueryEngine, d: str) -> None:
    """Write one engine's arrays + manifest into (fresh) directory `d`."""
    os.makedirs(d, exist_ok=True)
    enc = engine.encoded
    ef = enc.label_ef
    k2 = enc.incidence
    start = engine._start_sorted  # the order `enc.incidence` indexes
    arrays: dict[str, np.ndarray] = {
        "table_ranks": engine.grammar.table.ranks,
        "start_labels": start.labels,
        "start_nodes": start.nodes_flat,
        "start_offsets": start.offsets,
        "delta_inserts": engine.delta.inserts,
        "delta_tombstones": engine.delta.tombstones,
        "enc_terminal_ranks": enc.terminal_ranks,
        "enc_fn_lengths": np.asarray(enc.fn_lengths, dtype=np.int64),
        "ef_lows": ef._lows,
        "ef_low_words": ef._low_words,
        "ef_upper_words": ef._upper.words,
        "fn_words": enc.fn_stream[0],
        "edge_fn_words": enc.edge_fn_stream[0],
        "rule_words": enc.rule_stream[0],
    }
    for name, arr in engine.flat.to_arrays().items():
        arrays[f"flat_{name}"] = arr
    for i, level in enumerate(k2.levels):
        arrays[f"k2_level_{i}"] = level.words

    checksums: dict[str, int] = {}
    for name, arr in arrays.items():
        fname = f"{name}.npy"
        fpath = os.path.join(d, fname)
        np.save(fpath, np.ascontiguousarray(arr))
        with open(fpath, "rb") as f:
            checksums[fname] = zlib.crc32(f.read())
        # mid-write kill: some arrays on disk, no manifest -> aborted dir
        crash_point("snapshot.write_arrays")

    config = engine.config
    manifest = {
        "format": FORMAT_VERSION,
        "checksums": checksums,
        "n_terminals": int(engine.T),
        "start_n_nodes": int(start.n_nodes),
        "names": engine.grammar.table.names,
        "crossover": int(engine.crossover),
        "delta_budget": None if engine.delta_budget is None
        else int(engine.delta_budget),
        "base_edges": None if engine._base_edges is None
        else int(engine._base_edges),
        "rebuild_count": int(engine.rebuild_count),
        "config": None if config is None else asdict(config),
        "encoded": {
            "n_nodes": int(enc.n_nodes),
            "n_edges": int(enc.n_edges),
            "n_fns": int(enc.n_fns),
            "n_rules": int(enc.n_rules),
            "rule_symbol_count": int(enc.rule_symbol_count),
            "fn_bits": int(enc.fn_stream[1]),
            "edge_fn_bits": int(enc.edge_fn_stream[1]),
            "rule_bits": int(enc.rule_stream[1]),
        },
        "ef": {
            "n": int(ef.n), "universe": int(ef.universe), "l": int(ef.l),
            "low_bits": int(ef._low_bits), "upper_n": int(ef._upper.n),
        },
        "k2": {
            "n_rows": int(k2.n_rows), "n_cols": int(k2.n_cols),
            "k": int(k2.k), "h": int(k2.h), "n_points": int(k2.n_points),
            "level_bits": [int(lv.n) for lv in k2.levels],
        },
    }
    # manifest last: its presence is the directory's commit marker
    with open(os.path.join(d, MANIFEST), "w") as f:
        json.dump(manifest, f)


# -- loading ---------------------------------------------------------------

def read_manifest(path) -> dict:
    """Parse + version-check a snapshot manifest (SnapshotError on any
    problem — an unreadable manifest means an uncommitted/corrupt dir)."""
    mpath = os.path.join(os.fspath(path), MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        raise SnapshotError(f"unreadable snapshot manifest {mpath}: {exc}") \
            from exc
    fmt = manifest.get("format")
    if fmt != FORMAT_VERSION:
        raise SnapshotError(
            f"{mpath}: snapshot format {fmt!r} (this build reads "
            f"{FORMAT_VERSION})")
    return manifest


def _load_arrays(d: str, manifest: dict, mmap: bool, verify: bool) -> dict:
    out: dict[str, np.ndarray] = {}
    for fname, crc in manifest["checksums"].items():
        fpath = os.path.join(d, fname)
        if not os.path.exists(fpath):
            raise SnapshotError(f"snapshot array missing: {fpath}")
        if verify:
            with open(fpath, "rb") as f:
                actual = zlib.crc32(f.read())
            if actual != crc:
                raise SnapshotError(
                    f"checksum mismatch in {fpath}: "
                    f"stored {crc:#010x}, actual {actual:#010x}")
        out[fname[:-len(".npy")]] = np.load(
            fpath, mmap_mode="r" if mmap else None)
    return out


def load_snapshot(path, *, cache=_DEFAULT_CACHE, mmap: bool = True,
                  verify: bool = True) -> TripleQueryEngine:
    """Rebuild an engine from a snapshot directory — the cold-start path.

    ``mmap=True`` backs every array with a read-only memory map (safe:
    the engine never mutates its structural arrays in place; a rebuild
    swaps in fresh ones). ``verify=True`` checks each file's crc32 before
    trusting it. `cache` follows ``TripleQueryEngine`` semantics (default:
    a fresh cache unless ``ITR_RESULT_CACHE=0``).
    """
    d = os.fspath(path)
    manifest = read_manifest(d)
    arrays = _load_arrays(d, manifest, mmap, verify)
    try:
        engine = _reconstruct(manifest, arrays, cache)
    except (KeyError, ValueError, IndexError) as exc:
        raise SnapshotError(f"inconsistent snapshot {d}: {exc}") from exc
    return engine


def _reconstruct(manifest: dict, arrays: dict, cache) -> TripleQueryEngine:
    T = int(manifest["n_terminals"])
    names = manifest["names"]
    table = LabelTable(np.asarray(arrays["table_ranks"], dtype=np.int64), T,
                       list(names) if names is not None else None)
    start = Hypergraph(int(manifest["start_n_nodes"]),
                       arrays["start_labels"], arrays["start_nodes"],
                       arrays["start_offsets"])
    flat = FlatGrammar.from_arrays(
        T, {name: arrays[f"flat_{name}"] for name in FlatGrammar._ARRAY_FIELDS})
    rules = _rules_from_flat(flat, table)
    grammar = Grammar(table, start, rules)

    e = manifest["encoded"]
    efm = manifest["ef"]
    label_ef = EliasFano.from_parts(
        efm["n"], efm["universe"], efm["l"], arrays["ef_lows"],
        arrays["ef_upper_words"], efm["upper_n"],
        arrays["ef_low_words"], efm["low_bits"])
    k2m = manifest["k2"]
    incidence = K2Tree.from_levels(
        k2m["n_rows"], k2m["n_cols"], k2m["k"], k2m["h"], k2m["n_points"],
        [arrays[f"k2_level_{i}"] for i in range(len(k2m["level_bits"]))],
        k2m["level_bits"])
    encoded = EncodedGrammar(
        n_nodes=e["n_nodes"], n_edges=e["n_edges"], n_terminals=T,
        terminal_ranks=np.asarray(arrays["enc_terminal_ranks"]),
        label_ef=label_ef, incidence=incidence,
        fn_stream=(arrays["fn_words"], e["fn_bits"]),
        fn_lengths=np.asarray(arrays["enc_fn_lengths"]),
        n_fns=e["n_fns"],
        edge_fn_stream=(arrays["edge_fn_words"], e["edge_fn_bits"]),
        rule_stream=(arrays["rule_words"], e["rule_bits"]),
        rule_symbol_count=e["rule_symbol_count"], n_rules=e["n_rules"],
        names=list(names) if names is not None else None)

    cfg = manifest["config"]
    engine = TripleQueryEngine.from_state(
        grammar, encoded, flat,
        crossover=manifest["crossover"], cache=cache,
        delta_budget=manifest["delta_budget"],
        config=None if cfg is None else RepairConfig(**cfg),
        base_edges=manifest["base_edges"],
        rebuild_count=manifest["rebuild_count"])
    engine.delta.load_rows(arrays["delta_inserts"], arrays["delta_tombstones"])
    return engine


def _rules_from_flat(flat: FlatGrammar, table: LabelTable) -> dict[int, Rule]:
    """Rule dict from CSR slices — per-rule views, no stream decoding."""
    rules: dict[int, Rule] = {}
    eo, po = flat.edge_offsets, flat.param_offsets
    for r in range(flat.n_rules):
        lbl = int(flat.rule_labels[r])
        rank = int(table.ranks[lbl])
        e0, e1 = int(eo[r]), int(eo[r + 1])
        rhs = Hypergraph(
            rank,
            np.asarray(flat.edge_labels[e0:e1], dtype=np.int64),
            np.asarray(flat.params[po[e0]:po[e1]], dtype=np.int64),
            np.asarray(po[e0:e1 + 1] - po[e0], dtype=np.int64))
        rules[lbl] = Rule(lbl, rank, rhs)
    return rules


# -- term dictionary persistence --------------------------------------------

def save_term_dict(term_dict, path) -> str:
    """Write a :class:`~repro.core.term_dict.TermDict` into directory
    *path*: one ``.npy`` per array plus a crc32-checksummed manifest,
    written last — the same commit discipline as engine snapshots. The
    caller (``DurableShardedService.snapshot``) places the directory
    inside the versioned ``snap_NNNNNN.tmp`` tree, so atomicity rides the
    service-level rename."""
    d = os.fspath(path)
    os.makedirs(d, exist_ok=True)
    meta, arrays = term_dict.to_arrays()
    checksums: dict[str, int] = {}
    for name, arr in arrays.items():
        fname = f"{name}.npy"
        fpath = os.path.join(d, fname)
        np.save(fpath, np.ascontiguousarray(arr))
        with open(fpath, "rb") as f:
            checksums[fname] = zlib.crc32(f.read())
    manifest = {"format": FORMAT_VERSION, "kind": "term_dict",
                "spaces": meta, "checksums": checksums}
    with open(os.path.join(d, MANIFEST), "w") as f:
        json.dump(manifest, f)
    return d


def load_term_dict(path, *, verify: bool = True):
    """Inverse of :func:`save_term_dict`; raises :class:`SnapshotError`
    on a missing/corrupt directory. Arrays load eagerly (no mmap): the
    dictionary's append side mutates, and the arrays are small next to
    the engine structures."""
    from repro.core.term_dict import TermDict

    d = os.fspath(path)
    manifest = read_manifest(d)
    if manifest.get("kind") != "term_dict":
        raise SnapshotError(f"{d}: not a term-dictionary snapshot")
    arrays = _load_arrays(d, manifest, mmap=False, verify=verify)
    try:
        return TermDict.from_arrays(manifest["spaces"], arrays)
    except (KeyError, ValueError, IndexError) as exc:
        raise SnapshotError(f"inconsistent term-dict snapshot {d}: {exc}") \
            from exc
