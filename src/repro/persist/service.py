"""Durable sharded serving: snapshots + write-ahead log + replay recovery.

:class:`DurableShardedService` wraps a
:class:`~repro.serve.sharded.ShardedTripleService` with the two on-disk
structures that make it survive a kill at any instant:

* **Versioned service snapshots** — ``snap_NNNNNN/`` directories under
  the service root, each holding one engine snapshot per shard
  (`repro.persist.snapshot`) plus a ``service.json`` with the routing
  plan (and, when taken mid-migration, the successor plan). The manifest
  is written last and the directory is published by one ``os.rename``,
  so the newest *complete* directory is always a consistent state;
  older directories are garbage-collected only after the rename.
* **A write-ahead log** (`repro.persist.wal`) — every mutation and every
  rebalance state change appends a record BEFORE it applies in memory.
  Recovery = load the newest snapshot, replay the log over it.

Recovery invariants the crash oracle (`tests/test_crash_oracle.py`)
enforces at every injection point:

* an operation whose record predates the crash is fully recovered; one
  whose record never hit the disk never happened — there is no third
  state, because a torn final record is dropped by the tolerant reader;
* replay is idempotent: a crash *between* snapshot commit and WAL
  truncation replays the entire old log onto the new snapshot, which is
  a no-op by construction (mutations are last-writer-wins set
  operations; migration batches re-apply through a source-visibility
  probe — see ``ShardedTripleService._apply_migration_batch``);
* an in-flight migration needs no row lists on disk: the snapshot (or
  the ``rebalance_begin`` record) pins the successor plan, and the rows
  still to move are recomputed as the diff between where rows physically
  sit and where that plan routes them
  (:func:`repro.distributed.rebalance.migration_moves`);
* a shard whose snapshot is corrupt degrades instead of killing the
  tier: the service serves the surviving shards (holes counted in
  ``stats.degraded_patterns``), refuses writes to the hole, and
  :meth:`ShardedTripleService.reingest_shard` restores it from re-fed
  rows.

Knobs: ``ITR_SNAPSHOT_DIR`` (default service root), ``ITR_WAL_FSYNC``
(fsync-per-append, default on).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import struct
from dataclasses import dataclass, field

import numpy as np

from repro.core.delta import as_triple_rows
from repro.core.query import _env_flag
from repro.core.result_cache import QueryResultCache
from repro.distributed.partition import plan_from_dict, plan_to_dict
from repro.distributed.rebalance import RebalancePlan, migration_moves
from repro.persist.crash import crash_point
from repro.persist.snapshot import (
    SnapshotError,
    load_snapshot,
    load_term_dict,
    save_snapshot,
    save_term_dict,
)
from repro.persist.wal import (
    OP_DELETE,
    OP_INSERT,
    OP_MIGRATE,
    OP_NODE_TERMS,
    OP_PLAN_SWAP,
    OP_PRED_TERMS,
    OP_REBALANCE_BEGIN,
    WriteAheadLog,
    read_wal_records,
)
from repro.serve.sharded import (
    _DEFAULT_CACHE,
    _DEFAULT_SKEW,
    ShardedTripleService,
)

SERVICE_MANIFEST = "service.json"
WAL_FILE = "wal.log"
TERM_DICT_DIR = "term_dict"

_SNAP_RE = re.compile(r"^snap_(\d{6})$")

_MIGRATE_HDR = struct.Struct("<ii")  # src shard, dst shard


def resolve_snapshot_dir(root=None) -> str:
    """Service root: explicit `root`, else ``ITR_SNAPSHOT_DIR``."""
    if root is not None:
        return os.fspath(root)
    env = os.environ.get("ITR_SNAPSHOT_DIR", "").strip()
    if not env:
        raise ValueError(
            "no snapshot root: pass root= or set ITR_SNAPSHOT_DIR")
    return env


@dataclass
class RecoveryReport:
    """What :meth:`DurableShardedService.open` found and did."""

    snapshot_dir: str = ""
    snapshot_step: int = 0
    replayed_records: int = 0
    skipped_rows: int = 0        # mutation rows dropped (failed shards)
    skipped_batches: int = 0     # migration batches dropped (failed shards)
    torn_tail: bool = False      # WAL ended in a dropped partial record
    torn_reason: str = ""
    migration_resumed: bool = False
    failed_shards: list = field(default_factory=list)


# -- record packing --------------------------------------------------------

def _pack_rows(op: int, rows: np.ndarray) -> bytes:
    return bytes([op]) + np.ascontiguousarray(rows, dtype="<i8").tobytes()

def _unpack_rows(payload: bytes) -> np.ndarray:
    return np.frombuffer(payload, dtype="<i8").astype(np.int64).reshape(-1, 3)

def _pack_plan(op: int, plan) -> bytes:
    return bytes([op]) + json.dumps(plan_to_dict(plan)).encode()

def _pack_migrate(src: int, dst: int, rows: np.ndarray) -> bytes:
    return bytes([OP_MIGRATE]) + _MIGRATE_HDR.pack(src, dst) \
        + np.ascontiguousarray(rows, dtype="<i8").tobytes()

def _pack_terms(op: int, terms) -> bytes:
    # terms may contain any character, so each is length-prefixed
    # (u32 byte length + utf-8 bytes) rather than delimiter-joined
    parts = [bytes([op])]
    for t in terms:
        enc = t.encode("utf-8")
        parts.append(struct.pack("<I", len(enc)))
        parts.append(enc)
    return b"".join(parts)

def _unpack_terms(payload: bytes) -> list[str]:
    terms, off = [], 0
    while off < len(payload):
        (ln,) = struct.unpack_from("<I", payload, off)
        off += 4
        terms.append(payload[off:off + ln].decode("utf-8"))
        off += ln
    return terms


class DurableShardedService:
    """A sharded triple service whose state survives ``kill -9``.

    Build fresh with :meth:`build` (compress + initial snapshot) or
    recover with :meth:`open` (newest snapshot + WAL replay). The query
    plane and maintenance surface delegate to the wrapped
    :class:`ShardedTripleService`; the mutation surface
    (``insert_triples``/``delete_triples``) writes ahead to the log, and
    rebalance state changes journal themselves through the service's
    ``_journal`` hook. :meth:`snapshot` persists the current state and
    compacts the log.
    """

    def __init__(self, service: ShardedTripleService, root: str,
                 wal: WriteAheadLog, recovery: RecoveryReport | None = None):
        self.service = service
        self.root = os.fspath(root)
        self.wal = wal
        #: report of the recovery that produced this instance (None when
        #: built fresh)
        self.last_recovery = recovery

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, triples, n_nodes: int, n_preds: int, root=None,
              fsync: bool | None = None, replicas=None,
              replica_dispatch=None, replica_max_lag=None,
              **kwargs) -> "DurableShardedService":
        """Compress + shard `triples` (all :meth:`ShardedTripleService
        .build` kwargs pass through), then make the result durable: write
        the initial snapshot under `root` and open the WAL. `replicas`
        (default: ``ITR_REPLICAS``) > 0 additionally seeds that many read
        replica groups from the fresh snapshot —
        :meth:`enable_replication`."""
        root = resolve_snapshot_dir(root)
        service = ShardedTripleService.build(
            np.asarray(triples, dtype=np.int64), n_nodes, n_preds, **kwargs)
        os.makedirs(root, exist_ok=True)
        wal = WriteAheadLog(os.path.join(root, WAL_FILE), fsync=fsync)
        self = cls(service, root, wal)
        self.snapshot()
        self._attach()
        self.enable_replication(replicas, replica_dispatch, replica_max_lag)
        return self

    @classmethod
    def open(cls, root=None, *, fsync: bool | None = None, mmap: bool = True,
             verify: bool = True, max_batch: int = 1024, config=None,
             rebalance_skew=_DEFAULT_SKEW, cache=_DEFAULT_CACHE,
             serve_threads: int | None = None, replicas=None,
             replica_dispatch=None,
             replica_max_lag=None) -> "DurableShardedService":
        """Recover a service from disk: newest complete snapshot + replay.

        Shards whose snapshot fails to load degrade (served as holes)
        instead of failing the open; the log replays with journaling and
        auto-rebalance suppressed, dropping only records that touch
        failed shards. The returned instance carries a
        :class:`RecoveryReport` as ``last_recovery``.
        """
        root = resolve_snapshot_dir(root)
        step, snap = _newest_snapshot(root)
        manifest = _read_service_manifest(snap)
        plan = plan_from_dict(manifest["plan"])
        report = RecoveryReport(snapshot_dir=snap, snapshot_step=step)
        if cache is _DEFAULT_CACHE:
            cache = QueryResultCache() \
                if _env_flag("ITR_RESULT_CACHE", True) else None

        engines: list = []
        failed: list[int] = []
        for k in range(plan.n_shards):
            shard_view = cache.shard_view(k) if cache is not None else None
            try:
                engines.append(load_snapshot(
                    os.path.join(snap, f"shard_{k}"),
                    cache=shard_view, mmap=mmap, verify=verify))
            except SnapshotError:
                engines.append(None)  # placeholder built by mark_shard_failed
                failed.append(k)
        if config is None:
            config = next(
                (e.config for e in engines if e is not None), None)
        svc = ShardedTripleService(
            engines, plan, cache, max_batch, config=config,
            rebalance_skew=rebalance_skew, serve_threads=serve_threads)
        for k in failed:
            svc.mark_shard_failed(k)
        report.failed_shards = failed
        if manifest.get("term_dict"):
            svc.term_dict = load_term_dict(
                os.path.join(snap, TERM_DICT_DIR), verify=verify)

        mig_plan = manifest.get("migration_plan")
        if mig_plan is not None:
            new_plan = plan_from_dict(mig_plan)
            svc._migration = RebalancePlan(
                plan, new_plan, migration_moves(new_plan, svc.engines))
            report.migration_resumed = True

        wal = WriteAheadLog(os.path.join(root, WAL_FILE), fsync=fsync)
        self = cls(svc, root, wal, recovery=report)
        self._replay(report)
        self._attach()
        if not failed:  # degraded tiers serve primary-only until restored
            self.enable_replication(replicas, replica_dispatch,
                                    replica_max_lag, mmap=mmap, verify=verify)
        return self

    def _attach(self) -> None:
        self.service._journal = self._on_journal

    # -- read replication --------------------------------------------------
    def enable_replication(self, n_replicas=None, dispatch=None,
                           max_lag=None, *, mmap: bool = True,
                           verify: bool = True, auto_sync: bool = True):
        """Seed `n_replicas` read replica groups (default: resolve
        ``ITR_REPLICAS``; 0 = disable) from the newest snapshot, attach
        them to the router's dispatch, and catch them up to the live WAL.
        Replaces (and closes) any existing replica tier; returns the
        :class:`~repro.serve.replication.ReplicationManager`, or None when
        resolving to zero replicas."""
        from repro.serve.replication import (
            ReplicationManager,
            resolve_replicas,
        )
        svc = self.service
        n = resolve_replicas(n_replicas)
        old, svc._replicas = svc._replicas, None
        if old is not None:
            old.close()
        if n <= 0:
            return None
        if svc.failed_shards:
            raise RuntimeError(
                f"cannot seed replicas with failed shards "
                f"{sorted(svc.failed_shards)}: the snapshot they seed from "
                "must cover every shard; restore with reingest_shard() and "
                "snapshot() first")
        manager = ReplicationManager(
            svc, self.wal, self.root, n, dispatch, max_lag,
            mmap=mmap, verify=verify, auto_sync=auto_sync)
        manager.sync()  # groups start at the primary's state, lag 0
        svc._replicas = manager
        return manager

    @property
    def replicas(self):
        """The live ReplicationManager (None when replication is off)."""
        return self.service._replicas

    def sync_replicas(self) -> list[int]:
        """Drain the WAL tail into every replica group (quiesce); returns
        records applied per group ([] when replication is off)."""
        manager = self.service._replicas
        return manager.sync() if manager is not None else []

    def replica_stats(self) -> dict | None:
        """Replica lag accounting + dispatch counters (None when off)."""
        manager = self.service._replicas
        return manager.stats() if manager is not None else None

    # -- mutation (write-ahead) --------------------------------------------
    def insert_triples(self, triples) -> int:
        """Durably insert (s, p, o) rows: logged before applied."""
        return self._mutate(triples, OP_INSERT)

    def delete_triples(self, triples) -> int:
        """Durably delete (s, p, o) rows: logged before applied."""
        return self._mutate(triples, OP_DELETE)

    def _mutate(self, triples, op: int) -> int:
        svc = self.service
        rows = as_triple_rows(triples)
        if len(rows) == 0:
            return 0
        # one exclusive section for validate + append + apply: WAL order
        # must equal apply order (concurrent mutations appending in one
        # order and applying in another would diverge on replay), and the
        # routing state validated against must be the one applied under.
        # The inner service mutation re-takes write — the lock is
        # writer-reentrant for exactly this nesting.
        with svc._rw.write():
            # validate BEFORE the append: a record that cannot apply must
            # never reach the log, or replay would trip over it
            if int(rows[:, 1].max()) >= svc.plan.n_preds:
                raise ValueError(
                    f"predicate ids must be < {svc.plan.n_preds}; "
                    f"got {int(rows[:, 1].max())}")
            if svc.failed_shards:
                bad = sorted(svc.failed_shards)
                routed = svc.plan.route_triples(rows)
                if svc._migration is not None:
                    hits = np.isin(routed, bad) | np.isin(
                        svc._migration.new_plan.route_triples(rows), bad)
                else:
                    hits = np.isin(routed, bad)
                if hits.any():
                    raise RuntimeError(
                        f"cannot mutate failed shards {bad}; "
                        "restore them with reingest_shard() first")
            self.wal.append(_pack_rows(op, rows))
            return svc.insert_triples(rows) if op == OP_INSERT \
                else svc.delete_triples(rows)

    # -- term minting (WAL-covered) ----------------------------------------
    def add_node_terms(self, terms) -> np.ndarray:
        """Durably mint node-term ids: genuinely new terms are logged
        (first-seen order) BEFORE the dictionary learns them, so recovery
        replay and WAL-tailing replicas rebuild the identical id space."""
        return self._mint_terms(terms, OP_NODE_TERMS)

    def add_pred_terms(self, terms) -> np.ndarray:
        """Durably mint predicate-term ids (see :meth:`add_node_terms`);
        raises before logging anything if the mint would exceed the tier's
        fixed predicate capacity."""
        return self._mint_terms(terms, OP_PRED_TERMS)

    def _mint_terms(self, terms, op: int) -> np.ndarray:
        svc = self.service
        terms = list(terms)
        # same discipline as _mutate: validate + append + apply in one
        # exclusive section so WAL order equals mint order (ids are
        # assigned by arrival order — replay must see the same sequence)
        with svc._rw.write():
            td = svc._require_term_dict()
            lookup = td.node_id if op == OP_NODE_TERMS else td.pred_id
            fresh = [t for t in dict.fromkeys(terms) if lookup(t) is None]
            if op == OP_PRED_TERMS and td.n_preds + len(fresh) > svc.plan.n_preds:
                # validate BEFORE the append: a record that cannot apply
                # must never reach the log
                raise ValueError(
                    f"predicate capacity exhausted: tier was built with "
                    f"n_preds={svc.plan.n_preds}, dictionary holds "
                    f"{td.n_preds}, cannot mint {len(fresh)} more — rebuild "
                    "the tier with a larger predicate capacity")
            if fresh:
                self.wal.append(_pack_terms(op, fresh))
            return svc.add_node_terms(terms) if op == OP_NODE_TERMS \
                else svc.add_pred_terms(terms)

    # -- journaling hook (rebalance state changes) -------------------------
    def _on_journal(self, kind: str, payload) -> None:
        if kind == "migrate":
            src, dst, batch = payload
            self.wal.append(_pack_migrate(int(src), int(dst), batch))
        elif kind == "rebalance_begin":
            self.wal.append(_pack_plan(OP_REBALANCE_BEGIN, payload))
        elif kind == "plan_swap":
            self.wal.append(_pack_plan(OP_PLAN_SWAP, payload))
        else:  # a silent drop would corrupt recovery
            raise ValueError(f"unknown journal event {kind!r}")

    # -- snapshot / compaction ---------------------------------------------
    def snapshot(self, keep: int = 2) -> str:
        """Persist the current state as a new versioned snapshot, then
        compact: older snapshots are GC'd and the WAL truncated. Crash-safe
        at every step — a kill before the commit rename leaves the previous
        snapshot authoritative; one after it but before the WAL truncation
        replays the (now redundant) log onto the new snapshot, which is
        idempotent by construction."""
        svc = self.service
        # exclusive for the whole capture + commit + WAL reset: the
        # snapshot must be one instant of the tier, and a mutation
        # appended between the commit rename and the truncation would be
        # silently erased by the reset
        with svc._rw.write():
            if svc.failed_shards:
                raise RuntimeError(
                    f"cannot snapshot with failed shards "
                    f"{sorted(svc.failed_shards)}: the hole would become "
                    "permanent; restore them with reingest_shard() first")
            steps = _snapshot_steps(self.root)
            step = (steps[-1] if steps else 0) + 1
            final = os.path.join(self.root, f"snap_{step:06d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for k, engine in enumerate(svc.engines):
                save_snapshot(engine, os.path.join(tmp, f"shard_{k}"),
                              atomic=False)
            if svc.term_dict is not None:
                save_term_dict(svc.term_dict, os.path.join(tmp, TERM_DICT_DIR))
            manifest = {
                "format": 1,
                "plan": plan_to_dict(svc.plan),
                "migration_plan": None if svc._migration is None
                else plan_to_dict(svc._migration.new_plan),
                "term_dict": svc.term_dict is not None,
            }
            # service manifest last: the directory's commit marker
            with open(os.path.join(tmp, SERVICE_MANIFEST), "w") as f:
                json.dump(manifest, f)
            crash_point("snapshot.pre_commit")
            os.rename(tmp, final)
            crash_point("snapshot.post_commit")
            # gc only AFTER the new snapshot is committed: at no instant is
            # there zero complete snapshots on disk
            for old in steps[:len(steps) - keep + 1]:
                shutil.rmtree(os.path.join(self.root, f"snap_{old:06d}"),
                              ignore_errors=True)
            self.wal.reset()
            return final

    # -- replay ------------------------------------------------------------
    def _replay(self, report: RecoveryReport) -> None:
        """Apply every intact WAL record to the freshly loaded service.

        Journaling is detached (nothing re-logs) and the auto-rebalance
        trigger is disabled for the duration, so replay applies exactly
        the logged history — no new plans, no new migrations. Records
        that touch failed shards are dropped (and counted): their state
        is lost with the shard and comes back through re-ingest.
        """
        svc = self.service
        records, wal_report = read_wal_records(self.wal.path)
        # the WAL truncated any torn tail when it opened; report from its
        # open-time scan, where the tear was still visible
        scan = self.wal.recovery or wal_report
        report.torn_tail = scan.torn_tail
        report.torn_reason = scan.torn_reason
        svc._journal = None
        saved_skew = svc.rebalance_skew
        svc.rebalance_skew = None  # no auto-rebalance mid-replay
        try:
            for payload in records:
                apply_wal_record(svc, payload, report)
                report.replayed_records += 1
        finally:
            svc.rebalance_skew = saved_skew

    # -- lifecycle / delegation --------------------------------------------
    def close(self) -> None:
        """Shut down the whole hierarchy: journal detached, replica tier
        (if any) and scatter pools drained, WAL closed. Idempotent — every
        layer's close is a no-op the second time."""
        self.service._journal = None
        self.service.close()  # drains the replica tier + fan-out pool
        self.wal.close()

    def __enter__(self) -> "DurableShardedService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __getattr__(self, name: str):
        # query plane + maintenance surface of the wrapped service
        # (submit/flush/query/rebalance/rebuild/stats/...); mutations are
        # intercepted above so they hit the log first
        return getattr(self.service, name)


# -- record application ------------------------------------------------------

def apply_wal_record(svc: ShardedTripleService, payload: bytes,
                     report: RecoveryReport | None = None) -> None:
    """Apply one WAL payload to `svc` — the shared replay primitive.

    Both consumers of the log go through this switch: recovery replay
    (`DurableShardedService.open`, which passes its `report` so rows and
    migration batches touching failed shards are dropped and counted) and
    replica catch-up (`repro.serve.replication`, no report — replica
    groups are seeded whole, so nothing is droppable and any failure
    raises into the group's reseed path). One switch means a replica that
    tailed the log and a service that replayed it after a crash land on
    byte-identical state.
    """
    if report is None:
        report = RecoveryReport()
    op = payload[0]
    if op in (OP_INSERT, OP_DELETE):
        rows = _unpack_rows(payload[1:])
        rows = _drop_failed(svc, rows, report)
        if len(rows) == 0:
            return
        if op == OP_INSERT:
            svc.insert_triples(rows)
        else:
            svc.delete_triples(rows)
    elif op == OP_MIGRATE:
        src, dst = _MIGRATE_HDR.unpack_from(payload, 1)
        batch = _unpack_rows(payload[1 + _MIGRATE_HDR.size:])
        if src in svc.failed_shards or dst in svc.failed_shards:
            report.skipped_batches += 1
            return
        if svc._migration is not None:
            svc._migration.discard(batch)
        moved = svc._apply_migration_batch(src, dst, batch)
        svc.stats.migrated_rows += moved
    elif op == OP_REBALANCE_BEGIN:
        new_plan = plan_from_dict(json.loads(payload[1:].decode()))
        svc._migration = RebalancePlan(
            svc.plan, new_plan, migration_moves(new_plan, svc.engines))
        report.migration_resumed = not svc._migration.done
    elif op == OP_PLAN_SWAP:
        svc.plan = plan_from_dict(json.loads(payload[1:].decode()))
        svc._migration = None
        report.migration_resumed = False
    elif op in (OP_NODE_TERMS, OP_PRED_TERMS):
        # records hold only genuinely-new terms in first-seen order, so
        # appending them in log order reconstructs the exact id sequence
        # (idempotent: a term already present keeps its id)
        terms = _unpack_terms(payload[1:])
        td = svc.term_dict
        if td is None:
            from repro.core.term_dict import TermDict
            td = TermDict.empty()
            svc.term_dict = td
        if op == OP_NODE_TERMS:
            td.add_node_terms(terms)
        else:
            td.add_pred_terms(terms)
    else:
        raise SnapshotError(f"unknown WAL op code {op}")


def _drop_failed(svc: ShardedTripleService, rows: np.ndarray,
                 report: RecoveryReport) -> np.ndarray:
    if not svc.failed_shards or len(rows) == 0:
        return rows
    bad = sorted(svc.failed_shards)
    keep = ~np.isin(svc.plan.route_triples(rows), bad)
    if svc._migration is not None:
        keep &= ~np.isin(
            svc._migration.new_plan.route_triples(rows), bad)
    report.skipped_rows += int((~keep).sum())
    return rows[keep]


# -- snapshot directory scanning -------------------------------------------

def _snapshot_steps(root: str) -> list[int]:
    """Ascending steps of COMPLETE snapshot dirs (service manifest
    present — an aborted ``.tmp`` or manifest-less dir never counts)."""
    steps = []
    for entry in os.listdir(root):
        m = _SNAP_RE.match(entry)
        if m and os.path.exists(os.path.join(root, entry, SERVICE_MANIFEST)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def _newest_snapshot(root: str) -> tuple[int, str]:
    if not os.path.isdir(root):
        raise SnapshotError(f"no snapshot root at {root}")
    steps = _snapshot_steps(root)
    if not steps:
        raise SnapshotError(f"no complete snapshot under {root}")
    return steps[-1], os.path.join(root, f"snap_{steps[-1]:06d}")


def _read_service_manifest(snap: str) -> dict:
    try:
        with open(os.path.join(snap, SERVICE_MANIFEST)) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        raise SnapshotError(
            f"unreadable service manifest in {snap}: {exc}") from exc
