"""Crash-point injection for durability testing.

Real kill -9s cannot be produced inside the test process, so — in the
style of `repro.train.fault_tolerance.FailureInjector` — the durability
paths (snapshot write, WAL append, engine rebuild, migration apply) are
instrumented with named :func:`crash_point` calls, and tests arm a
:class:`CrashInjector` with a schedule ``{point_name: hit_number}``. When
an armed point reaches its scheduled hit it raises :class:`CrashPoint`,
which models the process dying *at that instant*: everything in memory is
garbage, and only what has already reached disk matters. The randomized
crash oracle (`tests/test_crash_oracle.py`) catches the exception, throws
the live service away, recovers from disk, and checks query parity.

`CrashPoint` subclasses ``BaseException`` on purpose: production code
that defensively catches ``Exception`` must not be able to "survive" a
simulated kill.

Disarmed cost is one global read and a ``None`` check per point — cheap
enough to leave the hooks in production paths permanently.

``ITR_CRASH_POINTS`` (e.g. ``"wal.append:2,snapshot.pre_commit:1"``) arms
a process-wide schedule at first use, for driving crash drills from the
command line without writing a test.

This module deliberately imports nothing from the rest of `repro` so any
layer (core, serve, persist) can call :func:`crash_point` without import
cycles.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_ENV_VAR = "ITR_CRASH_POINTS"


class CrashPoint(BaseException):
    """A simulated kill at a named injection point (not an ``Exception``:
    broad handlers must not swallow a crash)."""

    def __init__(self, name: str):
        super().__init__(name)
        self.name = name


class CrashInjector:
    """Deterministic crash schedule: ``{point_name: hit_number}`` raises
    :class:`CrashPoint` the `hit_number`-th (1-based) time that point is
    visited. `hits` keeps per-point visit counts for assertions."""

    def __init__(self, schedule: dict[str, int] | None = None):
        self.schedule = {str(k): int(v) for k, v in (schedule or {}).items()}
        self.hits: dict[str, int] = {}

    def visit(self, name: str) -> None:
        n = self.hits.get(name, 0) + 1
        self.hits[name] = n
        if self.schedule.get(name) == n:
            raise CrashPoint(name)


# the armed injector (None = disarmed); module-global so every layer's
# crash_point() calls see one schedule without threading state through APIs
_ACTIVE: CrashInjector | None = None
_ENV_CHECKED = False


def crash_point(name: str) -> None:
    """Visit the named injection point; raises :class:`CrashPoint` when an
    armed schedule says this visit is the crash."""
    global _ENV_CHECKED, _ACTIVE
    if not _ENV_CHECKED:
        _ENV_CHECKED = True
        spec = os.environ.get(_ENV_VAR, "").strip()
        if spec and _ACTIVE is None:
            _ACTIVE = CrashInjector(parse_crash_points(spec))
    if _ACTIVE is not None:
        _ACTIVE.visit(name)


def active_injector() -> CrashInjector | None:
    return _ACTIVE


@contextmanager
def inject_crashes(schedule: dict[str, int]):
    """Arm a crash schedule for the duration of the block; yields the
    :class:`CrashInjector` (its `hits` survive the block for assertions).
    Nested arming restores the previous injector on exit."""
    global _ACTIVE
    injector = CrashInjector(schedule)
    prev = _ACTIVE
    _ACTIVE = injector
    try:
        yield injector
    finally:
        _ACTIVE = prev


def parse_crash_points(spec: str) -> dict[str, int]:
    """Parse an ``ITR_CRASH_POINTS`` spec: comma-separated ``name:hit``
    entries (hit defaults to 1). Malformed entries raise — a typo'd crash
    drill silently testing nothing is worse than an error."""
    schedule: dict[str, int] = {}
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        name, _, hit = entry.partition(":")
        name = name.strip()
        if not name:
            raise ValueError(f"bad {_ENV_VAR} entry {entry!r}: empty point name")
        try:
            schedule[name] = int(hit) if hit.strip() else 1
        except ValueError:
            raise ValueError(
                f"bad {_ENV_VAR} entry {entry!r}: hit count must be an "
                f"integer") from None
    return schedule
