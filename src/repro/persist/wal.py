"""Mutation write-ahead log: framed, checksummed, truncation-tolerant.

The delta overlay (`repro.core.delta`) makes the sharded tier *mutable*;
this log makes the mutations *durable*. Every state change the snapshot
does not yet cover — triple inserts/deletes, rebalance plan decisions,
migration batches — is appended here BEFORE it applies in memory
(write-ahead ordering), so a crash at any instant loses at most work that
was never acknowledged:

* crash before the append    -> the operation never happened;
* crash during the append    -> a torn tail record, dropped by the reader;
* crash any time after       -> replay over the snapshot reproduces it.

Record framing is byte-exact and self-delimiting::

    header:  MAGIC (8 bytes, includes the format version)
    record:  u32 payload length | u32 crc32(payload) | payload

The reader walks frames until the file ends mid-frame or a CRC mismatch —
both are treated as the torn tail of the final, unacknowledged append (the
only place a crashed-but-fsynced log can be damaged) and reported, not
raised. Payloads are opaque here; `repro.persist.service` packs them
(numpy row blocks, JSON plan blobs) and owns the op-code registry below.

The same tolerant scan also serves *incremental* consumers:
:func:`tail_wal_records` / :class:`WalCursor` read only the records
appended since a byte offset — the feed that keeps read replicas
(`repro.serve.replication`) fresh — and flag a log compacted underneath
the cursor (``truncated``) so the consumer reseeds from a snapshot
instead of silently replaying from offset 0.

Durability knob: ``ITR_WAL_FSYNC`` (default on) controls fsync-per-append.
Off trades the crash-durability of the last few records for append
throughput — replay correctness is unaffected, only the loss window.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from dataclasses import dataclass, field

from repro.persist.crash import crash_point

MAGIC = b"ITRWAL01"

_FRAME = struct.Struct("<II")  # payload length, crc32(payload)

# op codes for service-level payloads (first byte of every payload)
OP_INSERT = 1          # triple rows inserted
OP_DELETE = 2          # triple rows deleted
OP_MIGRATE = 3         # one rebalance migration batch (src, dst, rows)
OP_REBALANCE_BEGIN = 4  # successor plan decided; migration starts
OP_PLAN_SWAP = 5       # successor plan adopted as THE routing plan
OP_NODE_TERMS = 6      # node terms minted into the term dictionary
OP_PRED_TERMS = 7      # predicate terms minted into the term dictionary


def resolve_wal_fsync(value=None) -> bool:
    """fsync-per-append policy: ``value`` if given, else ``ITR_WAL_FSYNC``
    (``0``/``false``/``off``/``no`` disable; anything else — including
    unset — keeps the default-on durable behavior)."""
    if value is not None:
        return bool(value)
    env = os.environ.get("ITR_WAL_FSYNC", "").strip().lower()
    return env not in ("0", "false", "off", "no")


@dataclass
class WalReadReport:
    """What the tolerant reader saw: clean records, plus whether (and
    where) it stopped at a damaged tail."""

    n_records: int = 0
    valid_bytes: int = 0    # offset of the first byte NOT covered by a record
    torn_tail: bool = False  # file continued past valid_bytes with garbage
    torn_reason: str = ""
    #: tail-only signal: the log is now SHORTER than the requested start
    #: offset — it was compacted (``reset()``) underneath the cursor, and
    #: nothing read from the current file can continue the old position
    truncated: bool = False
    errors: list = field(default_factory=list)


class WriteAheadLog:
    """Append-only mutation log over one file.

    `append` is the whole write surface: frame the payload, write, flush,
    fsync (unless disabled). Crash points ``wal.append`` (before any
    bytes), ``wal.torn`` (half the frame written and flushed — the
    torn-write simulation), and ``wal.post_append`` (bytes durable,
    acknowledgement not yet returned) let the crash oracle kill the
    process at every interesting instant.
    """

    def __init__(self, path, fsync: bool | None = None):
        self.path = os.fspath(path)
        self.fsync = resolve_wal_fsync(fsync)
        # appends are already serialized by the durable service's exclusive
        # write lock; this inner lock is defense in depth so two frames can
        # never interleave even if a caller appends outside that discipline
        self._lock = threading.Lock()
        fresh = not os.path.exists(self.path) \
            or os.path.getsize(self.path) < len(MAGIC)
        #: tolerant scan of the pre-existing log (None when created fresh)
        self.recovery: WalReadReport | None = None
        # unbuffered: every write() reaches the OS immediately, so an
        # abandoned handle (simulated kill) can never flush half-written
        # frames AFTER recovery has already read the file
        self._f = open(self.path, "ab" if not fresh else "wb", buffering=0)
        #: compactions (`reset()`) since this handle opened — a tail cursor
        #: seeded against one incarnation of the log is invalid as soon as
        #: this counter moves, even if the file has regrown past its offset
        self.resets = 0
        if fresh:
            self._f.write(MAGIC)
            self._flush()
            self._offset = len(MAGIC)
            self.n_records = 0
        else:
            _, self.recovery = read_wal_records(self.path)
            if self.recovery.torn_tail:
                # drop the torn tail NOW: appending after garbage would
                # make every later record unreadable to the next recovery
                self._f.truncate(self.recovery.valid_bytes)
                self._flush()
            self._offset = self.recovery.valid_bytes
            self.n_records = self.recovery.n_records

    # -- writing -----------------------------------------------------------
    def append(self, payload: bytes) -> None:
        """Durably append one record; returns only once the record is as
        durable as the fsync policy promises."""
        crash_point("wal.append")
        frame = _FRAME.pack(len(payload), zlib.crc32(payload)) + payload
        half = len(frame) // 2
        with self._lock:
            self._f.write(frame[:half])
            self._f.flush()
            # a kill here leaves half a frame on disk: the torn tail the
            # reader must drop without failing recovery
            crash_point("wal.torn")
            self._f.write(frame[half:])
            self._flush()
            self._offset += len(frame)
            self.n_records += 1
        crash_point("wal.post_append")

    def _flush(self) -> None:
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def reset(self) -> None:
        """Truncate to an empty log (after a snapshot makes the records
        redundant — log compaction). Bumps ``resets`` so tail cursors know
        their offsets died with the old incarnation."""
        with self._lock:
            self._f.truncate(len(MAGIC))
            self._f.seek(len(MAGIC))
            self._flush()
            self._offset = len(MAGIC)
            self.n_records = 0
            self.resets += 1

    @property
    def offset(self) -> int:
        """Byte offset one past the last acknowledged record (the position
        a fully caught-up tail cursor sits at)."""
        return self._offset

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_wal_records(path) -> tuple[list[bytes], WalReadReport]:
    """Read every intact record; tolerate a torn tail.

    Damage anywhere that can only be the final, unacknowledged append —
    a frame running past EOF, or a CRC mismatch on the last bytes — stops
    the scan and is *reported* (``report.torn_tail``), never raised:
    dropping an operation nobody was told succeeded is correct recovery.
    A missing file reads as an empty log; a bad magic header raises
    ``ValueError`` (that is corruption of acknowledged state, not a tail).
    """
    report = WalReadReport()
    records: list[bytes] = []
    if not os.path.exists(path):
        return records, report
    with open(path, "rb") as f:
        data = f.read()
    if len(data) < len(MAGIC):
        # even the header didn't finish: an empty log mid-creation
        report.torn_tail = len(data) > 0
        report.torn_reason = "short header" if data else ""
        return records, report
    if data[:len(MAGIC)] != MAGIC:
        raise ValueError(
            f"{path}: bad WAL magic {data[:len(MAGIC)]!r} (expected {MAGIC!r})")
    _scan_frames(data, len(MAGIC), records, report)
    return records, report


def _scan_frames(data: bytes, pos: int, records: list, report: WalReadReport
                 ) -> None:
    """Walk frames from byte `pos`, filling `records`/`report` — the one
    tolerant scan both full replay and incremental tailing go through."""
    report.valid_bytes = pos
    while pos < len(data):
        if pos + _FRAME.size > len(data):
            report.torn_tail = True
            report.torn_reason = f"short frame header at byte {pos}"
            break
        length, crc = _FRAME.unpack_from(data, pos)
        start = pos + _FRAME.size
        if start + length > len(data):
            report.torn_tail = True
            report.torn_reason = f"short payload at byte {pos}"
            break
        payload = data[start:start + length]
        if zlib.crc32(payload) != crc:
            report.torn_tail = True
            report.torn_reason = f"crc mismatch at byte {pos}"
            break
        records.append(payload)
        pos = start + length
        report.n_records += 1
        report.valid_bytes = pos
    else:
        report.valid_bytes = pos
    if report.torn_tail:
        report.errors.append(report.torn_reason)


def tail_wal_records(path, from_offset: int) -> tuple[list[bytes], WalReadReport]:
    """Incremental tolerant read: intact records from byte `from_offset` on.

    The torn-tail rules are exactly :func:`read_wal_records`' — a frame
    running past EOF or failing its CRC stops the scan and is reported,
    not raised, and ``report.valid_bytes`` is where the NEXT tail should
    start (so a cursor parked on a torn final record resumes cleanly once
    the append completes). Two extra contracts for cursors:

    * ``report.truncated`` is set when the file is now shorter than
      `from_offset` (or gone entirely while the cursor was mid-log): the
      log was compacted underneath the cursor, and the caller must reseed
      from a snapshot — silently rescanning from offset 0 would replay
      history the cursor already consumed onto state that already has it.
    * `from_offset` must be a frame boundary of the SAME log incarnation
      (a compaction followed by regrowth past the old offset is undetectable
      here — track :attr:`WriteAheadLog.resets` for that case).
    """
    report = WalReadReport()
    records: list[bytes] = []
    from_offset = max(int(from_offset), len(MAGIC))
    if not os.path.exists(path):
        report.truncated = from_offset > len(MAGIC)
        return records, report
    with open(path, "rb") as f:
        data = f.read()
    if len(data) < len(MAGIC):
        report.truncated = from_offset > len(MAGIC)
        report.torn_tail = len(data) > 0
        report.torn_reason = "short header" if data else ""
        return records, report
    if data[:len(MAGIC)] != MAGIC:
        raise ValueError(
            f"{path}: bad WAL magic {data[:len(MAGIC)]!r} (expected {MAGIC!r})")
    if from_offset > len(data):
        report.truncated = True
        report.valid_bytes = from_offset  # nothing here continues the cursor
        return records, report
    _scan_frames(data, from_offset, records, report)
    return records, report


@dataclass
class WalCursor:
    """A resumable tail position over one WAL file.

    ``tail()`` drains every record appended since the last call and
    advances; on a torn tail it stops at the damage and resumes past it on
    a later call (once the append completes). On truncation the cursor
    does NOT advance — the report's ``truncated`` flag tells the owner to
    reseed from a snapshot and start a fresh cursor.
    """

    path: str
    offset: int = len(MAGIC)
    records: int = 0   # records consumed since the cursor was seeded

    def tail(self) -> tuple[list[bytes], WalReadReport]:
        recs, report = tail_wal_records(self.path, self.offset)
        if not report.truncated:
            self.offset = report.valid_bytes
            self.records += len(recs)
        return recs, report
