import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.registry import all_cells, get_arch
from repro.launch.mesh import make_production_mesh, resolve_in_shardings, set_global_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import (
    model_flops,
    roofline_terms,
)


def _cell_meta(arch_id: str, shape_name: str) -> dict:
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    cfg = arch.config()
    meta = {"family": arch.family, "kind": shape.kind, **shape.params}
    if arch.family == "lm":
        meta.update(
            n_active_params=cfg.n_active_params(), n_params=cfg.n_params(),
            n_layers=cfg.n_layers, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
        )
    elif arch.family == "gnn":
        meta.update(n_layers=cfg.n_layers, d_hidden=cfg.d_hidden)
        if shape.kind == "minibatch":
            seeds, (f1, f2) = shape.params["batch_nodes"], shape.params["fanouts"]
            meta["n_nodes"] = seeds * (1 + f1 + f1 * f2)
            meta["n_edges"] = seeds * f1 + seeds * f1 * f2
        elif shape.kind == "molecule":
            meta["n_nodes"] = shape.params["batch"] * shape.params["n_nodes"]
            meta["n_edges"] = shape.params["batch"] * shape.params["n_edges"]
    else:
        meta.update(n_fields=cfg.n_fields, embed_dim=cfg.embed_dim,
                    n_params=cfg.n_params())
    return meta


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = int(np.prod(list(mesh.shape.values())))
    set_global_mesh(mesh)
    t0 = time.time()
    cell = build_cell(arch_id, shape_name, overrides=overrides)
    kw = {}
    if cell.meta and "out_shardings" in cell.meta:
        kw["out_shardings"] = resolve_in_shardings(mesh, cell.meta["out_shardings"])
    jitted = jax.jit(
        cell.fn, in_shardings=resolve_in_shardings(mesh, cell.in_specs),
        donate_argnums=cell.donate_argnums, **kw
    )
    lowered = jitted.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch_id} × {shape_name} × {'2pod' if multi_pod else '1pod'}] "
          f"memory_analysis: args={mem.argument_size_in_bytes/1e9:.3f}GB "
          f"out={mem.output_size_in_bytes/1e9:.3f}GB temp={mem.temp_size_in_bytes/1e9:.3f}GB "
          f"(per device)")
    cost = compiled.cost_analysis()
    # cost_analysis counts while-loop bodies ONCE (scan-undercount); use the
    # trip-count-aware HLO walk for the roofline terms (roofline/hlo_cost.py)
    from repro.roofline.hlo_cost import hlo_cost

    hc = hlo_cost(compiled.as_text(), n_devices)
    flops = hc.flops
    bytes_acc = hc.bytes
    wire = hc.wire_bytes
    colls = hc.collectives
    print(f"  hlo_cost(trip-aware): flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
          f"wire/dev={wire:.3e} | raw cost_analysis flops={float(cost.get('flops', 0.0)):.3e}")
    terms = roofline_terms(flops, bytes_acc, wire)
    meta = _cell_meta(arch_id, shape_name)
    mflops = model_flops(arch_id, shape_name, meta)
    hlo_global = flops * n_devices
    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": n_devices,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "cost": {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
        "collectives": colls,
        "wire_bytes_per_dev": wire,
        "roofline": terms,
        "model_flops_global": mflops,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": (mflops / hlo_global) if hlo_global else None,
    }
    fit = result["memory"]["peak_est_bytes"] < 16e9
    print(f"  roofline: compute={terms['compute_s']*1e3:.3f}ms memory={terms['memory_s']*1e3:.3f}ms "
          f"collective={terms['collective_s']*1e3:.3f}ms dominant={terms['dominant']} "
          f"| useful/HLO={result['useful_flops_ratio'] if result['useful_flops_ratio'] else float('nan'):.3f} "
          f"| fits16GB={fit}")
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON result(s) here")
    ap.add_argument("--override", action="append", default=[],
                    help="model-config override k=v (v parsed as python literal)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        import ast

        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    cells = [(args.arch, args.shape)] if args.arch and args.shape else all_cells()
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch_id, shape_name, mp, overrides or None))
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                results.append({
                    "arch": arch_id, "shape": shape_name,
                    "mesh": "pod2x16x16" if mp else "pod16x16",
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                })
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(results if len(results) > 1 else results[0], fh, indent=2)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\ndry-run: {n_ok}/{len(results)} cells compiled")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
