"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice);
multi-pod adds a leading `pod` axis (outer data-parallel over DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Single-device mesh for smoke tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
