"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice);
multi-pod adds a leading `pod` axis (outer data-parallel over DCN).
"""
from __future__ import annotations

import jax


def auto_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types across jax versions:
    `jax.sharding.AxisType` only exists from jax 0.5; on older releases
    Auto is already the default, so plain `make_mesh` is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def set_global_mesh(mesh):
    """`jax.set_mesh` across versions. Pre-0.6 jax has no process-global
    mesh setter; entering the mesh context (and deliberately never exiting —
    call sites set the mesh once per process: tests, dry-runs, trainers)
    gives the same ambient-mesh semantics."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()
    return mesh


def resolve_in_shardings(mesh, specs):
    """`jax.jit` sharding args across versions: jax with the explicit-mesh
    API (>= 0.6, detected via `jax.set_mesh`) accepts PartitionSpecs
    directly against the ambient mesh; older jax requires concrete
    `NamedSharding(mesh, spec)` objects. in_specs trees hold only
    PartitionSpecs (P() = replicated), so the mapping is 1:1."""
    if hasattr(jax, "set_mesh"):
        return specs
    P = jax.sharding.PartitionSpec
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s) if isinstance(s, P) else s,
        specs, is_leaf=lambda s: isinstance(s, P))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return auto_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke tests/examples."""
    return auto_mesh((1, 1), ("data", "model"))
