"""Multi-node training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --shape train_4k [--reduced] [--steps N] [--ckpt DIR] [--multi-pod]

On real hardware this runs under `jax.distributed.initialize` (one process
per host, mesh from --multi-pod); in this container use --reduced, which
shrinks the config and batch to CPU scale but exercises the identical code
path (cell builder -> jit with shardings -> step loop -> checkpoint).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               resolve_in_shardings, set_global_mesh)
from repro.launch.steps import build_cell
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.fault_tolerance import StragglerDetector, data_skip_offset


def _materialize(abstract, key):
    """Random-init concrete buffers matching an abstract pytree (driver-side
    stand-in for the per-arch init fns, which the cells embed abstractly)."""
    leaves, treedef = jax.tree_util.tree_flatten(abstract)
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, leaf in zip(keys, leaves):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            fan = leaf.shape[0] if leaf.ndim else 1
            vals.append(jax.random.normal(k, leaf.shape, leaf.dtype) * (0.02 / max(fan, 1) ** 0.5 + 0.01))
        elif jnp.issubdtype(leaf.dtype, jnp.integer):
            vals.append(jnp.zeros(leaf.shape, leaf.dtype))
        else:
            vals.append(jnp.zeros(leaf.shape, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, vals)


def _synth_batch(args_abstract, rng, vocab_hint=256):
    out = []
    for leaf in args_abstract:
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jnp.asarray(rng.integers(0, vocab_hint, leaf.shape), leaf.dtype))
        elif leaf.dtype == jnp.bool_:
            out.append(jnp.asarray(rng.random(leaf.shape) < 0.5))
        else:
            out.append(jnp.asarray(rng.normal(size=leaf.shape), leaf.dtype))
    return tuple(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="1x1 mesh (CPU smoke); default = production mesh")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    mesh = make_host_mesh() if args.host_mesh else make_production_mesh(multi_pod=args.multi_pod)
    set_global_mesh(mesh)
    cell = build_cell(args.arch, args.shape, reduced=args.reduced)
    step_fn = jax.jit(cell.fn, in_shardings=resolve_in_shardings(mesh, cell.in_specs),
                      donate_argnums=cell.donate_argnums)

    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    params = _materialize(cell.args[0], key)
    opt_state = _materialize(cell.args[1], key) if len(cell.args) > 2 else None
    # zero moments/step for a clean start
    if opt_state is not None:
        opt_state = jax.tree.map(lambda a: jnp.zeros_like(a), opt_state)

    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt) if args.ckpt else None
    if args.ckpt and latest_step(args.ckpt) is not None:
        state, start_step = restore_checkpoint(args.ckpt)
        params, opt_state = state["params"], state["opt_state"]
        print(f"restored step {start_step}; data offset "
              f"{data_skip_offset(start_step, cell.args[2].shape[0])}")

    straggler = StragglerDetector()
    vocab = getattr(get_arch(args.arch), "vocab", 256)
    for step in range(start_step, start_step + args.steps):
        batch = _synth_batch(cell.args[2:], rng, vocab_hint=vocab)
        t0 = time.monotonic()
        params, opt_state, loss, metrics = step_fn(params, opt_state, *batch)
        jax.block_until_ready(loss)
        dt = time.monotonic() - t0
        straggler.observe(jax.process_index(), dt)
        print(f"step {step}: loss={float(loss):.4f} "
              f"grad_norm={float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if ckpt and (step + 1) % 100 == 0:
            ckpt.save(step + 1, {"params": params, "opt_state": opt_state})
    if ckpt:
        ckpt.save(start_step + args.steps, {"params": params, "opt_state": opt_state})
        ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
