"""Step builders + abstract input specs for every (arch × shape) cell.

`build_cell(arch_id, shape_name)` returns a `Cell` whose `fn` is the jitted
step (train_step / prefill / decode / serve / retrieval per the shape's
kind), `args` are ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
no device allocation), and `in_specs` are PartitionSpecs resolved against
the active mesh (call under `jax.set_mesh`).

Sizes are rounded up to multiples of 256 (=16×16 mesh) where sharding needs
divisibility; the data loader performs the same padding in real runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ShapeSpec, get_arch
from repro.distributed.collectives import partitioned_segment_sum
from repro.distributed.sharding import logical_spec, param_spec, zero1_spec
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def _r256(n: int) -> int:
    return ((n + 255) // 256) * 256


# gradient-accumulation microbatches per LM train step (activation-memory
# control: yi-34b carries 60 layers × (B_local, 4k, 7168) between scan steps)
GRAD_ACCUM = {
    "yi-34b": 16,
    "gemma2-9b": 8,
    "phi3.5-moe-42b-a6.6b": 8,
    "qwen2-1.5b": 4,
    "olmoe-1b-7b": 8,  # 4 left 23 GB/dev (§Roofline baseline); 8 fits
}


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple            # pytree of ShapeDtypeStruct
    in_specs: tuple        # matching pytree of PartitionSpec
    donate_argnums: tuple = ()
    meta: dict | None = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _kp_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def _tree_param_specs(abstract_params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = [param_spec(_kp_str(kp), leaf.shape) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _tree_opt_specs(abstract_opt, pspecs_by_path):
    """master/m/v get ZeRO-1 augmented specs; step is replicated."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_opt)
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        if path == "step":
            specs.append(P())
            continue
        sub = path.split("/", 1)[1]  # strip master|m|v prefix
        base = pspecs_by_path.get(sub, P())
        specs.append(zero1_spec(base, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _pspecs_by_path(abstract_params):
    flat, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[path] = param_spec(path, leaf.shape)
    return out


# ===================================================================== LM
def _lm_cell(arch_id: str, shape: ShapeSpec, reduced: bool, overrides=None) -> Cell:
    import dataclasses

    arch = get_arch(arch_id)
    cfg = arch.reduced() if reduced else arch.config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    B = shape.params["global_batch"]
    S = shape.params["seq_len"]
    if reduced:
        B, S = 2, min(S, 64)
    opt_cfg = AdamWConfig()

    abstract_params = jax.eval_shape(partial(tf_mod.init_params, cfg), jax.random.PRNGKey(0))
    pspecs = _tree_param_specs(abstract_params)

    if shape.kind == "train":
        abstract_opt = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), abstract_params)
        pby = _pspecs_by_path(abstract_params)
        ospecs = _tree_opt_specs(abstract_opt, pby)
        n_micro = 1 if reduced else GRAD_ACCUM.get(arch_id, 1)
        # fp32 grad accumulator sharded ZeRO-style (params spec + data axis):
        # the per-microbatch reduce-scatter this induces is the standard
        # ZeRO-2 trade (collective traffic for accumulator memory)
        flatp, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
        gspecs = jax.tree_util.tree_unflatten(treedef, [
            zero1_spec(pby[_kp_str(kp)], leaf.shape) for kp, leaf in flatp
        ])
        have_mesh = any(s != () and tuple(s) != (None,) * len(tuple(s)) for s in jax.tree.leaves(gspecs)) \
            if jax.tree.leaves(gspecs) else False

        def train_step(params, opt_state, tokens, targets):
            Bl, Sl = tokens.shape
            mb = Bl // n_micro
            tok = tokens.reshape(n_micro, mb, Sl)
            tgt = targets.reshape(n_micro, mb, Sl)

            def constrain(tree):
                if not have_mesh:
                    return tree
                return jax.tree.map(
                    lambda a, sp: a if all(e is None for e in sp) else
                    jax.lax.with_sharding_constraint(a, sp),
                    tree, gspecs,
                )

            def micro(carry, xs):
                g_acc, loss_acc = carry
                t, y = xs
                loss, g = jax.value_and_grad(tf_mod.forward_loss)(params, t, y, cfg)
                g_acc = constrain(jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g))
                return (g_acc, loss_acc + loss), None

            g0 = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_acc, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)), (tok, tgt))
            grads = jax.tree.map(lambda g: g / n_micro, g_acc)
            params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
            return params, opt_state, loss_sum / n_micro, metrics

        args = (
            abstract_params, abstract_opt,
            _sds((B, S), jnp.int32), _sds((B, S), jnp.int32),
        )
        specs = (pspecs, ospecs,
                 logical_spec(("batch", None), (B, S)),
                 logical_spec(("batch", None), (B, S)))
        return Cell(arch_id, shape.name, train_step, args, specs, donate_argnums=(0, 1))

    if shape.kind == "prefill":
        def prefill(params, tokens):
            return tf_mod.prefill_step(params, tokens, cfg)

        args = (abstract_params, _sds((B, S), jnp.int32))
        specs = (pspecs, logical_spec(("batch", None), (B, S)))
        return Cell(arch_id, shape.name, prefill, args, specs)

    # decode (incl. long_500k): one new token against a seq_len KV cache
    abstract_cache = jax.eval_shape(partial(tf_mod.init_cache, cfg, B, S))
    lead = (None,) * len(cfg.layers_leading)
    cache_spec = jax.tree.map(
        lambda l: logical_spec(lead + ("batch", "kv_seq", "kv_heads", None), l.shape),
        abstract_cache,
    )

    def decode(params, cache, tokens, index):
        return tf_mod.decode_step(params, cache, tokens, index, cfg)

    args = (abstract_params, abstract_cache, _sds((B,), jnp.int32), _sds((), jnp.int32))
    specs = (pspecs, cache_spec, logical_spec(("batch",), (B,)), P())
    # out_shardings pin the new cache to the input layout so donation
    # aliases it in place (otherwise GSPMD may pick a different out
    # sharding and double the cache footprint)
    out_specs = (P(), cache_spec)
    return Cell(arch_id, shape.name, decode, args, specs, donate_argnums=(1,),
                meta={"out_shardings": out_specs})


# ===================================================================== GNN
_GNN_EDGE_FEAT = 8
_GNN_OUT = {"gcn-cora": None, "gatedgcn": None, "meshgraphnet": 3, "nequip": 1}


def _gnn_sizes(shape: ShapeSpec, reduced: bool):
    p = shape.params
    if shape.kind == "minibatch":
        seeds = p["batch_nodes"]
        f1, f2 = p["fanouts"]
        n = seeds * (1 + f1 + f1 * f2)
        e = seeds * f1 + seeds * f1 * f2
        d_feat, n_cls = p["d_feat"], p["n_classes"]
    elif shape.kind == "molecule":
        n = p["batch"] * p["n_nodes"]
        e = p["batch"] * p["n_edges"]
        d_feat, n_cls = p["d_feat"], 1
    else:
        n, e = p["n_nodes"], p["n_edges"]
        d_feat, n_cls = p["d_feat"], p.get("n_classes", 2)
    if reduced:
        scale = max(n // 64, 1)
        n, e = max(n // scale, 8), max(e // scale, 16)
        d_feat = min(d_feat, 16)
    return _r256(n), _r256(e), d_feat, n_cls


def _gnn_cell(arch_id: str, shape: ShapeSpec, reduced: bool) -> Cell:
    arch = get_arch(arch_id)
    cfg = arch.reduced() if reduced else arch.config()
    n, e, d_feat, n_cls = _gnn_sizes(shape, reduced)
    opt_cfg = AdamWConfig()
    key = jax.random.PRNGKey(0)

    # §Perf D: receiver-partitioned edges (loader contract, see
    # distributed.collectives.partition_edges) make message aggregation a
    # local scatter per shard instead of a full-(N,d) all-reduce per layer
    agg = partitioned_segment_sum
    if arch_id == "gcn-cora":
        init = partial(gnn_mod.gcn_init, cfg, key, d_feat, n_cls)
        apply_fn = lambda p, b: gnn_mod.gcn_apply(p, b["x"], b["senders"], b["receivers"], n, cfg, agg_fn=agg)
    elif arch_id == "gatedgcn":
        init = partial(gnn_mod.gatedgcn_init, cfg, key, d_feat, _GNN_EDGE_FEAT, n_cls)
        apply_fn = lambda p, b: gnn_mod.gatedgcn_apply(
            p, b["x"], b["ef"], b["senders"], b["receivers"], n, cfg, agg_fn=agg)
    elif arch_id == "meshgraphnet":
        init = partial(gnn_mod.meshgraphnet_init, cfg, key, d_feat, _GNN_EDGE_FEAT, 3)
        apply_fn = lambda p, b: gnn_mod.meshgraphnet_apply(
            p, b["x"], b["ef"], b["senders"], b["receivers"], n, cfg, agg_fn=agg)
    else:  # nequip
        init = partial(gnn_mod.nequip_init, cfg, key, 64)
        apply_fn = lambda p, b: gnn_mod.nequip_apply(
            p, b["species"], b["pos"], b["senders"], b["receivers"], n, cfg, agg_fn=agg)

    abstract_params = jax.eval_shape(init)
    pspecs = _tree_param_specs(abstract_params)
    abstract_opt = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), abstract_params)
    ospecs = _tree_opt_specs(abstract_opt, _pspecs_by_path(abstract_params))

    edge_spec = logical_spec(("edges",), (e,))
    batch = {
        "senders": (_sds((e,), jnp.int32), edge_spec),
        "receivers": (_sds((e,), jnp.int32), edge_spec),
    }
    if arch_id == "nequip":
        batch["species"] = (_sds((n,), jnp.int32), P())
        batch["pos"] = (_sds((n, 3), jnp.float32), P())
    else:
        batch["x"] = (_sds((n, d_feat), jnp.float32), P())
        if arch_id != "gcn-cora":
            batch["ef"] = (_sds((e, _GNN_EDGE_FEAT), jnp.float32),
                           logical_spec(("edges", None), (e, _GNN_EDGE_FEAT)))

    regression = arch_id in ("meshgraphnet", "nequip")
    if regression:
        d_out = _GNN_OUT[arch_id]
        batch["y"] = (_sds((n, d_out), jnp.float32), P())
    else:
        batch["y"] = (_sds((n,), jnp.int32), P())
    if shape.kind == "minibatch":
        batch["seed_mask"] = (_sds((n,), jnp.bool_), P())
    if shape.kind == "molecule":
        batch["graph_ids"] = (_sds((n,), jnp.int32), P())

    def loss_fn(params, b):
        out = apply_fn(params, b)
        if regression:
            per_node = jnp.mean(jnp.square(out - b["y"]), axis=-1)
        else:
            logp = jax.nn.log_softmax(out)
            per_node = -jnp.take_along_axis(logp, jnp.maximum(b["y"], 0)[:, None], axis=1)[:, 0]
        if "seed_mask" in b:
            w = b["seed_mask"].astype(jnp.float32)
            return (per_node * w).sum() / jnp.maximum(w.sum(), 1)
        return per_node.mean()

    def train_step(params, opt_state, b):
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss, metrics

    args = (abstract_params, abstract_opt, {k: v[0] for k, v in batch.items()})
    specs = (pspecs, ospecs, {k: v[1] for k, v in batch.items()})
    return Cell(arch_id, shape.name, train_step, args, specs, donate_argnums=(0, 1))


# ================================================================= RecSys
def _dlrm_cell(arch_id: str, shape: ShapeSpec, reduced: bool) -> Cell:
    arch = get_arch(arch_id)
    cfg = arch.reduced() if reduced else arch.config()
    key = jax.random.PRNGKey(0)

    if shape.kind == "retrieval":
        n_cand = _r256(shape.params["n_candidates"]) if not reduced else 1024
        d = cfg.embed_dim

        def retrieval(query, cands):
            return dlrm_mod.retrieval_scores(query, cands, k=100)

        args = (_sds((d,), jnp.float32), _sds((n_cand, d), jnp.float32))
        specs = (P(), logical_spec(("table_rows", None), (n_cand, d)))
        return Cell(arch_id, shape.name, retrieval, args, specs)

    B = shape.params["batch"]
    if reduced:
        B = 32
    abstract_params = jax.eval_shape(partial(dlrm_mod.dlrm_init, cfg), key)
    pspecs = _tree_param_specs(abstract_params)
    dense = _sds((B, cfg.n_dense), jnp.float32)
    sparse = _sds((B, cfg.n_sparse), jnp.int32)
    bspec = logical_spec(("wide_batch", None), (B, cfg.n_dense))

    if shape.kind == "serve":
        def serve(params, dense, sparse):
            return dlrm_mod.dlrm_apply(params, dense, sparse, cfg)

        return Cell(arch_id, shape.name, serve, (abstract_params, dense, sparse),
                    (pspecs, bspec, bspec))

    opt_cfg = AdamWConfig(sgd_paths=("tables",))
    abstract_opt = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), abstract_params)
    ospecs = _tree_opt_specs(abstract_opt, _pspecs_by_path(abstract_params))

    def train_step(params, opt_state, dense, sparse, labels):
        loss, grads = jax.value_and_grad(dlrm_mod.dlrm_loss)(params, dense, sparse, labels, cfg)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss, metrics

    args = (abstract_params, abstract_opt, dense, sparse, _sds((B,), jnp.float32))
    specs = (pspecs, ospecs, bspec, bspec, logical_spec(("wide_batch",), (B,)))
    return Cell(arch_id, shape.name, train_step, args, specs, donate_argnums=(0, 1))


# ================================================================= dispatch
def build_cell(arch_id: str, shape_name: str, reduced: bool = False,
               overrides: dict | None = None) -> Cell:
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    if arch.family == "lm":
        return _lm_cell(arch_id, shape, reduced, overrides)
    if arch.family == "gnn":
        return _gnn_cell(arch_id, shape, reduced)
    return _dlrm_cell(arch_id, shape, reduced)
