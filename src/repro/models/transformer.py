"""Decoder-only transformer covering the five assigned LM architectures:
dense GQA (qwen2/yi), Gemma-2 (alternating local/global attention, logit
softcaps, post-norms), and MoE (phi-3.5-MoE 16e top-2, OLMoE 64e top-8).

Functional: params are pytrees; layers are stacked on a leading dim and
scanned (keeps HLO size O(1) in depth — 60-layer yi-34b compiles fast);
remat policy is configurable. Sharding is expressed via logical axis names
(repro.distributed.sharding) so the same model lowers on 1 device, one pod
(data×model) and multi-pod (pod×data×model) meshes.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import chunked_cross_entropy, rms_norm, rope


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group: int = 2048
    # gemma-2 extras
    local_window: int | None = None    # if set, layers alternate local/global
    attn_softcap: float | None = None
    final_softcap: float | None = None
    post_norms: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # numerics / lowering
    dtype: Any = jnp.bfloat16
    remat: str = "full"                # none | full | dots
    ce_chunk: int = 256
    scan_layers: bool = True
    attn_chunk_q: int = 512            # flash-style chunking kicks in when
    attn_chunk_k: int = 1024           # q length exceeds attn_chunk_q
    context_parallel: bool = False     # shard q-sequence over 'model' inside
                                       # attention (K/V all-gathered) — the
                                       # §Perf A2 optimization; essential when
                                       # head counts don't divide the TP axis
    seq_parallel_residual: bool = False  # §Perf A3 — REFUTED on this mesh:
                                         # GSPMD falls back to involuntary
                                         # full remat on the stream
                                         # transitions (collective 59->257 s)

    @property
    def alternating(self) -> bool:
        return self.local_window is not None

    @property
    def layers_leading(self) -> tuple:
        return (self.n_layers // 2, 2) if self.alternating else (self.n_layers,)

    def n_params(self) -> int:
        d, h, kv, dh, f, v = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim, self.d_ff, self.vocab
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.n_experts:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def n_active_params(self) -> int:
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_like = self.n_params() - self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return dense_like


# ---------------------------------------------------------------- params
def init_params(cfg: TransformerConfig, key) -> dict:
    keys = iter(jax.random.split(key, 64))
    d, h, kv, dh, f, v = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.d_ff, cfg.vocab)
    L = cfg.layers_leading

    def stacked(shape, k, scale=None):
        scale = scale if scale is not None else 1.0 / (shape[0] ** 0.5)
        return jax.random.normal(k, L + shape, cfg.dtype) * scale

    layer = {
        "ln_attn": jnp.zeros(L + (d,), cfg.dtype),
        "wq": stacked((d, h * dh), next(keys)),
        "wk": stacked((d, kv * dh), next(keys)),
        "wv": stacked((d, kv * dh), next(keys)),
        "wo": stacked((h * dh, d), next(keys)),
        "ln_mlp": jnp.zeros(L + (d,), cfg.dtype),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros(L + (h * dh,), cfg.dtype)
        layer["bk"] = jnp.zeros(L + (kv * dh,), cfg.dtype)
        layer["bv"] = jnp.zeros(L + (kv * dh,), cfg.dtype)
    if cfg.post_norms:
        layer["ln_attn_post"] = jnp.zeros(L + (d,), cfg.dtype)
        layer["ln_mlp_post"] = jnp.zeros(L + (d,), cfg.dtype)
    if cfg.n_experts:
        layer["router"] = stacked((d, cfg.n_experts), next(keys))
        layer["w_gate_e"] = stacked((cfg.n_experts, d, f), next(keys), scale=1.0 / d ** 0.5)
        layer["w_up_e"] = stacked((cfg.n_experts, d, f), next(keys), scale=1.0 / d ** 0.5)
        layer["w_down_e"] = stacked((cfg.n_experts, f, d), next(keys), scale=1.0 / f ** 0.5)
    else:
        layer["w_gate"] = stacked((d, f), next(keys))
        layer["w_up"] = stacked((d, f), next(keys))
        layer["w_down"] = stacked((f, d), next(keys))
    return {
        "embed": jax.random.normal(next(keys), (v, d), cfg.dtype) * 0.02,
        "layers": layer,
        "ln_final": jnp.zeros((d,), cfg.dtype),
        "w_vocab": jax.random.normal(next(keys), (d, v), cfg.dtype) * (1.0 / d ** 0.5),
    }


# ---------------------------------------------------------------- attention
def _attention(x, p, cfg: TransformerConfig, positions, *, window, cache=None,
               cache_index=None):
    B, S, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = h // kv
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, kv, group, dh)
    k = k.reshape(B, S, kv, dh)
    v = v.reshape(B, S, kv, dh)
    q = rope(q.reshape(B, S, kv * group, dh), positions, cfg.rope_theta).reshape(B, S, kv, group, dh)
    k = rope(k, positions, cfg.rope_theta)
    ctx_par = cfg.context_parallel and cache is None and S > cfg.attn_chunk_q
    if ctx_par:
        # context parallelism: q-sequence sharded over the TP axis inside
        # the flash chunks, K/V replicated within it (one small all-gather
        # per layer) — scores shard over 'model' even when head counts
        # don't divide the TP axis (yi: 56 heads, qwen2: 12)
        k = shard(k, ("batch", None, None, None))
        v = shard(v, ("batch", None, None, None))
    else:
        q = shard(q, ("batch", None, "kv_heads", None, None))
        k = shard(k, ("batch", None, "kv_heads", None))

    if cache is not None:
        ck, cv = cache  # (B, Smax, kv, dh)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        k_att, v_att = ck, cv
        Skv = ck.shape[1]
        k_pos = jnp.arange(Skv)
        q_pos = positions  # (B, S) absolute
        new_cache = (ck, cv)
    else:
        k_att, v_att = k, v
        Skv = S
        k_pos = jnp.arange(S)
        q_pos = positions
        new_cache = None

    scale = dh ** -0.5
    if S > cfg.attn_chunk_q and S % cfg.attn_chunk_q == 0:
        out = _flash_jnp(q, k_att, v_att, q_pos, k_pos, window=window,
                         softcap=cfg.attn_softcap, scale=scale,
                         chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
                         seq_shard=ctx_par)
    else:
        # keep K/V in cache dtype with fp32 MXU accumulation: an explicit
        # .astype(f32) on k_att gets hoisted OUT of the layer scan by XLA,
        # materializing an fp32 copy of the entire stacked KV cache
        # (measured: 3 x 5.6 GB/device on gemma2 decode_32k)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k_att,
                            preferred_element_type=jnp.float32) * scale
        if cfg.attn_softcap is not None:
            scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
        # scores: (B, kv, group, S, Skv); mask broadcast (B, 1, 1, S, Skv)
        mask = (q_pos[:, :, None] >= k_pos[None, None, :])[:, None, None]
        if window is not None:
            mask &= (k_pos[None, None, :] > q_pos[:, :, None] - window)[:, None, None]
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_att.dtype), v_att,
                         preferred_element_type=jnp.float32)
    out = out.reshape(B, S, h * dh).astype(x.dtype)
    return out @ p["wo"], new_cache


def _flash_jnp(q, k, v, q_pos, k_pos, *, window, softcap, scale,
               chunk_q, chunk_k, seq_shard=False):
    """Memory-efficient attention: double scan (q chunks × kv chunks) with
    an online softmax — the pure-jnp twin of kernels/flash_attention.py,
    used on long sequences so no S×S score tensor is ever materialized.

    q: (B, S, kv, g, dh); k, v: (B, Skv, kv, dh); returns (B, S, kv, g, dh).
    """
    B, S, kvh, g, dh = q.shape
    Skv = k.shape[1]
    ck = chunk_k if Skv % chunk_k == 0 else Skv
    nq, nk = S // chunk_q, Skv // ck
    qs = jnp.moveaxis(q.reshape(B, nq, chunk_q, kvh, g, dh), 1, 0)
    qps = jnp.moveaxis(q_pos.reshape(B, nq, chunk_q), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, ck, kvh, dh), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, ck, kvh, dh), 1, 0)
    kps = k_pos.reshape(nk, ck)
    if seq_shard:
        # context parallelism: each q chunk's rows shard over 'model'
        qs = shard(qs, (None, "batch", "seq_model", None, None, None))

    def q_step(_, qc):
        q_blk, qp = qc  # (B, Cq, kv, g, dh), (B, Cq)

        def kv_step(carry, kc):
            m, l, acc = carry
            k_blk, v_blk, kp = kc
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            mask = (qp[:, :, None] >= kp[None, None, :])[:, None, None]
            if window is not None:
                mask &= (kp[None, None, :] > qp[:, :, None] - window)[:, None, None]
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, kvh, g, chunk_q), -1e30, jnp.float32),
            jnp.zeros((B, kvh, g, chunk_q), jnp.float32),
            jnp.zeros((B, kvh, g, chunk_q, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (ks, vs, kps))
        o = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
        o = jnp.moveaxis(o, 3, 1)  # (B, Cq, kv, g, dh)
        if seq_shard:
            o = shard(o, ("batch", "seq_model", None, None, None))
        return None, o

    _, out = jax.lax.scan(q_step, None, (qs, qps))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, kvh, g, dh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------- FFN / MoE
def _residual_names(cfg) -> tuple:
    # sequence-parallel residual stream (Megatron-SP): outside attention all
    # ops are per-token, so the (B, S, D) stream could shard over 'model' on
    # S. Measured (§Perf A3): GSPMD handles the stream<->matmul transitions
    # with involuntary full remat — 4.4× MORE collective — so default off.
    if cfg.context_parallel and cfg.seq_parallel_residual:
        return ("batch", "seq_model", None)
    return ("batch", None, None)


def _dense_ffn(x, p, cfg):
    gate = jax.nn.silu(x @ p["w_gate"])
    up = x @ p["w_up"]
    y = (gate * up) @ p["w_down"]
    return shard(y, _residual_names(cfg))


def _moe_ffn(x, p, cfg: TransformerConfig):
    """GShard grouped dispatch, fully parallel layout (§Perf B1–B3).

    Token groups are a *tensor axis sharded over the data mesh axis* (not a
    scan): with experts on 'model', every stage — one-hot dispatch, expert
    GEMMs, weighted combine — is device-local for the (group-shard, expert-
    shard) pair it lives on. The earlier scanned-group variant replicated
    the expert GEMMs across the data axis (16× redundant compute, §B2) or
    all-reduced full combine outputs per group (§B2'). Dispatch/combine
    tensors are bf16, routing positions exact int32 cumsum (§B1).
    Returns (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = min(cfg.moe_group, B * S)
    tokens = x.reshape(-1, d)
    n = tokens.shape[0] // G
    tokens = tokens.reshape(n, G, d)
    tokens = shard(tokens, ("batch", None, None))
    C = max(int(k * G / E * cfg.capacity_factor), k)

    cdt = cfg.dtype  # bf16 at scale; fp32 in reduced configs (CPU-executable)
    logits = jnp.einsum("ngd,de->nge", tokens, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                            # (n, G, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    onehot_i = jax.nn.one_hot(idx, E, dtype=jnp.int32)              # (n, G, k, E)
    flat = onehot_i.reshape(n, G * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                           # (n, G*k, E)
    pos = (pos * flat).sum(-1).reshape(n, G, k)
    keep = pos < C
    onehot = onehot_i.astype(cdt)
    disp = jnp.einsum("ngke,ngkc->ngec",
                      onehot * keep[..., None].astype(cdt),
                      jax.nn.one_hot(pos, C, dtype=cdt))      # (n, G, E, C)
    disp = shard(disp, ("batch", None, "experts", None))
    xe = jnp.einsum("ngec,ngd->necd", disp, tokens.astype(cdt),
                    preferred_element_type=jnp.float32).astype(cdt)
    xe = shard(xe, ("batch", "experts", None, None))
    hidden = jax.nn.silu(jnp.einsum(
        "necd,edf->necf", xe, p["w_gate_e"].astype(cdt),
        preferred_element_type=jnp.float32)) \
        * jnp.einsum("necd,edf->necf", xe, p["w_up_e"].astype(cdt),
                     preferred_element_type=jnp.float32)
    ye = jnp.einsum("necf,efd->necd", hidden.astype(cdt),
                    p["w_down_e"].astype(cdt),
                    preferred_element_type=jnp.float32).astype(cdt)
    ye = shard(ye, ("batch", "experts", None, None))
    gate_e = jnp.einsum("ngke,ngk->nge", onehot.astype(jnp.float32), gates * keep)
    y = jnp.einsum("ngec,nge,necd->ngd", disp, gate_e.astype(cdt), ye,
                   preferred_element_type=jnp.float32)
    # load-balance aux loss (Switch): E * mean(top1 fraction) . mean(prob)
    frac = onehot_i[:, :, 0].astype(jnp.float32).mean((0, 1))
    aux = E * jnp.sum(frac * probs.mean((0, 1)))
    return y.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------- layers
def _layer(x, p, cfg: TransformerConfig, positions, *, window, cache=None, cache_index=None):
    a_in = rms_norm(x, p["ln_attn"])
    attn, new_cache = _attention(a_in, p, cfg, positions, window=window,
                                 cache=cache, cache_index=cache_index)
    if cfg.post_norms:
        attn = rms_norm(attn, p["ln_attn_post"])
    x = x + attn
    x = shard(x, _residual_names(cfg))
    m_in = rms_norm(x, p["ln_mlp"])
    if cfg.n_experts:
        mlp, aux = _moe_ffn(m_in, p, cfg)
    else:
        mlp, aux = _dense_ffn(m_in, p, cfg), jnp.float32(0.0)
    if cfg.post_norms:
        mlp = rms_norm(mlp, p["ln_mlp_post"])
    return x + mlp, aux, new_cache


def _remat(fn, cfg: TransformerConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _stack_scan(x, params, cfg: TransformerConfig, positions, caches=None, cache_index=None):
    """Scan over (stacked) layers; handles gemma2-style (L/2, 2) alternation."""

    def step(carry, xs):
        xc, aux_acc = carry
        p_layer, cache_l = xs

        if cfg.alternating:
            windows = (cfg.local_window, None)
            new_cache_l = []
            for sub in range(2):
                p_sub = jax.tree.map(lambda a: a[sub], p_layer)
                c_sub = None if cache_l is None else jax.tree.map(lambda a: a[sub], cache_l)
                xc, aux, nc = _layer(xc, p_sub, cfg, positions, window=windows[sub],
                                     cache=c_sub, cache_index=cache_index)
                aux_acc = aux_acc + aux
                new_cache_l.append(nc)
            nc_stacked = (None if caches is None else
                          jax.tree.map(lambda *a: jnp.stack(a), *new_cache_l))
            return (xc, aux_acc), nc_stacked
        else:
            xc, aux, nc = _layer(xc, p_layer, cfg, positions, window=None,
                                 cache=cache_l, cache_index=cache_index)
            return (xc, aux_acc + aux), nc

    step = _remat(step, cfg)
    xs = (params["layers"], caches) if caches is not None else (params["layers"], None)
    if caches is None:
        (x, aux), _ = jax.lax.scan(lambda c, pl: step(c, (pl, None)),
                                   (x, jnp.float32(0.0)), params["layers"])
        return x, aux, None
    (x, aux), new_caches = jax.lax.scan(step, (x, jnp.float32(0.0)), xs)
    return x, aux, new_caches


# ---------------------------------------------------------------- entry points
def forward_logits(params, tokens, cfg: TransformerConfig):
    """Teacher-forced logits (B, S, V) — testing/serving prefill path."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, _aux, _ = _stack_scan(x, params, cfg, positions)
    x = rms_norm(x, params["ln_final"])
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["w_vocab"].astype(jnp.float32))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def forward_loss(params, tokens, targets, cfg: TransformerConfig):
    """Training forward: tokens/targets (B, S) -> scalar loss."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, _residual_names(cfg))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, aux, _ = _stack_scan(x, params, cfg, positions)
    x = rms_norm(x, params["ln_final"])
    loss = chunked_cross_entropy(x, params["w_vocab"], targets,
                                 chunk=cfg.ce_chunk, softcap=cfg.final_softcap)
    return loss + 0.01 * aux / max(cfg.n_layers, 1)


def init_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    shape = cfg.layers_leading + (batch, max_len, kv, dh)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill_step(params, tokens, cfg: TransformerConfig, max_len: int | None = None):
    """Serving prefill: run the full prompt, materialize the KV cache, and
    return (last-position logits (B, V), cache). `max_len` reserves cache
    room beyond the prompt for subsequent decode_steps."""
    cfg = replace(cfg, remat="none")  # no grads in serving; remat only copies
    B, S = tokens.shape
    max_len = max_len or S
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    caches = init_cache(cfg, B, max_len)
    x, _aux, new_caches = _stack_scan(x, params, cfg, positions,
                                      caches=caches, cache_index=0)
    x_last = rms_norm(x[:, -1], params["ln_final"])
    logits = (x_last @ params["w_vocab"]).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, new_caches


def decode_step(params, cache, tokens, cur_index, cfg: TransformerConfig):
    """One-token decode: tokens (B,) int32, cur_index scalar — returns
    (logits (B, V), new_cache). KV cache is (L..., B, Smax, kv, dh)."""
    cfg = replace(cfg, remat="none")  # no grads in serving; remat only copies
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cfg.dtype)  # (B, 1, D)
    positions = jnp.full((B, 1), cur_index, dtype=jnp.int32)
    caches = cache
    x, _aux, new_caches = _stack_scan(x, params, cfg, positions,
                                      caches=caches, cache_index=cur_index)
    x = rms_norm(x, params["ln_final"])
    logits = (x[:, 0] @ params["w_vocab"]).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, new_caches
