"""Shared model building blocks (pure JAX, functional params-as-pytrees)."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict of jnp arrays


def dense_init(key, d_in, d_out, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


def rope(x, positions, theta=10000.0):
    """x: (..., S, H, D); positions: (..., S). Rotary over last dim pairs."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def chunked_cross_entropy(h, w_vocab, targets, *, chunk=256, softcap=None):
    """Token CE without materializing (B, S, V) logits at once.

    h: (B, S, D); w_vocab: (D, V); targets: (B, S) int32; -100 = ignore.
    Scans sequence chunks: per-chunk logits live only inside the scan body —
    the key memory optimization for 256k vocabularies at 4k context.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    h_c = h.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)       # (n, B, c, D)
    t_c = targets.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        loss_sum, count = carry
        hc, tc = xs
        logits = jnp.einsum("bcd,dv->bcv", hc.astype(jnp.float32), w_vocab.astype(jnp.float32))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = tc != -100
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - tgt, 0.0)
        return (loss_sum + nll.sum(), count + mask.sum()), None

    (loss_sum, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (h_c, t_c))
    return loss_sum / jnp.maximum(count, 1)


def mlp_params(key, sizes, dtype=jnp.float32, bias=True):
    keys = jax.random.split(key, len(sizes) - 1)
    layers = []
    for k, d_in, d_out in zip(keys, sizes[:-1], sizes[1:]):
        p = {"w": dense_init(k, d_in, d_out, dtype=dtype)}
        if bias:
            p["b"] = jnp.zeros((d_out,), dtype)
        layers.append(p)
    return layers


def mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, p in enumerate(layers):
        x = x @ p["w"]
        if "b" in p:
            x = x + p["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
