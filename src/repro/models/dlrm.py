"""DLRM (Naumov et al. 2019), MLPerf benchmark config over Criteo-1TB.

Huge sparse embedding tables (the hot path) + bottom MLP over dense
features + dot feature interaction + top MLP. Tables are row-sharded over
the *whole* mesh (logical axis "table_rows" -> data×model) — 26 tables,
~178M total rows × 128 = ~91 GB fp32, ~356 MB per chip at 256 chips.

Lookups are single-hot per field on Criteo (the embedding-bag kernel in
repro.kernels handles multi-hot for other datasets).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models.common import mlp_apply, mlp_params

# MLPerf DLRM Criteo Terabyte per-field cardinalities (dlrm repo day-23)
CRITEO_TB_ROWS = (
    45833188, 36746, 17245, 7413, 20243, 3, 7114, 1441, 62, 29275261,
    1572176, 345138, 10, 2209, 11267, 128, 4, 974, 14, 48937457,
    11316796, 40094537, 452104, 12606, 104, 35,
)


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    row_counts: tuple = CRITEO_TB_ROWS
    interaction: str = "dot"
    compute_dtype: str = "bfloat16"  # activation/wire dtype; fp32 in reduced
    row_pad: int = 256  # pad table rows to a mesh multiple so row-sharding
                        # applies (unpadded rows fall back to replication —
                        # the §Perf C1 iteration measured 90 GB/device)

    def padded_rows(self, rows: int) -> int:
        return ((rows + self.row_pad - 1) // self.row_pad) * self.row_pad

    @property
    def n_fields(self) -> int:
        return self.n_sparse + 1  # + bottom-MLP output as a field

    def n_params(self) -> int:
        total = sum(self.row_counts) * self.embed_dim
        dims = [self.n_dense] + list(self.bot_mlp)
        total += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        f = self.n_fields
        d_int = f * (f - 1) // 2 + self.embed_dim
        dims = [d_int] + list(self.top_mlp)
        total += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return total


def dlrm_init(cfg: DLRMConfig, key):
    keys = jax.random.split(key, cfg.n_sparse + 2)
    tables = {
        f"table_{i}": jax.random.normal(
            keys[i], (cfg.padded_rows(rows), cfg.embed_dim), jnp.float32)
        / (cfg.embed_dim ** 0.5)
        for i, rows in enumerate(cfg.row_counts)
    }
    bot = mlp_params(keys[-2], [cfg.n_dense] + list(cfg.bot_mlp))
    f = cfg.n_fields
    d_int = f * (f - 1) // 2 + cfg.embed_dim
    top = mlp_params(keys[-1], [d_int] + list(cfg.top_mlp))
    return {"tables": tables, "bot": bot, "top": top}


def _interact(fields):
    """fields: (B, F, D) -> (B, F(F-1)/2) strictly-lower-tri dot products
    (bf16 inputs, fp32 MXU accumulation)."""
    B, F, D = fields.shape
    z = jnp.einsum("bfd,bgd->bfg", fields, fields,
                   preferred_element_type=jnp.float32)
    ii, jj = np.tril_indices(F, k=-1)
    return z[:, ii, jj]


def dlrm_apply(params, dense, sparse, cfg: DLRMConfig):
    """dense: (B, n_dense) float; sparse: (B, n_sparse) int32 -> logits (B,).

    Batch is sharded over the *whole* mesh (wide_batch; MLPerf DLRM
    practice): the MLP compute data-parallelizes 256-way and embedding
    grads stay row-local instead of dense-all-reducing (§Perf C2)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    dense = shard(dense, ("wide_batch", None))
    x_bot = mlp_apply(params["bot"], dense, act=jax.nn.relu, final_act=True)
    embs = []
    for i in range(cfg.n_sparse):
        tbl = params["tables"][f"table_{i}"]
        # NOTE (§Perf C3/C5, refuted): forcing bf16 onto the gather
        # redistribution (convert-before-gather, with/without an
        # optimization barrier) does NOT change the wire — GSPMD emits the
        # masked-select + all-reduce in the table dtype regardless. A true
        # fix needs a manual shard_map all-to-all dispatch (future work).
        embs.append(tbl[sparse[:, i]].astype(cdt))
    fields = jnp.stack([x_bot.astype(cdt)] + embs, axis=1)  # (B, F, D)
    fields = shard(fields, ("wide_batch", None, None))  # stays bf16 on the wire
    inter = _interact(fields).astype(jnp.float32)
    top_in = jnp.concatenate([x_bot, inter], axis=-1)
    out = mlp_apply(params["top"], top_in, act=jax.nn.relu)
    return out[:, 0]


def dlrm_loss(params, dense, sparse, labels, cfg: DLRMConfig):
    logits = dlrm_apply(params, dense, sparse, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_scores(query_emb, candidate_embs, k=100):
    """retrieval_cand shape: one query vs n_candidates item vectors.

    Batched dot scoring (no loop) + top-k, the production retrieval path."""
    scores = candidate_embs @ query_emb  # (n_cand,)
    return jax.lax.top_k(scores, k)
