"""The four assigned GNN architectures: GCN (Kipf), GatedGCN (Bresson),
MeshGraphNet (Pfaff), and a NequIP-style E(3)-equivariant network.

Message passing uses `jax.ops.segment_sum` over (senders, receivers) edge
arrays — JAX has no sparse message-passing primitive, so this IS the system
(see kernels/segment_matmul.py for the Pallas SpMM used on TPU). All models
are functional: `<arch>_init(cfg, key, ...) -> params`,
`<arch>_apply(params, batch, cfg) -> outputs`.

NequIP note (DESIGN.md §3): the l<=2 irrep tensor products are implemented
in *Cartesian* form — scalars, vectors, and symmetric-traceless 3x3 tensors
with exact closed-form couplings (dot / cross / traceless-outer /
matrix-vector / matrix-matrix) — which is basis-equivalent to the spherical
Wigner-3j formulation at l_max=2 and exactly E(3)-equivariant (verified by
the rotation property tests).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import layer_norm, mlp_apply, mlp_params

segsum = jax.ops.segment_sum


def _gather(x, idx):
    """Row gather with -1 = masked (zero row) — supports the padded
    receiver-partitioned edge layout of distributed.collectives."""
    safe = x[jnp.maximum(idx, 0)]
    return jnp.where((idx >= 0)[:, None], safe, 0.0)


# ===================================================================== GCN
@dataclass(frozen=True)
class GCNConfig:
    n_layers: int = 2
    d_hidden: int = 16
    norm: str = "sym"
    name: str = "gcn-cora"


def gcn_init(cfg: GCNConfig, key, d_in: int, n_out: int):
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [n_out]
    return {"layers": mlp_params(key, dims, bias=True)}


def gcn_apply(params, x, senders, receivers, n_nodes, cfg: GCNConfig, agg_fn=None):
    agg_fn = agg_fn or (lambda m, r, n: segsum(m, r, num_segments=n))
    valid = (senders >= 0).astype(x.dtype)
    deg = agg_fn(valid, receivers, n_nodes) + 1.0
    inv_sqrt = jax.lax.rsqrt(deg)
    for i, p in enumerate(params["layers"]):
        h = x @ p["w"] + p["b"]
        # sym-normalized propagation with self loops: D^-1/2 (A+I) D^-1/2 h
        msg = _gather(h, senders) * _gather(inv_sqrt[:, None], senders)
        agg = agg_fn(msg, receivers, n_nodes) * inv_sqrt[:, None]
        h = agg + h * (inv_sqrt * inv_sqrt)[:, None]
        x = jax.nn.relu(h) if i < len(params["layers"]) - 1 else h
    return x


# ================================================================ GatedGCN
@dataclass(frozen=True)
class GatedGCNConfig:
    n_layers: int = 16
    d_hidden: int = 70
    name: str = "gatedgcn"


def gatedgcn_init(cfg: GatedGCNConfig, key, d_in: int, d_edge: int, n_out: int):
    d = cfg.d_hidden
    keys = jax.random.split(key, cfg.n_layers * 5 + 4)
    ki = iter(keys)

    def lin(k, i, o):
        return {"w": jax.random.normal(k, (i, o), jnp.float32) / (i ** 0.5),
                "b": jnp.zeros((o,), jnp.float32)}

    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "U": lin(next(ki), d, d), "V": lin(next(ki), d, d),
            "A": lin(next(ki), d, d), "B": lin(next(ki), d, d), "C": lin(next(ki), d, d),
            "ln_h": (jnp.ones((d,)), jnp.zeros((d,))),
            "ln_e": (jnp.ones((d,)), jnp.zeros((d,))),
        })
    return {
        "embed_h": lin(next(ki), d_in, d),
        "embed_e": lin(next(ki), d_edge, d),
        "readout": lin(next(ki), d, n_out),
        "layers": layers,
    }


def _lin(p, x):
    return x @ p["w"] + p["b"]


def gatedgcn_apply(params, x, e_feat, senders, receivers, n_nodes, cfg: GatedGCNConfig, agg_fn=None):
    agg_fn = agg_fn or (lambda m, r, n: segsum(m, r, num_segments=n))
    mask = (senders >= 0).astype(x.dtype)[:, None]
    h = _lin(params["embed_h"], x)
    e = _lin(params["embed_e"], e_feat)
    for p in params["layers"]:
        e_new = _gather(_lin(p["A"], h), senders) + _gather(_lin(p["B"], h), receivers) + _lin(p["C"], e)
        e = e + jax.nn.relu(layer_norm(e_new, *p["ln_e"]))
        eta = jax.nn.sigmoid(e) * mask
        denom = agg_fn(eta, receivers, n_nodes) + 1e-6
        msg = eta * _gather(_lin(p["V"], h), senders)
        agg = agg_fn(msg, receivers, n_nodes) / denom
        h = h + jax.nn.relu(layer_norm(_lin(p["U"], h) + agg, *p["ln_h"]))
    return _lin(params["readout"], h)


# ============================================================ MeshGraphNet
@dataclass(frozen=True)
class MeshGraphNetConfig:
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    name: str = "meshgraphnet"


def _mgn_mlp(key, d_in, d_out, cfg):
    sizes = [d_in] + [cfg.d_hidden] * cfg.mlp_layers + [d_out]
    return mlp_params(key, sizes, bias=True)


def meshgraphnet_init(cfg: MeshGraphNetConfig, key, d_node: int, d_edge: int, d_out: int):
    keys = jax.random.split(key, 2 * cfg.n_layers + 3)
    d = cfg.d_hidden
    return {
        "enc_node": _mgn_mlp(keys[0], d_node, d, cfg),
        "enc_edge": _mgn_mlp(keys[1], d_edge, d, cfg),
        "blocks": [
            {
                "edge_mlp": _mgn_mlp(keys[2 + 2 * i], 3 * d, d, cfg),
                "node_mlp": _mgn_mlp(keys[3 + 2 * i], 2 * d, d, cfg),
            }
            for i in range(cfg.n_layers)
        ],
        "dec": _mgn_mlp(keys[-1], d, d_out, cfg),
    }


def meshgraphnet_apply(params, x, e_feat, senders, receivers, n_nodes, cfg: MeshGraphNetConfig, agg_fn=None):
    agg_fn = agg_fn or (lambda m, r, n: segsum(m, r, num_segments=n))
    mask = (senders >= 0).astype(x.dtype)[:, None]
    h = mlp_apply(params["enc_node"], x)
    e = mlp_apply(params["enc_edge"], e_feat)
    for blk in params["blocks"]:
        e = e + mlp_apply(blk["edge_mlp"],
                          jnp.concatenate([e, _gather(h, senders), _gather(h, receivers)], -1))
        agg = agg_fn(e * mask, receivers, n_nodes)
        h = h + mlp_apply(blk["node_mlp"], jnp.concatenate([h, agg], -1))
    return mlp_apply(params["dec"], h)


# ================================================================== NequIP
@dataclass(frozen=True)
class NequIPConfig:
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep order
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    name: str = "nequip"


def _sym_traceless(m):
    sym = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    return sym - tr * jnp.eye(3) / 3.0


def nequip_init(cfg: NequIPConfig, key, n_species: int):
    C = cfg.d_hidden
    keys = iter(jax.random.split(key, 4 + cfg.n_layers * 8))

    def lin(k, i, o):
        return jax.random.normal(k, (i, o), jnp.float32) / (i ** 0.5)

    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            # radial MLPs: rbf -> per-channel weights for each coupling path
            "rad0": mlp_params(next(keys), [cfg.n_rbf, 32, 4 * C], bias=True),
            "rad1": mlp_params(next(keys), [cfg.n_rbf, 32, 4 * C], bias=True),
            "rad2": mlp_params(next(keys), [cfg.n_rbf, 32, 3 * C], bias=True),
            "self0": lin(next(keys), C, C),
            "self1": lin(next(keys), C, C),
            "self2": lin(next(keys), C, C),
            "mix0": lin(next(keys), C, C),
        })
    return {
        "embed": jax.random.normal(next(keys), (n_species, C), jnp.float32) * 0.5,
        "layers": layers,
        "out": mlp_params(next(keys), [C, 32, 1], bias=True),
    }


def _rbf(r, cfg: NequIPConfig):
    """Bessel-like radial basis with smooth cutoff envelope."""
    n = jnp.arange(1, cfg.n_rbf + 1, dtype=jnp.float32)
    rc = cfg.cutoff
    safe = jnp.maximum(r, 1e-6)
    basis = jnp.sin(n * jnp.pi * safe[:, None] / rc) / safe[:, None]
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / rc, 0, 1)) + 1.0)
    return basis * env[:, None]


def nequip_apply(params, species, positions, senders, receivers, n_nodes, cfg: NequIPConfig, agg_fn=None):
    """species (N,), positions (N, 3) -> per-node scalar energies (N, 1).

    Features: s (N, C) scalars; v (N, C, 3) vectors; t (N, C, 3, 3)
    symmetric-traceless. Exact Cartesian tensor-product couplings per layer.
    """
    C = cfg.d_hidden
    agg_fn = agg_fn or (lambda m, r, n: segsum(m, r, num_segments=n))
    emask = (senders >= 0).astype(jnp.float32)
    s = params["embed"][species]
    v = jnp.zeros((n_nodes, C, 3), jnp.float32)
    t = jnp.zeros((n_nodes, C, 3, 3), jnp.float32)

    rel = _gather(positions, senders) - _gather(positions, receivers)  # (E, 3)
    r = jnp.linalg.norm(rel + 1e-12, axis=-1)
    dirs = rel / jnp.maximum(r[:, None], 1e-6)              # l=1 part
    dir2 = _sym_traceless(dirs[:, :, None] * dirs[:, None, :])  # l=2 part
    rbf = _rbf(r, cfg) * emask[:, None]                     # (E, n_rbf); pads zeroed

    for p in params["layers"]:
        w0 = mlp_apply(p["rad0"], rbf).reshape(-1, 4, C)    # scalar-output paths
        w1 = mlp_apply(p["rad1"], rbf).reshape(-1, 4, C)    # vector-output paths
        w2 = mlp_apply(p["rad2"], rbf).reshape(-1, 3, C)    # tensor-output paths
        s_j = _gather(s, senders)
        v_j, t_j = v[jnp.maximum(senders, 0)], t[jnp.maximum(senders, 0)]
        v_j = jnp.where((senders >= 0)[:, None, None], v_j, 0.0)
        t_j = jnp.where((senders >= 0)[:, None, None, None], t_j, 0.0)

        # --- scalar messages: 0x0->0, 1x1->0 (dot), 2x2->0 (frobenius), Y0
        m0 = (
            w0[:, 0] * s_j
            + w0[:, 1] * jnp.einsum("eci,eci->ec", v_j, dirs[:, None, :])
            + w0[:, 2] * jnp.einsum("ecij,eij->ec", t_j, dir2)
            + w0[:, 3] * jnp.einsum("eci,eci->ec", v_j, v_j)
        )
        # --- vector messages: 0xY1->1, 1x1->1 (cross), 2xY1->1 (M.dir), 1 passthrough
        m1 = (
            w1[:, 0, :, None] * s_j[:, :, None] * dirs[:, None, :]
            + w1[:, 1, :, None] * jnp.cross(v_j, jnp.broadcast_to(dirs[:, None, :], v_j.shape))
            + w1[:, 2, :, None] * jnp.einsum("ecij,ej->eci", t_j, dirs)
            + w1[:, 3, :, None] * v_j
        )
        # --- tensor messages: 0xY2->2, 1x(x)Y1->2 (traceless outer), 2 passthrough
        outer = _sym_traceless(v_j[:, :, :, None] * dirs[:, None, None, :])
        m2 = (
            w2[:, 0, :, None, None] * s_j[:, :, None, None] * dir2[:, None, :, :]
            + w2[:, 1, :, None, None] * outer
            + w2[:, 2, :, None, None] * t_j
        )

        s_agg = agg_fn(m0, receivers, n_nodes)
        v_agg = agg_fn(m1.reshape(m1.shape[0], -1), receivers, n_nodes).reshape(-1, C, 3)
        t_agg = agg_fn(m2.reshape(m2.shape[0], -1), receivers, n_nodes).reshape(-1, C, 3, 3)

        # self-interaction (channel mixing, order-preserving) + gated nonlinearity
        s_new = s + jax.nn.silu(s_agg @ p["self0"] + s @ p["mix0"])
        gate = jax.nn.sigmoid(s_new)[:, :, None]
        v = v + gate * jnp.einsum("eci,cd->edi", v_agg, p["self1"])
        t = t + gate[..., None] * jnp.einsum("ecij,cd->edij", t_agg, p["self2"])
        s = s_new

    return mlp_apply(params["out"], s)
