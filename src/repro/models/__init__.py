"""Model zoo: the 10 assigned architectures (5 LM transformers, 4 GNNs, DLRM)."""
from repro.models import common, dlrm, gnn, transformer

__all__ = ["common", "dlrm", "gnn", "transformer"]
