"""Data layer: synthetic graph generators (paper-dataset stand-ins), an
N-Triples parser, the ITR-compressed GraphStore, and neighbor samplers."""
from repro.data.synthetic import rdf_like, version_graph, web_graph, molecule_batch
from repro.data.graph_store import GraphStore
from repro.data.sampler import NeighborSampler
from repro.data.rdf import parse_ntriples, write_ntriples

__all__ = [
    "rdf_like",
    "version_graph",
    "web_graph",
    "molecule_batch",
    "GraphStore",
    "NeighborSampler",
    "parse_ntriples",
    "write_ntriples",
]
