"""Data layer: synthetic graph generators (paper-dataset stand-ins), an
N-Triples parser, the ITR-compressed GraphStore, and neighbor samplers."""
from repro.data.synthetic import rdf_like, version_graph, web_graph, molecule_batch
from repro.data.graph_store import GraphStore
from repro.data.sampler import NeighborSampler
from repro.data.rdf import ParseReport, iter_ntriples, parse_ntriples, write_ntriples
from repro.data.ingest import (
    IngestStats,
    ingest_file,
    ingest_rows,
    iter_tsv,
    resolve_ingest_batch,
    scan_predicates,
)

__all__ = [
    "rdf_like",
    "version_graph",
    "web_graph",
    "molecule_batch",
    "GraphStore",
    "NeighborSampler",
    "ParseReport",
    "iter_ntriples",
    "parse_ntriples",
    "write_ntriples",
    "IngestStats",
    "ingest_file",
    "ingest_rows",
    "iter_tsv",
    "resolve_ingest_batch",
    "scan_predicates",
]
