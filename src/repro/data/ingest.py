"""Streaming RDF ingestion: term rows -> dictionary ids -> live services.

The bridge between real RDF files and the id-speaking tier: decoded
``(s, p, o)`` term-string rows stream in (N-Triples via
:func:`repro.data.rdf.iter_ntriples`, or 3-column TSV via
:func:`iter_tsv`), each batch mints ids through the target's term
dictionary and lands via ``insert_triples`` — so on a
:class:`~repro.persist.service.DurableShardedService` every batch is two
WAL appends away from being crash-proof (term records + row record), and
WAL-tailing replicas rebuild the identical id space.

Per-batch accounting lives in :class:`IngestStats`; malformed input lines
are *counted and surfaced* (first few sampled), never silently dropped.

Capacity note: node ids may grow without bound (partition plans route
out-of-range ids), but predicate capacity is fixed when a tier is built
(`n_preds` terminal labels per shard engine). Pre-size it —
:func:`scan_predicates` is the one-pass helper — or minting a predicate
past capacity raises mid-ingest.

Knob: ``ITR_INGEST_BATCH`` (default 4096) — rows per mint+insert batch.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.term_dict import TermDict
from repro.data.rdf import ParseReport, iter_ntriples

DEFAULT_INGEST_BATCH = 4096


def resolve_ingest_batch(value=None) -> int:
    """Rows per ingest batch: explicit argument > ``ITR_INGEST_BATCH`` >
    default 4096. Values below 1 clamp to 1; unparsable falls back."""
    if value is not None:
        return max(1, int(value))
    raw = os.environ.get("ITR_INGEST_BATCH", "").strip()
    if not raw:
        return DEFAULT_INGEST_BATCH
    try:
        return max(1, int(raw))
    except ValueError:
        return DEFAULT_INGEST_BATCH


@dataclass
class IngestStats:
    """What one ingestion run did, batch by batch."""

    rows: int = 0              # triples handed to insert_triples
    inserted: int = 0          # triples actually added (dedup excluded)
    statements: int = 0        # well-formed statements seen in the source
    malformed: int = 0         # source lines skipped (see samples)
    malformed_samples: list = field(default_factory=list)
    new_nodes: int = 0         # node terms minted by this run
    new_preds: int = 0         # predicate terms minted by this run
    batches: int = 0
    seconds: float = 0.0

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.seconds if self.seconds > 0 else 0.0

    def as_dict(self) -> dict:
        return {"rows": self.rows, "inserted": self.inserted,
                "statements": self.statements, "malformed": self.malformed,
                "malformed_samples": list(self.malformed_samples),
                "new_nodes": self.new_nodes, "new_preds": self.new_preds,
                "batches": self.batches, "seconds": self.seconds,
                "rows_per_s": self.rows_per_s}


def iter_tsv(source, report: ParseReport | None = None):
    """Stream ``(s, p, o)`` rows from tab-separated lines (terms taken
    verbatim — the LLM-extraction / export format, no N-Triples syntax).
    Lines without exactly three non-empty fields are counted as malformed
    on *report* and skipped."""
    close = False
    if isinstance(source, (str, os.PathLike)):
        fh = open(source, "r", encoding="utf-8")
        close = True
    else:
        fh = source
    try:
        for line in fh:
            if report is not None:
                report.lines += 1
            stripped = line.rstrip("\r\n")
            if not stripped.strip() or stripped.lstrip().startswith("#"):
                continue
            fields = stripped.split("\t")
            if len(fields) != 3 or not all(f.strip() for f in fields):
                if report is not None:
                    report.record_malformed(stripped)
                continue
            if report is not None:
                report.statements += 1
            yield fields[0], fields[1], fields[2]
    finally:
        if close:
            fh.close()


def _row_iter(path: str, fmt: str, report: ParseReport):
    if fmt == "auto":
        ext = os.path.splitext(os.fspath(path))[1].lower()
        fmt = "tsv" if ext in (".tsv", ".tab") else "ntriples"
    if fmt == "ntriples":
        return iter_ntriples(path, report)
    if fmt == "tsv":
        return iter_tsv(path, report)
    raise ValueError(f"unknown ingest format {fmt!r} "
                     "(expected 'auto', 'ntriples', or 'tsv')")


def scan_predicates(path, fmt: str = "auto"):
    """One streaming pass over a file: distinct predicate terms in
    first-seen order plus the well-formed statement count — the inputs
    needed to size a tier (``n_preds``) before ingesting into it."""
    report = ParseReport()
    preds: dict[str, None] = {}
    for _, p_t, _ in _row_iter(path, fmt, report):
        preds[p_t] = None
    return list(preds), report.statements


def ingest_rows(target, rows, *, term_dict: TermDict | None = None,
                batch_size: int | None = None, stats: IngestStats | None = None,
                progress=None) -> IngestStats:
    """Stream decoded ``(s, p, o)`` term rows into *target* in batches.

    *target* is an engine or service exposing ``insert_triples``; term ids
    mint through ``target.add_node_terms`` / ``add_pred_terms`` when
    present (the services — on the durable one that path WAL-covers every
    new term), else directly through the dictionary. The dictionary is
    ``term_dict`` if given, else ``target.term_dict``; a target with
    neither gets a fresh :class:`TermDict` attached via
    ``attach_term_dict``. ``progress(stats)`` fires after every batch.
    """
    td = term_dict if term_dict is not None else getattr(target, "term_dict", None)
    if td is None:
        td = TermDict.empty()
        attach = getattr(target, "attach_term_dict", None)
        if attach is None:
            raise ValueError(
                f"{type(target).__name__} has no term dictionary and no "
                "attach_term_dict(); pass term_dict= explicitly")
        attach(td)
    add_nodes = getattr(target, "add_node_terms", None) or td.add_node_terms
    add_preds = getattr(target, "add_pred_terms", None) or td.add_pred_terms
    batch_size = resolve_ingest_batch(batch_size)
    stats = stats if stats is not None else IngestStats()
    t0 = time.perf_counter()

    def flush(batch: list) -> None:
        n0_nodes, n0_preds = td.n_nodes, td.n_preds
        # subjects + objects in ONE mint call: one WAL record per batch
        node_ids = add_nodes([r[0] for r in batch] + [r[2] for r in batch])
        pred_ids = add_preds([r[1] for r in batch])
        n = len(batch)
        rows_arr = np.stack(
            [node_ids[:n], np.asarray(pred_ids, dtype=np.int64),
             node_ids[n:]], axis=1)
        stats.inserted += int(target.insert_triples(rows_arr))
        stats.rows += n
        stats.batches += 1
        stats.new_nodes += td.n_nodes - n0_nodes
        stats.new_preds += td.n_preds - n0_preds
        stats.seconds = time.perf_counter() - t0
        if progress is not None:
            progress(stats)

    batch: list = []
    for row in rows:
        batch.append(row)
        if len(batch) >= batch_size:
            flush(batch)
            batch = []
    if batch:
        flush(batch)
    stats.seconds = time.perf_counter() - t0
    return stats


def ingest_file(target, path, *, fmt: str = "auto",
                term_dict: TermDict | None = None,
                batch_size: int | None = None, progress=None) -> IngestStats:
    """Stream one N-Triples (``.nt``) or TSV file into *target*.

    Returns :class:`IngestStats` with the malformed-line count (and
    samples) from the parse folded in, so callers see data loss instead
    of a silently smaller graph.
    """
    report = ParseReport()
    stats = ingest_rows(target, _row_iter(path, fmt, report),
                        term_dict=term_dict, batch_size=batch_size,
                        progress=progress)
    stats.statements = report.statements
    stats.malformed = report.malformed
    stats.malformed_samples = list(report.samples)
    return stats
