"""N-Triples reader/writer with ID dictionaries and a streaming iterator.

The paper converts every dataset to RDF notation and feeds the same file to
all compressors; this module is that common input path. Handles ``<iri>``
terms, ``_:label`` blank nodes, and ``"literal"`` objects (plain,
``@lang``-tagged, or ``^^<datatype>``-typed); blank nodes are treated as
IRIs for id purposes.

Terms circulate in *decoded* form: IRIs and blank nodes keep their surface
spelling (``<http://…>``, ``_:b1``), literals keep the surrounding quotes
and any suffix but hold the raw, unescaped body text. ``encode_term`` /
``decode_term`` convert between that canonical form and the escaped
on-the-wire N-Triples spelling, so parse → write → parse is the identity
even for literals containing quotes, backslashes, or newlines.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field

import numpy as np

# A blank-node label must not end with '.', so a statement terminator with
# no preceding space ("_:b1.") stays a terminator instead of being swallowed
# into the label (the old pattern was `_:\S+`).
_BNODE = r"_:[A-Za-z0-9_](?:[A-Za-z0-9_.\-]*[A-Za-z0-9_\-])?"
_TERM = re.compile(
    r'(<[^>]*>|' + _BNODE + r'|"(?:[^"\\]|\\.)*"(?:\^\^<[^>]*>|@[\w-]+)?)'
)

# escaped-literal body: ECHAR escapes plus \uXXXX / \UXXXXXXXX
_UNESCAPE = re.compile(r"\\(u[0-9a-fA-F]{4}|U[0-9a-fA-F]{8}|.)")
_ECHAR_DECODE = {"t": "\t", "b": "\b", "n": "\n", "r": "\r", "f": "\f",
                 '"': '"', "'": "'", "\\": "\\"}
_ECHAR_ENCODE = {"\\": "\\\\", '"': '\\"', "\n": "\\n", "\r": "\\r",
                 "\t": "\\t"}
# suffix of a literal term (after the closing quote): datatype or lang tag
_LITERAL = re.compile(r'^"(.*)"(\^\^<[^>]*>|@[\w-]+)?$', re.DOTALL)


@dataclass
class ParseReport:
    """What a parse saw: total lines, parsed statements, and the malformed
    lines that were skipped (count + first few samples) — returned so
    ingestion can surface data loss instead of hiding it."""

    lines: int = 0
    statements: int = 0
    malformed: int = 0
    samples: list = field(default_factory=list)

    _MAX_SAMPLES = 5

    def record_malformed(self, line: str) -> None:
        self.malformed += 1
        if len(self.samples) < self._MAX_SAMPLES:
            self.samples.append(line)

    def as_dict(self) -> dict:
        return {"lines": self.lines, "statements": self.statements,
                "malformed": self.malformed, "samples": list(self.samples)}


def unescape_literal(body: str) -> str:
    """Decode an escaped N-Triples literal body to raw text."""

    def _sub(m: re.Match) -> str:
        esc = m.group(1)
        if esc[0] in "uU" and len(esc) > 1:
            return chr(int(esc[1:], 16))
        try:
            return _ECHAR_DECODE[esc]
        except KeyError:
            raise ValueError(f"invalid literal escape: \\{esc}") from None

    return _UNESCAPE.sub(_sub, body)


def escape_literal(body: str) -> str:
    """Encode raw literal text into its N-Triples escaped spelling."""
    return "".join(_ECHAR_ENCODE.get(ch, ch) for ch in body)


def _split_literal(term: str):
    """Split a literal term into (body, suffix). The suffix (lang tag or
    datatype) never contains a quote, so the split point is the last ``"``."""
    m = _LITERAL.match(term)
    if m is None:
        raise ValueError(f"not a literal term: {term!r}")
    return m.group(1), m.group(2) or ""


def decode_term(term: str) -> str:
    """On-the-wire term -> canonical decoded form (see module docstring)."""
    if term.startswith('"'):
        body, suffix = _split_literal(term)
        return '"' + unescape_literal(body) + '"' + suffix
    return term


def encode_term(term: str) -> str:
    """Canonical decoded term -> escaped on-the-wire N-Triples spelling."""
    if term.startswith('"'):
        body, suffix = _split_literal(term)
        return '"' + escape_literal(body) + '"' + suffix
    return term


def iter_ntriples(source, report: ParseReport | None = None):
    """Stream decoded ``(s, p, o)`` term-string rows from an N-Triples
    source (a path or any iterable of lines). Lines that do not parse to at
    least three terms are counted (and sampled) on *report* and skipped —
    never silently dropped when the caller passes a report."""
    close = False
    if isinstance(source, (str, os.PathLike)):
        fh = open(source, "r", encoding="utf-8")
        close = True
    else:
        fh = source
    try:
        for line in fh:
            if report is not None:
                report.lines += 1
            stripped = line.strip()
            if not stripped or stripped.startswith("#"):
                continue
            terms = _TERM.findall(stripped)
            if len(terms) < 3:
                if report is not None:
                    report.record_malformed(stripped)
                continue
            if report is not None:
                report.statements += 1
            yield (decode_term(terms[0]), decode_term(terms[1]),
                   decode_term(terms[2]))
    finally:
        if close:
            fh.close()


def parse_ntriples(path: str):
    """Returns ``(triples int64[n,3], node_names, pred_names, report)``.

    Node/predicate ids are minted first-seen; ``report`` is a
    :class:`ParseReport` whose ``malformed`` count covers every non-empty,
    non-comment line that did not parse to three terms.
    """
    nodes: dict[str, int] = {}
    preds: dict[str, int] = {}
    rows = []
    report = ParseReport()
    for s_t, p_t, o_t in iter_ntriples(path, report):
        s = nodes.setdefault(s_t, len(nodes))
        p = preds.setdefault(p_t, len(preds))
        o = nodes.setdefault(o_t, len(nodes))
        rows.append((s, p, o))
    triples = np.array(rows, dtype=np.int64) if rows else np.zeros((0, 3), dtype=np.int64)
    return triples, list(nodes), list(preds), report


def write_ntriples(path: str, triples: np.ndarray, node_names=None, pred_names=None):
    """Write id triples as N-Triples, re-escaping literal bodies on the way
    out so ``parse -> write -> parse`` round-trips adversarial literals."""
    triples = np.asarray(triples, dtype=np.int64)
    with open(path, "w", encoding="utf-8") as fh:
        for s, p, o in triples:
            s_t = node_names[s] if node_names else f"<http://ex.org/n{s}>"
            p_t = pred_names[p] if pred_names else f"<http://ex.org/p{p}>"
            o_t = node_names[o] if node_names else f"<http://ex.org/n{o}>"
            fh.write(f"{encode_term(s_t)} {encode_term(p_t)} {encode_term(o_t)} .\n")
