"""Minimal N-Triples reader/writer with ID dictionaries.

The paper converts every dataset to RDF notation and feeds the same file to
all compressors; this module is that common input path. Handles `<iri>`
terms and `"literal"` objects; blank nodes `_:b` are treated as IRIs.
"""
from __future__ import annotations

import re

import numpy as np

_TERM = re.compile(r'(<[^>]*>|_:\S+|"(?:[^"\\]|\\.)*"(?:\^\^<[^>]*>|@[\w-]+)?)')


def parse_ntriples(path: str):
    """Returns (triples int64[n,3], node_names list, pred_names list)."""
    nodes: dict[str, int] = {}
    preds: dict[str, int] = {}
    rows = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            terms = _TERM.findall(line)
            if len(terms) < 3:
                continue
            s_t, p_t, o_t = terms[0], terms[1], terms[2]
            s = nodes.setdefault(s_t, len(nodes))
            p = preds.setdefault(p_t, len(preds))
            o = nodes.setdefault(o_t, len(nodes))
            rows.append((s, p, o))
    triples = np.array(rows, dtype=np.int64) if rows else np.zeros((0, 3), dtype=np.int64)
    return triples, list(nodes), list(preds)


def write_ntriples(path: str, triples: np.ndarray, node_names=None, pred_names=None):
    triples = np.asarray(triples, dtype=np.int64)
    with open(path, "w", encoding="utf-8") as fh:
        for s, p, o in triples:
            s_t = node_names[s] if node_names else f"<http://ex.org/n{s}>"
            p_t = pred_names[p] if pred_names else f"<http://ex.org/p{p}>"
            o_t = node_names[o] if node_names else f"<http://ex.org/n{o}>"
            fh.write(f"{s_t} {p_t} {o_t} .\n")
