"""Synthetic graph generators mirroring the paper's dataset families
(Table 1b) at configurable scale. The container is offline, so these are
structural stand-ins: same |V|/|E|/|T| regimes and skew, not the same data.

* :func:`rdf_like`      — homepages/geo/jamendo style: Zipf predicates,
                          star-shaped subjects, literal-like leaf objects.
* :func:`web_graph`     — WikiTalk/NotreDame style: single label,
                          preferential attachment.
* :func:`version_graph` — ttt-win/chess style: many near-isomorphic small
                          subgraphs + few node labels (the ITR+ showcase).
* :func:`molecule_batch`— batches of small graphs (GNN `molecule` shape).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TripleDataset:
    triples: np.ndarray          # int64[n, 3] (s, p, o), deduplicated
    n_nodes: int
    n_preds: int
    node_labels: np.ndarray | None = None  # int64[n_nodes] or None
    node_label_names: list[str] | None = None
    name: str = ""

    @property
    def n_triples(self) -> int:
        return len(self.triples)


def _dedup(triples: np.ndarray) -> np.ndarray:
    return np.unique(triples, axis=0)


def rdf_like(n_nodes=5000, n_edges=20000, n_preds=25, seed=0, name="rdf-like") -> TripleDataset:
    rng = np.random.default_rng(seed)
    # Zipf predicates; entity-like subjects each carrying a handful of
    # (predicate, object) pairs — the paper's RDF datasets (homepages, geo,
    # jamendo) have small per-subject stars, not mega-hubs — and objects
    # that are mostly fresh leaves (literals) plus some shared resources
    preds = (rng.zipf(1.6, n_edges * 2) - 1) % n_preds
    n_subjects = max(n_nodes // 3, 1)
    # mild skew: degree ∝ zipf(2.8) capped, average ~ E / n_subjects
    subj_pool = rng.integers(0, n_subjects, n_edges * 2)
    hub = (rng.zipf(2.8, n_edges * 2) - 1).clip(0, 19)
    subj_pool = (subj_pool + hub * 0) % n_subjects  # keep uniform base
    obj_shared = rng.integers(0, n_nodes, n_edges * 2)
    obj_leaf = rng.integers(n_nodes // 3, n_nodes, n_edges * 2)
    is_leaf = rng.random(n_edges * 2) < 0.6
    objs = np.where(is_leaf, obj_leaf, obj_shared)
    triples = _dedup(np.stack([subj_pool, preds, objs], axis=1).astype(np.int64))[:n_edges]
    return TripleDataset(triples, n_nodes, n_preds, name=name)


def web_graph(n_nodes=3000, n_edges=15000, seed=0, name="web-graph") -> TripleDataset:
    rng = np.random.default_rng(seed)
    # preferential attachment: target probability proportional to degree+1
    src = rng.integers(0, n_nodes, n_edges * 2)
    # approximate PA by sampling targets from a growing multiset
    targets = np.empty(n_edges * 2, dtype=np.int64)
    pool = rng.integers(0, max(n_nodes // 10, 1), 64)
    for i in range(0, len(targets), 1024):
        chunk = min(1024, len(targets) - i)
        picks = rng.integers(0, len(pool), chunk)
        fresh = rng.integers(0, n_nodes, chunk)
        use_pool = rng.random(chunk) < 0.7
        targets[i : i + chunk] = np.where(use_pool, pool[picks], fresh)
        pool = np.concatenate([pool, targets[i : i + chunk][:128]])
    triples = _dedup(
        np.stack([src, np.zeros(len(src), dtype=np.int64), targets], axis=1).astype(np.int64)
    )[:n_edges]
    return TripleDataset(triples, n_nodes, 1, name=name)


def version_graph(n_groups=400, group_size=9, n_node_labels=3, seed=0, name="version-graph") -> TripleDataset:
    """ttt-win style: each state is a star of `group_size` cells whose edges
    use per-position predicates; states chain via a `move` predicate; cells
    carry one of `n_node_labels` node labels (x / o / b)."""
    rng = np.random.default_rng(seed)
    n_preds = group_size + 1  # position predicates + 'move'
    centers = np.arange(n_groups)
    cell_base = n_groups
    triples = []
    for g in range(n_groups):
        cells = cell_base + g * group_size + np.arange(group_size)
        for pos in range(group_size):
            triples.append((centers[g], pos, cells[pos]))
        if g > 0:
            triples.append((centers[g - 1], group_size, centers[g]))
    triples = np.array(triples, dtype=np.int64)
    n_nodes = cell_base + n_groups * group_size
    node_labels = np.full(n_nodes, -1, dtype=np.int64)
    node_labels[cell_base:] = rng.integers(0, n_node_labels, n_groups * group_size)
    return TripleDataset(
        _dedup(triples), n_nodes, n_preds,
        node_labels=node_labels,
        node_label_names=[f"lab{i}" for i in range(n_node_labels)],
        name=name,
    )


def molecule_batch(batch=128, n_nodes=30, n_edges=64, d_feat=16, seed=0):
    """Batched small graphs for the GNN `molecule` shape: block-diagonal
    edge index + per-node features + per-graph labels."""
    rng = np.random.default_rng(seed)
    srcs, dsts, graph_ids = [], [], []
    for b in range(batch):
        s = rng.integers(0, n_nodes, n_edges) + b * n_nodes
        d = rng.integers(0, n_nodes, n_edges) + b * n_nodes
        srcs.append(s)
        dsts.append(d)
        graph_ids.append(np.full(n_nodes, b))
    feats = rng.normal(size=(batch * n_nodes, d_feat)).astype(np.float32)
    y = rng.normal(size=(batch,)).astype(np.float32)
    return {
        "senders": np.concatenate(srcs),
        "receivers": np.concatenate(dsts),
        "node_feat": feats,
        "graph_ids": np.concatenate(graph_ids),
        "y": y,
        "n_graphs": batch,
    }


# paper Table 1b stand-ins at reduced scale (scale=1.0 would be full size)
PAPER_DATASETS = {
    "homepages-en": lambda scale=0.1, seed=0: rdf_like(
        int(98665 * scale), int(50000 * scale), 1, seed, "homepages-en"),
    "geo-coordinates-en": lambda scale=0.1, seed=0: rdf_like(
        int(46107 * scale), int(50000 * scale), 4, seed, "geo-coordinates-en"),
    "jamendo": lambda scale=0.05, seed=0: rdf_like(
        int(396531 * scale), int(1047951 * scale), 25, seed, "jamendo"),
    "archiveshub": lambda scale=0.05, seed=0: rdf_like(
        int(280556 * scale), int(1361816 * scale), 139, seed, "archiveshub"),
    "scholarydata-dump": lambda scale=0.05, seed=0: rdf_like(
        int(140042 * scale), int(1159985 * scale), 84, seed, "scholarydata-dump"),
    "chess-legal": lambda scale=0.2, seed=0: version_graph(
        max(int(76272 * scale) // 10, 10), 9, 13, seed, "chess-legal"),
    "ttt-win": lambda scale=1.0, seed=0: version_graph(
        max(int(5634 * scale) // 10, 10), 9, 3, seed, "ttt-win"),
    "WikiTalk": lambda scale=0.01, seed=0: web_graph(
        int(2394385 * scale), int(5021410 * scale), seed, "WikiTalk"),
    "NotreDame": lambda scale=0.02, seed=0: web_graph(
        int(325729 * scale), int(1497134 * scale), seed, "NotreDame"),
    "CA-AstroPh": lambda scale=0.1, seed=0: web_graph(
        int(18772 * scale), int(396160 * scale), seed, "CA-AstroPh"),
}
