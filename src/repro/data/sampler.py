"""Fanout neighbor sampler (GraphSAGE-style) over a GraphStore CSR view.

Produces layered subgraph batches for `minibatch_lg`: seed nodes, then for
each hop a uniform sample of up to `fanout[h]` in-neighbors per frontier
node. Output is a bipartite block per hop (senders/receivers into a
compacted node set) — the exact structure the GNN minibatch step consumes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SampledBlock:
    senders: np.ndarray     # positions into the previous layer's node list
    receivers: np.ndarray   # positions into the next (smaller) node list
    n_src: int
    n_dst: int


@dataclass
class SampledBatch:
    node_ids: np.ndarray    # global ids of all nodes needed (layer-0 first)
    blocks: list[SampledBlock]
    seeds: np.ndarray


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray, fanouts: tuple[int, ...]):
        self.indptr, self.indices = indptr, indices
        self.fanouts = tuple(fanouts)

    def sample(self, seeds: np.ndarray, rng: np.random.Generator) -> SampledBatch:
        seeds = np.asarray(seeds, dtype=np.int64)
        layers = [seeds]
        edges_per_hop = []
        frontier = seeds
        for f in self.fanouts:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            take = np.minimum(deg, f)
            # ragged uniform sample without replacement approximated by
            # with-replacement draw then dedup per (dst, src)
            dst_rep = np.repeat(np.arange(len(frontier)), take)
            base = np.repeat(self.indptr[frontier], take)
            degs = np.repeat(np.maximum(deg, 1), take)
            offs = (rng.random(len(base)) * degs).astype(np.int64)
            src = self.indices[base + offs]
            key = dst_rep * (self.indices.max() + 2) + src
            _, uniq_idx = np.unique(key, return_index=True)
            dst_rep, src = dst_rep[uniq_idx], src[uniq_idx]
            edges_per_hop.append((src, dst_rep))
            frontier = np.unique(src)
            layers.append(frontier)

        # compact node ids: union of all layers, seeds keep positions 0..len-1
        all_nodes = np.concatenate(layers)
        node_ids, first_pos = np.unique(all_nodes, return_index=True)
        # build position lookup
        lookup = {int(v): i for i, v in enumerate(node_ids)}
        blocks = []
        for hop, (src, dst_rep) in enumerate(edges_per_hop):
            dst_global = layers[hop][dst_rep]
            senders = np.array([lookup[int(v)] for v in src], dtype=np.int64)
            receivers = np.array([lookup[int(v)] for v in dst_global], dtype=np.int64)
            blocks.append(
                SampledBlock(senders, receivers, n_src=len(node_ids), n_dst=len(node_ids))
            )
        return SampledBatch(node_ids=node_ids, blocks=blocks, seeds=seeds)
