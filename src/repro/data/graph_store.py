"""GraphStore — the paper's compressed graph as a first-class data-layer
service of the training framework.

Graphs are held as an ITR grammar; point lookups (neighborhoods, triple
patterns) run on the compressed form via :class:`TripleQueryEngine`.
Training hot paths (full-batch GNN adjacency, high-throughput fanout
sampling) use a lazily *materialized* CSR view — decompressed once, cached —
because a sampled-training step issues thousands of neighbor lookups per
batch. Storage stays compressed; the CSR cache is working memory.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Hypergraph,
    LabelTable,
    RepairConfig,
    TripleQueryEngine,
    compress,
    encode,
)


class GraphStore:
    def __init__(self, grammar, stats=None):
        self.grammar = grammar
        self.stats = stats
        self.encoded = encode(grammar)
        self.engine = TripleQueryEngine(grammar, self.encoded)
        self._csr = None
        self._csc = None

    # ------------------------------------------------------------- build
    @classmethod
    def from_triples(
        cls, triples: np.ndarray, n_nodes: int, n_preds: int, config: RepairConfig | None = None
    ) -> "GraphStore":
        table = LabelTable.terminals([2] * n_preds)
        graph = Hypergraph.from_triples(triples, n_nodes)
        grammar, stats = compress(graph, table, config)
        return cls(grammar, stats)

    @property
    def n_nodes(self) -> int:
        return self.grammar.start.n_nodes

    # ------------------------------------------------------- point paths
    def neighbors_out(self, v: int) -> np.ndarray:
        """Compressed-path neighborhood query (paper: `v ? ?`)."""
        return self.engine.neighbors_out(v)

    def neighbors_in(self, v: int) -> np.ndarray:
        return self.engine.neighbors_in(v)

    def triples(self, s=None, p=None, o=None) -> list[tuple]:
        return self.engine.query(s, p, o)

    def compressed_size_bytes(self) -> int:
        return self.encoded.size_in_bytes()

    # ---------------------------------------------------- training paths
    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, indices) over out-edges; materialized once."""
        if self._csr is None:
            g = self.grammar.decompress()
            ranks = g.ranks()
            r2 = ranks == 2
            src = g.nodes_flat[g.offsets[:-1][r2]]
            dst = g.nodes_flat[g.offsets[:-1][r2] + 1]
            self._csr = _to_csr(src, dst, self.n_nodes)
        return self._csr

    def csc(self) -> tuple[np.ndarray, np.ndarray]:
        if self._csc is None:
            g = self.grammar.decompress()
            ranks = g.ranks()
            r2 = ranks == 2
            src = g.nodes_flat[g.offsets[:-1][r2]]
            dst = g.nodes_flat[g.offsets[:-1][r2] + 1]
            self._csc = _to_csr(dst, src, self.n_nodes)
        return self._csc

    def edge_index(self) -> tuple[np.ndarray, np.ndarray]:
        """(senders, receivers) COO arrays for full-batch GNNs."""
        indptr, indices = self.csr()
        senders = np.repeat(np.arange(self.n_nodes), np.diff(indptr))
        return senders, indices


def _to_csr(src: np.ndarray, dst: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, dst.astype(np.int64)
