"""GraphStore — the paper's compressed graph as a first-class data-layer
service of the training framework.

Graphs are held as an ITR grammar; point lookups (neighborhoods, triple
patterns) run on the compressed form via :class:`TripleQueryEngine`.
Training hot paths (full-batch GNN adjacency, high-throughput fanout
sampling) use a lazily *materialized* CSR view — decompressed once, cached —
because a sampled-training step issues thousands of neighbor lookups per
batch. Storage stays compressed; the CSR cache is working memory.

Point lookups share the engine's cross-request result cache: a
neighborhood query is the (v, ?, ?) / (?, ?, v) pattern, so hot entities
hit the same LRU as triple-pattern traffic (`query_cache_stats` exposes
hit/miss/eviction counters for serving dashboards).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Hypergraph,
    LabelTable,
    RepairConfig,
    TripleQueryEngine,
    compress,
    encode,
)


_DEFAULT = object()  # "engine decides" sentinel: cache=None must mean OFF


class GraphStore:
    def __init__(self, grammar, stats=None, cache=_DEFAULT):
        self.grammar = grammar
        self.stats = stats
        self.encoded = encode(grammar)
        engine_kwargs = {} if cache is _DEFAULT else {"cache": cache}
        self.engine = TripleQueryEngine(grammar, self.encoded, **engine_kwargs)
        self._csr = None
        self._csc = None

    # ------------------------------------------------------------- build
    @classmethod
    def from_triples(
        cls, triples: np.ndarray, n_nodes: int, n_preds: int, config: RepairConfig | None = None
    ) -> "GraphStore":
        table = LabelTable.terminals([2] * n_preds)
        graph = Hypergraph.from_triples(triples, n_nodes)
        grammar, stats = compress(graph, table, config)
        return cls(grammar, stats)

    @property
    def n_nodes(self) -> int:
        return self.grammar.start.n_nodes

    # ------------------------------------------------------- point paths
    def neighbors_out(self, v: int) -> np.ndarray:
        """Compressed-path neighborhood query (paper: `v ? ?`)."""
        return self.engine.neighbors_out(v)

    def neighbors_in(self, v: int) -> np.ndarray:
        return self.engine.neighbors_in(v)

    def neighbors_out_batch(self, vs) -> list[np.ndarray]:
        """Batched `v ? ?` neighborhoods — one frontier, cache-shared.

        View-backed internally: duplicate vs in one batch share a single
        (read-only) result array instead of per-duplicate copies."""
        return self.engine.neighbors_out_batch(vs)

    def neighbors_in_batch(self, vs) -> list[np.ndarray]:
        return self.engine.neighbors_in_batch(vs)

    def triples(self, s=None, p=None, o=None) -> list[tuple]:
        return self.engine.query(s, p, o)

    def triples_batch_view(self, s_arr, p_arr, o_arr):
        """Batched pattern lookup as a :class:`~repro.core.query
        .QueryResultView` — qid -> shared entry arrays, duplicates never
        materialized; `.materialize()` recovers the flat array layout."""
        return self.engine.query_batch_view(s_arr, p_arr, o_arr)

    def query_cache_stats(self):
        """Engine result-cache counters (None when caching is disabled)."""
        return self.engine.cache.stats if self.engine.cache is not None else None

    def compressed_size_bytes(self) -> int:
        return self.encoded.size_in_bytes()

    # ---------------------------------------------------- training paths
    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, indices) over out-edges; materialized once."""
        if self._csr is None:
            g = self.grammar.decompress()
            ranks = g.ranks()
            r2 = ranks == 2
            src = g.nodes_flat[g.offsets[:-1][r2]]
            dst = g.nodes_flat[g.offsets[:-1][r2] + 1]
            self._csr = _to_csr(src, dst, self.n_nodes)
        return self._csr

    def csc(self) -> tuple[np.ndarray, np.ndarray]:
        if self._csc is None:
            g = self.grammar.decompress()
            ranks = g.ranks()
            r2 = ranks == 2
            src = g.nodes_flat[g.offsets[:-1][r2]]
            dst = g.nodes_flat[g.offsets[:-1][r2] + 1]
            self._csc = _to_csr(dst, src, self.n_nodes)
        return self._csc

    def edge_index(self) -> tuple[np.ndarray, np.ndarray]:
        """(senders, receivers) COO arrays for full-batch GNNs."""
        indptr, indices = self.csr()
        senders = np.repeat(np.arange(self.n_nodes), np.diff(indptr))
        return senders, indices


def _to_csr(src: np.ndarray, dst: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, dst.astype(np.int64)
