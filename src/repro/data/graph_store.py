"""GraphStore — the paper's compressed graph as a first-class data-layer
service of the training framework.

Graphs are held as an ITR grammar; point lookups (neighborhoods, triple
patterns) run on the compressed form via :class:`TripleQueryEngine`.
Training hot paths (full-batch GNN adjacency, high-throughput fanout
sampling) use a lazily *materialized* CSR view — decompressed once, cached —
because a sampled-training step issues thousands of neighbor lookups per
batch. Storage stays compressed; the CSR cache is working memory.

Point lookups share the engine's cross-request result cache: a
neighborhood query is the (v, ?, ?) / (?, ?, v) pattern, so hot entities
hit the same LRU as triple-pattern traffic (`query_cache_stats` exposes
hit/miss/eviction counters for serving dashboards).

The store is writable: `insert_triples`/`delete_triples` ride the
engine's delta overlay, so point lookups stay exact immediately, and the
materialized CSR/CSC training views are invalidated (and rebuilt
overlay-applied on next use). Node ids must stay within the store's
fixed `n_nodes` universe — training adjacency shapes are allocated
against it — unlike the bare engine, which lets inserts grow the graph.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Hypergraph,
    LabelTable,
    RepairConfig,
    TripleQueryEngine,
    compress,
    encode,
)


_DEFAULT = object()  # "engine decides" sentinel: cache=None must mean OFF


class GraphStore:
    def __init__(self, grammar, stats=None, cache=_DEFAULT, config=None):
        self.grammar = grammar
        self.stats = stats
        self.encoded = encode(grammar)
        engine_kwargs = {} if cache is _DEFAULT else {"cache": cache}
        self.engine = TripleQueryEngine(grammar, self.encoded, config=config,
                                        **engine_kwargs)
        self._csr = None
        self._csc = None

    # ------------------------------------------------------------- build
    @classmethod
    def from_triples(
        cls, triples: np.ndarray, n_nodes: int, n_preds: int, config: RepairConfig | None = None
    ) -> "GraphStore":
        table = LabelTable.terminals([2] * n_preds)
        graph = Hypergraph.from_triples(triples, n_nodes)
        grammar, stats = compress(graph, table, config)
        return cls(grammar, stats, config=config)

    @property
    def n_nodes(self) -> int:
        return self.grammar.start.n_nodes

    # ------------------------------------------------------- point paths
    def neighbors_out(self, v: int) -> np.ndarray:
        """Compressed-path neighborhood query (paper: `v ? ?`)."""
        return self.engine.neighbors_out(v)

    def neighbors_in(self, v: int) -> np.ndarray:
        return self.engine.neighbors_in(v)

    def neighbors_out_batch(self, vs) -> list[np.ndarray]:
        """Batched `v ? ?` neighborhoods — one frontier, cache-shared.

        View-backed internally: duplicate vs in one batch share a single
        (read-only) result array instead of per-duplicate copies."""
        return self.engine.neighbors_out_batch(vs)

    def neighbors_in_batch(self, vs) -> list[np.ndarray]:
        return self.engine.neighbors_in_batch(vs)

    def triples(self, s=None, p=None, o=None) -> list[tuple]:
        return self.engine.query(s, p, o)

    def triples_batch_view(self, s_arr, p_arr, o_arr):
        """Batched pattern lookup as a :class:`~repro.core.query
        .QueryResultView` — qid -> shared entry arrays, duplicates never
        materialized; `.materialize()` recovers the flat array layout."""
        return self.engine.query_batch_view(s_arr, p_arr, o_arr)

    def query_cache_stats(self):
        """Engine result-cache counters (None when caching is disabled)."""
        return self.engine.cache.stats if self.engine.cache is not None else None

    def compressed_size_bytes(self) -> int:
        return self.encoded.size_in_bytes()

    # ----------------------------------------------------------- mutation
    def insert_triples(self, triples) -> int:
        """Insert (s, p, o) rows (engine delta overlay); returns how many
        were actually new. Node ids must be < `n_nodes` — the training
        views' shapes are fixed at build. Materialized CSR/CSC views are
        dropped and rebuilt overlay-applied on next use."""
        rows = np.asarray(triples, dtype=np.int64).reshape(-1, 3)
        if len(rows) and int(rows[:, [0, 2]].max()) >= self.n_nodes:
            raise ValueError(
                f"node ids must be < n_nodes={self.n_nodes}; rebuild the "
                f"store from triples to grow the node universe")
        return self._after_mutation(self.engine.insert_triples(rows))

    def delete_triples(self, triples) -> int:
        """Delete (s, p, o) rows; returns how many were actually present."""
        return self._after_mutation(self.engine.delete_triples(triples))

    def rebuild(self, config=None) -> bool:
        """Recompress base+delta now; True if the overlay was non-empty."""
        return bool(self._after_mutation(int(self.engine.rebuild(config))))

    def _after_mutation(self, applied: int) -> int:
        """Refresh grammar/encoding refs (the engine swaps them on
        auto-rebuild) and drop materialized views when anything changed."""
        if applied:
            self.grammar = self.engine.grammar
            self.encoded = self.engine.encoded
            self._csr = None
            self._csc = None
        return applied

    # ---------------------------------------------------- training paths
    def _rank2_rows(self) -> np.ndarray:
        """Logical (s, p, o) rows: decompressed rank-2 base edges with the
        mutation overlay applied (ITR+ node-label edges are skipped)."""
        g = self.grammar.decompress()
        r2 = g.ranks() == 2
        starts = g.offsets[:-1][r2]
        rows = np.stack(
            [g.nodes_flat[starts], g.labels[r2], g.nodes_flat[starts + 1]],
            axis=1) if r2.any() else np.zeros((0, 3), dtype=np.int64)
        return self.engine.delta.apply(rows)

    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, indices) over out-edges; materialized once."""
        if self._csr is None:
            rows = self._rank2_rows()
            self._csr = _to_csr(rows[:, 0], rows[:, 2], self.n_nodes)
        return self._csr

    def csc(self) -> tuple[np.ndarray, np.ndarray]:
        if self._csc is None:
            rows = self._rank2_rows()
            self._csc = _to_csr(rows[:, 2], rows[:, 0], self.n_nodes)
        return self._csc

    def edge_index(self) -> tuple[np.ndarray, np.ndarray]:
        """(senders, receivers) COO arrays for full-batch GNNs."""
        indptr, indices = self.csr()
        senders = np.repeat(np.arange(self.n_nodes), np.diff(indptr))
        return senders, indices


def _to_csr(src: np.ndarray, dst: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, dst.astype(np.int64)
