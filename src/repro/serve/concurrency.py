"""Concurrency primitives for the serving tier.

Two things live here, both consumed by `repro.serve.sharded` (and, through
it, `repro.persist.service`):

* :class:`RWLock` — the reader-writer lock behind the tier's concurrency
  discipline (documented end to end in ``docs/CONCURRENCY.md``). Queries
  are *readers*: any number of flushes run concurrently, each seeing one
  consistent (plan, migration, engines) state for its whole duration.
  Mutations, rebuilds, rebalance steps, failure handling, and snapshots
  are *writers*: fully exclusive, so every invariant the single-threaded
  oracle suites pin (migration-safe routing, disjoint partitions,
  WAL-order == apply-order) holds under arbitrary interleaving — writers
  simply never interleave with anything.

  The lock is **write-preferring** (a waiting writer blocks new readers,
  so mutation latency is bounded by in-flight flushes, not by a steady
  reader stream) and **writer-reentrant**: the thread holding write may
  re-acquire write (``DurableShardedService`` wraps the WAL append and
  the in-memory apply in one exclusive section around the inner service's
  own write-locked mutation) and may acquire read (a write-locked
  rebalance probes visibility through the query path). Upgrading
  read → write is refused with ``RuntimeError`` — two readers upgrading
  simultaneously would deadlock, so the attempt fails loudly instead.

* :func:`resolve_serve_threads` — the ``ITR_SERVE_THREADS`` knob: how
  many threads a sharded flush may fan scatter-gather work out across.
  Per-shard engines are independent and the post-build read path is
  numpy (GIL-releasing), so unselective scatter latency drops roughly
  with core count until the shard count or the machine runs out.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager


class RWLock:
    """Write-preferring reader-writer lock with a reentrant writer.

    * ``read()``: shared — many threads at once; reentrant per thread;
      granted immediately to the thread currently holding write.
    * ``write()``: exclusive — waits for all readers to drain and blocks
      new ones while waiting; reentrant in the owning thread.
    * read → write upgrade raises ``RuntimeError`` (it deadlocks by
      construction when two readers try it; fail loudly instead).
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers: dict[int, int] = {}   # thread ident -> read depth
        self._writer: int | None = None      # ident of the write holder
        self._write_depth = 0
        self._waiting_writers = 0

    @contextmanager
    def read(self):
        self.acquire_read()
        try:
            yield
        finally:
            self.release_read()

    @contextmanager
    def write(self):
        self.acquire_write()
        try:
            yield
        finally:
            self.release_write()

    def acquire_read(self):
        me = threading.get_ident()
        with self._cond:
            # the write owner and already-admitted readers bypass the
            # writer-preference barrier: blocking them would deadlock
            if self._writer == me or me in self._readers:
                self._readers[me] = self._readers.get(me, 0) + 1
                return
            while self._writer is not None or self._waiting_writers:
                self._cond.wait()
            self._readers[me] = 1

    def release_read(self):
        me = threading.get_ident()
        with self._cond:
            depth = self._readers.get(me)
            if depth is None:
                raise RuntimeError("release_read without acquire_read")
            if depth > 1:
                self._readers[me] = depth - 1
            else:
                del self._readers[me]
                self._cond.notify_all()

    def acquire_write(self):
        me = threading.get_ident()
        with self._cond:
            if self._writer == me:
                self._write_depth += 1
                return
            if me in self._readers:
                raise RuntimeError(
                    "read->write upgrade would deadlock; release the read "
                    "lock (or take the write lock first)")
            self._waiting_writers += 1
            try:
                while self._writer is not None or self._readers:
                    self._cond.wait()
            finally:
                self._waiting_writers -= 1
            self._writer = me
            self._write_depth = 1

    def release_write(self):
        me = threading.get_ident()
        with self._cond:
            if self._writer != me:
                raise RuntimeError("release_write by a non-owner thread")
            self._write_depth -= 1
            if self._write_depth == 0:
                self._writer = None
                self._cond.notify_all()

    # -- introspection (tests / diagnostics) -------------------------------
    @property
    def write_held(self) -> bool:
        return self._writer is not None

    @property
    def active_readers(self) -> int:
        return len(self._readers)


def resolve_serve_threads(value=None) -> int:
    """Resolve the scatter-gather fan-out width (``ITR_SERVE_THREADS``).

    Returns the number of threads a sharded flush may use to query shard
    engines in parallel; ``1`` means the sequential fan-out. Resolution:

    * explicit `value` wins over the environment;
    * ``off``/``none``/``never`` (case-insensitive), ``0``, ``1``, or any
      negative value → ``1`` (sequential);
    * unset/empty/unparsable → ``os.cpu_count()`` (the default: shard
      engines are independent and numpy releases the GIL, so one thread
      per core is the natural width; the effective pool is further capped
      at the shard count by the service).
    """
    if value is None:
        value = os.environ.get("ITR_SERVE_THREADS", "")
    text = str(value).strip().lower()
    default = os.cpu_count() or 1
    if not text:
        return default
    if text in ("off", "none", "never"):
        return 1
    try:
        n = int(text)
    except ValueError:
        return default
    return max(1, n)
