"""Sharded multi-engine triple serving: partitioned engines behind a
scatter-gather router with a shared result-cache tier.

One engine per graph partition (``repro.distributed.partition``), all
sharing a single :class:`~repro.core.result_cache.QueryResultCache` keyed
by ``(shard, S, P, O)`` through per-shard views — one budget, one stats
block, no collisions. The router sends each pattern to the single shard
that owns it when the partition axis is bound (P under ``predicate_hash``,
S under ``node_range``) and scatter-gathers the unselective ones (``?P?``
and ``??O`` under ``node_range``, ``S??``/``??O`` under
``predicate_hash``, ``???`` always) across every shard in ONE micro-batch
flush each — a flush never issues more than one engine call per shard per
``max_batch`` chunk, regardless of how many patterns scatter.

Merging is view-based end to end: each shard answers with shared
per-pattern entry arrays (:class:`~repro.core.query.QueryResultView`), a
scattered pattern's answer is the concatenation of its per-shard entries
(partitions are disjoint, so no dedup), and duplicate tickets share one
merged entry. Merged results are themselves cached in a reserved
namespace of the shared tier, so a *warm* scattered pattern is one
lookup — no fan-out, no re-concatenation. ``flush()`` materializes tuple
lists per *unique* pattern — ``flush_view()`` is the zero-replication
escape hatch.

Partitions are mutable. ``insert_triples``/``delete_triples`` route
mutation rows to their owning shard (``PartitionPlan.route_triples`` —
the same placement rule the build used, so a shard's overlay only ever
holds triples that shard would answer for) and apply them to that
engine's :class:`~repro.core.delta.DeltaOverlay`; ``invalidate(shard)``
then bumps ONLY the mutated shards' cache generations (plus the merged
namespace, whose entries depend on every shard) so the other shards stay
warm. When a shard's overlay outgrows the engines' ``ITR_DELTA_BUDGET``
it alone is recompressed through the RePair pipeline and atomically
swapped — :meth:`ShardedTripleService.rebuild` is the explicit handle —
which is what makes rebuild cost O(dirty shards), not O(graph).

Partitions also *re-cut themselves*. Mutation skews shard loads and the
build-time `PartitionPlan` never follows, so the tier watches its live
per-shard edge counts (base + overlay) and — when their ``max/mean``
skew crosses ``ITR_REBALANCE_SKEW``, or on an explicit
:meth:`ShardedTripleService.rebalance` — computes a successor plan
(`repro.distributed.rebalance`: re-quantiled ``node_range`` boundaries
or LPT-re-packed predicate groups) and migrates the rows whose owner
changed, in bounded batches: each batch arrives through the destination
overlay before leaving the source via tombstones inside one call, so
partitions stay disjoint at every public boundary, and only the two
shards it touched lose their warm cache entries. While moves are
pending the router stops trusting single-shard ownership for any
pattern the outgoing and incoming plans route differently (it scatters
instead — exact on disjoint partitions wherever each row currently
sits), and mutations of in-motion rows delete on both candidate shards
/ insert on the incoming owner after probing the outgoing one, so
serving and writes stay exact mid-migration.

The tier is **safe under concurrent callers** (contract:
``docs/CONCURRENCY.md``). Queries run as *readers* under the service's
:class:`~repro.serve.concurrency.RWLock` — any number of threads flush at
once, each seeing one consistent (plan, migration, engines) state —
while mutations, rebuilds, rebalancing, and failure handling are fully
exclusive *writers*, so every single-threaded routing invariant above
survives arbitrary interleaving. Within one flush, scatter-gather work
additionally fans out across shard engines on a thread pool sized by
``ITR_SERVE_THREADS`` (engines are independent and the post-build read
path is numpy, which releases the GIL); per-engine locks serialize the
engines' internal scratch state, and the merge is deterministic in shard
order, so threaded and sequential flushes are byte-identical.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from repro.core import (
    Hypergraph,
    LabelTable,
    QueryResultCache,
    TripleQueryEngine,
    compress,
)
from repro.core.flatten import concat_ragged
from repro.core.delta import as_triple_rows
from repro.core.query import (
    _DEFAULT_BUDGET,
    QueryResultView,
    _env_flag,
    _freeze_entry,
)
from repro.distributed.partition import (
    PartitionPlan,
    make_plan,
    partition_triples,
)
from repro.distributed.rebalance import (
    live_shard_edges,
    measure_skew,
    plan_rebalance,
    resolve_rebalance_skew,
)
from repro.persist.crash import crash_point
from repro.serve.concurrency import RWLock, resolve_serve_threads
from repro.serve.triple_service import MicroBatchService

# sentinel: "create a default shared QueryResultCache unless disabled by env"
_DEFAULT_CACHE = object()

# sentinel: "resolve the rebalance trigger from ITR_REBALANCE_SKEW"
_DEFAULT_SKEW = object()

# migration rows an AUTO-triggered rebalance applies per mutation call:
# the trigger starts the migration and each subsequent applied mutation
# drains another bounded chunk, so one insert never blocks on moving the
# whole diff (explicit rebalance() drains to completion on demand)
_AUTO_MOVES_PER_CALL = 4096

# reserved shard id for cross-shard MERGED scattered results in the shared
# tier (real shards are >= 0; -1 is the single-engine default namespace).
# A warm scattered pattern is then one lookup instead of a full fan-out +
# re-concatenation; invalidate() bumps this namespace alongside any shard,
# since a merged entry depends on every shard's data.
_MERGED_SHARD = -2


@dataclass
class ShardedServiceStats:
    """Rolling counters for the scatter-gather router.

    `owned` / `scattered` count *unique* patterns per flush (the unit of
    routing work); `shard_batches` counts per-shard engine micro-batch
    executions — each flush issues up to ``ceil(sub_batch / max_batch)``
    chunks per shard, where a shard's sub-batch is its owned patterns
    plus every scattered one.
    """

    queries: int = 0
    flushes: int = 0
    results: int = 0
    unique_patterns: int = 0
    owned: int = 0
    scattered: int = 0
    merged_hits: int = 0  # scattered patterns answered from the merged tier
    shard_batches: int = 0
    inserted: int = 0     # triples actually added (mutation no-ops excluded)
    deleted: int = 0      # triples actually removed
    rebuilds: int = 0     # per-shard grammar recompressions (auto + explicit)
    rebalances: int = 0   # migrations started (auto-trigger + explicit)
    migrated_rows: int = 0  # rows moved between shards by rebalancing
    degraded_patterns: int = 0  # patterns answered with a failed shard's hole
    replica_flushes: int = 0  # flushes served by a read replica group
    bgp_queries: int = 0      # whole-BGP joins answered (hits + executions)
    bgp_cache_hits: int = 0   # BGPs served straight from the merged cache
    string_queries: int = 0   # query_strings / query_bgp_strings calls
    unknown_term_empties: int = 0  # string queries short-circuited to empty
    total_s: float = 0.0
    last_flush_qps: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0


class ShardedTripleService(MicroBatchService):
    """Scatter-gather front end over P partitioned :class:`TripleQueryEngine`s.

    Construct directly from pre-built engines + plan (engines must cover
    plan.n_shards, in shard order), or via :meth:`build` from raw triples.
    The request plane (`submit`/`flush`/`query_many`) is the shared
    :class:`~repro.serve.triple_service.MicroBatchService` surface.
    """

    def __init__(self, engines: list[TripleQueryEngine], plan: PartitionPlan,
                 cache: QueryResultCache | None = None, max_batch: int = 1024,
                 config=None, rebalance_skew=_DEFAULT_SKEW,
                 serve_threads: int | None = None):
        super().__init__()
        assert len(engines) == plan.n_shards, \
            f"{len(engines)} engines for {plan.n_shards} shards"
        self.engines = engines
        self.plan = plan
        self.cache = cache  # the shared tier (engines hold shard views of it)
        self.max_batch = int(max_batch)
        self.config = config  # RepairConfig reused by per-shard rebuilds
        self.stats = ShardedServiceStats()
        # concurrency discipline (docs/CONCURRENCY.md): queries read-lock,
        # every mutating surface write-locks, so routing invariants pinned
        # single-threaded hold under any interleaving
        self._rw = RWLock()
        # engines keep per-instance scratch (frontier arena, memo tables),
        # so two threads of ONE flush must not enter the same engine at once
        self._engine_locks = [threading.Lock() for _ in engines]
        self._stats_lock = threading.Lock()  # stats blocks are not atomic
        #: scatter fan-out width (threads per flush); 1 = sequential
        self.serve_threads = resolve_serve_threads(serve_threads)
        self._pool: ThreadPoolExecutor | None = None  # lazy, sized on first use
        self._pool_lock = threading.Lock()
        # auto-rebalance trigger (max/mean live-edge skew); None = explicit only
        if rebalance_skew is _DEFAULT_SKEW:
            self.rebalance_skew = resolve_rebalance_skew()
        else:
            self.rebalance_skew = None if rebalance_skew is None \
                else resolve_rebalance_skew(rebalance_skew)
        self._migration = None        # in-flight RebalancePlan, or None
        self._futile_total: int | None = None  # auto-trigger backoff anchor
        #: shards whose recovery failed — served as empty holes, writes refused
        self.failed_shards: set[int] = set()
        # durability hook (repro.persist.service installs it): called as
        # _journal(kind, payload) BEFORE a rebalance state change applies
        self._journal = None
        # cache-namespace indirection: shard k's entries live under
        # namespace _cache_ns[k] of the shared tier, merged scatter results
        # under _merged_ns. The primary uses the identity mapping; replica
        # group services (repro.serve.replication) get disjoint negative
        # namespaces, so a lagging replica serves from its own generation's
        # entries and never mixes them with the primary's fresher ones.
        self._cache_ns: list[int] = list(range(plan.n_shards))
        self._merged_ns: int = _MERGED_SHARD
        # read-replica dispatch (a ReplicationManager once the durable
        # service enables replication; flushes then prefer a replica group)
        self._replicas = None
        # optional TermDict for the string-term surfaces (attach_term_dict)
        self.term_dict = None

    # -- construction ----------------------------------------------------
    @classmethod
    def build(cls, triples: np.ndarray, n_nodes: int, n_preds: int,
              n_shards: int = 4, strategy: str = "predicate_hash",
              config=None, cache=_DEFAULT_CACHE, crossover: int | None = None,
              max_batch: int = 1024, delta_budget=_DEFAULT_BUDGET,
              rebalance_skew=_DEFAULT_SKEW,
              serve_threads: int | None = None) -> "ShardedTripleService":
        """Partition -> compress each subgraph -> one engine per shard.

        `cache` is the shared result-cache tier (default: one
        :class:`QueryResultCache` shared by all shards, disabled by
        ``ITR_RESULT_CACHE=0``; pass ``None`` to disable explicitly).
        `delta_budget` is each engine's mutation-overlay rebuild threshold
        (default: read ``ITR_DELTA_BUDGET``; ``None`` = auto-rebuild off).
        `rebalance_skew` is the live max/mean shard-load ratio at/above
        which the mutation path starts an online rebalance (default: read
        ``ITR_REBALANCE_SKEW``; ``None`` = only explicit ``rebalance()``).
        `serve_threads` is the scatter fan-out width (default: read
        ``ITR_SERVE_THREADS``, falling back to the core count).
        """
        plan = make_plan(strategy, n_shards, n_nodes, n_preds, triples=triples)
        if cache is _DEFAULT_CACHE:
            cache = QueryResultCache() if _env_flag("ITR_RESULT_CACHE", True) else None
        engine_kwargs = {} if delta_budget is _DEFAULT_BUDGET \
            else {"delta_budget": delta_budget}
        engines = []
        for k, sub in enumerate(partition_triples(triples, plan)):
            table = LabelTable.terminals([2] * n_preds)
            graph = Hypergraph.from_triples(sub, n_nodes)
            grammar, _ = compress(graph, table, config)
            engine = TripleQueryEngine(
                grammar,
                cache=cache.shard_view(k) if cache is not None else None,
                crossover=crossover, config=config, **engine_kwargs)
            engine._base_edges = len(sub)  # skew checks skip the decompress
            engines.append(engine)
        return cls(engines, plan, cache, max_batch, config=config,
                   rebalance_skew=rebalance_skew, serve_threads=serve_threads)

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    # -- request plane ---------------------------------------------------
    def _flush_columns(self, s, p, o) -> QueryResultView:
        """Execute one taken batch under the reader lock.

        Safe from any number of threads at once: the read lock pins one
        consistent (plan, migration, engines) state for the whole flush,
        and everything `_run` touches concurrently (shared cache,
        per-engine scratch, stats) is locked at its own level.
        """
        n = len(s)
        t0 = time.perf_counter()
        with self._rw.read():
            group = None
            if self._replicas is not None and not self._rw.write_held:
                # write_held while we hold read means WE are the writer (a
                # write-locked probe, e.g. contains_triples mid-mutation):
                # it must observe the primary's half-applied state, not a
                # replica's. Plain readers can never see write_held here.
                group = self._replicas.acquire()
            if group is not None:
                try:
                    # the whole flush runs on ONE consistent replica group,
                    # so merged scatter results never mix freshness levels;
                    # the group's own read lock excludes its WAL-tail applies
                    with group.service._rw.read():
                        view = group.service._run(s, p, o)
                finally:
                    self._replicas.release(group)
            else:
                view = self._run(s, p, o)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            st = self.stats
            st.queries += n
            st.flushes += 1
            st.results += view.total_results()
            st.total_s += dt
            st.last_flush_qps = n / dt if dt > 0 else 0.0
            if group is not None:
                st.replica_flushes += 1
        return view

    def query_bgp(self, patterns):
        """Evaluate a basic graph pattern over the sharded tier.

        Sub-pattern batches go through :meth:`_flush_columns`, so each
        join step inherits the full serving stack: micro-batch dedup, the
        shared result cache, owned-vs-scatter shard routing, the threaded
        fan-out pool, and replica dispatch. Whole-BGP results are cached
        in the merged-scatter namespace (disable with ``ITR_BGP_CACHE=0``)
        keyed by the canonicalized pattern list; the namespace generation
        is bumped by `invalidate()` on ANY shard change, so it acts as a
        tier-wide generation vector and stale joins can never be served.

        Concurrency: each join step takes the read lock on its own (same
        discipline as `query`), so a BGP is atomic *per step*, not across
        steps — a mutation landing between steps can surface a mixed
        view, exactly like two independent `query` calls would see. The
        cache insert is guarded by the generation observed before the
        first step, so such a mixed result is never cached.
        """
        from repro.core.bgp import (
            SelectivityStats,
            bgp_cache_key,
            bgp_variables,
            decode_result_entry,
            encode_result_entry,
            execute_bgp,
            parse_bgp,
        )
        patterns = parse_bgp(patterns)
        out_vars = bgp_variables(patterns)
        cache = self.cache if _env_flag("ITR_BGP_CACHE", True) else None
        key = gen0 = None
        if cache is not None:
            key = bgp_cache_key(patterns)
            gen0 = cache.generation(self._merged_ns)
            hit = cache.lookup(*key, shard=self._merged_ns)
            if hit is not None:
                with self._stats_lock:
                    self.stats.bgp_queries += 1
                    self.stats.bgp_cache_hits += 1
                return decode_result_entry(hit, out_vars)
        with self._rw.read():  # pin engines for the stats pass only
            stats = SelectivityStats.merge(
                eng.selectivity() for eng in self.engines)
        result = execute_bgp(patterns, self._flush_columns, stats)
        if cache is not None and cache.generation(self._merged_ns) == gen0:
            cache.insert(*key, encode_result_entry(result),
                         shard=self._merged_ns)
        with self._stats_lock:
            self.stats.bgp_queries += 1
        return result

    # -- string-term surfaces (require an attached TermDict) ---------------
    def attach_term_dict(self, term_dict) -> None:
        """Attach a :class:`~repro.core.term_dict.TermDict` mapping term
        strings to the dense ids this tier serves. One dictionary covers
        the whole tier (ids are global, shards are an id-space partition);
        string queries resolve terms once here at the boundary, then run
        on ids through the normal scatter-gather path."""
        self.term_dict = term_dict

    def _require_term_dict(self):
        if self.term_dict is None:
            raise ValueError(
                "no term dictionary attached — call attach_term_dict() "
                "(or ingest through repro.data.ingest, which attaches one)")
        return self.term_dict

    def query_strings(self, s: str | None, p: str | None, o: str | None):
        """One (S, P, O) pattern with *term strings* (``None`` = unbound).
        A bound term the dictionary has never seen short-circuits to
        ``[]`` without touching any shard. Returns term triples."""
        td = self._require_term_dict()
        from repro.core.term_dict import resolve_string_triple
        s_id, p_id, o_id, known = resolve_string_triple(td, s, p, o)
        with self._stats_lock:
            self.stats.string_queries += 1
            if not known:
                self.stats.unknown_term_empties += 1
        if not known:
            return []
        out = []
        for label, nodes in self.query(s_id, p_id, o_id):
            if len(nodes) != 2:
                raise ValueError(
                    f"string queries need rank-2 edges, got rank {len(nodes)}")
            out.append((td.node_term(nodes[0]), td.pred_term(label),
                        td.node_term(nodes[1])))
        return out

    def query_bgp_strings(self, patterns) -> list[dict]:
        """`query_bgp` with string terms: patterns are (s, p, o) tuples of
        ``?var`` names / constant term strings; unknown constants
        short-circuit to ``[]`` without executing any join step. Returns
        ``[{var: term}, ...]`` binding rows (deterministic order)."""
        td = self._require_term_dict()
        from repro.core.term_dict import bgp_result_to_terms, resolve_string_bgp
        id_patterns, pred_vars, known = resolve_string_bgp(td, patterns)
        with self._stats_lock:
            self.stats.string_queries += 1
            if not known:
                self.stats.unknown_term_empties += 1
        if not known:
            return []
        return bgp_result_to_terms(td, self.query_bgp(id_patterns), pred_vars)

    def add_node_terms(self, terms) -> np.ndarray:
        """Mint node ids for *terms* (known terms keep theirs); int64 ids
        in input order. Node ids may extend past the build-time universe —
        the plan routes them (clipped node ranges / hashed predicates)."""
        with self._rw.write():
            return self._require_term_dict().add_node_terms(terms)

    def add_pred_terms(self, terms) -> np.ndarray:
        """Mint predicate ids for *terms*. Predicate capacity is fixed at
        build time (`n_preds` terminal labels per shard engine), so terms
        that would mint past it raise instead of corrupting the id space —
        pre-size `n_preds` when building a tier for streaming ingestion."""
        with self._rw.write():
            td = self._require_term_dict()
            fresh = [t for t in dict.fromkeys(terms) if td.pred_id(t) is None]
            if td.n_preds + len(fresh) > self.plan.n_preds:
                raise ValueError(
                    f"predicate capacity exhausted: tier was built with "
                    f"n_preds={self.plan.n_preds}, dictionary holds "
                    f"{td.n_preds}, cannot mint {len(fresh)} more — rebuild "
                    "the tier with a larger predicate capacity")
            return td.add_pred_terms(terms)

    # -- fan-out pool ------------------------------------------------------
    def set_serve_threads(self, n: int | None) -> int:
        """Change the scatter fan-out width; returns the resolved value.
        ``None`` re-reads ``ITR_SERVE_THREADS``. The old pool (if any) is
        drained and replaced lazily on the next threaded flush."""
        self.serve_threads = resolve_serve_threads(n)
        with self._pool_lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        return self.serve_threads

    def close(self) -> None:
        """Drain the fan-out pool and shut down any attached replica tier
        (idempotent across the whole hierarchy — every close here and in
        the replica groups' own services is a no-op the second time; the
        primary service itself stays usable, a later threaded flush just
        re-creates its pool)."""
        replicas, self._replicas = self._replicas, None
        if replicas is not None:
            replicas.close()  # closes each group service's pool too
        with self._pool_lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._pool_lock:
            if self._pool is None:
                width = min(self.serve_threads, max(1, self.n_shards))
                self._pool = ThreadPoolExecutor(
                    max_workers=width, thread_name_prefix="itr-serve")
            return self._pool

    # -- scatter-gather core ---------------------------------------------
    def _run(self, s: np.ndarray, p: np.ndarray, o: np.ndarray) -> QueryResultView:
        # service-level dedup: route and merge each unique pattern once
        key = np.stack([s, p, o], axis=1)
        uniq, inv = np.unique(key, axis=0, return_inverse=True)
        inv = inv.reshape(-1)
        nu = len(uniq)
        u_s, u_p, u_o = uniq[:, 0], uniq[:, 1], uniq[:, 2]
        routes = self._route_patterns(u_s, u_p, u_o)
        cache = self.cache

        entries: list = [None] * nu
        # scattered patterns: the merged cross-shard result is itself cached
        # (reserved namespace), so a warm repeat is one lookup, not a fan-out
        scatter: list[int] = []
        merged_hits = 0
        for u in np.flatnonzero(routes < 0):
            u = int(u)
            hit = cache.lookup(u_s[u], u_p[u], u_o[u], shard=self._merged_ns) \
                if cache is not None else None
            if hit is None:
                scatter.append(u)
            else:
                entries[u] = hit
                merged_hits += 1
        scatter = np.asarray(scatter, dtype=np.int64)
        degraded = 0
        if self.failed_shards:
            # every pattern owned by (or scattered across) a failed shard is
            # answered with that shard's rows missing — count the holes
            failed = sorted(self.failed_shards)
            degraded = int(np.isin(routes, failed).sum()) + len(scatter)
        with self._stats_lock:
            self.stats.unique_patterns += nu
            self.stats.merged_hits += merged_hits
            self.stats.owned += int((routes >= 0).sum())
            self.stats.scattered += int((routes < 0).sum())
            self.stats.degraded_patterns += degraded

        # merge-missing scattered patterns accumulate one chunk per shard;
        # work items are collected first so they can fan out across the pool
        parts: dict[int, list] = {int(u): [] for u in scatter}
        work: list[tuple[int, np.ndarray, np.ndarray]] = []
        for k in range(len(self.engines)):
            if k in self.failed_shards:
                continue  # hole: owned patterns fall through to empty entries
            own = np.flatnonzero(routes == k)
            idx = own if len(scatter) == 0 else np.concatenate([own, scatter])
            if len(idx) == 0:
                continue
            work.append((k, own, idx))
        if len(work) > 1 and self.serve_threads > 1:
            # pool workers call _shard_entries only — they never touch the
            # RWLock (a worker acquiring read while a writer waits on the
            # submitting reader would deadlock by writer preference)
            pool = self._ensure_pool()
            futs = [pool.submit(self._shard_entries, k,
                                u_s[idx], u_p[idx], u_o[idx])
                    for k, _, idx in work]
            results = [f.result() for f in futs]
        else:
            results = [self._shard_entries(k, u_s[idx], u_p[idx], u_o[idx])
                       for k, _, idx in work]
        # merge in shard order (work is k-ascending): threaded and
        # sequential flushes produce byte-identical views
        n_batches = 0
        for (k, own, idx), (pos_entries, nb) in zip(work, results):
            n_batches += nb
            for j, u in enumerate(own):
                entries[int(u)] = pos_entries[j]
            for j, u in enumerate(scatter):
                parts[int(u)].append(pos_entries[len(own) + j])
        with self._stats_lock:
            self.stats.shard_batches += n_batches
        for u, chunks in parts.items():
            # merged chunks are shared across duplicate tickets: read-only.
            # A scattered result is deliberately held twice in the shared
            # tier (per-shard chunks + this merged copy): the merged entry
            # makes warm repeats O(1), while the per-shard chunks mean a
            # single-shard invalidate() re-executes ONE shard, not all P.
            entry = _freeze_entry(concat_ragged(chunks))
            entries[u] = entry
            if cache is not None:
                cache.insert(u_s[u], u_p[u], u_o[u], entry,
                             shard=self._merged_ns)
        for u in range(nu):  # shards==0 or routing gaps: empty result
            if entries[u] is None:
                entries[u] = _freeze_entry(concat_ragged([]))
        return QueryResultView(entries, inv)

    def _route_patterns(self, s: np.ndarray, p: np.ndarray, o: np.ndarray
                        ) -> np.ndarray:
        """Owning shard per unique pattern (-1 = scatter-gather).

        The migration-safe routing rule: while a rebalance migration is
        in flight, a pattern is sent to a single shard only when the
        outgoing AND incoming plans agree on it — rows whose ownership is
        changing may physically sit on either side mid-migration, and
        agreement means none of the pattern's rows are among them.
        Everything else scatters, which is exact on disjoint partitions
        regardless of migration progress.
        """
        routes = self.plan.route_batch(s, p, o)
        if self._migration is not None:
            incoming = self._migration.new_plan.route_batch(s, p, o)
            routes = np.where(routes == incoming, routes, -1)
        return routes

    def _shard_entries(self, k: int, s, p, o) -> tuple[list, int]:
        """One shard's entries for its sub-batch, in submission order —
        one engine micro-batch per `max_batch` chunk. Returns
        ``(entries, n_batches)``; runs under the shard's engine lock, so
        threaded fan-out never interleaves inside one engine (each keeps
        per-instance scratch: the frontier arena, memo tables)."""
        engine = self.engines[k]
        out: list = []
        n_batches = 0
        with self._engine_locks[k]:
            for lo in range(0, len(s), self.max_batch):
                hi = min(lo + self.max_batch, len(s))
                view = engine.query_batch_view(s[lo:hi], p[lo:hi], o[lo:hi])
                out.extend(view.entry(i) for i in range(view.n_queries))
                n_batches += 1
        return out, n_batches

    # -- mutation ---------------------------------------------------------
    def insert_triples(self, triples) -> int:
        """Insert (s, p, o) rows; returns how many were actually new.

        Each row is routed to its owning shard (`PartitionPlan
        .route_triples`) and applied to that engine's delta overlay; only
        the mutated shards' cache generations are bumped (plus the merged
        scatter-gather namespace). A shard whose overlay exceeds the
        engines' ``ITR_DELTA_BUDGET`` recompresses itself on the spot —
        the incremental-rebuild path.
        """
        return self._mutate(triples, insert=True)

    def delete_triples(self, triples) -> int:
        """Delete (s, p, o) rows; returns how many were actually present.
        Routing, invalidation, and the rebuild budget behave exactly as in
        :meth:`insert_triples`."""
        return self._mutate(triples, insert=False)

    def _mutate(self, triples, insert: bool) -> int:
        rows = as_triple_rows(triples)
        if len(rows) == 0:
            return 0
        if int(rows[:, 1].max()) >= self.plan.n_preds:
            raise ValueError(
                f"predicate ids must be < {self.plan.n_preds}; "
                f"got {int(rows[:, 1].max())}")
        with self._rw.write():  # exclusive: no flush observes a half-applied
            # mutation, routing state never changes under a reader
            if self._migration is None:
                applied = self._apply_rows(rows, insert,
                                           self.plan.route_triples(rows))
            else:
                applied = self._mutate_in_flight(rows, insert)
            if insert:
                self.stats.inserted += applied
            else:
                self.stats.deleted += applied
            if applied:
                self._maybe_auto_rebalance()
        return applied

    def _apply_rows(self, rows: np.ndarray, insert: bool,
                    shards: np.ndarray) -> int:
        """Apply mutation rows to the given per-row shards; bump only the
        shards that actually changed."""
        if self.failed_shards and \
                np.isin(shards, sorted(self.failed_shards)).any():
            raise RuntimeError(
                f"cannot mutate failed shards {sorted(self.failed_shards)}; "
                "restore them with reingest_shard() first")
        applied = 0
        for k in np.unique(shards):
            k = int(k)
            engine = self.engines[k]
            sub = rows[shards == k]
            before = engine.rebuild_count
            n = engine.insert_triples(sub) if insert \
                else engine.delete_triples(sub)
            self.stats.rebuilds += engine.rebuild_count - before
            if n:  # only mutated shards lose their warm cache entries
                applied += n
                self.invalidate(k)
        return applied

    def _mutate_in_flight(self, rows: np.ndarray, insert: bool) -> int:
        """Mutations while a rebalance migration is in flight.

        Rows whose owner is the same under the outgoing and incoming
        plans apply normally — none of them are in motion. A row whose
        ownership is changing may physically sit on either side, so:

        * deletes are first discarded from the pending moves (a later
          migration batch must never resurrect them) and then applied to
          BOTH candidate shards — each engine's set semantics no-ops the
          side that doesn't hold the row;
        * inserts probe the outgoing owner and, only if the row is not
          visible there (an unmigrated copy would otherwise end up
          duplicated across shards), land on the INCOMING owner — where
          the completed migration will expect them.
        """
        mig = self._migration
        old_s = self.plan.route_triples(rows)
        new_s = mig.new_plan.route_triples(rows)
        stable = old_s == new_s
        applied = self._apply_rows(rows[stable], insert, old_s[stable]) \
            if stable.any() else 0
        if stable.all():
            return applied
        moving = ~stable
        mrows, ma, mb = rows[moving], old_s[moving], new_s[moving]
        if insert:
            present = np.zeros(len(mrows), dtype=bool)
            for k in np.unique(ma):
                sel = ma == k
                present[sel] = self.engines[int(k)].contains_triples(mrows[sel])
            if not present.all():
                applied += self._apply_rows(mrows[~present], True,
                                            mb[~present])
        else:
            mig.discard(mrows)
            applied += self._apply_rows(mrows, False, ma)
            applied += self._apply_rows(mrows, False, mb)
        return applied

    def contains_triples(self, triples) -> np.ndarray:
        """bool[n]: is each (s, p, o) row currently visible in the tier?
        Routed like fully-bound queries, so it is exact mid-migration and
        while degraded (rows on a failed shard read as absent)."""
        rows = np.asarray(triples, dtype=np.int64).reshape(-1, 3)
        out = np.zeros(len(rows), dtype=bool)
        for i, (s, p, o) in enumerate(rows):
            out[i] = len(self.query(int(s), int(p), int(o))) > 0
        return out

    def rebuild(self, shard: int | None = None, force: bool = False) -> list[int]:
        """Incrementally recompress dirty shards; returns rebuilt shard ids.

        With `shard` given, that shard rebuilds if its overlay is
        non-empty. With `shard=None`, every shard whose overlay exceeds
        its engine's budget rebuilds — or every shard with any overlay at
        all under `force=True` (the "flush all deltas now" maintenance
        knob). Clean shards are never touched, which is the point: rebuild
        cost scales with the mutated fraction of the graph, not its size.
        """
        shards = range(self.n_shards) if shard is None else [int(shard)]
        rebuilt: list[int] = []
        with self._rw.write():  # engine.rebuild swaps engine internals —
            # it must never overlap a flush reading the same engine
            for k in shards:
                engine = self.engines[k]
                if engine.delta.is_empty:
                    continue
                over = engine.delta_budget is not None \
                    and engine.delta.size > engine.delta_budget
                if shard is not None or force or over:
                    engine.rebuild(self.config)
                    self.stats.rebuilds += 1
                    self.invalidate(k)
                    rebuilt.append(k)
        return rebuilt

    def delta_sizes(self) -> list[int]:
        """Per-shard overlay size (rows diverging from the compressed
        base) — the quantity :meth:`rebuild` budgets against."""
        return [e.delta.size for e in self.engines]

    # -- online rebalancing ------------------------------------------------
    def rebalance(self, force: bool = False,
                  max_moves: int | None = None) -> dict:
        """Re-cut the partition online; migrate rows between shards.

        With a migration already in flight this continues it — up to
        `max_moves` rows (``None`` = run to completion). Otherwise the
        live ``max/mean`` shard skew is measured and, when it is at/above
        the service's trigger (or under ``force=True``), a successor plan
        is computed (`plan_rebalance`: re-quantiled ``node_range``
        boundaries or LPT-re-packed predicate groups) and migration
        starts. Each migrated batch arrives through the destination
        shard's delta overlay and leaves the source via tombstones inside
        this call, so partitions stay disjoint at every public boundary
        and queries between calls are exact (the router scatters any
        pattern the two plans route differently while moves are pending).
        Only the shards a batch touched have their cache generations
        bumped. A re-cut that cannot move anything (structurally stuck
        skew, e.g. fewer predicates than shards) is adopted as-is and
        arms the auto-trigger backoff.

        Returns a summary: ``skew`` (at entry), ``moved`` (rows migrated
        by THIS call), ``pending`` (rows still to move), ``active``
        (migration still in flight).
        """
        with self._rw.write():  # plan/migration state swaps exclusively:
            # a reader sees either the old routing state or the new one
            if self.failed_shards:
                raise RuntimeError(
                    f"cannot rebalance with failed shards "
                    f"{sorted(self.failed_shards)}; restore them with "
                    "reingest_shard() first")
            skew = self.skew()
            if self._migration is None:
                threshold = self.rebalance_skew
                if not force and (threshold is None or skew < threshold):
                    return {"skew": skew, "moved": 0, "pending": 0,
                            "active": False}
                mig = plan_rebalance(self.plan, self.engines)
                if mig.total_rows == 0:
                    # same assignment for every live row: adopt the re-cut
                    # (future routing may still improve) and back off
                    self._journal_event("plan_swap", mig.new_plan)
                    self.plan = mig.new_plan
                    self._futile_total = int(
                        live_shard_edges(self.engines).sum())
                    return {"skew": skew, "moved": 0, "pending": 0,
                            "active": False}
                self._journal_event("rebalance_begin", mig.new_plan)
                self._migration = mig
                self.stats.rebalances += 1
                self._futile_total = None
            moved = self._apply_migration(max_moves)
            return {"skew": skew, "moved": moved,
                    "pending": self._migration.pending_rows
                    if self._migration is not None else 0,
                    "active": self._migration is not None}

    def _apply_migration(self, max_moves: int | None = None) -> int:
        """Migrate up to `max_moves` pending rows; finalize when drained.

        Each batch inserts into the destination overlay BEFORE tombstoning
        the source — both sides change inside this method, so no query can
        observe the transient double-ownership — and bumps only the two
        touched shards' generations. Once every move has been applied the
        successor plan becomes the routing plan: at that point it is the
        exact description of where every row lives.
        """
        mig = self._migration
        moved = 0
        for src, dst, batch in mig.take(max_moves):
            self._journal_event("migrate", (src, dst, batch))
            moved += self._apply_migration_batch(src, dst, batch)
        self.stats.migrated_rows += moved
        if mig.done:
            self._journal_event("plan_swap", mig.new_plan)
            self.plan = mig.new_plan
            self._migration = None
        return moved

    def _apply_migration_batch(self, src: int, dst: int,
                               batch: np.ndarray) -> int:
        """Move one logged batch from `src` to `dst` — idempotently.

        Only the rows still *visible at the source* are inserted at the
        destination. Live migration never notices (the `RebalancePlan`
        contract puts every pending row on its src shard), but WAL replay
        does: re-applying an already-applied batch after a crash must not
        duplicate rows onto dst, and a batch replayed after the row was
        deleted (discard happened post-append) must not resurrect it.
        The src-side delete is set-semantic, so it is idempotent as-is.
        """
        e_src, e_dst = self.engines[src], self.engines[dst]
        at_src = e_src.contains_triples(batch)
        batch = batch[at_src]
        if len(batch) == 0:
            return 0
        before = e_src.rebuild_count + e_dst.rebuild_count
        crash_point("migrate.pre_apply")
        e_dst.insert_triples(batch)
        crash_point("migrate.mid_apply")
        e_src.delete_triples(batch)
        self.stats.rebuilds += \
            e_src.rebuild_count + e_dst.rebuild_count - before
        self.invalidate(src)
        self.invalidate(dst)
        return len(batch)

    def _journal_event(self, kind: str, payload) -> None:
        """Hand a rebalance state change to the installed durability hook
        BEFORE it applies (write-ahead ordering); no-op when undurable."""
        if self._journal is not None:
            self._journal(kind, payload)

    def _maybe_auto_rebalance(self) -> None:
        """Mutation-path trigger: start a rebalance once live skew reaches
        the threshold, migrating at most ``_AUTO_MOVES_PER_CALL`` rows per
        mutation call — the trigger pays the plan computation, then every
        subsequent applied mutation drains another bounded chunk, so no
        single write blocks on moving the whole diff. Backoff: when a
        triggered re-cut could not move anything, auto checks stay off
        until the tier's live size drifts >25% from that futile snapshot —
        an unfixable structural skew must not cost an O(graph) plan
        computation per mutation."""
        if self.rebalance_skew is None or self.n_shards < 2 \
                or self.failed_shards:
            return
        if self._migration is not None:  # drain the in-flight migration
            self._apply_migration(_AUTO_MOVES_PER_CALL)
            return
        counts = live_shard_edges(self.engines)
        total = int(counts.sum())
        if self._futile_total is not None and \
                abs(total - self._futile_total) * 4 <= self._futile_total:
            return
        if measure_skew(counts) >= self.rebalance_skew:
            self.rebalance(force=True, max_moves=_AUTO_MOVES_PER_CALL)

    @property
    def migration_active(self) -> bool:
        """True while rebalance moves are pending (routing is in its
        conservative dual-plan mode)."""
        return self._migration is not None

    def live_edges(self) -> list[int]:
        """Per-shard live triple counts (compressed base + overlay), the
        load signal rebalancing watches — unlike :meth:`shard_sizes`,
        which reports compressed start-graph edges."""
        return [int(v) for v in live_shard_edges(self.engines)]

    def skew(self) -> float:
        """Live ``max/mean`` shard-load ratio (1.0 = balanced; compare
        against `rebalance_skew`)."""
        return measure_skew(live_shard_edges(self.engines))

    # -- degraded serving --------------------------------------------------
    def mark_shard_failed(self, shard: int) -> None:
        """Degrade one shard: serve around it instead of dying with it.

        The recovery path calls this when a shard's snapshot won't load
        (corruption, missing files). The shard's engine is replaced by an
        empty placeholder, queries keep flowing — owned patterns answer
        empty, scattered patterns merge the surviving shards — with every
        affected pattern counted in ``stats.degraded_patterns``. Writes to
        the failed shard and rebalancing are refused until
        :meth:`reingest_shard` restores it.
        """
        k = int(shard)
        if not 0 <= k < self.n_shards:
            raise ValueError(f"shard {k} out of range [0, {self.n_shards})")
        with self._rw.write():  # the engine swap must not race a flush
            self.failed_shards.add(k)
            self.engines[k] = self._build_shard_engine(
                k, np.zeros((0, 3), dtype=np.int64))
            self.invalidate(k)

    def reingest_shard(self, shard: int, triples) -> int:
        """Restore a failed shard from re-ingested rows (e.g. re-extracted
        from the upstream source); returns how many rows it now holds.
        Compresses the rows into a fresh engine, clears the failure flag,
        and invalidates the shard's (and merged) cache namespaces."""
        k = int(shard)
        if k not in self.failed_shards:
            raise ValueError(f"shard {k} is not marked failed")
        rows = as_triple_rows(triples)
        with self._rw.write():
            mine = rows[self.plan.route_triples(rows) == k] \
                if len(rows) else rows
            self.engines[k] = self._build_shard_engine(k, mine)
            self.failed_shards.discard(k)
            self.invalidate(k)
        return len(mine)

    def _build_shard_engine(self, k: int, rows: np.ndarray) -> TripleQueryEngine:
        """Compress `rows` into a fresh engine wired to shard `k`'s cache
        view (the build-time recipe, reused by degrade/reingest)."""
        table = LabelTable.terminals([2] * self.plan.n_preds)
        graph = Hypergraph.from_triples(rows, self.plan.n_nodes)
        grammar, _ = compress(graph, table, self.config)
        engine = TripleQueryEngine(
            grammar,
            cache=self.cache.shard_view(self._cache_ns[k])
            if self.cache is not None else None,
            config=self.config)
        engine._base_edges = len(rows)
        return engine

    # -- maintenance / introspection -------------------------------------
    def invalidate(self, shard: int | None = None) -> None:
        """Invalidate cached results (generation bump on the shared tier):
        one shard's entries, or every shard's when `shard` is None. The
        hook for mutable partitions — other shards stay warm. Merged
        cross-shard entries depend on every shard, so their namespace is
        bumped on any invalidation."""
        if self.cache is None:
            return
        shards = range(self.n_shards) if shard is None else [shard]
        for k in shards:
            self.cache.bump_generation(self._cache_ns[k])
        self.cache.bump_generation(self._merged_ns)

    def cache_stats(self):
        """Shared-tier cache counters (None when caching is disabled)."""
        return self.cache.stats if self.cache is not None else None

    def shard_sizes(self) -> list[int]:
        """Start-graph edges per shard (partition balance diagnostics)."""
        return [int(e.grammar.start.n_edges) for e in self.engines]
