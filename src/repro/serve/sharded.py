"""Sharded multi-engine triple serving: partitioned engines behind a
scatter-gather router with a shared result-cache tier.

One engine per graph partition (``repro.distributed.partition``), all
sharing a single :class:`~repro.core.result_cache.QueryResultCache` keyed
by ``(shard, S, P, O)`` through per-shard views — one budget, one stats
block, no collisions. The router sends each pattern to the single shard
that owns it when the partition axis is bound (P under ``predicate_hash``,
S under ``node_range``) and scatter-gathers the unselective ones (``?P?``
and ``??O`` under ``node_range``, ``S??``/``??O`` under
``predicate_hash``, ``???`` always) across every shard in ONE micro-batch
flush each — a flush never issues more than one engine call per shard per
``max_batch`` chunk, regardless of how many patterns scatter.

Merging is view-based end to end: each shard answers with shared
per-pattern entry arrays (:class:`~repro.core.query.QueryResultView`), a
scattered pattern's answer is the concatenation of its per-shard entries
(partitions are disjoint, so no dedup), and duplicate tickets share one
merged entry. Merged results are themselves cached in a reserved
namespace of the shared tier, so a *warm* scattered pattern is one
lookup — no fan-out, no re-concatenation. ``flush()`` materializes tuple
lists per *unique* pattern — ``flush_view()`` is the zero-replication
escape hatch.

``invalidate(shard)`` bumps the shared cache's per-shard generation — the
hook for the day partitions become mutable: rewriting one shard's grammar
must not cold-start the other shards' warm entries.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core import (
    Hypergraph,
    LabelTable,
    QueryResultCache,
    TripleQueryEngine,
    compress,
)
from repro.core.flatten import concat_ragged
from repro.core.query import QueryResultView, _env_flag, _freeze_entry
from repro.distributed.partition import (
    PartitionPlan,
    make_plan,
    partition_triples,
)
from repro.serve.triple_service import MicroBatchService

# sentinel: "create a default shared QueryResultCache unless disabled by env"
_DEFAULT_CACHE = object()

# reserved shard id for cross-shard MERGED scattered results in the shared
# tier (real shards are >= 0; -1 is the single-engine default namespace).
# A warm scattered pattern is then one lookup instead of a full fan-out +
# re-concatenation; invalidate() bumps this namespace alongside any shard,
# since a merged entry depends on every shard's data.
_MERGED_SHARD = -2


@dataclass
class ShardedServiceStats:
    """Rolling counters for the scatter-gather router.

    `owned` / `scattered` count *unique* patterns per flush (the unit of
    routing work); `shard_batches` counts per-shard engine micro-batch
    executions — each flush issues up to ``ceil(sub_batch / max_batch)``
    chunks per shard, where a shard's sub-batch is its owned patterns
    plus every scattered one.
    """

    queries: int = 0
    flushes: int = 0
    results: int = 0
    unique_patterns: int = 0
    owned: int = 0
    scattered: int = 0
    merged_hits: int = 0  # scattered patterns answered from the merged tier
    shard_batches: int = 0
    total_s: float = 0.0
    last_flush_qps: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0


class ShardedTripleService(MicroBatchService):
    """Scatter-gather front end over P partitioned :class:`TripleQueryEngine`s.

    Construct directly from pre-built engines + plan (engines must cover
    plan.n_shards, in shard order), or via :meth:`build` from raw triples.
    The request plane (`submit`/`flush`/`query_many`) is the shared
    :class:`~repro.serve.triple_service.MicroBatchService` surface.
    """

    def __init__(self, engines: list[TripleQueryEngine], plan: PartitionPlan,
                 cache: QueryResultCache | None = None, max_batch: int = 1024):
        super().__init__()
        assert len(engines) == plan.n_shards, \
            f"{len(engines)} engines for {plan.n_shards} shards"
        self.engines = engines
        self.plan = plan
        self.cache = cache  # the shared tier (engines hold shard views of it)
        self.max_batch = int(max_batch)
        self.stats = ShardedServiceStats()

    # -- construction ----------------------------------------------------
    @classmethod
    def build(cls, triples: np.ndarray, n_nodes: int, n_preds: int,
              n_shards: int = 4, strategy: str = "predicate_hash",
              config=None, cache=_DEFAULT_CACHE, crossover: int | None = None,
              max_batch: int = 1024) -> "ShardedTripleService":
        """Partition -> compress each subgraph -> one engine per shard.

        `cache` is the shared result-cache tier (default: one
        :class:`QueryResultCache` shared by all shards, disabled by
        ``ITR_RESULT_CACHE=0``; pass ``None`` to disable explicitly).
        """
        plan = make_plan(strategy, n_shards, n_nodes, n_preds, triples=triples)
        if cache is _DEFAULT_CACHE:
            cache = QueryResultCache() if _env_flag("ITR_RESULT_CACHE", True) else None
        engines = []
        for k, sub in enumerate(partition_triples(triples, plan)):
            table = LabelTable.terminals([2] * n_preds)
            graph = Hypergraph.from_triples(sub, n_nodes)
            grammar, _ = compress(graph, table, config)
            engines.append(TripleQueryEngine(
                grammar,
                cache=cache.shard_view(k) if cache is not None else None,
                crossover=crossover))
        return cls(engines, plan, cache, max_batch)

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    # -- request plane ---------------------------------------------------
    def flush_view(self) -> QueryResultView:
        """Execute all pending patterns; results as a shared-entry view
        indexed by ticket (duplicate tickets share one merged entry).
        An empty flush is a no-op: nothing counted, no time accrued."""
        cols = self._take_pending()
        if cols is None:
            return QueryResultView.empty()
        s, p, o = cols
        n = len(s)
        t0 = time.perf_counter()
        view = self._run(s, p, o)
        dt = time.perf_counter() - t0
        st = self.stats
        st.queries += n
        st.flushes += 1
        st.results += view.total_results()
        st.total_s += dt
        st.last_flush_qps = n / dt if dt > 0 else 0.0
        return view

    def query(self, s: int | None, p: int | None, o: int | None) -> tuple:
        """Submit one pattern and flush; returns ITS results even if other
        submissions were already pending (they are flushed alongside)."""
        ticket = self.submit(s, p, o)
        return self.flush()[ticket]

    # -- scatter-gather core ---------------------------------------------
    def _run(self, s: np.ndarray, p: np.ndarray, o: np.ndarray) -> QueryResultView:
        # service-level dedup: route and merge each unique pattern once
        key = np.stack([s, p, o], axis=1)
        uniq, inv = np.unique(key, axis=0, return_inverse=True)
        inv = inv.reshape(-1)
        nu = len(uniq)
        u_s, u_p, u_o = uniq[:, 0], uniq[:, 1], uniq[:, 2]
        routes = self.plan.route_batch(u_s, u_p, u_o)
        cache = self.cache
        self.stats.unique_patterns += nu

        entries: list = [None] * nu
        # scattered patterns: the merged cross-shard result is itself cached
        # (reserved namespace), so a warm repeat is one lookup, not a fan-out
        scatter: list[int] = []
        for u in np.flatnonzero(routes < 0):
            u = int(u)
            hit = cache.lookup(u_s[u], u_p[u], u_o[u], shard=_MERGED_SHARD) \
                if cache is not None else None
            if hit is None:
                scatter.append(u)
            else:
                entries[u] = hit
                self.stats.merged_hits += 1
        scatter = np.asarray(scatter, dtype=np.int64)
        self.stats.owned += int((routes >= 0).sum())
        self.stats.scattered += int((routes < 0).sum())

        # merge-missing scattered patterns accumulate one chunk per shard
        parts: dict[int, list] = {int(u): [] for u in scatter}
        for k, engine in enumerate(self.engines):
            own = np.flatnonzero(routes == k)
            idx = own if len(scatter) == 0 else np.concatenate([own, scatter])
            if len(idx) == 0:
                continue
            pos_entries = self._shard_entries(engine, u_s[idx], u_p[idx], u_o[idx])
            for j, u in enumerate(own):
                entries[int(u)] = pos_entries[j]
            for j, u in enumerate(scatter):
                parts[int(u)].append(pos_entries[len(own) + j])
        for u, chunks in parts.items():
            # merged chunks are shared across duplicate tickets: read-only.
            # A scattered result is deliberately held twice in the shared
            # tier (per-shard chunks + this merged copy): the merged entry
            # makes warm repeats O(1), while the per-shard chunks mean a
            # single-shard invalidate() re-executes ONE shard, not all P.
            entry = _freeze_entry(concat_ragged(chunks))
            entries[u] = entry
            if cache is not None:
                cache.insert(u_s[u], u_p[u], u_o[u], entry, shard=_MERGED_SHARD)
        for u in range(nu):  # shards==0 or routing gaps: empty result
            if entries[u] is None:
                entries[u] = _freeze_entry(concat_ragged([]))
        return QueryResultView(entries, inv)

    def _shard_entries(self, engine: TripleQueryEngine, s, p, o) -> list:
        """One shard's entries for its sub-batch, in submission order —
        one engine micro-batch per `max_batch` chunk."""
        out: list = []
        for lo in range(0, len(s), self.max_batch):
            hi = min(lo + self.max_batch, len(s))
            view = engine.query_batch_view(s[lo:hi], p[lo:hi], o[lo:hi])
            out.extend(view.entry(i) for i in range(view.n_queries))
            self.stats.shard_batches += 1
        return out

    # -- maintenance / introspection -------------------------------------
    def invalidate(self, shard: int | None = None) -> None:
        """Invalidate cached results (generation bump on the shared tier):
        one shard's entries, or every shard's when `shard` is None. The
        hook for mutable partitions — other shards stay warm. Merged
        cross-shard entries depend on every shard, so their namespace is
        bumped on any invalidation."""
        if self.cache is None:
            return
        shards = range(self.n_shards) if shard is None else [shard]
        for k in shards:
            self.cache.bump_generation(k)
        self.cache.bump_generation(_MERGED_SHARD)

    def cache_stats(self):
        """Shared-tier cache counters (None when caching is disabled)."""
        return self.cache.stats if self.cache is not None else None

    def shard_sizes(self) -> list[int]:
        """Start-graph edges per shard (partition balance diagnostics)."""
        return [int(e.grammar.start.n_edges) for e in self.engines]
