"""Batched LM serving engine: continuous-batching prefill + decode.

Requests are padded into a fixed batch; prefill materializes the KV cache
(one `prefill_step`), then `decode_step` runs one token per iteration for
the whole batch with per-sequence stop handling. Greedy or temperature
sampling. The cache layout (L..., B, Smax, kv, dh) matches the decode dry-
run cells, so the engine and the roofline analyze the same computation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    prefill_step,
)


@dataclass
class GenerationResult:
    tokens: np.ndarray      # (B, <=max_new) generated ids (pad_id-padded)
    n_generated: np.ndarray
    prefill_ms: float
    decode_ms_per_token: float


class ServeEngine:
    def __init__(self, params, cfg: TransformerConfig, *, max_len: int = 512,
                 pad_id: int = 0, eos_id: int | None = None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.pad_id = pad_id
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, t: prefill_step(p, t, cfg, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(p, c, t, i, cfg))

    def _sample(self, logits, key, temperature):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> GenerationResult:
        import time

        B = len(prompts)
        plen = max(len(p) for p in prompts)
        assert plen + max_new_tokens <= self.max_len
        tokens = np.full((B, plen), self.pad_id, np.int32)
        for i, p in enumerate(prompts):
            tokens[i, plen - len(p):] = p  # left-pad so last position is real
        tokens = jnp.asarray(tokens)

        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, tokens)
        logits.block_until_ready()
        prefill_ms = (time.monotonic() - t0) * 1e3

        key = jax.random.PRNGKey(seed)
        out = np.full((B, max_new_tokens), self.pad_id, np.int32)
        done = np.zeros(B, bool)
        n_gen = np.zeros(B, np.int64)
        t0 = time.monotonic()
        cur = self._sample(logits, key, temperature)
        for t in range(max_new_tokens):
            cur_np = np.asarray(cur)
            newly = ~done
            out[newly, t] = cur_np[newly]
            n_gen[newly] += 1
            if self.eos_id is not None:
                done |= cur_np == self.eos_id
                if done.all():
                    break
            logits, cache = self._decode(self.params, cache, cur, plen + t)
            key, sub = jax.random.split(key)
            cur = self._sample(logits, sub, temperature)
        decode_ms = (time.monotonic() - t0) * 1e3 / max(int(n_gen.max()), 1)
        return GenerationResult(out, n_gen, prefill_ms, decode_ms)
