from repro.serve.concurrency import RWLock, resolve_serve_threads
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.sharded import ShardedServiceStats, ShardedTripleService
from repro.serve.triple_service import (
    MicroBatchService,
    ServiceStats,
    TripleQueryService,
)

__all__ = [
    "ServeEngine",
    "GenerationResult",
    "MicroBatchService",
    "TripleQueryService",
    "ServiceStats",
    "ShardedTripleService",
    "ShardedServiceStats",
    "RWLock",
    "resolve_serve_threads",
]
