from repro.serve.concurrency import RWLock, resolve_serve_threads
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.replication import (
    ReplicaGroup,
    ReplicaSet,
    ReplicationManager,
    ShardReplica,
    resolve_replica_dispatch,
    resolve_replica_max_lag,
    resolve_replicas,
)
from repro.serve.sharded import ShardedServiceStats, ShardedTripleService
from repro.serve.triple_service import (
    MicroBatchService,
    ServiceStats,
    TripleQueryService,
)

__all__ = [
    "ServeEngine",
    "GenerationResult",
    "MicroBatchService",
    "TripleQueryService",
    "ServiceStats",
    "ShardedTripleService",
    "ShardedServiceStats",
    "ReplicationManager",
    "ReplicaGroup",
    "ReplicaSet",
    "ShardReplica",
    "RWLock",
    "resolve_serve_threads",
    "resolve_replicas",
    "resolve_replica_dispatch",
    "resolve_replica_max_lag",
]
