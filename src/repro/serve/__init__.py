from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.triple_service import ServiceStats, TripleQueryService

__all__ = ["ServeEngine", "GenerationResult", "TripleQueryService", "ServiceStats"]
