"""Batched triple-query serving on the compressed grammar.

Production traffic arrives as independent (S,P,O) lookups; answering them
one at a time wastes the engine's batch path. `TripleQueryService`
accumulates submitted patterns into a pending micro-batch and executes the
whole batch in ONE level-synchronous frontier (`TripleQueryEngine
.query_batch_view`), so per-request Python overhead is paid once per
flush instead of once per query. Results flow through
:class:`~repro.core.query.QueryResultView` internally — duplicate tickets
share one per-pattern entry instead of replicated copies (`flush_view`
exposes the view; `flush` materializes shared tuple lists per ticket).
`query_many` is the synchronous convenience wrapper (submit-all + flush).

The engine's cross-request result cache makes dedup streaming: a pattern
seen in any earlier flush (or earlier in this one) is answered from the
cache instead of re-executing the frontier. Flush-time stats therefore
separate *submitted* queries from *executed* unique patterns and *cache
hits* — `qps` alone would hide the difference between a fast engine and a
warm cache.

The service is numpy-only — it runs wherever the engine runs — and keeps
rolling throughput stats so serving dashboards can track queries/second.

Thread-safety: the pending queue is internally locked, and every
request-plane entry (``query``/``query_many``/``flush``) takes its
tickets atomically — two threads calling ``query()`` concurrently can
never read each other's results. What happens *after* the tickets are
taken depends on the subclass: :class:`ShardedTripleService
<repro.serve.sharded.ShardedTripleService>` executes under a reader lock
and is safe from any number of threads, while
:class:`TripleQueryService` fronts one engine (one arena, one frontier)
and must not be flushed from two threads at once — the full contract is
in ``docs/CONCURRENCY.md``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.query import QueryResultView, TripleQueryEngine


@dataclass
class ServiceStats:
    """Rolling serving counters.

    `queries` counts submitted patterns; `executed` counts unique patterns
    that actually ran on the engine (frontier or scalar worklist); and
    `cache_hits` counts unique patterns answered from the cross-request
    result cache. In-batch duplicates are neither executed nor cache hits —
    they ride on batch dedup — so `executed + cache_hits <= queries` per
    flush, with equality only when every pattern in the flush is distinct.
    """

    queries: int = 0
    batches: int = 0
    results: int = 0
    executed: int = 0
    cache_hits: int = 0
    inserted: int = 0   # triples actually added via the mutation API
    deleted: int = 0    # triples actually removed
    rebuilds: int = 0   # grammar recompressions (auto + explicit)
    total_s: float = 0.0
    last_batch_qps: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_s if self.total_s > 0 else 0.0

    @property
    def cache_hit_rate(self) -> float:
        n = self.executed + self.cache_hits
        return self.cache_hits / n if n else 0.0


@dataclass
class _Pending:
    s: list = field(default_factory=list)
    p: list = field(default_factory=list)
    o: list = field(default_factory=list)


class MicroBatchService:
    """Shared request plane for micro-batching query services.

    Provides the pending queue (`submit` -> ticket, None = unbound slot,
    encoded as -1), the view-backed `flush` (shared tuple lists per
    unique pattern — treat results as read-only), and the synchronous
    entries `query` / `query_many`. Subclasses implement
    :meth:`_flush_columns`, which executes aligned int64 pattern columns
    and returns the :class:`QueryResultView`.

    The pending queue is guarded by an internal lock, and each
    synchronous entry takes its tickets *atomically*: `query` grabs its
    own ticket together with everything already pending (flushing
    bystanders alongside, as ever), `query_many` takes the whole queue
    but returns only its own patterns' results, and two threads doing
    either can never observe each other's tickets. The raw
    `submit`/`flush` split remains single-caller by nature — a ticket is
    an index into whichever flush happens next, so handing submit and
    flush to different threads needs external coordination (see
    ``docs/CONCURRENCY.md``).
    """

    def __init__(self):
        self._pending = _Pending()
        self._pending_lock = threading.Lock()

    def _submit_locked(self, s: int | None, p: int | None, o: int | None) -> int:
        ticket = len(self._pending.s)
        self._pending.s.append(-1 if s is None else int(s))
        self._pending.p.append(-1 if p is None else int(p))
        self._pending.o.append(-1 if o is None else int(o))
        return ticket

    def submit(self, s: int | None, p: int | None, o: int | None) -> int:
        """Queue one (S,P,O) pattern; returns its ticket in the next flush."""
        with self._pending_lock:
            return self._submit_locked(s, p, o)

    @property
    def pending(self) -> int:
        return len(self._pending.s)

    def _take_pending_locked(self):
        batch, self._pending = self._pending, _Pending()
        if not batch.s:
            return None
        return (np.asarray(batch.s, dtype=np.int64),
                np.asarray(batch.p, dtype=np.int64),
                np.asarray(batch.o, dtype=np.int64))

    def _take_pending(self):
        with self._pending_lock:
            return self._take_pending_locked()

    def _flush_columns(self, s, p, o) -> QueryResultView:
        """Execute one taken batch (aligned int64 columns, -1 = unbound).

        Subclass hook: owns timing/stats and the actual execution. Must
        be safe to call without the pending lock held — the sharded
        service runs it under its reader lock from many threads at once.
        """
        raise NotImplementedError

    def flush_view(self) -> QueryResultView:
        """Execute all pending queries; results as a shared-entry view
        indexed by ticket (:class:`QueryResultView`) — duplicate tickets
        share one entry, nothing is replicated. An empty flush is a
        no-op: no batch is counted, no time accrued.
        """
        cols = self._take_pending()
        if cols is None:
            return QueryResultView.empty()
        return self._flush_columns(*cols)

    def flush(self) -> list[tuple]:
        """Execute all pending queries; returns results indexed by ticket.

        View-backed: each result sequence is built once per unique
        pattern and shared — as an immutable tuple — across duplicate
        tickets.
        """
        return self.flush_view().tuple_lists()

    def query(self, s: int | None, p: int | None, o: int | None) -> tuple:
        """One synchronous query: submit + flush, returning THIS pattern's
        results (anything already pending is flushed alongside, its
        tickets still owned by whoever submitted them). The ticket take
        is atomic, so concurrent `query` callers get disjoint batches."""
        with self._pending_lock:
            ticket = self._submit_locked(s, p, o)
            cols = self._take_pending_locked()
        return self._flush_columns(*cols).tuple_lists()[ticket]

    def query_many(self, patterns) -> list[tuple]:
        """patterns: iterable of (s, p, o) with None = unbound. Returns
        one result tuple per pattern, in order — results for tickets
        other callers already had pending are flushed alongside but not
        returned here (they belong to those callers' flush)."""
        with self._pending_lock:
            base = len(self._pending.s)
            for s, p, o in patterns:
                self._submit_locked(s, p, o)
            cols = self._take_pending_locked()
        if cols is None:
            return []
        return self._flush_columns(*cols).tuple_lists()[base:]


class TripleQueryService(MicroBatchService):
    """Micro-batching front end over a :class:`TripleQueryEngine`.

    `submit` returns a ticket (index into the next flush); `flush` runs the
    pending batch and returns one result list per ticket. `max_batch`
    bounds a single frontier's width: larger pending sets are executed in
    chunks so memory stays flat under unselective patterns.
    """

    def __init__(self, engine: TripleQueryEngine, max_batch: int = 1024):
        super().__init__()
        self.engine = engine
        self.max_batch = int(max_batch)
        self.stats = ServiceStats()

    def _flush_columns(self, s, p, o) -> QueryResultView:
        """Execute one taken batch on the engine, chunked by `max_batch`.

        NOT safe from multiple threads at once: the engine reuses one
        frontier arena per instance. Use the sharded service (which
        wraps execution in per-engine locks) for concurrent callers.
        """
        n = len(s)
        cache = self.engine.cache
        before = cache.stats.snapshot() if cache is not None else None
        views: list[QueryResultView] = []
        t0 = time.perf_counter()
        executed_uncached = 0
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            chunk = self.engine.query_batch_view(s[lo:hi], p[lo:hi], o[lo:hi])
            views.append(chunk)
            self.stats.batches += 1
            if before is None:  # no cache: in-batch dedup still collapses
                executed_uncached += len(chunk.entries)
        view = views[0] if len(views) == 1 else QueryResultView.concat(views)
        dt = time.perf_counter() - t0
        self.stats.queries += n
        self.stats.results += view.total_results()
        self.stats.total_s += dt
        self.stats.last_batch_qps = n / dt if dt > 0 else 0.0
        if before is not None:
            # engine cache counters moved once per *unique* pattern: the
            # hit delta is served-from-cache, the miss delta is executed
            self.stats.cache_hits += cache.stats.hits - before.hits
            self.stats.executed += cache.stats.misses - before.misses
        else:
            self.stats.executed += executed_uncached
        return view

    # -- mutation ---------------------------------------------------------
    def insert_triples(self, triples) -> int:
        """Insert (s, p, o) rows into the engine's delta overlay; returns
        how many were actually new. Subsequent flushes see them — the
        engine bumps its cache generation and auto-rebuilds past
        ``ITR_DELTA_BUDGET`` (see :meth:`TripleQueryEngine.insert_triples`)."""
        before = self.engine.rebuild_count
        n = self.engine.insert_triples(triples)
        self.stats.inserted += n
        self.stats.rebuilds += self.engine.rebuild_count - before
        return n

    def delete_triples(self, triples) -> int:
        """Delete (s, p, o) rows; returns how many were actually present."""
        before = self.engine.rebuild_count
        n = self.engine.delete_triples(triples)
        self.stats.deleted += n
        self.stats.rebuilds += self.engine.rebuild_count - before
        return n

    def rebuild(self, config=None) -> bool:
        """Recompress base+delta now (regardless of budget); True if the
        overlay was non-empty and a rebuild ran."""
        rebuilt = self.engine.rebuild(config)
        self.stats.rebuilds += rebuilt
        return rebuilt
