"""Replicated read scaling: snapshot-seeded, WAL-tailing replica groups.

Shards spread the graph; replicas multiply read throughput over it. A
*replica group* is one extra consistent copy of the whole sharded tier:
every shard's engine cold-started from the newest service snapshot
(`repro.persist.snapshot`, mmap-shared so N replicas of a shard cost one
page-cache copy of its compressed base) and kept fresh by tailing the
primary's write-ahead log (`repro.persist.wal.WalCursor`) and applying
each record through the same switch recovery replay uses
(:func:`repro.persist.service.apply_wal_record`). Mutations only ever go
to the primary; acknowledged WAL records define each group's position in
history, so a group is always *some* exact past state of the tier —
never a mix.

Groups are whole-tier copies rather than independent per-shard engine
pools for a correctness reason: WAL records interleave per-shard
mutations with cross-shard migration batches and plan swaps, and a
per-shard tail could not apply an ``OP_MIGRATE`` (two shards change in
one record) or answer a scattered pattern at one instant of history.
Dispatching a *whole flush* to one group keeps every merged scatter
result single-generation. :class:`ReplicaSet` is the per-shard view over
the groups (shard ``k``'s N replica engines) for introspection and lag
accounting.

**Dispatch** (:meth:`ReplicationManager.acquire`): a flush goes to a
replica group only when the group is *dispatchable* — same log
incarnation as the primary (``WriteAheadLog.resets``), lag within
``max_lag`` records, routing state in agreement (equal plans, both or
neither mid-migration with equal successor plans), healthy. Among
dispatchable groups, ``round_robin`` rotates and ``least_loaded`` picks
the fewest in-flight flushes (``ITR_REPLICA_DISPATCH``). Anything else —
including any flush issued by a thread that holds the primary's write
lock (a mid-mutation visibility probe must see half-applied primary
state) — serves from the primary, which is always correct, just not
scaled.

**Cache generations**: the shared result tier is keyed by namespace, and
each group gets its own disjoint block of (negative) namespaces for its
per-shard and merged entries via the router's ``_cache_ns``/``_merged_ns``
indirection. A lagging group therefore serves warm results that are
consistent *with its own generation* — primary invalidations never purge
them, and group catch-up invalidates exactly the group's namespaces.

**Failover**: a group whose catch-up fails — torn-tail apply error, or a
log compacted underneath its cursor (``report.truncated`` /
``resets`` mismatch after ``wal.reset()``) — is dropped and reseeded
from the newest snapshot, mirroring the durable tier's degraded-serving
philosophy: the read plane heals itself from the same artifacts recovery
uses, and is never allowed to silently replay from offset 0.

Knobs: ``ITR_REPLICAS`` (groups per tier, default 0 = off),
``ITR_REPLICA_DISPATCH`` (``round_robin``/``least_loaded``),
``ITR_REPLICA_MAX_LAG`` (dispatch lag bound in WAL records).
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass

from repro.core import TripleQueryEngine
from repro.distributed.partition import plan_from_dict, plans_equal
from repro.distributed.rebalance import RebalancePlan, migration_moves
from repro.persist.wal import WalCursor, WriteAheadLog
from repro.serve.sharded import ShardedTripleService

# replica cache namespaces sit below the reserved ids (-1 single-engine
# default, -2 primary merged): group g owns the contiguous block
# [_NS_BASE - g*(n_shards+1) - n_shards, _NS_BASE - g*(n_shards+1)]
_NS_BASE = -3

DEFAULT_MAX_LAG = 1024

DISPATCH_POLICIES = ("round_robin", "least_loaded")


def resolve_replicas(value=None) -> int:
    """Replica groups per tier: explicit `value`, else ``ITR_REPLICAS``.
    ``0`` (the default), negatives, and unparsable text mean no
    replication."""
    if value is None:
        value = os.environ.get("ITR_REPLICAS", "")
    text = str(value).strip().lower()
    if not text or text in ("off", "none", "never"):
        return 0
    try:
        return max(0, int(text))
    except ValueError:
        return 0


def resolve_replica_dispatch(value=None) -> str:
    """Dispatch policy: explicit `value`, else ``ITR_REPLICA_DISPATCH``;
    anything not in :data:`DISPATCH_POLICIES` falls back to
    ``round_robin``."""
    if value is None:
        value = os.environ.get("ITR_REPLICA_DISPATCH", "")
    text = str(value).strip().lower()
    return text if text in DISPATCH_POLICIES else "round_robin"


def resolve_replica_max_lag(value=None) -> int | None:
    """Dispatch lag bound in WAL records: explicit `value`, else
    ``ITR_REPLICA_MAX_LAG`` (default ``DEFAULT_MAX_LAG``); ``off``/
    ``none``/negative mean unbounded (``None`` — any caught-up-enough
    group serves, callers quiesce with an explicit sync)."""
    if value is None:
        value = os.environ.get("ITR_REPLICA_MAX_LAG", "")
    text = str(value).strip().lower()
    if not text:
        return DEFAULT_MAX_LAG
    if text in ("off", "none", "unbounded"):
        return None
    try:
        n = int(text)
    except ValueError:
        return DEFAULT_MAX_LAG
    return None if n < 0 else n


@dataclass
class ShardReplica:
    """One shard's read-only engine inside one replica group (the unit a
    :class:`ReplicaSet` enumerates)."""

    shard: int
    group: int
    engine: TripleQueryEngine
    cache_ns: int          # the group-private namespace its entries live in
    lag_records: int | None  # group lag (None: different log incarnation)


class ReplicaSet:
    """Per-shard view over the replica groups: shard ``k``'s N read-only
    engines, one per group, each at its group's position in history."""

    def __init__(self, shard: int, replicas: list[ShardReplica]):
        self.shard = int(shard)
        self.replicas = replicas

    def __len__(self) -> int:
        return len(self.replicas)

    def __iter__(self):
        return iter(self.replicas)

    @property
    def max_lag_records(self) -> int:
        """Worst lag across this shard's replicas (0 when empty; a replica
        on a dead log incarnation counts as unbounded-stale)."""
        worst = 0
        for r in self.replicas:
            if r.lag_records is None:
                return -1  # incomparable: pending reseed
            worst = max(worst, r.lag_records)
        return worst


class ReplicaGroup:
    """One whole-tier read replica: a snapshot-seeded service plus the WAL
    cursor that keeps it fresh. All coordination state lives here; the
    manager's sync/reseed paths hold ``_lock`` while touching it."""

    def __init__(self, index: int, service: ShardedTripleService,
                 cursor: WalCursor, seeded_resets: int):
        self.index = index
        self.service = service
        self.cursor = cursor
        #: WriteAheadLog.resets captured at seed time — the log incarnation
        #: this cursor's offsets are valid against
        self.seeded_resets = seeded_resets
        self.healthy = True     # False: catch-up failed, reseed pending
        self.in_flight = 0      # flushes currently running on this group
        self.flushes = 0        # flushes served (lifetime)
        self.reseeds = 0        # snapshot re-seeds (failover events)
        self._lock = threading.Lock()  # serializes sync/reseed per group

    @property
    def records(self) -> int:
        """WAL records applied since seed (the group's generation)."""
        return self.cursor.records


class ReplicationManager:
    """Owns the replica groups of one durable sharded tier.

    Constructed (and attached to the primary router) by
    :meth:`repro.persist.service.DurableShardedService.enable_replication`.
    The router calls :meth:`acquire`/:meth:`release` per flush;
    :meth:`sync` drains the WAL tail into every group (the quiesce step);
    everything else is introspection and lifecycle.
    """

    def __init__(self, primary: ShardedTripleService, wal: WriteAheadLog,
                 root: str, n_replicas: int, dispatch=None, max_lag=None,
                 *, mmap: bool = True, verify: bool = True,
                 auto_sync: bool = True):
        self.primary = primary
        self.wal = wal
        self.root = os.fspath(root)
        self.dispatch = resolve_replica_dispatch(dispatch)
        self.max_lag = resolve_replica_max_lag(max_lag)
        self.mmap = bool(mmap)
        self.verify = bool(verify)
        #: opportunistically tail-sync one group when no group is
        #: dispatchable at acquire time (self-healing without a thread)
        self.auto_sync = bool(auto_sync)
        self.closed = False
        self._dispatch_lock = threading.Lock()
        self._rr = 0
        self._plan_memo: dict = {}  # (id, id) -> (plan, plan, equal)
        self.groups = [ReplicaGroup(g, *self._seed(g))
                       for g in range(int(n_replicas))]

    # -- seeding / failover ------------------------------------------------
    def _seed(self, index: int):
        """Cold-start group `index` from the newest snapshot: returns
        (service, cursor, seeded_resets). Runs under the primary's read
        lock so no snapshot/compaction or mutation moves the ground
        underneath the (snapshot, WAL incarnation) pair being captured."""
        from repro.persist.service import (
            _newest_snapshot,
            _read_service_manifest,
        )
        from repro.persist.snapshot import load_snapshot

        primary = self.primary
        with primary._rw.read():
            resets = self.wal.resets
            _, snap = _newest_snapshot(self.root)
            manifest = _read_service_manifest(snap)
            plan = plan_from_dict(manifest["plan"])
            cache = primary.cache
            base = _NS_BASE - index * (plan.n_shards + 1)
            engines = []
            for k in range(plan.n_shards):
                view = cache.shard_view(base - 1 - k) \
                    if cache is not None else None
                engines.append(load_snapshot(
                    os.path.join(snap, f"shard_{k}"),
                    cache=view, mmap=self.mmap, verify=self.verify))
            svc = ShardedTripleService(
                engines, plan, cache, max_batch=primary.max_batch,
                config=primary.config, rebalance_skew=None,
                serve_threads=primary.serve_threads)
            svc._merged_ns = base
            svc._cache_ns = [base - 1 - k for k in range(plan.n_shards)]
            if manifest.get("term_dict"):
                # the group gets its own dictionary copy, caught up by the
                # same WAL term records the primary minted through — so a
                # replica answers string queries with the identical id space
                from repro.persist.service import TERM_DICT_DIR
                from repro.persist.snapshot import load_term_dict
                svc.term_dict = load_term_dict(
                    os.path.join(snap, TERM_DICT_DIR), verify=self.verify)
            mig = manifest.get("migration_plan")
            if mig is not None:
                new_plan = plan_from_dict(mig)
                svc._migration = RebalancePlan(
                    plan, new_plan, migration_moves(new_plan, svc.engines))
            # every record in the current log postdates the newest snapshot
            # (snapshot() resets the WAL in the same exclusive section), so
            # a fresh cursor from the header is exactly "resume from seed"
            return svc, WalCursor(self.wal.path), resets

    def _reseed_locked(self, group: ReplicaGroup) -> None:
        """Failover: drop the group's state, reseed from the newest
        snapshot. The old service's cache namespaces are invalidated (a
        half-applied record may have left entries no future state
        matches) and its pool drained; in-flight flushes finish on the
        old engines, which stay valid until released."""
        old = group.service
        group.service, group.cursor, group.seeded_resets = \
            self._seed(group.index)
        group.healthy = True
        group.reseeds += 1
        old.invalidate()
        old.close()

    # -- catch-up ----------------------------------------------------------
    def sync(self) -> list[int]:
        """Tail the WAL into every group (reseeding any group the log was
        compacted underneath); returns records applied per group. After a
        `sync` with no concurrent mutations, every group is at the
        primary's exact state — the quiesce step the consistency oracle
        leans on."""
        return [self._sync_group(g, allow_reseed=True) for g in self.groups]

    def _sync_group(self, group: ReplicaGroup, allow_reseed: bool) -> int:
        with group._lock:
            return self._sync_group_locked(group, allow_reseed)

    def _sync_group_locked(self, group: ReplicaGroup,
                           allow_reseed: bool) -> int:
        from repro.persist.service import apply_wal_record

        applied = 0
        # two passes: the first may discover the group needs a reseed
        # (stale incarnation, truncation, apply failure); the second tails
        # the fresh log onto the reseeded state
        for _ in range(2):
            if self.closed:
                break
            stale = (not group.healthy
                     or group.seeded_resets != self.wal.resets
                     or group.cursor.offset > self.wal.offset)
            if stale:
                if not allow_reseed:
                    break
                self._reseed_locked(group)
            recs, report = group.cursor.tail()
            if report.truncated:
                # compacted between the staleness check and the read
                group.healthy = False
                continue
            try:
                if recs:
                    # exclusive on the GROUP only: dispatched flushes on
                    # other groups and the primary keep flowing
                    with group.service._rw.write():
                        for payload in recs:
                            apply_wal_record(group.service, payload)
            except Exception:
                group.healthy = False  # failed catch-up: drop + reseed
                continue
            applied += len(recs)
            break
        return applied

    # -- dispatch ----------------------------------------------------------
    def _plans_match(self, a, b) -> bool:
        # memoized by identity pair (plans are immutable once routing);
        # strong refs in the memo keep ids stable, and the memo is tiny —
        # plan objects only change on rebalance
        if a is b:
            return True
        key = (id(a), id(b))
        hit = self._plan_memo.get(key)
        if hit is not None and hit[0] is a and hit[1] is b:
            return hit[2]
        ok = plans_equal(a, b)
        if len(self._plan_memo) > 64:
            self._plan_memo.clear()
        self._plan_memo[key] = (a, b, ok)
        return ok

    def _dispatchable(self, group: ReplicaGroup) -> bool:
        """May a flush run on this group right now? Same log incarnation,
        bounded lag, agreeing routing state, healthy."""
        if not group.healthy or group.seeded_resets != self.wal.resets:
            return False
        if self.max_lag is not None \
                and self.wal.n_records - group.records > self.max_lag:
            return False
        ps, gs = self.primary, group.service
        if gs.failed_shards:
            return False
        if (ps._migration is None) != (gs._migration is None):
            return False
        if not self._plans_match(ps.plan, gs.plan):
            return False
        if ps._migration is not None and not self._plans_match(
                ps._migration.new_plan, gs._migration.new_plan):
            return False
        return True

    def acquire(self) -> ReplicaGroup | None:
        """Pick a group for one flush (None: serve from the primary).
        Pair every non-None return with :meth:`release`."""
        if self.closed or not self.groups or self.primary.failed_shards:
            return None
        cand = [g for g in self.groups if self._dispatchable(g)]
        if not cand and self.auto_sync:
            self._opportunistic_sync()
            cand = [g for g in self.groups if self._dispatchable(g)]
        if not cand:
            return None
        with self._dispatch_lock:
            if self.dispatch == "least_loaded":
                group = min(cand, key=lambda g: (g.in_flight, g.index))
            else:
                group = cand[self._rr % len(cand)]
                self._rr += 1
            group.in_flight += 1
        return group

    def release(self, group: ReplicaGroup) -> None:
        with self._dispatch_lock:
            group.in_flight -= 1
            group.flushes += 1

    def _opportunistic_sync(self) -> None:
        """No group was dispatchable: try a non-blocking tail-sync of the
        most-lagged group (reseeds are left to the explicit sync path —
        they load engines from disk and do not belong on a query)."""
        for group in sorted(self.groups, key=lambda g: g.records):
            if group._lock.acquire(blocking=False):
                try:
                    self._sync_group_locked(group, allow_reseed=False)
                finally:
                    group._lock.release()
                return

    # -- introspection -----------------------------------------------------
    def _group_lag(self, group: ReplicaGroup) -> int | None:
        """Lag in WAL records (None: the group's cursor belongs to a dead
        log incarnation and cannot be compared — reseed pending)."""
        if not group.healthy or group.seeded_resets != self.wal.resets:
            return None
        return max(0, self.wal.n_records - group.records)

    def replica_set(self, shard: int) -> ReplicaSet:
        """Shard `shard`'s replicas, one per group."""
        k = int(shard)
        if not 0 <= k < self.primary.n_shards:
            raise ValueError(
                f"shard {k} out of range [0, {self.primary.n_shards})")
        return ReplicaSet(k, [
            ShardReplica(shard=k, group=g.index,
                         engine=g.service.engines[k]
                         if k < len(g.service.engines) else None,
                         cache_ns=g.service._cache_ns[k]
                         if k < len(g.service._cache_ns) else 0,
                         lag_records=self._group_lag(g))
            for g in self.groups])

    def stats(self) -> dict:
        """Lag accounting + dispatch counters, JSON-shaped. The headline
        ``max_lag_records`` is the worst comparable group lag (stale
        incarnations pending reseed are counted separately)."""
        lags = [self._group_lag(g) for g in self.groups]
        comparable = [v for v in lags if v is not None]
        return {
            "n_replicas": len(self.groups),
            "dispatch": self.dispatch,
            "max_lag": self.max_lag,
            "primary_records": self.wal.n_records,
            "max_lag_records": max(comparable, default=0),
            "stale_groups": sum(1 for v in lags if v is None),
            "groups": [{
                "replica": g.index,
                "records": g.records,
                "offset": g.cursor.offset,
                "lag_records": lag,
                "flushes": g.flushes,
                "in_flight": g.in_flight,
                "reseeds": g.reseeds,
                "dispatchable": self._dispatchable(g),
            } for g, lag in zip(self.groups, lags)],
        }

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Shut the replica tier down: no further dispatch, every group
        service's pool drained. Idempotent — a second close (direct, or
        via any service in the hierarchy) is a no-op."""
        if self.closed:
            return
        self.closed = True
        for group in self.groups:
            with group._lock:
                group.service.close()
