"""DLRM dot-interaction Pallas kernel.

Per sample: Z = X Xᵀ over the F field embeddings (one MXU batched matmul),
then the strictly-lower triangle is extracted with a precomputed index
gather. Grid over batch blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dot_kernel(tri_ref, x_ref, o_ref, *, f):
    x = x_ref[...]                              # (Bb, F, D)
    z = jax.lax.dot_general(
        x, x, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )                                           # (Bb, F, F)
    zf = z.reshape(z.shape[0], f * f)
    o_ref[...] = zf[:, tri_ref[...]].astype(o_ref.dtype)


def dot_interaction(x, *, block_b=128, interpret=False):
    """x: (B, F, D) -> (B, F*(F-1)/2) strictly-lower-tri interactions."""
    B, F, D = x.shape
    block_b = min(block_b, B)
    assert B % block_b == 0
    ii, jj = np.tril_indices(F, k=-1)
    tri_flat = jnp.asarray(ii * F + jj, dtype=jnp.int32)
    P = len(ii)
    return pl.pallas_call(
        functools.partial(_dot_kernel, f=F),
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((block_b, F, D), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, P), x.dtype),
        interpret=interpret,
    )(tri_flat, x)
