"""Embedding-bag Pallas kernel (DLRM multi-hot lookup + segment reduce).

One grid step owns a (batch-block × feature-block) tile: it gathers up to
L rows per bag from the table and reduces over the bag axis (sum or mean).
JAX has no native EmbeddingBag; this is the framework's own implementation
(gather + in-register reduce), with `-1` padding for ragged bags.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(idx_ref, tbl_ref, o_ref, *, combiner):
    idx = idx_ref[...]                          # (Bb, L)
    valid = idx >= 0
    rows = tbl_ref[jnp.maximum(idx, 0)]         # (Bb, L, Db)
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        denom = jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(out.dtype)
        out = out / denom
    o_ref[...] = out.astype(o_ref.dtype)


def embedding_bag(
    table, indices, *, combiner="sum", block_b=128, block_d=None, interpret=False
):
    """table: (V, D); indices: (B, L) int32, -1-padded -> (B, D)."""
    V, D = table.shape
    B, L = indices.shape
    block_b = min(block_b, B)
    block_d = block_d or min(D, 128)
    assert B % block_b == 0 and D % block_d == 0
    return pl.pallas_call(
        functools.partial(_bag_kernel, combiner=combiner),
        grid=(B // block_b, D // block_d),
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i, j: (i, 0)),
            pl.BlockSpec((V, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(indices, table)
