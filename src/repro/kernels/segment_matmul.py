"""CSR block-row SpMM Pallas kernel — the GNN message-aggregation hot spot.

TPU adaptation of gather-GEMM-scatter (GE-SpMM / FusedMM family): edges are
sorted by destination and bucketed into fixed destination-node blocks; each
grid step gathers the block's source rows and *scatters via a one-hot
matmul* — `onehot(local_dst)ᵀ @ gathered` — turning the irregular scatter
into an MXU contraction (the TPU-native trick; GPUs use atomics instead).

Host-side prep (:func:`build_csr_blocks`) pads each destination block's
edge list to a power-of-two bound; `-1` marks padding. Feature dim is
blocked as the second grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def build_csr_blocks(senders, receivers, n_nodes, block_n=128):
    """Sort edges by receiver and bucket into dst blocks of `block_n` rows.

    Returns (src_idx, local_dst) of shape (NB, Emax): source node id and
    receiver offset within the block for each edge slot; -1 = padding.
    """
    senders = np.asarray(senders, dtype=np.int32)
    receivers = np.asarray(receivers, dtype=np.int32)
    order = np.argsort(receivers, kind="stable")
    senders, receivers = senders[order], receivers[order]
    nb = (n_nodes + block_n - 1) // block_n
    blk = receivers // block_n
    counts = np.bincount(blk, minlength=nb)
    emax = max(int(counts.max()) if len(counts) else 1, 1)
    emax = 1 << (emax - 1).bit_length()  # pad to power of two
    src_idx = np.full((nb, emax), -1, dtype=np.int32)
    local_dst = np.full((nb, emax), -1, dtype=np.int32)
    pos_in_blk = np.arange(len(receivers)) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts
    )
    src_idx[blk, pos_in_blk] = senders
    local_dst[blk, pos_in_blk] = receivers % block_n
    return src_idx, local_dst


def _spmm_kernel(src_ref, dst_ref, x_ref, o_ref, *, block_n):
    idx = src_ref[0]                       # (Emax,)
    valid = idx >= 0
    rows = x_ref[jnp.maximum(idx, 0)]      # (Emax, Db) gather
    rows = jnp.where(valid[:, None], rows, 0.0)
    onehot = (
        dst_ref[0][:, None] == jax.lax.iota(jnp.int32, block_n)[None, :]
    ).astype(rows.dtype)                   # (Emax, block_n); -1 rows all-zero
    o_ref[0] = jax.lax.dot_general(
        onehot, rows, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def csr_spmm(x, src_idx, local_dst, n_nodes, *, block_n=128, block_d=None, interpret=False):
    """out[r] = Σ_{e: dst=r} x[src[e]]; x: (N, D) -> (n_nodes_padded, D)."""
    nb, emax = src_idx.shape
    N, D = x.shape
    block_d = block_d or min(D, 128)
    assert D % block_d == 0
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, block_n=block_n),
        grid=(nb, D // block_d),
        in_specs=[
            pl.BlockSpec((1, emax), lambda i, j: (i, 0)),
            pl.BlockSpec((1, emax), lambda i, j: (i, 0)),
            pl.BlockSpec((N, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n, block_d), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nb, block_n, D), x.dtype),
        interpret=interpret,
    )(src_idx, local_dst, x)
    return out.reshape(nb * block_n, D)[:n_nodes]
