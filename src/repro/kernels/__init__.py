"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel lives in its own module (pl.pallas_call + BlockSpec), has a
pure-jnp oracle in `ref.py`, and a jitted wrapper in `ops.py` that picks
interpret mode off-TPU. See tests/test_kernels_*.py for the sweep tests.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (
    bitvec_rank,
    build_csr_blocks,
    csr_spmm,
    digram_pair_counts,
    dot_interaction,
    embedding_bag,
    flash_attention,
)

__all__ = [
    "ops",
    "ref",
    "bitvec_rank",
    "build_csr_blocks",
    "csr_spmm",
    "digram_pair_counts",
    "dot_interaction",
    "embedding_bag",
    "flash_attention",
]
