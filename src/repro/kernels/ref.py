"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(
    q, k, v, *, causal=True, window=None, softcap=None, sm_scale=None
):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); GQA via head broadcast."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Sk = k.shape[2]
    q_idx = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned (decode-friendly)
    k_idx = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def spmm_ref(x, senders, receivers, n_out):
    """Sum aggregation: out[r] = sum_{e: receivers[e]=r} x[senders[e]]."""
    msgs = x[senders]
    return jax.ops.segment_sum(msgs, receivers, num_segments=n_out)


def embedding_bag_ref(table, indices, combiner="sum"):
    """table: (V, D); indices: (B, L) with -1 padding."""
    mask = (indices >= 0)[..., None]
    rows = table[jnp.maximum(indices, 0)] * mask
    out = rows.sum(axis=1)
    if combiner == "mean":
        denom = jnp.maximum(mask.sum(axis=1), 1)
        out = out / denom
    return out


def digram_pair_counts_ref(its, cnts):
    """Per-node pairwise digram counts (paper's count_v formula).

    its, cnts: (N, K) int32, -1/-0 padded. Returns (it_lo, it_hi, count)
    each (N, P) with P = K(K+1)/2; padded pairs carry count 0.
    """
    N, K = its.shape
    ii, jj = np.triu_indices(K)
    it1 = its[:, ii]
    it2 = its[:, jj]
    c1 = cnts[:, ii]
    c2 = cnts[:, jj]
    valid = (it1 >= 0) & (it2 >= 0)
    cv = jnp.where(ii[None, :] == jj[None, :], c1 // 2, jnp.minimum(c1, c2))
    cv = jnp.where(valid, cv, 0)
    lo = jnp.minimum(it1, it2)
    hi = jnp.maximum(it1, it2)
    return lo, hi, cv


def dot_interaction_ref(x):
    """DLRM dot-interaction: x (B, F, D) -> strictly-lower-tri of x @ x^T."""
    B, F, D = x.shape
    z = jnp.einsum("bfd,bgd->bfg", x, x)
    ii, jj = np.tril_indices(F, k=-1)
    return z[:, ii, jj]


def bitvec_rank_ref(words, word_ranks, positions):
    """rank1(pos) over packed uint32 words with exclusive word prefix ranks."""
    w = positions >> 5
    rem = (positions & 31).astype(jnp.uint32)
    word = words[w]
    mask = jnp.where(rem == 0, jnp.uint32(0), (jnp.uint32(1) << rem) - jnp.uint32(1))
    masked = word & mask
    # popcount via SWAR
    m1 = jnp.uint32(0x55555555)
    m2 = jnp.uint32(0x33333333)
    m4 = jnp.uint32(0x0F0F0F0F)
    x = masked - ((masked >> 1) & m1)
    x = (x & m2) + ((x >> 2) & m2)
    x = (x + (x >> 4)) & m4
    pc = (x * jnp.uint32(0x01010101)) >> 24
    return word_ranks[w] + pc.astype(jnp.int32)
