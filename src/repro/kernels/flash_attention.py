"""Flash attention Pallas TPU kernel (online-softmax, blocked, GQA-aware).

Target layout: grid (batch*q_heads, Sq/bq, Sk/bk); the K/V BlockSpec index
map folds grouped-query attention (q head h reads kv head h // group), so
no repeated K/V materialization. VMEM scratch carries the running max m,
normalizer l, and output accumulator across the sequential k-block axis.
Supports causal masking (right-aligned, so Sq < Sk decodes work), Gemma-2
style sliding windows and logit soft-capping. Fully-masked k-blocks are
skipped with `pl.when` (structural block skipping — on TPU this saves the
MXU work; in interpret mode it is exercised for correctness).

MXU alignment: bq/bk default 128 (v5e systolic tile); D padded by caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, sm_scale, causal, window, softcap, block_q, block_k, sq, sk,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # right-aligned absolute positions (supports Sq < Sk decode windows)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + (sk - sq)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    # structural skip: is any (q, k) pair in this block pair visible?
    lo_q, hi_q = q_pos[0], q_pos[-1]
    lo_k = k_pos[0]
    block_visible = jnp.bool_(True)
    if causal:
        block_visible &= lo_k <= hi_q
    if window is not None:
        block_visible &= k_pos[-1] > lo_q - window

    @pl.when(block_visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, causal=True, window=None, softcap=None, sm_scale=None,
    block_q=128, block_k=128, interpret=False,
):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad sequence to block multiple"
    if sm_scale is None:
        sm_scale = float(1.0 / (D ** 0.5))

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Sk, D)
    vr = v.reshape(B * Hkv, Sk, D)

    def kv_map(h, qi, ki):
        return (h // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel,
        sm_scale=sm_scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, sq=Sq, sk=Sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, Sq // block_q, Sk // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
