"""Digram pair-count Pallas kernel — the paper's Count step, TPU-native.

Input: per-node top-K incidence-type histograms (its, cnts), -1-padded.
Each grid step evaluates the paper's count_v formula for a block of nodes
over all K(K+1)/2 unordered type pairs at once:

    count_v(i1, i2) = min(c(v,i1), c(v,i2))   if i1 != i2
                      c(v,i1) // 2            if i1 == i2

Outputs the canonicalized (lo, hi) pair ids and counts; the host (or a
segment-sum stage) aggregates over nodes. This turns the hash-map inner
loop of the C implementation into a dense vectorized tile.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _digram_kernel(ii_ref, jj_ref, it_ref, cnt_ref, lo_ref, hi_ref, out_ref):
    its = it_ref[...]                # (Nb, K)
    cnts = cnt_ref[...]
    ii, jj = ii_ref[...], jj_ref[...]
    it1 = its[:, ii]
    it2 = its[:, jj]
    c1 = cnts[:, ii]
    c2 = cnts[:, jj]
    same = (ii == jj)[None, :]
    cv = jnp.where(same, c1 // 2, jnp.minimum(c1, c2))
    valid = (it1 >= 0) & (it2 >= 0)
    out_ref[...] = jnp.where(valid, cv, 0)
    lo_ref[...] = jnp.minimum(it1, it2)
    hi_ref[...] = jnp.maximum(it1, it2)


def digram_pair_counts(its, cnts, *, block_n=256, interpret=False):
    """its, cnts: (N, K) int32 -> (lo, hi, count) each (N, K(K+1)/2)."""
    N, K = its.shape
    block_n = min(block_n, N)
    assert N % block_n == 0
    ii, jj = np.triu_indices(K)
    P = len(ii)
    lo, hi, cnt = pl.pallas_call(
        _digram_kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, P), lambda i: (i, 0)),
            pl.BlockSpec((block_n, P), lambda i: (i, 0)),
            pl.BlockSpec((block_n, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, P), jnp.int32),
            jax.ShapeDtypeStruct((N, P), jnp.int32),
            jax.ShapeDtypeStruct((N, P), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(ii, jnp.int32), jnp.asarray(jj, jnp.int32), its, cnts)
    return lo, hi, cnt
