"""Batched bitvector rank1 Pallas kernel (query-time hot op of the k²-tree).

rank1(pos) = word_ranks[pos/32] + popcount(words[pos/32] & mask(pos%32)).
Popcount is the SWAR bit dance on uint32 lanes — no LUT, pure VPU ops.
Full words + prefix ranks are resident; positions are blocked on the grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount32(x):
    m1 = jnp.uint32(0x55555555)
    m2 = jnp.uint32(0x33333333)
    m4 = jnp.uint32(0x0F0F0F0F)
    x = x - ((x >> 1) & m1)
    x = (x & m2) + ((x >> 2) & m2)
    x = (x + (x >> 4)) & m4
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _rank_kernel(pos_ref, words_ref, ranks_ref, o_ref):
    pos = pos_ref[...]
    w = pos >> 5
    rem = (pos & 31).astype(jnp.uint32)
    word = words_ref[w]
    mask = jnp.where(rem == 0, jnp.uint32(0), (jnp.uint32(1) << rem) - jnp.uint32(1))
    o_ref[...] = ranks_ref[w] + _popcount32(word & mask)


def bitvec_rank(words, word_ranks, positions, *, block_q=1024, interpret=False):
    """words: (W,) uint32; word_ranks: (W,) int32 exclusive prefix;
    positions: (Q,) int32 with pos/32 < W. Returns rank1 at each position.

    Q may be any size: positions are padded up to the block boundary (pad
    queries re-read position 0, always in-bounds) and the pad is sliced off.
    """
    (W,) = words.shape
    (Q,) = positions.shape
    if Q == 0:
        return jnp.zeros(0, jnp.int32)
    block_q = min(block_q, Q)
    pad = (-Q) % block_q
    if pad:
        positions = jnp.pad(positions, (0, pad))
    qp = Q + pad
    out = pl.pallas_call(
        _rank_kernel,
        grid=(qp // block_q,),
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((W,), lambda i: (0,)),
            pl.BlockSpec((W,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        interpret=interpret,
    )(positions, words, word_ranks)
    return out[:Q] if pad else out
