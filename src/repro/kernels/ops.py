"""Jitted public wrappers for the Pallas kernels.

On non-TPU backends the kernels run in `interpret=True` mode (the kernel
body executes as traced JAX ops — bit-exact correctness, no Mosaic); on TPU
they compile to Mosaic. Models call these wrappers through the
`use_pallas` config switch so CPU dry-runs lower the pure-jnp reference
path while TPU runs get the kernels.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.bitvec_rank import bitvec_rank as _bitvec_rank
from repro.kernels.digram_count import digram_pair_counts as _digram_pair_counts
from repro.kernels.dot_interaction import dot_interaction as _dot_interaction
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.segment_matmul import build_csr_blocks, csr_spmm as _csr_spmm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "sm_scale", "block_q", "block_k")
)
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    sm_scale=None, block_q=128, block_k=128):
    return _flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("n_nodes", "block_n", "block_d"))
def csr_spmm(x, src_idx, local_dst, n_nodes, *, block_n=128, block_d=None):
    return _csr_spmm(
        x, src_idx, local_dst, n_nodes, block_n=block_n, block_d=block_d,
        interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("combiner", "block_b", "block_d"))
def embedding_bag(table, indices, *, combiner="sum", block_b=128, block_d=None):
    return _embedding_bag(
        table, indices, combiner=combiner, block_b=block_b, block_d=block_d,
        interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("block_b",))
def dot_interaction(x, *, block_b=128):
    return _dot_interaction(x, block_b=block_b, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_n",))
def digram_pair_counts(its, cnts, *, block_n=256):
    return _digram_pair_counts(its, cnts, block_n=block_n, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_q",))
def bitvec_rank(words, word_ranks, positions, *, block_q=1024):
    return _bitvec_rank(words, word_ranks, positions, block_q=block_q, interpret=_interpret())


__all__ = [
    "flash_attention",
    "csr_spmm",
    "build_csr_blocks",
    "embedding_bag",
    "dot_interaction",
    "digram_pair_counts",
    "bitvec_rank",
    "ref",
]
