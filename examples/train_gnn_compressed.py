#!/usr/bin/env python
"""End-to-end training driver: GNN trained from an ITR-compressed GraphStore.

The paper's compressed graph is the *data layer*: the web-graph is stored as
an SL-HR grammar; the neighbor sampler draws fanout batches from it; a
GatedGCN trains for a few hundred steps with checkpointing, an injected
worker failure at step 120, and automatic restore — the full fault-tolerant
loop at example scale.

    PYTHONPATH=src python examples/train_gnn_compressed.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import GraphStore, NeighborSampler, web_graph
from repro.models import gnn as gnn_mod
from repro.train import (
    AdamWConfig,
    FailureInjector,
    Trainer,
    TrainerConfig,
    WorkerFailure,
)

D_FEAT, N_CLASSES, SEEDS = 32, 7, 64


def make_data(store, feats, labels, sampler, rng, cfg, n_pad, e_pad):
    """Pad every sampled batch to (n_pad nodes, e_pad edges) so the jitted
    train step compiles once; padded edges point at a dedicated dummy node."""
    dummy = n_pad - 1

    def batches():
        while True:
            seeds = rng.choice(store.n_nodes, SEEDS, replace=False)
            batch = sampler.sample(seeds, rng)
            senders = np.concatenate([b.senders for b in batch.blocks])[:e_pad]
            receivers = np.concatenate([b.receivers for b in batch.blocks])[:e_pad]
            n, e = len(batch.node_ids), len(senders)
            x = np.zeros((n_pad, D_FEAT), np.float32)
            x[:n] = feats[batch.node_ids]
            y = np.zeros(n_pad, np.int64)
            y[:n] = labels[batch.node_ids]
            seed_mask = np.zeros(n_pad, bool)
            seed_mask[np.searchsorted(batch.node_ids, batch.seeds)] = True
            s_pad = np.full(e_pad, dummy, np.int32)
            r_pad = np.full(e_pad, dummy, np.int32)
            s_pad[:e], r_pad[:e] = senders, receivers
            yield {
                "x": jnp.asarray(x),
                "ef": jnp.zeros((e_pad, 4), jnp.float32),
                "senders": jnp.asarray(s_pad),
                "receivers": jnp.asarray(r_pad),
                "y": jnp.asarray(y, jnp.int32),
                "mask": jnp.asarray(seed_mask),
            }
    return batches()


def main():
    rng = np.random.default_rng(0)
    ds = web_graph(n_nodes=2000, n_edges=12000, seed=0)
    store = GraphStore.from_triples(ds.triples, ds.n_nodes, ds.n_preds)
    print(f"GraphStore: |V|={store.n_nodes} |E|={ds.n_triples} "
          f"compressed={store.compressed_size_bytes()} bytes "
          f"({store.stats.rules_created} grammar rules)")
    print(f"sample neighborhood query (compressed path): "
          f"N_out(0) = {store.neighbors_out(0)[:8]}")

    indptr, indices = store.csc()
    sampler = NeighborSampler(indptr, indices, fanouts=(15, 10))
    feats = rng.normal(size=(store.n_nodes, D_FEAT)).astype(np.float32)
    labels = rng.integers(0, N_CLASSES, store.n_nodes)

    cfg = get_arch("gatedgcn").reduced()
    params = gnn_mod.gatedgcn_init(cfg, jax.random.PRNGKey(0), D_FEAT, 4, N_CLASSES)

    def loss_fn(p, b):
        logits = gnn_mod.gatedgcn_apply(p, b["x"], b["ef"], b["senders"],
                                        b["receivers"], b["x"].shape[0], cfg)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, b["y"][:, None], axis=1)[:, 0]
        w = b["mask"].astype(jnp.float32)
        return (nll * w).sum() / jnp.maximum(w.sum(), 1)

    ckpt_dir = tempfile.mkdtemp(prefix="itr_gnn_ckpt_")
    tc = TrainerConfig(total_steps=300, checkpoint_every=50, log_every=50,
                       checkpoint_dir=ckpt_dir,
                       opt=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=300))
    trainer = Trainer(loss_fn, params, tc,
                      failure_injector=FailureInjector({120: [0]}))
    n_pad = min(store.n_nodes + 1, SEEDS * (1 + 15 + 150))
    e_pad = SEEDS * 15 * 11
    data = make_data(store, feats, labels, sampler, rng, cfg, n_pad, e_pad)
    try:
        trainer.run(data)
    except WorkerFailure as e:
        print(f"!! {e} — restoring from checkpoint")
        # fresh worker = fresh init (the failed worker's buffers were donated)
        fresh = gnn_mod.gatedgcn_init(cfg, jax.random.PRNGKey(1), D_FEAT, 4, N_CLASSES)
        trainer = Trainer(loss_fn, fresh, tc)
        assert trainer.maybe_restore()
        print(f"   restored at step {trainer.step}")
        trainer.run(data, steps=tc.total_steps - trainer.step)

    log = trainer.metrics_log
    print("training log (post-restore):")
    for rec in log:
        print(f"  step {rec['step']:>4} loss {rec['loss']:.4f}")
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} ({'improved' if last < first else 'WORSE'})")


if __name__ == "__main__":
    main()
