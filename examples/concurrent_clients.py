#!/usr/bin/env python
"""Concurrent clients: many threads querying one sharded service while
mutations land — the minimal pattern docs/CONCURRENCY.md documents.

    PYTHONPATH=src python examples/concurrent_clients.py

Queries run concurrently under the service's read lock; inserts/deletes
are exclusive writers, so every thread sees a consistent pre- or
post-mutation state, never a torn one. Each thread gets exactly its own
results back (ticket-taking is atomic), verified here against a
single-threaded oracle engine.
"""

from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import Hypergraph, LabelTable, TripleQueryEngine, compress, encode
from repro.data import rdf_like
from repro.serve.sharded import ShardedTripleService


def main():
    ds = rdf_like(n_nodes=600, n_edges=2400, n_preds=8, seed=3)
    svc = ShardedTripleService.build(ds.triples, ds.n_nodes, ds.n_preds,
                                     n_shards=4)
    print(f"dataset: |V|={ds.n_nodes} |E|={ds.n_triples} |T|={ds.n_preds}; "
          f"{svc.n_shards} shards, scatter fan-out width "
          f"{min(svc.serve_threads, svc.n_shards)}")

    # single-threaded oracle for the base graph
    table = LabelTable.terminals([2] * ds.n_preds)
    graph = Hypergraph.from_triples(ds.triples, ds.n_nodes)
    grammar, _ = compress(graph, table)
    oracle = TripleQueryEngine(grammar, encode(grammar), cache=None)

    # 8 threads, each firing point lookups and unselective scatters; the
    # futures' results are per-caller — no cross-thread ticket mixups
    subjects = [int(s) for s in ds.triples[:64, 0]]
    patterns = [(s, None, None) for s in subjects]
    patterns += [(None, p, None) for p in range(ds.n_preds)]
    with ThreadPoolExecutor(max_workers=8) as pool:
        futures = [pool.submit(svc.query, *pat) for pat in patterns]
        answers = [f.result() for f in futures]
    for pat, got in zip(patterns, answers):
        assert sorted(got) == sorted(oracle.query(*pat)), pat
    print(f"{len(patterns)} queries across 8 threads: "
          f"all matched the single-threaded oracle")

    # mutations are exclusive writers — safe to issue while the pool above
    # is still serving; queries before/after see consistent states
    s, p = subjects[0], 0
    rows = np.array([[s, p, ds.n_nodes - 1], [s, p, ds.n_nodes - 2]])
    svc.insert_triples(rows)
    res = svc.query(s, p, None)
    assert all((p, (s, int(o))) in res for o in rows[:, 2])
    svc.delete_triples(rows)
    assert sorted(svc.query(s, p, None)) == sorted(oracle.query(s, p, None))
    print("insert/delete interleaved with serving: queries stayed exact")

    svc.close()  # drain the scatter fan-out pool
    print("OK")


if __name__ == "__main__":
    main()
