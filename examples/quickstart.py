#!/usr/bin/env python
"""Quickstart: compress a graph with ITR, query it, verify, report sizes.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.baselines import ntriples_size_bytes
from repro.core import (
    Hypergraph,
    LabelTable,
    TripleQueryEngine,
    compress,
    encode,
    query_oracle,
)
from repro.data import rdf_like


def main():
    ds = rdf_like(n_nodes=2000, n_edges=8000, n_preds=12, seed=0)
    print(f"dataset: |V|={ds.n_nodes} |E|={ds.n_triples} |T|={ds.n_preds}")

    table = LabelTable.terminals([2] * ds.n_preds)
    graph = Hypergraph.from_triples(ds.triples, ds.n_nodes)

    grammar, stats = compress(graph, table)
    print(f"compressed: {stats.iterations} digram rules, "
          f"{stats.replaced_occurrences} occurrences replaced, "
          f"size {stats.initial_size_units} -> {stats.final_size_units} units")

    enc = encode(grammar)
    raw = ntriples_size_bytes(ds.triples)
    print(f"succinct encoding: {enc.size_in_bytes()} bytes "
          f"({enc.size_in_bytes() / raw:.2%} of N-Triples)")

    engine = TripleQueryEngine(grammar, enc)
    s, p, o = map(int, ds.triples[7])
    for pat, (qs, qp, qo) in {
        "S ? ?": (s, None, None), "? P ?": (None, p, None),
        "? ? O": (None, None, o), "S P O": (s, p, o),
    }.items():
        res = engine.query(qs, qp, qo)
        ref = query_oracle(graph, qs, qp, qo)
        assert sorted(res) == sorted(ref)
        print(f"  {pat}: {len(res)} matches (verified vs oracle)")

    decompressed = grammar.decompress()
    assert sorted(decompressed.edge_tuples()) == sorted(graph.edge_tuples())
    print("decompress == original: OK")

    # sharded serving: partition -> one engine per shard -> scatter-gather
    # router with a shared result-cache tier (see repro/serve/sharded.py)
    from repro.serve.sharded import ShardedTripleService

    svc = ShardedTripleService.build(
        ds.triples, ds.n_nodes, ds.n_preds,
        n_shards=4, strategy="predicate_hash")
    res = svc.query_many([(s, None, None), (None, p, None), (s, p, o)])
    for r, (qs, qp, qo) in zip(res, [(s, None, None), (None, p, None), (s, p, o)]):
        assert sorted(r) == sorted(engine.query(qs, qp, qo))
    st = svc.stats
    print(f"sharded (P={svc.n_shards}, edges/shard={svc.shard_sizes()}): "
          f"{st.owned} owned + {st.scattered} scatter-gathered patterns, "
          f"verified vs single engine")

    # BGP joins: conjunctive patterns with shared variables, planned by
    # selectivity stats from the compressed CSR and executed as batched
    # id-array joins through the same sharded path (docs/ARCHITECTURE.md §12)
    p2 = (p + 1) % ds.n_preds
    bgp = f"{s} {p} ?y . ?y {p2} ?z"
    res_bgp = svc.query_bgp(bgp)
    naive = sorted(
        (int(y), int(z))
        for _, (_, y) in engine.query(s, p, None)
        for _, (_, z) in engine.query(int(y), p2, None))
    assert sorted(res_bgp.tuples()) == sorted(set(naive))
    print(f"BGP '{bgp}': vars={res_bgp.vars}, {len(res_bgp)} bindings "
          f"(verified vs per-pattern join)")

    # mutation: inserts/deletes land in a per-shard delta overlay (routed
    # to the owning shard) and queries stay exact immediately; an explicit
    # rebuild() recompresses dirty shards through RePair (docs/ARCHITECTURE.md)
    import numpy as np

    new_rows = np.array([[s, p, ds.n_nodes - 1], [s, p, ds.n_nodes - 2]])
    n_new = svc.insert_triples(new_rows)
    n_gone = svc.delete_triples(ds.triples[:3])
    res = svc.query(s, p, None)
    for row in new_rows:
        assert (int(row[1]), (int(row[0]), int(row[2]))) in res
    print(f"mutated: +{n_new} inserted, -{n_gone} deleted "
          f"(delta rows/shard={svc.delta_sizes()}), queries exact via overlay")

    rebuilt = svc.rebuild(force=True)  # recompress only the dirty shards
    assert svc.delta_sizes() == [0] * svc.n_shards
    assert all(t in svc.query(s, p, None) for t in res)  # still exact
    print(f"rebuilt shards {rebuilt}: overlays folded into fresh grammars, "
          f"results unchanged")

    # online rebalancing: mutation skews shard loads; rebalance() re-cuts
    # the plan and migrates rows between shards while queries stay exact
    # (auto-triggered past ITR_REBALANCE_SKEW, explicit via force=True)
    hot = np.stack([np.full(60, s), np.full(60, p),
                    np.arange(60) % ds.n_nodes], axis=1)
    svc.insert_triples(hot)  # every row lands on one predicate's shard
    skew_before = svc.skew()
    before = svc.query(s, p, None)
    summary = svc.rebalance(force=True)
    assert sorted(svc.query(s, p, None)) == sorted(before)  # still exact
    print(f"rebalanced: skew {skew_before:.2f} -> {svc.skew():.2f}, "
          f"{summary['moved']} rows migrated "
          f"(live edges/shard={svc.live_edges()}), queries unchanged")

    # durability: mmap-able snapshots + a mutation write-ahead log. Build
    # writes an initial snapshot; every mutation is logged BEFORE it
    # applies, so a kill at any instant recovers to exactly the
    # acknowledged state — open() loads the newest snapshot (no RePair)
    # and replays the log over it (see docs/ARCHITECTURE.md §9)
    import tempfile

    from repro.persist.service import DurableShardedService

    with tempfile.TemporaryDirectory() as root:
        dsvc = DurableShardedService.build(
            ds.triples, ds.n_nodes, ds.n_preds, root=root, n_shards=2)
        dsvc.insert_triples(new_rows)      # logged, then applied
        expected = sorted(dsvc.query(s, p, None))
        dsvc.wal.close()                   # simulate kill -9: no shutdown

        dsvc = DurableShardedService.open(root)   # snapshot + WAL replay
        rec = dsvc.last_recovery
        assert sorted(dsvc.query(s, p, None)) == expected
        print(f"recovered: snapshot step {rec.snapshot_step} + "
              f"{rec.replayed_records} WAL record(s) replayed, "
              f"queries match the pre-kill state")
        dsvc.snapshot()                    # persist + compact the log
        dsvc.close()


if __name__ == "__main__":
    main()
