#!/usr/bin/env python
"""Streaming RDF ingestion into a durable sharded tier, queried by term
strings — the ARCHITECTURE.md §13 pipeline end to end:

  scan predicates -> build an empty durable tier sized for them ->
  ingest the N-Triples stream in batches (terms minted through the WAL)
  -> query by strings -> snapshot -> reopen -> same answers.

    PYTHONPATH=src python examples/ingest_rdf.py [file.nt]
"""
import sys
import tempfile

import numpy as np

from repro.core.term_dict import TermDict
from repro.data.ingest import ingest_file, scan_predicates
from repro.persist.service import DurableShardedService


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "tests/fixtures/small.nt"

    # pass 1: predicate capacity is fixed at build time, so count it first
    preds, statements = scan_predicates(path)
    print(f"{path}: {statements} statements, {len(preds)} predicates")

    with tempfile.TemporaryDirectory() as root:
        svc = DurableShardedService.build(
            np.zeros((0, 3), dtype=np.int64), n_nodes=1, n_preds=len(preds),
            root=root, n_shards=2)
        svc.attach_term_dict(TermDict.empty())

        # pass 2: stream the file in; every batch mints its new terms
        # through the WAL, then lands through one insert_triples
        stats = ingest_file(svc, path, batch_size=1024)
        print(f"ingested {stats.rows} triples in {stats.batches} batches "
              f"({stats.rows_per_s:,.0f} rows/s), minted "
              f"{stats.new_nodes} node + {stats.new_preds} predicate terms")
        if stats.malformed:
            print(f"  skipped {stats.malformed} malformed line(s), "
                  f"e.g. {stats.malformed_samples[:1]}")

        # query by term strings: ids resolve once at the boundary
        subject = svc.term_dict.node_term(0)
        rows = svc.query_strings(subject, None, None)
        print(f"\nquery_strings({subject!r}, None, None):")
        for s, p, o in rows:
            print(f"  {s} {p} {o}")

        pred = svc.term_dict.pred_term(0)
        bgp = svc.query_bgp_strings([("?x", pred, "?y")])
        print(f"\nquery_bgp_strings([('?x', {pred!r}, '?y')]): "
              f"{len(bgp)} binding rows")

        # durability: snapshot, reopen, same string answers
        svc.snapshot()
        svc.close()
        svc = DurableShardedService.open(root=root)
        again = svc.query_strings(subject, None, None)
        assert again == rows, "reopened tier answered differently"
        print("\nreopened from snapshot: same answers — OK")
        svc.close()


if __name__ == "__main__":
    main()
