#!/usr/bin/env python
"""Serve a small LM with batched requests: prefill + token-by-token decode
through the KV-cache engine (the same computation the decode_* dry-run
cells lower at production scale).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve import ServeEngine


def main():
    cfg = get_arch("gemma2-9b").reduced()  # local/global + softcap engine path
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(params, cfg, max_len=96, eos_id=None)

    rng = np.random.default_rng(0)
    requests = [rng.integers(2, cfg.vocab, rng.integers(4, 12)).tolist()
                for _ in range(8)]
    print(f"serving {len(requests)} batched requests "
          f"(model={cfg.name}, vocab={cfg.vocab})")
    res = engine.generate(requests, max_new_tokens=16, temperature=0.0)
    for i, (req, out) in enumerate(zip(requests, res.tokens)):
        print(f"  req{i}: prompt[{len(req)}] -> {out[:int(res.n_generated[i])].tolist()}")
    print(f"prefill: {res.prefill_ms:.1f} ms, decode: {res.decode_ms_per_token:.1f} ms/token")

    # determinism check (greedy)
    res2 = engine.generate(requests, max_new_tokens=16, temperature=0.0)
    assert np.array_equal(res.tokens, res2.tokens)
    print("greedy decode deterministic: OK")


if __name__ == "__main__":
    main()
