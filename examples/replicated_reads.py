#!/usr/bin/env python
"""Replicated reads: snapshot-seeded, WAL-tailing replica groups behind
the shard router — the read-scaling pattern ARCHITECTURE.md §11 documents.

    PYTHONPATH=src python examples/replicated_reads.py

A durable sharded tier built with ``replicas=2`` seeds two whole-tier
read copies from the snapshot (mmap-shared pages) and keeps them fresh
by tailing the WAL. Queries dispatch round-robin across the replica
groups; mutations go only to the primary and flow to replicas as log
records. ``sync_replicas()`` is the quiesce step: after it, every
replica answers exactly like the primary — which this script checks
against a plain-Python set oracle, including after a ``snapshot()``
compacts the log under lagging cursors and forces a reseed.
"""

import tempfile

import numpy as np

from repro.data import rdf_like
from repro.persist.service import DurableShardedService


def oracle_query(triples, s, p, o):
    return sorted((tp, (ts, to)) for ts, tp, to in triples
                  if (s is None or ts == s) and (p is None or tp == p)
                  and (o is None or to == o))


def main():
    ds = rdf_like(n_nodes=400, n_edges=1600, n_preds=8, seed=11)
    oracle = {tuple(map(int, r)) for r in ds.triples}

    with tempfile.TemporaryDirectory() as root:
        svc = DurableShardedService.build(
            ds.triples, ds.n_nodes, ds.n_preds, root=root, n_shards=4,
            replicas=2, replica_dispatch="round_robin")
        stats = svc.replica_stats()
        print(f"tier: {svc.service.n_shards} shards x "
              f"{stats['n_replicas']} replica groups, "
              f"dispatch={stats['dispatch']}, lag={stats['max_lag_records']}")

        # reads dispatch across the replica groups; the primary only has
        # to serve the mutation path
        probes = [(int(s), None, None) for s in ds.triples[:16, 0]]
        probes += [(None, p, None) for p in range(ds.n_preds)]
        for s, p, o in probes:
            assert sorted(svc.query(s, p, o)) == oracle_query(oracle, s, p, o)
        served = svc.service.stats.replica_flushes
        print(f"{len(probes)} queries: {served} served by replicas, "
              f"all matching the set oracle")

        # mutations land on the primary and reach replicas via the WAL
        rows = np.array([[1, 0, 2], [1, 0, 3], [7, 1, 2]])
        svc.insert_triples(rows)
        oracle.update(tuple(map(int, r)) for r in rows)
        print(f"after insert: replica lag = "
              f"{svc.replica_stats()['max_lag_records']} record(s)")
        svc.sync_replicas()  # quiesce: tail the log into every group
        assert svc.replica_stats()["max_lag_records"] == 0
        assert sorted(svc.query(1, 0, None)) == oracle_query(oracle, 1, 0, None)
        print("sync_replicas(): lag 0, replica answers exact")

        # snapshot() compacts the WAL under lagging cursors — replicas
        # detect the truncation and reseed rather than replaying stale
        # history; answers stay exact
        svc.delete_triples(rows[:1])
        oracle.discard(tuple(map(int, rows[0])))
        svc.snapshot()
        svc.insert_triples(rows[:1])
        oracle.add(tuple(map(int, rows[0])))
        svc.sync_replicas()
        reseeds = sum(g["reseeds"] for g in svc.replica_stats()["groups"])
        assert reseeds > 0
        for s, p, o in probes:
            assert sorted(svc.query(s, p, o)) == oracle_query(oracle, s, p, o)
        print(f"snapshot under lagging cursors: {reseeds} reseed(s), "
              f"queries stayed exact")

        svc.close()  # drains replica pools + primary pool, idempotent
    print("OK")


if __name__ == "__main__":
    main()
