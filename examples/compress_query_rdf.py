#!/usr/bin/env python
"""Paper pipeline end-to-end on an RDF file: N-Triples -> dictionaries ->
ITR / ITR+ compression -> all 8 triple-query patterns vs baselines.

    PYTHONPATH=src python examples/compress_query_rdf.py [file.nt]
"""
import sys
import tempfile

import numpy as np

from benchmarks.common import PATTERNS, build_all, time_queries
from repro.data import parse_ntriples, version_graph, write_ntriples
from repro.data.synthetic import TripleDataset


def main():
    if len(sys.argv) > 1:
        path = sys.argv[1]
    else:
        ds0 = version_graph(n_groups=300, seed=0)
        path = tempfile.mktemp(suffix=".nt")
        write_ntriples(path, ds0.triples)
        print(f"(no input given: generated ttt-win-style graph at {path})")
    triples, node_names, pred_names, report = parse_ntriples(path)
    ds = TripleDataset(np.unique(triples, axis=0), len(node_names), len(pred_names), name=path)
    print(f"parsed {path}: |V|={ds.n_nodes} |E|={ds.n_triples} |T|={ds.n_preds}")
    if report.malformed:
        print(f"  WARNING: {report.malformed} malformed line(s) skipped, "
              f"e.g. {report.samples[:2]}")

    built = build_all(ds)
    raw = built.pop("raw_bytes")
    for method, b in built.items():
        extra = ""
        if "stats" in b:
            extra = f" ({b['stats'].rules_created} rules)"
        print(f"{method:<12} {b['size']:>9} bytes  ratio {b['size']/raw:.4f}{extra}")

    print("\nquery latency (us/query):")
    for pattern in PATTERNS:
        line = f"  {pattern}: "
        for method, b in built.items():
            us, _ = time_queries(b["engine"], ds, pattern, n_queries=100)
            line += f"{method}={us:9.1f}  "
        print(line)


if __name__ == "__main__":
    main()
