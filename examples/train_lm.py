#!/usr/bin/env python
"""Train a small decoder LM for a few hundred steps on synthetic structured
data, with gradient compression (int8 + error feedback) and checkpointing —
the end-to-end exercise of the LM training path at laptop scale (the full
configs run through the same code in the dry-run).

    PYTHONPATH=src python examples/train_lm.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tf_mod
from repro.train import AdamWConfig, CompressionConfig, Trainer, TrainerConfig


def synthetic_batches(vocab, batch=8, seq=64, seed=0):
    """Structured sequences (arithmetic-progression tokens) — learnable."""
    rng = np.random.default_rng(seed)
    while True:
        start = rng.integers(0, vocab - 1, (batch, 1))
        step = rng.integers(1, 5, (batch, 1))
        seqs = (start + step * np.arange(seq + 1)) % vocab
        yield {"tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
               "targets": jnp.asarray(seqs[:, 1:], jnp.int32)}


def main():
    cfg = dataclasses.replace(get_arch("qwen2-1.5b").reduced(),
                              n_layers=4, d_model=128, d_ff=256)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} scaled to {n_params/1e6:.2f}M params")

    def loss_fn(p, batch):
        return tf_mod.forward_loss(p, batch["tokens"], batch["targets"], cfg)

    tc = TrainerConfig(
        total_steps=300, checkpoint_every=100, log_every=25,
        checkpoint_dir=tempfile.mkdtemp(prefix="itr_lm_ckpt_"),
        opt=AdamWConfig(lr=3e-3, warmup_steps=30, total_steps=300),
        compression=CompressionConfig(codec="int8"),
    )
    trainer = Trainer(loss_fn, params, tc)
    log = trainer.run(synthetic_batches(cfg.vocab))
    for rec in log:
        print(f"  step {rec['step']:>4} loss {rec['loss']:.4f} lr {rec['lr']:.2e}")
    assert log[-1]["loss"] < log[0]["loss"] * 0.8, "LM failed to learn"
    print(f"loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f} with int8-compressed grads: OK")


if __name__ == "__main__":
    main()
