"""Root pytest config: gate the optional `hypothesis` dependency.

The target container does not ship hypothesis; registering the fallback
shim (tests/_hypothesis_fallback.py) under the `hypothesis` name keeps the
property tests collectable and running deterministically. A real
hypothesis install always wins — the shim is only used on ImportError.
"""
import importlib.util
import sys
from pathlib import Path

try:
    import hypothesis  # noqa: F401
except ImportError:
    _shim_path = Path(__file__).parent / "tests" / "_hypothesis_fallback.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
