"""Root pytest config: gate the optional `hypothesis` dependency and the
nightly `slow` marker.

The target container does not ship hypothesis; registering the fallback
shim (tests/_hypothesis_fallback.py) under the `hypothesis` name keeps the
property tests collectable and running deterministically. A real
hypothesis install always wins — the shim is only used on ImportError.

Tests marked ``@pytest.mark.slow`` (large-budget randomized suites) are
skipped by the tier-1 run and selected by the nightly/manual CI lane via
``pytest -m slow`` (.github/workflows/nightly.yml).
"""
import importlib.util
import sys
from pathlib import Path

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    _shim_path = Path(__file__).parent / "tests" / "_hypothesis_fallback.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: large-budget randomized suite; tier-1 skips it, the nightly "
        "lane selects it with `pytest -m slow`")


def pytest_collection_modifyitems(config, items):
    if "slow" in (config.option.markexpr or ""):
        return  # explicitly selected (nightly lane): run them
    skip_slow = pytest.mark.skip(
        reason="slow suite: nightly lane only (pytest -m slow)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
