"""Deterministic BGP join tests: parser, planner, executor, sharded tier,
whole-BGP cache, and the edge-case regression pins (empty intermediate
short-circuit, repeated variables, all-variable join steps, zero-row
inputs). The randomized machine lives in test_bgp_oracle.py; the
brute-force reference in _bgp_oracle.py.
"""
import numpy as np
import pytest

from _bgp_oracle import assert_bgp_equal, oracle_bgp
from repro.core import bgp as bgp_mod
from repro.core.bgp import (
    BGPResult,
    SelectivityStats,
    TriplePattern,
    _join_indices,
    bgp_cache_key,
    bgp_variables,
    canonical_bgp,
    decode_result_entry,
    encode_result_entry,
    execute_bgp,
    parse_bgp,
    plan_bgp,
)
from repro.core.hypergraph import Hypergraph, LabelTable
from repro.core.query import TripleQueryEngine
from repro.core.repair import compress
from repro.distributed.partition import STRATEGIES
from repro.serve.sharded import ShardedTripleService

N_NODES, N_PREDS = 16, 4

# handcrafted rows guaranteeing every join shape the suite probes:
# a pred-0 triangle (cycle), a self-loop, a 3-pred star at node 7,
# cross-predicate chains, and a lone pred-3 edge for selectivity plans
_FIXED = [
    (1, 0, 2), (2, 0, 3), (3, 0, 1),          # triangle on pred 0
    (5, 0, 5),                                # self-loop
    (7, 1, 8), (7, 2, 9), (7, 0, 10),         # star hub
    (1, 1, 4), (4, 2, 6), (6, 1, 2),          # chain 1 -0/1/2-> ...
    (12, 3, 13),                              # rare predicate
    (2, 1, 3), (3, 2, 5), (10, 1, 11),
]


def _rows(extra_seed=None, n_extra=30):
    rows = list(_FIXED)
    if extra_seed is not None:
        rng = np.random.default_rng(extra_seed)
        extra = np.stack([rng.integers(0, N_NODES, n_extra),
                          rng.integers(0, 3, n_extra),  # keep pred 3 rare
                          rng.integers(0, N_NODES, n_extra)], axis=1)
        rows += [tuple(map(int, r)) for r in extra]
    return np.array(sorted(set(rows)), dtype=np.int64)


def _engine(rows, **kwargs):
    table = LabelTable.terminals([2] * N_PREDS)
    grammar, _ = compress(Hypergraph.from_triples(rows, N_NODES), table)
    kwargs.setdefault("cache", None)
    kwargs.setdefault("crossover", 0)
    kwargs.setdefault("delta_budget", None)
    return TripleQueryEngine(grammar, **kwargs)


@pytest.fixture(scope="module")
def rows():
    return _rows(extra_seed=3)


@pytest.fixture(scope="module")
def engine(rows):
    return _engine(rows)


def _triples(engine_or_svc):
    if hasattr(engine_or_svc, "current_triples"):
        return [tuple(map(int, r)) for r in engine_or_svc.current_triples()]
    return [tuple(map(int, r))
            for eng in engine_or_svc.engines
            for r in eng.current_triples()]


# -- parsing ---------------------------------------------------------------

def test_parse_string_and_tuple_forms_agree():
    from_str = parse_bgp("?x 0 ?y . ?y 1 17")
    from_tuples = parse_bgp([("?x", 0, "?y"), ("?y", 1, 17)])
    assert from_str == from_tuples
    assert from_str[0] == TriplePattern("?x", 0, "?y")
    assert from_str[1].o == 17
    assert bgp_variables(from_str) == ["?x", "?y"]


def test_parse_rejects_bad_terms():
    with pytest.raises(ValueError):
        parse_bgp("")  # empty BGP
    with pytest.raises(ValueError):
        parse_bgp("?x 0")  # arity
    with pytest.raises(ValueError):
        parse_bgp("? 0 1")  # bare '?'
    with pytest.raises(ValueError):
        parse_bgp([("worksFor", 0, 1)])  # string without term dictionary
    with pytest.raises(ValueError):
        parse_bgp([(-1, 0, 1)])  # negative constant
    with pytest.raises(TypeError):
        parse_bgp([(None, 0, 1)])


def test_variables_first_appearance_order():
    pats = parse_bgp("?b 0 ?a . ?c 1 ?a . ?a 2 ?d")
    assert bgp_variables(pats) == ["?b", "?a", "?c", "?d"]


def test_canonical_bgp_renames_variables():
    a = parse_bgp("?x 0 ?y . ?y 1 17")
    b = parse_bgp("?s 0 ?t . ?t 1 17")
    c = parse_bgp("?x 0 ?y . ?x 1 17")  # different join structure
    assert canonical_bgp(a) == canonical_bgp(b)
    assert canonical_bgp(a) != canonical_bgp(c)
    assert bgp_cache_key(a) == bgp_cache_key(b)
    assert bgp_cache_key(a) != bgp_cache_key(c)
    assert all(k <= -2 for k in bgp_cache_key(a))  # disjoint from patterns


# -- planner ---------------------------------------------------------------

def test_selectivity_stats_exact_pred_card(engine, rows):
    stats = engine.selectivity()
    want = np.bincount(rows[:, 1], minlength=N_PREDS)
    assert stats.pred_card.tolist() == want.tolist()
    assert stats.total == len(rows)
    assert stats.n_subjects >= len(set(rows[:, 0].tolist()))
    assert stats.n_objects >= len(set(rows[:, 2].tolist()))


def test_selectivity_stats_merge():
    a = SelectivityStats(10, np.array([4, 6]), 3, 5)
    b = SelectivityStats(5, np.array([1, 2, 2]), 2, 2)
    m = SelectivityStats.merge([a, b])
    assert m.total == 15 and m.pred_card.tolist() == [5, 8, 2]
    assert m.n_subjects == 5 and m.n_objects == 7
    assert SelectivityStats.merge([]).total == 0


def test_plan_starts_with_most_selective():
    stats = SelectivityStats(16, np.array([10, 5, 1, 0]), 8, 8)
    pats = parse_bgp("?a 0 ?b . ?b 2 ?c")
    assert plan_bgp(pats, stats) == [1, 0]


def test_plan_prefers_connected_over_cheaper_disconnected():
    stats = SelectivityStats(16, np.array([10, 5, 1, 0]), 8, 8)
    pats = parse_bgp("?a 2 ?b . ?b 0 ?c . ?c 1 ?d")
    # after the cheap pred-2 start, pred-1 is cheaper than pred-0 but is
    # not connected to the solved variables yet — the plan must not take
    # a cartesian step while a connected pattern exists
    assert plan_bgp(pats, stats) == [0, 1, 2]


def test_execute_rejects_bad_order(engine):
    with pytest.raises(ValueError):
        execute_bgp("?x 0 ?y . ?y 1 ?z", engine.query_batch_view,
                    order=[0, 0])


# -- engine-level execution ------------------------------------------------

def test_single_pattern_bgp(engine):
    assert_bgp_equal(engine.query_bgp("?x 1 ?y"), _triples(engine), "?x 1 ?y")


def test_chain2(engine):
    assert_bgp_equal(engine.query_bgp("?x 0 ?y . ?y 1 ?z"),
                     _triples(engine), "?x 0 ?y . ?y 1 ?z")


def test_chain3(engine):
    bgp = "?x 0 ?y . ?y 1 ?z . ?z 2 ?w"
    assert_bgp_equal(engine.query_bgp(bgp), _triples(engine), bgp)


def test_star(engine):
    bgp = "?h 0 ?a . ?h 1 ?b . ?h 2 ?c"
    res = engine.query_bgp(bgp)
    assert_bgp_equal(res, _triples(engine), bgp)
    assert len(res) > 0  # the fixture star hub must actually match


def test_cycle(engine):
    bgp = "?x 0 ?y . ?y 0 ?z . ?z 0 ?x"
    res = engine.query_bgp(bgp)
    assert_bgp_equal(res, _triples(engine), bgp)
    assert (1, 2, 3) in res.tuples()  # fixture triangle
    assert (5, 5, 5) in res.tuples()  # self-loop closes a 'cycle' too


def test_cartesian_product(engine):
    triples = _triples(engine)
    bgp = "?a 3 ?b . ?c 2 ?d"  # no shared variables
    res = engine.query_bgp(bgp)
    assert_bgp_equal(res, triples, bgp)
    n3 = sum(1 for _, p, _ in triples if p == 3)
    n2 = sum(1 for _, p, _ in triples if p == 2)
    assert len(res) == n3 * n2 > 0


def test_unsatisfiable_patterns(engine):
    res = engine.query_bgp("?x 0 ?y . ?y 3 15")
    assert_bgp_equal(res, _triples(engine), "?x 0 ?y . ?y 3 15")
    assert len(res) == 0 and res.vars == ("?x", "?y")
    assert engine.query_bgp([(0, 3, 0)]).tuples() == []


def test_constant_only_pattern(engine):
    present = _triples(engine)[0]
    bgp = [present, ("?x", 0, "?y")]
    assert_bgp_equal(engine.query_bgp(bgp), _triples(engine), bgp)
    absent = [(15, 3, 15), ("?x", 0, "?y")]
    assert len(engine.query_bgp(absent)) == 0


# -- edge-case regression pins --------------------------------------------

def test_repeated_variable_within_pattern(engine):
    for bgp in ("?x 0 ?x", "?x ?p ?x", [("?x", "?x", "?y")]):
        assert_bgp_equal(engine.query_bgp(bgp), _triples(engine), bgp)
    assert (5,) in engine.query_bgp("?x 0 ?x").tuples()  # the fixture self-loop


def test_all_variable_pattern_as_join_step(engine):
    bgp = "?s ?p ?o . ?o 1 ?w"
    assert_bgp_equal(engine.query_bgp(bgp), _triples(engine), bgp)
    assert_bgp_equal(engine.query_bgp("?s ?p ?o"), _triples(engine),
                     "?s ?p ?o")


def test_empty_intermediate_short_circuits(engine):
    calls = []

    def counting(s, p, o):
        calls.append(len(s))
        return engine.query_batch_view(s, p, o)

    res = execute_bgp("?x 3 15 . ?x 0 ?y . ?y 1 ?z", counting,
                      order=[0, 1, 2])
    assert len(res) == 0 and res.vars == ("?x", "?y", "?z")
    assert calls == [1]  # later patterns never executed


def test_zero_row_inputs_on_join_path():
    empty = np.zeros((0, 3), dtype=np.int64)
    svc = ShardedTripleService.build(empty, N_NODES, N_PREDS, n_shards=2)
    try:
        res = svc.query_bgp("?s ?p ?o . ?s 0 ?y")
        assert len(res) == 0 and res.vars == ("?s", "?p", "?o", "?y")
    finally:
        svc.close()


def test_result_entry_roundtrip():
    rows = np.array([[3, 1], [0, 2]], dtype=np.int64)
    rows.flags.writeable = False
    res = BGPResult(("?a", "?b"), rows)
    back = decode_result_entry(encode_result_entry(res), res.vars)
    assert back.vars == res.vars and back.tuples() == res.tuples()
    # zero rows and zero vars both survive
    for r in (BGPResult(("?a",), np.zeros((0, 1), dtype=np.int64)),
              BGPResult((), np.zeros((1, 0), dtype=np.int64))):
        back = decode_result_entry(encode_result_entry(r), r.vars)
        assert back.tuples() == r.tuples()


def test_bgp_result_api(engine):
    res = engine.query_bgp("?y 1 ?x")
    assert res.vars == ("?y", "?x")
    rows = res.tuples()
    assert rows == sorted(rows)  # deterministic lexicographic order
    assert len(res) == len(rows)
    assert res.bindings()[0] == dict(zip(res.vars, rows[0]))
    assert not res.rows.flags.writeable


# -- join machinery units --------------------------------------------------

def test_join_indices_matches_bruteforce():
    rng = np.random.default_rng(5)
    left = rng.integers(0, 4, size=(30, 2)).astype(np.int64)
    right = rng.integers(0, 4, size=(20, 2)).astype(np.int64)
    li, ri = _join_indices(left, right)
    got = sorted(zip(li.tolist(), ri.tolist()))
    want = sorted((i, j) for i in range(len(left))
                  for j in range(len(right))
                  if (left[i] == right[j]).all())
    assert got == want


def test_hash_join_mode_matches_bind_mode(engine, monkeypatch):
    bgp = "?x ?p ?y . ?y ?q ?z"
    bind = execute_bgp(bgp, engine.query_batch_view, None)
    monkeypatch.setattr(bgp_mod, "_BIND_FANOUT", 0)  # force scan+hash path
    hashed = execute_bgp(bgp, engine.query_batch_view, None)
    assert bind.tuples() == hashed.tuples() and len(bind) > 0
    assert_bgp_equal(hashed, _triples(engine), bgp)


# -- sharded tier ----------------------------------------------------------

def test_sharded_matches_oracle_all_strategies(rows):
    bgps = ["?x 0 ?y . ?y 1 ?z",
            "?h 0 ?a . ?h 1 ?b",
            "?s ?p ?o . ?o 2 ?w"]
    triples = [tuple(map(int, r)) for r in rows]
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            svc = ShardedTripleService.build(
                rows, N_NODES, N_PREDS, n_shards=n_shards, strategy=strategy)
            try:
                for bgp in bgps:
                    assert_bgp_equal(svc.query_bgp(bgp), triples, bgp)
            finally:
                svc.close()


def test_durable_service_dispatches_query_bgp(rows, tmp_path):
    from repro.persist.service import DurableShardedService
    svc = DurableShardedService.build(
        rows, N_NODES, N_PREDS, root=str(tmp_path / "svc"), n_shards=2,
        rebalance_skew=None)
    try:
        bgp = "?x 0 ?y . ?y 1 ?z"
        assert_bgp_equal(svc.query_bgp(bgp), _triples(svc.service), bgp)
        svc.insert_triples([[0, 0, 7], [7, 1, 9]])
        assert_bgp_equal(svc.query_bgp(bgp), _triples(svc.service), bgp)
    finally:
        svc.close()


# -- whole-BGP cache -------------------------------------------------------

def test_whole_bgp_cache_hits_and_env_off(rows, monkeypatch):
    svc = ShardedTripleService.build(rows, N_NODES, N_PREDS, n_shards=2)
    try:
        bgp = "?x 0 ?y . ?y 1 ?z"
        first = svc.query_bgp(bgp)
        again = svc.query_bgp("?a 0 ?b . ?b 1 ?c")  # canonical-equal
        assert again.tuples() == first.tuples()
        assert again.vars == ("?a", "?b", "?c")  # caller's names, not cached
        assert svc.stats.bgp_cache_hits == 1
        assert svc.stats.bgp_queries == 2
        monkeypatch.setenv("ITR_BGP_CACHE", "0")
        svc.query_bgp(bgp)
        assert svc.stats.bgp_cache_hits == 1  # cache bypassed entirely
    finally:
        svc.close()


def test_stale_bgp_cache_regression(rows):
    """The generation-vector key must invalidate whole-BGP entries on ANY
    shard change — without it, this exact sequence served a stale join."""
    svc = ShardedTripleService.build(rows, N_NODES, N_PREDS, n_shards=2)
    try:
        bgp = "?x 0 ?y . ?y 1 ?z"
        before = svc.query_bgp(bgp)
        # new pred-1 edge hanging off an existing pred-0 edge => answer grows
        s, _, o = next(t for t in _triples(svc) if t[1] == 0)
        svc.insert_triples([[o, 1, 15]])
        after = svc.query_bgp(bgp)
        assert_bgp_equal(after, _triples(svc), bgp)
        assert len(after) > len(before)
        svc.delete_triples([[o, 1, 15]])
        assert svc.query_bgp(bgp).tuples() == before.tuples()
    finally:
        svc.close()


def test_bgp_correct_across_mutation_rebuild_rebalance(rows):
    bgp = "?x 0 ?y . ?y ?p ?z"
    for strategy in STRATEGIES:
        svc = ShardedTripleService.build(
            rows, N_NODES, N_PREDS, n_shards=2, strategy=strategy,
            rebalance_skew=None)
        try:
            assert_bgp_equal(svc.query_bgp(bgp), _triples(svc), bgp)
            svc.insert_triples([[0, 0, 13], [13, 2, 14], [13, 3, 1]])
            assert_bgp_equal(svc.query_bgp(bgp), _triples(svc), bgp)
            svc.delete_triples(rows[:5])
            assert_bgp_equal(svc.query_bgp(bgp), _triples(svc), bgp)
            svc.rebuild(force=True)
            assert_bgp_equal(svc.query_bgp(bgp), _triples(svc), bgp)
            svc.rebalance(force=True)
            assert_bgp_equal(svc.query_bgp(bgp), _triples(svc), bgp)
        finally:
            svc.close()


def test_oracle_helper_agrees_with_itself():
    triples = [(0, 0, 1), (1, 1, 2), (0, 0, 2)]
    vars_, rows_ = oracle_bgp(triples, "?x 0 ?y . ?y 1 ?z")
    assert vars_ == ["?x", "?y", "?z"] and rows_ == [(0, 1, 2)]
    # duplicate-free cartesian sanity
    _, both = oracle_bgp(triples, "?a 0 ?b . ?c 1 ?d")
    assert both == [(0, 1, 1, 2), (0, 2, 1, 2)]
