"""Launch-layer tests: every cell builder lowers+compiles at reduced scale
on a 1×1 host mesh (the full-scale 256/512-chip compiles are the dry-run
sweep; this is the fast regression guard)."""
import jax
import pytest

from repro.configs import all_cells, get_arch
from repro.launch.mesh import make_host_mesh, resolve_in_shardings, set_global_mesh
from repro.launch.steps import build_cell

# one representative shape per family kind keeps this under a minute
FAST_CELLS = [
    ("qwen2-1.5b", "train_4k"),
    ("gemma2-9b", "decode_32k"),
    ("olmoe-1b-7b", "prefill_32k"),
    ("gcn-cora", "full_graph_sm"),
    ("gatedgcn", "molecule"),
    ("nequip", "minibatch_lg"),
    ("meshgraphnet", "ogb_products"),
    ("dlrm-mlperf", "train_batch"),
    ("dlrm-mlperf", "serve_p99"),
    ("dlrm-mlperf", "retrieval_cand"),
]


@pytest.fixture(scope="module", autouse=True)
def host_mesh():
    mesh = make_host_mesh()
    set_global_mesh(mesh)
    yield mesh


@pytest.mark.parametrize("arch,shape", FAST_CELLS)
def test_cell_lowers_and_compiles_reduced(arch, shape, host_mesh):
    cell = build_cell(arch, shape, reduced=True)
    jitted = jax.jit(cell.fn, in_shardings=resolve_in_shardings(host_mesh, cell.in_specs),
                     donate_argnums=cell.donate_argnums)
    compiled = jitted.lower(*cell.args).compile()
    assert compiled.cost_analysis() is not None
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0


def test_all_cells_enumerate_40():
    cells = all_cells()
    assert len(cells) == 40
    archs = {a for a, _ in cells}
    assert len(archs) == 10


def test_registry_configs_buildable():
    for arch_id in {a for a, _ in all_cells()}:
        spec = get_arch(arch_id)
        cfg = spec.config()
        red = spec.reduced()
        assert cfg.name and red.name
