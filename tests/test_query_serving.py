"""Serving-grade query path: frontier arena, cross-request result cache,
crossover dispatch, service flush stats, batched neighborhood parity."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FrontierArena,
    Hypergraph,
    LabelTable,
    QueryResultCache,
    TripleQueryEngine,
    compress,
    query_oracle,
)
from repro.serve.triple_service import TripleQueryService
from tests.test_itr_core import random_hypergraph


def _triple_engine(seed=0, n_nodes=15, n_preds=3, n_edges=80, **kwargs):
    rng = np.random.default_rng(seed)
    triples = np.stack(
        [rng.integers(0, n_nodes, n_edges), rng.integers(0, n_preds, n_edges),
         rng.integers(0, n_nodes, n_edges)], axis=1)
    table = LabelTable.terminals([2] * n_preds)
    g = Hypergraph.from_triples(triples, n_nodes)
    grammar, _ = compress(g, table)
    return TripleQueryEngine(grammar, **kwargs), g, triples


# ---------------------------------------------------------------- arena
def test_frontier_arena_growth_and_reuse():
    arena = FrontierArena(edge_cap=2, node_cap=2)
    arena.push(np.array([0, 0]), np.array([5, 6]), np.array([2, 1]),
               np.array([10, 11, 12]))
    arena.push(np.array([1]), np.array([7]), np.array([3]), np.array([1, 2, 3]))
    q, l, n, o = arena.finish()
    assert q.tolist() == [0, 0, 1]
    assert l.tolist() == [5, 6, 7]
    assert n.tolist() == [10, 11, 12, 1, 2, 3]
    assert o.tolist() == [0, 2, 3, 6]
    assert arena.edge_capacity >= 3 and arena.node_capacity >= 6
    # finish() resets: the arena is immediately reusable
    assert arena.n_edges == 0 and arena.n_nodes == 0
    q2, l2, n2, o2 = arena.finish()
    assert len(l2) == 0 and o2.tolist() == [0]
    # earlier results were copies, untouched by further pushes
    arena.push(np.array([9]), np.array([9]), np.array([1]), np.array([99]))
    assert l.tolist() == [5, 6, 7]


def test_engine_results_survive_arena_reuse():
    engine, g, triples = _triple_engine(seed=1)
    s0 = int(triples[0, 0])
    r1 = engine.query_batch_arrays([s0], None, None)
    saved = tuple(a.copy() for a in r1)
    # a second, different query reuses the arena; first results must hold
    engine.query_batch_arrays([None], [int(triples[1, 1])], None)
    for a, b in zip(r1, saved):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------- cache unit
def test_result_cache_lru_eviction_and_stats():
    cache = QueryResultCache(max_entries=2, max_edges=1 << 20)
    e = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
    assert cache.lookup(1, -1, -1) is None
    cache.insert(1, -1, -1, e)
    cache.insert(2, -1, -1, e)
    assert cache.lookup(1, -1, -1) is not None  # refresh 1 -> 2 becomes LRU
    cache.insert(3, -1, -1, e)                  # evicts 2
    assert cache.lookup(2, -1, -1) is None
    assert cache.lookup(3, -1, -1) is not None
    st = cache.stats
    assert st.evictions == 1 and st.inserts == 3
    assert st.hits == 2 and st.misses == 2
    assert st.hit_rate == pytest.approx(0.5)


def test_result_cache_predicate_segment_is_isolated():
    cache = QueryResultCache(max_entries=1, predicate_entries=4)
    e = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
    cache.insert(-1, 0, -1, e)  # ?P? -> predicate segment
    cache.insert(-1, 1, -1, e)
    # a burst of selective inserts may thrash the general segment...
    for s in range(5):
        cache.insert(s, -1, -1, e)
    # ...but the predicate segment stays warm
    assert cache.lookup(-1, 0, -1) is not None
    assert cache.lookup(-1, 1, -1) is not None
    assert cache.stats.predicate_hits == 2


def test_result_cache_edge_budget_and_oversize():
    big = (np.arange(10), np.arange(20), np.arange(0, 22, 2))
    cache = QueryResultCache(max_entries=100, max_edges=25, max_entry_edges=15)
    for s in range(4):
        cache.insert(s, -1, -1, big)  # 10 edges each; budget 25 -> evictions
    assert cache.cached_edges <= 25
    assert cache.stats.evictions >= 1
    huge = (np.arange(16), np.arange(32), np.arange(0, 34, 2))
    cache.insert(9, -1, -1, huge)  # > max_entry_edges: skipped
    assert cache.lookup(9, -1, -1) is None
    assert cache.stats.oversize_skips == 1


# ---------------------------------------------------------------- engine+cache
def test_cached_queries_match_oracle_and_count_hits():
    engine, g, triples = _triple_engine(seed=2, cache=QueryResultCache(), crossover=0)
    s0, p0 = int(triples[0, 0]), int(triples[0, 1])
    want_s = sorted(query_oracle(g, s0, None, None))
    want_p = sorted(query_oracle(g, None, p0, None))
    assert sorted(engine.query(s0, None, None)) == want_s
    assert sorted(engine.query(None, p0, None)) == want_p
    miss0 = engine.cache.stats.misses
    # repeats are cache hits and still exact
    assert sorted(engine.query(s0, None, None)) == want_s
    assert sorted(engine.query(None, p0, None)) == want_p
    assert engine.cache.stats.hits >= 2
    assert engine.cache.stats.misses == miss0
    assert engine.cache.stats.predicate_hits >= 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_cache_parity_random_hypergraph_batches(seed):
    """Batches re-run against a warm cache must equal the oracle exactly,
    including mixed hit/miss batches with duplicates."""
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng, n_nodes=14, n_edges=50)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=0)
    s = int(rng.integers(0, 14))
    p = int(rng.integers(0, 3))
    patterns = [(s, None, None), (None, p, None), (s, None, None),
                (None, None, s), (None, None, None)]
    ss, pp, oo = (list(c) for c in zip(*patterns))
    first = engine.query_batch(ss, pp, oo)
    second = engine.query_batch(ss, pp, oo)  # all-hit replay
    # third: half warm, half fresh
    patterns3 = patterns + [(None, None, int(rng.integers(0, 14)))]
    s3, p3, o3 = (list(c) for c in zip(*patterns3))
    third = engine.query_batch(s3, p3, o3)
    for i, (qs, qp, qo) in enumerate(patterns3):
        want = sorted(query_oracle(g, qs, qp, qo))
        if i < len(patterns):
            assert sorted(first[i]) == want
            assert sorted(second[i]) == want
        assert sorted(third[i]) == want
    assert engine.cache.stats.hits > 0


def test_cached_single_query_arrays_are_read_only():
    """Single-query results alias live cache entries; mutation must raise
    instead of corrupting future answers."""
    engine, g, triples = _triple_engine(seed=11, cache=QueryResultCache(),
                                        crossover=0)
    s0 = int(triples[0, 0])
    _, labels, nodes, _ = engine.query_batch_arrays([s0], None, None)
    if len(nodes):
        with pytest.raises(ValueError):
            nodes[0] = 999
        with pytest.raises(ValueError):
            labels[0] = 999
    # repeat (a cache hit) is uncorrupted and exact
    assert sorted(engine.query(s0, None, None)) == \
        sorted(query_oracle(g, s0, None, None))


def test_cache_entries_do_not_pin_batch_buffers():
    """Entries split from a miss batch must be copies: a view would keep
    the whole batch's result arrays alive, defeating the edge budget."""
    engine, g, triples = _triple_engine(seed=12, cache=QueryResultCache(),
                                        crossover=0)
    s0, s1 = int(triples[0, 0]), int(triples[1, 0])
    p0 = int(triples[0, 1])
    engine.query_batch_arrays([s0, s1, -1], [-1, -1, p0], [-1, -1, -1])
    entries = list(engine.cache._general.entries.values()) + \
        list(engine.cache._predicate.entries.values())
    assert len(entries) == 3
    for labels, nodes, offsets in entries:
        assert labels.base is None and nodes.base is None


def test_cache_disabled_engine_still_exact():
    engine, g, triples = _triple_engine(seed=3, cache=None)
    s0 = int(triples[0, 0])
    want = sorted(query_oracle(g, s0, None, None))
    assert sorted(engine.query(s0, None, None)) == want
    assert engine.cache is None


# ---------------------------------------------------------------- dispatch
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_crossover_dispatch_parity(seed):
    """With the crossover forced wide, every selective pattern routes to the
    scalar worklist — results must still equal the oracle, and unselective
    patterns must still take the frontier."""
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng, n_nodes=12, n_edges=40)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=8)
    s = int(rng.integers(0, 12))
    p = int(rng.integers(0, 3))
    for qs, qp, qo in [(s, None, None), (None, None, s), (s, p, None),
                       (None, p, s), (None, p, None), (None, None, None)]:
        assert sorted(engine.query(qs, qp, qo)) == sorted(query_oracle(g, qs, qp, qo))


def test_crossover_env_override(monkeypatch):
    monkeypatch.setenv("ITR_QUERY_CROSSOVER", "5")
    engine, _, _ = _triple_engine(seed=4)
    assert engine.crossover == 5
    monkeypatch.setenv("ITR_QUERY_CROSSOVER", "0")
    engine, _, _ = _triple_engine(seed=4)
    assert engine.crossover == 0


def test_crossover_env_invalid_values_fall_back_to_calibration(monkeypatch):
    """Unparseable ITR_QUERY_CROSSOVER must not crash engine build — the
    knob is ignored and the width is calibrated as if unset."""
    for bogus in ("not-a-number", "3.5", "1e3", ""):
        monkeypatch.setenv("ITR_QUERY_CROSSOVER", bogus)
        engine, _, _ = _triple_engine(seed=4)
        assert 0 <= engine.crossover <= 8, bogus


def test_crossover_env_negative_clamps_to_zero(monkeypatch):
    monkeypatch.setenv("ITR_QUERY_CROSSOVER", "-3")
    engine, _, _ = _triple_engine(seed=4)
    assert engine.crossover == 0  # negative width means "always frontier"


def test_crossover_env_whitespace_is_stripped(monkeypatch):
    monkeypatch.setenv("ITR_QUERY_CROSSOVER", "  6  ")
    engine, _, _ = _triple_engine(seed=4)
    assert engine.crossover == 6


def test_result_cache_env_falsy_spellings(monkeypatch):
    """Every documented falsy spelling of ITR_RESULT_CACHE disables the
    default cache; anything else (including unset/empty) keeps it on."""
    for off in ("0", "off", "OFF", "false", "False", "no", " No "):
        monkeypatch.setenv("ITR_RESULT_CACHE", off)
        engine, _, _ = _triple_engine(seed=4)
        assert engine.cache is None, off
    for on in ("1", "on", "true", "yes", "anything-else"):
        monkeypatch.setenv("ITR_RESULT_CACHE", on)
        engine, _, _ = _triple_engine(seed=4)
        assert engine.cache is not None, on
    monkeypatch.delenv("ITR_RESULT_CACHE", raising=False)
    engine, _, _ = _triple_engine(seed=4)
    assert engine.cache is not None  # default: enabled
    monkeypatch.setenv("ITR_RESULT_CACHE", "")
    engine, _, _ = _triple_engine(seed=4)
    assert engine.cache is not None  # empty string = unset, not falsy


def test_crossover_calibration_runs():
    engine, _, _ = _triple_engine(seed=5)  # no override: measured at build
    assert 0 <= engine.crossover <= 8


# ---------------------------------------------------------------- service
def _service(seed=6, **kwargs):
    engine, g, triples = _triple_engine(seed=seed, cache=QueryResultCache(),
                                        crossover=0)
    return TripleQueryService(engine, **kwargs), g, triples


def test_service_empty_flush_is_noop():
    service, _, _ = _service()
    assert service.flush() == []
    st = service.stats
    assert st.queries == 0 and st.batches == 0 and st.executed == 0
    assert st.cache_hits == 0 and st.total_s == 0.0


def test_service_counts_hits_separately_from_executed():
    service, g, triples = _service(seed=7)
    s0, s1 = int(triples[0, 0]), int(triples[1, 0])
    # flush 1: three submissions, two unique patterns, nothing cached yet
    service.submit(s0, None, None)
    service.submit(s0, None, None)
    service.submit(s1, None, None)
    out = service.flush()
    assert [sorted(r) for r in out] == [
        sorted(query_oracle(g, s0, None, None)),
        sorted(query_oracle(g, s0, None, None)),
        sorted(query_oracle(g, s1, None, None))]
    assert service.stats.queries == 3
    assert service.stats.executed == 2   # unique patterns actually run
    assert service.stats.cache_hits == 0
    # flush 2: the same patterns again — all answered from the cache
    service.submit(s0, None, None)
    service.submit(s1, None, None)
    service.flush()
    assert service.stats.queries == 5
    assert service.stats.executed == 2   # nothing new executed
    assert service.stats.cache_hits == 2
    assert service.stats.cache_hit_rate == pytest.approx(0.5)


def test_service_streaming_dedup_across_chunks():
    """max_batch splits one flush into micro-batches; a pattern executed in
    chunk 1 must be a cache hit in chunk 2 (streaming dedup)."""
    service, g, triples = _service(seed=8, max_batch=2)
    s0, s1 = int(triples[0, 0]), int(triples[2, 0])
    for s in (s0, s1, s0, s0):
        service.submit(s, None, None)
    out = service.flush()
    assert len(out) == 4 and service.stats.batches == 2
    assert service.stats.executed == 2
    assert service.stats.cache_hits == 1  # chunk 2's unique s0 hit the cache
    for r, s in zip(out, (s0, s1, s0, s0)):
        assert sorted(r) == sorted(query_oracle(g, s, None, None))


def test_service_without_cache_counts_unique_executed():
    engine, g, triples = _triple_engine(seed=9, cache=None, crossover=0)
    service = TripleQueryService(engine)
    s0 = int(triples[0, 0])
    service.submit(s0, None, None)
    service.submit(s0, None, None)
    service.flush()
    assert service.stats.queries == 2
    assert service.stats.executed == 1  # in-batch dedup still collapses
    assert service.stats.cache_hits == 0


# ---------------------------------------------------------------- neighbors
def _scalar_neighbors(engine, v: int, slot: int) -> np.ndarray:
    """Neighborhood oracle via the seed scalar worklist: distinct nodes in
    tuple position `slot` of the edges matching (v ? ?) / (? ? v)."""
    res = engine.query_scalar(v if slot == 1 else None, None,
                              v if slot == 0 else None)
    vals = {int(nodes[slot]) for _, nodes in res if len(nodes) > slot}
    return np.array(sorted(vals), dtype=np.int64)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_neighbors_batch_parity_random_grammars(seed):
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng, n_nodes=13, n_edges=45)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=0)
    vs = rng.integers(0, 13, 6).tolist() + [0, 0]  # duplicates exercise dedup
    outs = engine.neighbors_out_batch(vs)
    ins = engine.neighbors_in_batch(vs)
    assert len(outs) == len(vs) and len(ins) == len(vs)
    for v, got_out, got_in in zip(vs, outs, ins):
        np.testing.assert_array_equal(got_out, _scalar_neighbors(engine, int(v), 1))
        np.testing.assert_array_equal(got_in, _scalar_neighbors(engine, int(v), 0))
        # scalar convenience wrappers agree with the batch
        np.testing.assert_array_equal(engine.neighbors_out(int(v)), got_out)
        np.testing.assert_array_equal(engine.neighbors_in(int(v)), got_in)


def test_neighbors_batch_negative_and_out_of_range_nodes():
    engine, g, _ = _triple_engine(seed=10)
    big = engine.encoded.incidence.n_rows + 7
    outs = engine.neighbors_out_batch([-1, big])
    ins = engine.neighbors_in_batch([-3, big])
    for r in outs + ins:
        assert len(r) == 0
