"""Brute-force BGP reference: nested loops over an explicit triple set.

No engine code on this side — patterns match by scanning every triple for
every partial binding, so any divergence from `query_bgp` (lost/duplicated
bindings, variable-order bugs, stale cache entries, join-order effects) is
the engine's fault, not the oracle's. Deliberately quadratic-and-worse:
the randomized suites keep graphs small and guard against blowups with
`max_bindings`.
"""
from __future__ import annotations


class OracleBlowup(Exception):
    """Intermediate binding set exceeded the caller's budget."""


def _match(pattern, triple, binding):
    """Extend `binding` over one pattern x triple, or None on mismatch."""
    out = dict(binding)
    for term, val in zip(pattern.terms, triple):
        if isinstance(term, str):
            if term in out:
                if out[term] != val:
                    return None
            else:
                out[term] = val
        elif term != val:
            return None
    return out


def oracle_bgp(triples, patterns, max_bindings: int | None = None):
    """All bindings of `patterns` over `triples`, the slow honest way.

    `triples` is any iterable of (s, p, o) rows; `patterns` anything
    `parse_bgp` accepts. Returns ``(vars, rows)`` with vars in
    first-appearance order and rows a sorted list of int tuples — the
    exact comparison shape of ``BGPResult.tuples()``. Raises
    :class:`OracleBlowup` if an intermediate binding set exceeds
    `max_bindings` (the randomized machine skips those queries instead of
    burning minutes in nested Python loops).
    """
    from repro.core.bgp import bgp_variables, parse_bgp

    patterns = parse_bgp(patterns)
    out_vars = bgp_variables(patterns)
    rows = [tuple(int(v) for v in t) for t in triples]
    bindings = [{}]
    for pat in patterns:
        nxt = []
        for binding in bindings:
            for triple in rows:
                extended = _match(pat, triple, binding)
                if extended is not None:
                    nxt.append(extended)
        if max_bindings is not None and len(nxt) > max_bindings:
            raise OracleBlowup(f"{len(nxt)} bindings > {max_bindings}")
        bindings = nxt
        if not bindings:
            break
    return out_vars, sorted(tuple(b[v] for v in out_vars) for b in bindings)


def assert_bgp_equal(result, triples, patterns) -> None:
    """`result` (a BGPResult) must equal the brute-force answer exactly —
    same variable order, same binding multiset, same row sort."""
    want_vars, want_rows = oracle_bgp(triples, patterns)
    assert list(result.vars) == list(want_vars), (result.vars, want_vars)
    assert result.tuples() == want_rows, (
        len(result.tuples()), len(want_rows), patterns)
