"""Online rebalancing: skew detection, LPT predicate re-pack, quantile
boundary re-cut, migration bookkeeping (take/discard), migration-safe
routing + mutation while moves are in flight, empty-shard edge cases, and
the hardened `PartitionPlan` routing surfaces (zero-row batches, subject
ids at/above the last node_range boundary)."""
import numpy as np
import pytest

from repro.core import Hypergraph, LabelTable, TripleQueryEngine, compress
from repro.distributed.partition import (
    STRATEGIES,
    PartitionPlan,
    diff_plans,
    make_plan,
    subject_quantile_boundaries,
)
from repro.distributed.rebalance import (
    DEFAULT_REBALANCE_SKEW,
    RebalancePlan,
    balance_predicates,
    live_shard_edges,
    measure_skew,
    plan_rebalance,
    resolve_rebalance_skew,
)
from repro.serve.sharded import _MERGED_SHARD, ShardedTripleService

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]

N_NODES, N_PREDS = 24, 4


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


def _unique_triples(seed, n_edges=90, n_nodes=N_NODES, n_preds=N_PREDS):
    rng = np.random.default_rng(seed)
    t = np.stack([rng.integers(0, n_nodes, n_edges),
                  rng.integers(0, n_preds, n_edges),
                  rng.integers(0, n_nodes, n_edges)], axis=1)
    return np.unique(t, axis=0)


def _engine(triples, n_nodes=N_NODES, n_preds=N_PREDS):
    table = LabelTable.terminals([2] * n_preds)
    grammar, _ = compress(Hypergraph.from_triples(triples, n_nodes), table)
    return TripleQueryEngine(grammar, cache=None, crossover=0,
                             delta_budget=None)


def _assert_parity(svc, logical_rows, probes):
    oracle = _engine(logical_rows) if len(logical_rows) else None
    for row in probes:
        s, p, o = map(int, row)
        for pattern in PATTERN_NAMES:
            qs, qp, qo = _bind(pattern, s, p, o)
            got = sorted(svc.query(qs, qp, qo))
            want = sorted(oracle.query_scalar(qs, qp, qo)) if oracle else []
            assert got == want, (pattern, (s, p, o))


def _logical(svc) -> np.ndarray:
    return np.concatenate([e.current_triples() for e in svc.engines])


# ------------------------------------------------------------ trigger knob
def test_resolve_rebalance_skew_spellings(monkeypatch):
    monkeypatch.delenv("ITR_REBALANCE_SKEW", raising=False)
    assert resolve_rebalance_skew() == DEFAULT_REBALANCE_SKEW
    for spelling in ("off", "NONE", " never "):
        monkeypatch.setenv("ITR_REBALANCE_SKEW", spelling)
        assert resolve_rebalance_skew() is None
    monkeypatch.setenv("ITR_REBALANCE_SKEW", "2.5")
    assert resolve_rebalance_skew() == 2.5
    monkeypatch.setenv("ITR_REBALANCE_SKEW", "0")
    assert resolve_rebalance_skew() is None
    monkeypatch.setenv("ITR_REBALANCE_SKEW", "-3")
    assert resolve_rebalance_skew() is None
    monkeypatch.setenv("ITR_REBALANCE_SKEW", "0.25")  # sub-1 clamps to 1.0
    assert resolve_rebalance_skew() == 1.0
    monkeypatch.setenv("ITR_REBALANCE_SKEW", "not-a-number")
    assert resolve_rebalance_skew() == DEFAULT_REBALANCE_SKEW
    # explicit values bypass the environment
    assert resolve_rebalance_skew(3.0) == 3.0
    assert resolve_rebalance_skew(-1) is None


def test_measure_skew():
    assert measure_skew([]) == 1.0
    assert measure_skew([7]) == 1.0          # single shard: balanced
    assert measure_skew([0, 0, 0]) == 1.0    # empty tier: balanced
    assert measure_skew([10, 10, 10, 10]) == 1.0
    assert measure_skew([40, 0, 0, 0]) == 4.0  # everything on one shard
    assert measure_skew([30, 10]) == 1.5


def test_live_shard_edges_tracks_overlay():
    base = _unique_triples(0)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=2,
                                     delta_budget=None, rebalance_skew=None)
    counts = live_shard_edges(svc.engines)
    assert int(counts.sum()) == len(base)
    rows = np.array([[1, 0, 23], [2, 0, 22], [3, 0, 21]])
    rows = rows[~np.array([tuple(r) in {tuple(b) for b in base}
                           for r in rows.tolist()])]
    target = int(svc.plan.route_triples(rows)[0])
    svc.insert_triples(rows)
    after = live_shard_edges(svc.engines)
    assert after[target] == counts[target] + len(rows)
    svc.delete_triples(base[:4])
    assert int(live_shard_edges(svc.engines).sum()) == \
        len(base) + len(rows) - 4


# ------------------------------------------------------------- plan re-cut
def test_balance_predicates_lpt():
    counts = np.array([100, 90, 10, 10, 10, 10])
    prior = np.zeros(6, dtype=np.int64)  # everything parked on shard 0
    assign = balance_predicates(counts, 3, prior)
    load = np.bincount(assign, weights=counts, minlength=3)
    assert load.max() <= 100  # the single-biggest predicate is the floor
    assert measure_skew(load.astype(np.int64)) < measure_skew(
        np.array([240, 0, 0]))
    # zero-count predicates never churn off their prior shard
    counts0 = np.array([50, 0, 50])
    assign0 = balance_predicates(counts0, 2, np.array([0, 1, 0]))
    assert assign0[1] == 1
    with pytest.raises(ValueError):
        balance_predicates(counts, 3, np.zeros(4, dtype=np.int64))


def test_subject_quantile_boundaries_recut():
    # no observations: even id ranges
    b = subject_quantile_boundaries(None, 4, 100)
    assert b.tolist() == [0, 25, 50, 75, 100]
    assert subject_quantile_boundaries(np.zeros(0, np.int64), 2, 10).tolist() \
        == [0, 5, 10]
    # subjects packed into a prefix: cuts follow the distribution
    subs = np.repeat(np.arange(8), 25)  # 200 rows in [0, 8) of [0, 1000)
    b = subject_quantile_boundaries(subs, 4, 1000)
    assert b[0] == 0 and b[-1] == 1000
    assert np.all(np.diff(b) >= 0)
    assert b[3] <= 8  # inner cuts sit inside the observed prefix
    counts = np.bincount(np.searchsorted(b, subs, side="right") - 1,
                         minlength=4)
    assert counts.max() <= 2 * (len(subs) // 4 + 25)


def test_pred_assign_overrides_hash_and_validates():
    assign = np.array([2, 0, 1, 2], dtype=np.int64)
    plan = PartitionPlan("predicate_hash", 3, 20, 4, pred_assign=assign)
    assert plan.route(-1, 1, -1) == 0
    assert plan.route(5, 3, 7) == 2          # P owns regardless of S/O
    assert plan.route(5, -1, -1) == -1       # S?? still scatters
    trip = np.array([[1, 0, 2], [3, 2, 4]])
    assert plan.triple_shards(trip).tolist() == [2, 1]
    assert plan.route_triples(trip).tolist() == [2, 1]
    assert plan.pred_assignment().tolist() == assign.tolist()
    # predicate ids past n_preds clamp onto the last predicate's shard,
    # identically for routing and placement
    assert plan.route(-1, 9, -1) == 2
    assert plan.route_triples(np.array([[0, 9, 0]]))[0] == 2
    rb = plan.route_batch(np.array([-1, -1]), np.array([1, -1]),
                          np.array([-1, 3]))
    assert rb.tolist() == [0, -1]
    with pytest.raises(ValueError):  # wrong length
        PartitionPlan("predicate_hash", 3, 20, 4,
                      pred_assign=np.array([0, 1]))
    with pytest.raises(ValueError):  # shard id out of range
        PartitionPlan("predicate_hash", 3, 20, 4,
                      pred_assign=np.array([0, 1, 3, 0]))
    with pytest.raises(ValueError):  # wrong strategy
        PartitionPlan("node_range", 2, 20, 4,
                      boundaries=np.array([0, 10, 20]),
                      pred_assign=np.array([0, 0, 1, 1]))


def test_diff_plans_masks_moved_rows():
    old = make_plan("predicate_hash", 2, 20, 3)
    new = PartitionPlan("predicate_hash", 2, 20, 3,
                        pred_assign=1 - old.pred_assignment())
    trip = _unique_triples(1, n_preds=3)
    mask = diff_plans(old, new, trip)
    assert mask.all()  # every predicate flipped shards
    assert diff_plans(old, old, trip).sum() == 0
    assert diff_plans(old, new, np.zeros((0, 3), np.int64)).shape == (0,)
    assert diff_plans(old, new, []).shape == (0,)


# ------------------------------------------------- hardened routing surfaces
def test_route_triples_zero_row_batches():
    for strategy in STRATEGIES:
        plan = make_plan(strategy, 3, 20, 4)
        for empty in ([], np.zeros((0, 3), dtype=np.int64),
                      np.zeros(0, dtype=np.int64)):
            out = plan.route_triples(empty)
            assert out.shape == (0,) and out.dtype == np.int64
        with pytest.raises(ValueError):  # malformed non-empty still rejected
            plan.route_triples(np.array([[1, 2]]))
        with pytest.raises(ValueError):
            plan.route_triples(np.array([1, 2, 3]))
        rb = plan.route_batch(np.zeros(0, np.int64), np.zeros(0, np.int64),
                              np.zeros(0, np.int64))
        assert rb.shape == (0,)


def test_node_range_clamps_at_and_past_last_boundary():
    """Regression pin: subject ids at/above the final boundary (inserts
    that grow the graph) clamp onto the last shard — identically for
    pattern routing and triple placement."""
    plan = make_plan("node_range", 4, 100, 3)
    last = plan.n_shards - 1
    assert plan.boundaries[-1] == 100
    for s in (99, 100, 101, 10**6):
        assert plan.route(s, -1, -1) == last
    rb = plan.route_batch(np.array([99, 100, 10**6, -1]),
                          np.full(4, -1), np.full(4, -1))
    assert rb.tolist() == [last, last, last, -1]
    rows = np.array([[100, 0, 0], [10**6, 1, 2]])
    assert plan.route_triples(rows).tolist() == [last, last]
    # placement == routing at the clamp (the mutation-correctness rule)
    assert plan.route(100, 0, 0) == int(plan.route_triples(
        np.array([[100, 0, 0]]))[0])


# ------------------------------------------------- RebalancePlan bookkeeping
def _dummy_plans():
    old = make_plan("predicate_hash", 2, 20, 3)
    new = PartitionPlan("predicate_hash", 2, 20, 3,
                        pred_assign=1 - old.pred_assignment())
    return old, new


def test_rebalance_plan_take_batches_and_splits():
    old, new = _dummy_plans()
    r1 = np.array([[0, 0, 1], [1, 0, 2], [2, 0, 3]])
    r2 = np.array([[3, 1, 4], [4, 1, 5]])
    mig = RebalancePlan(old, new, [(0, 1, r1), (1, 0, r2)])
    assert mig.total_rows == 5 and mig.pending_rows == 5 and not mig.done
    first = mig.take(2)  # cap splits the first move
    assert len(first) == 1 and first[0][:2] == (0, 1) and len(first[0][2]) == 2
    assert mig.pending_rows == 3
    rest = mig.take(None)
    assert [(a, b, len(r)) for a, b, r in rest] == [(0, 1, 1), (1, 0, 2)]
    assert mig.done and mig.take(10) == []
    # zero-length moves are dropped at construction
    assert RebalancePlan(old, new, [(0, 1, np.zeros((0, 3), np.int64))]).done


def test_rebalance_plan_discard_prevents_redelivery():
    old, new = _dummy_plans()
    rows = np.array([[0, 0, 1], [1, 0, 2], [2, 0, 3]])
    mig = RebalancePlan(old, new, [(0, 1, rows)])
    assert mig.discard(rows[1:2]) == 1
    assert mig.pending_rows == 2
    assert mig.discard(np.array([[9, 9, 9]])) == 0  # absent rows: no-op
    assert mig.discard(np.zeros((0, 3), np.int64)) == 0
    remaining = np.concatenate([r for _, _, r in mig.take(None)])
    assert (1, 0, 2) not in {tuple(r) for r in remaining}


# --------------------------------------------------------- service-level
def test_explicit_rebalance_reduces_skew_and_stays_exact():
    base = _unique_triples(2, n_edges=100)
    for strategy in STRATEGIES:
        svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=3,
                                         strategy=strategy, delta_budget=None,
                                         rebalance_skew=None)
        # skew it: a burst sharing one subject AND one predicate lands on
        # a single shard under either strategy
        burst = np.stack([np.full(30, 2), np.full(30, 1),
                          np.arange(30) % N_NODES], axis=1)
        svc.insert_triples(burst)
        skew_before = svc.skew()
        logical = _logical(svc)
        res = svc.rebalance(force=True)
        assert not svc.migration_active and res["pending"] == 0
        if res["moved"]:
            assert svc.stats.rebalances == 1
            assert svc.stats.migrated_rows == res["moved"]
            assert svc.skew() <= skew_before
        # the adopted plan exactly describes where every row now lives
        for k, e in enumerate(svc.engines):
            rows = e.current_triples()
            if len(rows):
                assert (svc.plan.triple_shards(rows) == k).all()
        probes = np.concatenate([base[:2], burst[:2]])
        _assert_parity(svc, logical, probes)


def test_rebalance_below_threshold_is_a_noop():
    base = _unique_triples(3)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=2,
                                     delta_budget=None, rebalance_skew=100.0)
    res = svc.rebalance()  # not forced, skew far below 100
    assert res == {"skew": res["skew"], "moved": 0, "pending": 0,
                   "active": False}
    assert svc.stats.rebalances == 0 and not svc.migration_active


def test_migration_bumps_only_touched_shards():
    base = _unique_triples(4, n_edges=100)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=3,
                                     strategy="node_range", delta_budget=None,
                                     rebalance_skew=None)
    burst = np.stack([np.full(40, 1), np.arange(40) % N_PREDS,
                      np.arange(40) % N_NODES], axis=1)
    svc.insert_triples(np.unique(burst, axis=0))
    # predict the first migration batch (same deterministic computation
    # rebalance() will run) to find a shard it does NOT touch
    predicted = plan_rebalance(svc.plan, svc.engines).pending_moves()
    assert predicted, "burst must force at least one move"
    src, dst, rows = predicted[0]
    untouched = ({0, 1, 2} - {src, dst}).pop()
    gens = [svc.cache.generation(k) for k in range(3)]
    merged_gen = svc.cache.generation(_MERGED_SHARD)
    res = svc.rebalance(force=True, max_moves=len(rows))
    assert res["moved"] == len(rows)
    assert svc.cache.generation(src) > gens[src]
    assert svc.cache.generation(dst) > gens[dst]
    assert svc.cache.generation(untouched) == gens[untouched]
    assert svc.cache.generation(_MERGED_SHARD) > merged_gen
    svc.rebalance()  # drain so the service ends in a steady state
    assert not svc.migration_active


def test_inflight_migration_serves_and_mutates_exactly():
    base = _unique_triples(5, n_edges=100)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=3,
                                     strategy="node_range", delta_budget=None,
                                     rebalance_skew=None)
    burst = np.unique(np.stack([np.full(36, 3), np.arange(36) % N_PREDS,
                                np.arange(36) % N_NODES], axis=1), axis=0)
    svc.insert_triples(burst)
    logical = {tuple(map(int, r)) for r in _logical(svc)}
    res = svc.rebalance(force=True, max_moves=5)
    assert svc.migration_active and res["pending"] > 0
    # queries are exact mid-migration (ownership-changing patterns scatter)
    probes = np.concatenate([base[:2], burst[:2]])
    _assert_parity(svc, np.array(sorted(logical)), probes)

    # delete a row that is still pending migration: it must not resurrect
    pending = svc._migration.pending_moves()
    victim = pending[0][2][:1]
    assert svc.delete_triples(victim) == 1
    logical.discard(tuple(map(int, victim[0])))

    # insert a row whose ownership is changing mid-flight: lands once
    moving_mask = diff_plans(svc.plan, svc._migration.new_plan,
                             np.array(sorted(logical)))
    fresh = None
    for s in range(N_NODES):
        for o in range(N_NODES):
            cand = (s, 0, o)
            if cand not in logical and \
                    svc.plan.route(s, -1, -1) != \
                    svc._migration.new_plan.route(s, -1, -1):
                fresh = cand
                break
        if fresh:
            break
    if fresh is not None:
        assert svc.insert_triples(np.array([fresh])) == 1
        assert svc.insert_triples(np.array([fresh])) == 0  # exactly-once
        logical.add(fresh)
    assert moving_mask.shape  # silence linters; mask exercised diff_plans

    svc.rebalance()  # drain
    assert not svc.migration_active
    logical_rows = np.array(sorted(logical))
    _assert_parity(svc, logical_rows, probes)
    vs, vp, vo = map(int, victim[0])
    assert (vp, (vs, vo)) not in svc.query(vs, vp, vo)  # stayed deleted
    # every row sits exactly where the adopted plan says
    assert sum(svc.live_edges()) == len(logical)
    for k, e in enumerate(svc.engines):
        rows = e.current_triples()
        if len(rows):
            assert (svc.plan.triple_shards(rows) == k).all()


def test_auto_rebalance_triggers_from_mutation_path():
    base = _unique_triples(6, n_edges=80)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=3,
                                     strategy="node_range", delta_budget=None,
                                     rebalance_skew=1.2)
    assert svc.rebalance_skew == 1.2
    # keep inserting into one subject range until the trigger fires
    rng = np.random.default_rng(0)
    hot_lo, hot_hi = int(svc.plan.boundaries[0]), int(svc.plan.boundaries[1])
    for _ in range(12):
        rows = np.stack([rng.integers(hot_lo, max(hot_hi, hot_lo + 1), 15),
                         rng.integers(0, N_PREDS, 15),
                         rng.integers(0, N_NODES, 15)], axis=1)
        svc.insert_triples(rows)
        if svc.stats.rebalances:
            break
    assert svc.stats.rebalances >= 1
    assert svc.stats.migrated_rows > 0
    # auto moves are bounded per call; at this scale one chunk drains all
    assert not svc.migration_active
    probes = _logical(svc)[:3]
    _assert_parity(svc, _logical(svc), probes)


def test_auto_rebalance_futility_backoff():
    """Structurally stuck skew (one predicate, many shards) must not cost
    a plan computation on every mutation: the first futile attempt arms
    the backoff."""
    base = np.unique(np.stack([np.arange(40) % N_NODES, np.zeros(40, np.int64),
                               (np.arange(40) * 7) % N_NODES], axis=1), axis=0)
    svc = ShardedTripleService.build(base, N_NODES, 1, n_shards=4,
                                     strategy="predicate_hash",
                                     delta_budget=None, rebalance_skew=1.5)
    assert svc.skew() == 4.0  # all rows on the single predicate's shard
    svc.insert_triples(np.array([[1, 0, 20]]))
    assert svc.stats.rebalances == 0       # attempt found nothing to move
    assert svc._futile_total is not None   # ...and armed the backoff
    anchor = svc._futile_total
    svc.insert_triples(np.array([[2, 0, 21]]))
    assert svc._futile_total == anchor     # no re-attempt within the band
    _assert_parity(svc, _logical(svc), _logical(svc)[:2])


# ------------------------------------------------------- empty-shard cases
def test_empty_shard_serves_rebuilds_and_receives_rows_node_range():
    rng = np.random.default_rng(7)
    triples = np.unique(np.stack([np.repeat(np.arange(18), 4),
                                  rng.integers(0, N_PREDS, 72),
                                  rng.integers(0, N_NODES, 72)], axis=1),
                        axis=0)
    svc = ShardedTripleService.build(triples, N_NODES, N_PREDS, n_shards=3,
                                     strategy="node_range", delta_budget=None,
                                     rebalance_skew=None)
    victim = 1
    owned = svc.engines[victim].current_triples()
    assert len(owned) > 0
    assert svc.delete_triples(owned) == len(owned)
    assert svc.live_edges()[victim] == 0
    # the empty shard serves empty results without error, owned + scattered
    s_mid = int(svc.plan.boundaries[victim])
    assert list(svc.query(s_mid, None, None)) == []
    logical = _logical(svc)
    _assert_parity(svc, logical, np.concatenate([logical[:2], owned[:1]]))
    # rebuild folds the all-tombstone overlay into an empty grammar
    rebuilt = svc.rebuild(shard=victim, force=True)
    assert rebuilt == [victim] and svc.delta_sizes()[victim] == 0
    assert svc.live_edges()[victim] == 0
    assert list(svc.query(s_mid, None, None)) == []
    # rebalancing re-cuts the boundaries and hands the empty shard rows
    res = svc.rebalance(force=True)
    assert res["moved"] > 0
    assert svc.live_edges()[victim] > 0
    _assert_parity(svc, logical, logical[:2])


def test_empty_shard_serves_and_rebalances_predicate_hash():
    base = _unique_triples(8, n_edges=90, n_preds=3)
    svc = ShardedTripleService.build(base, N_NODES, 3, n_shards=2,
                                     strategy="predicate_hash",
                                     delta_budget=None, rebalance_skew=None)
    # empty one predicate group entirely -> its shard may go empty
    assign = svc.plan.pred_assignment()
    victim_pred = next(p for p in range(3)
                       if (assign == assign[p]).sum() == 1)
    victim = int(assign[victim_pred])
    dead = base[base[:, 1] == victim_pred]
    svc.delete_triples(dead)
    assert svc.live_edges()[victim] == 0
    assert list(svc.query(None, victim_pred, None)) == []
    logical = _logical(svc)
    _assert_parity(svc, logical, logical[:2])
    res = svc.rebalance(force=True)  # LPT re-packs live groups onto it
    assert res["moved"] > 0 and svc.live_edges()[victim] > 0
    _assert_parity(svc, logical, logical[:2])
