"""Training substrate (optimizer, checkpoint/restart, compression, fault
tolerance) + serving engine tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tf_mod
from repro.serve import ServeEngine
from repro.train import (
    AdamWConfig,
    CompressionConfig,
    ElasticPlan,
    FailureInjector,
    HeartbeatMonitor,
    StragglerDetector,
    Trainer,
    TrainerConfig,
    WorkerFailure,
    adamw_update,
    compress_int8,
    compress_topk,
    data_skip_offset,
    init_opt_state,
    init_residual,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    schedule,
)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array([2.0])}
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0, total_steps=200)
    opt = init_opt_state(params, cfg)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss_fn(params)) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(schedule(jnp.asarray(0), cfg)) == 0.0
    assert abs(float(schedule(jnp.asarray(10), cfg)) - 1.0) < 1e-6
    assert float(schedule(jnp.asarray(110), cfg)) == pytest.approx(0.1, rel=1e-3)


def test_sgd_paths_have_no_moments():
    params = {"tables": {"t0": jnp.ones((4, 2))}, "mlp": {"w": jnp.ones((2, 2))}}
    cfg = AdamWConfig(sgd_paths=("tables",), lr=0.5, warmup_steps=0,
                      weight_decay=0.0, grad_clip=1e9)
    opt = init_opt_state(params, cfg)
    assert opt["m"]["tables"]["t0"] is None
    assert opt["m"]["mlp"]["w"] is not None
    g = jax.tree.map(jnp.ones_like, params)
    p2, opt2, _ = adamw_update(params, g, opt, cfg)
    # plain SGD on the table: p - lr*g exactly
    np.testing.assert_allclose(np.asarray(p2["tables"]["t0"]), 0.5, rtol=1e-5)


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.ones((3,))]}
    save_checkpoint(str(tmp_path), 7, tree)
    got, step = restore_checkpoint(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(got["a"], np.asarray(tree["a"]))
    np.testing.assert_array_equal(got["nested"]["b"].astype(np.float32),
                                  np.ones(4, np.float32))
    assert isinstance(got["lst"], list) and len(got["lst"]) == 2
    # no .tmp leftovers = atomic commit
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_crash_leaves_tmp_and_previous_survives(tmp_path, monkeypatch):
    """A save that dies mid-write must leave the previous checkpoint
    authoritative, and the next save must clean up the stale .tmp."""
    tree = {"w": jnp.arange(8).astype(jnp.float32)}
    save_checkpoint(str(tmp_path), 1, tree)

    real_save = np.save
    calls = {"n": 0}

    def dying_save(path, arr, **kw):
        calls["n"] += 1
        if calls["n"] == 2:  # second leaf of the step-2 save
            raise OSError("disk gone")
        real_save(path, arr, **kw)

    monkeypatch.setattr(np, "save", dying_save)
    tree2 = {"w": jnp.full((8,), 2.0), "b": jnp.ones((3,))}
    with pytest.raises(OSError, match="disk gone"):
        save_checkpoint(str(tmp_path), 2, tree2)
    monkeypatch.setattr(np, "save", real_save)

    # the aborted attempt is visible only as a .tmp; restore ignores it
    assert any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert latest_step(str(tmp_path)) == 1
    got, step = restore_checkpoint(str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(got["w"], np.arange(8, dtype=np.float32))

    # retrying the same step reuses the name: stale .tmp cleaned, commit ok
    save_checkpoint(str(tmp_path), 2, tree2)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    got, step = restore_checkpoint(str(tmp_path))
    assert step == 2
    np.testing.assert_array_equal(got["b"], np.ones(3, np.float32))


def test_checkpoint_exotic_dtype_views_roundtrip(tmp_path):
    """bf16/fp8 leaves serialize as integer views; restore must hand back
    the original dtype with bit-exact contents."""
    import ml_dtypes

    rng = np.random.default_rng(3)
    base = rng.standard_normal(16).astype(np.float32)
    tree = {
        "bf16": base.astype(ml_dtypes.bfloat16),
        "fp8_e4m3": base.astype(ml_dtypes.float8_e4m3fn),
        "fp8_e5m2": base.astype(ml_dtypes.float8_e5m2),
        "plain": base,
    }
    save_checkpoint(str(tmp_path), 3, tree)
    got, step = restore_checkpoint(str(tmp_path))
    assert step == 3
    for key, want in tree.items():
        assert got[key].dtype == want.dtype, key
        # bit-exact: compare through the integer view, not float equality
        view = {"bf16": np.uint16}.get(key, np.uint8)
        if key == "plain":
            np.testing.assert_array_equal(got[key], want)
        else:
            np.testing.assert_array_equal(got[key].view(view),
                                          want.view(view), err_msg=key)


def test_checkpoint_restart_resumes_training(tmp_path):
    cfg = get_arch("qwen2-1.5b").reduced()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    def loss_fn(p, batch):
        return tf_mod.forward_loss(p, batch["tokens"], batch["targets"], cfg)

    def data():
        while True:
            yield {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}

    tc = TrainerConfig(total_steps=6, checkpoint_every=3, log_every=2,
                       checkpoint_dir=str(tmp_path))
    t1 = Trainer(loss_fn, params, tc)
    t1.run(data(), steps=6)
    assert latest_step(str(tmp_path)) == 6

    # fresh trainer restores and continues from step 6
    t2 = Trainer(loss_fn, tf_mod.init_params(cfg, jax.random.PRNGKey(1)), tc)
    assert t2.maybe_restore()
    assert t2.step == 6
    log = t2.run(data(), steps=2)
    assert t2.step == 8
    # restored params equal saved params (not the fresh init)
    p_saved, _ = restore_checkpoint(str(tmp_path), 6)
    leaf_saved = jax.tree.leaves(p_saved["params"])[0]
    leaf_restored = jax.tree.leaves(t1.params)[0]
    np.testing.assert_allclose(np.asarray(leaf_saved, np.float32),
                               np.asarray(leaf_restored, np.float32))


def test_failure_inject_and_recover(tmp_path):
    params = {"w": jnp.array([4.0])}

    def loss_fn(p, batch):
        return jnp.sum((p["w"] - batch) ** 2)

    def data():
        while True:
            yield jnp.array([1.0])

    tc = TrainerConfig(total_steps=20, checkpoint_every=5, log_every=5,
                       checkpoint_dir=str(tmp_path),
                       opt=AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0))
    t = Trainer(loss_fn, params, tc, failure_injector=FailureInjector({12: [0]}))
    with pytest.raises(WorkerFailure):
        t.run(data())
    assert latest_step(str(tmp_path)) == 10
    # recovery: restore and finish — exactly-once data semantics via offset
    # (fresh init: the failed trainer's buffers were donated by its step fn)
    t2 = Trainer(loss_fn, {"w": jnp.array([4.0])}, tc)
    assert t2.maybe_restore() and t2.step == 10
    assert data_skip_offset(t2.step, global_batch=8) == 80
    t2.run(data(), steps=10)
    assert t2.step == 20


# ---------------------------------------------------------------- compression
def test_int8_error_feedback_preserves_signal():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    res = init_residual(g)
    # accumulate decoded grads over steps; with error feedback the sum of
    # decoded equals the sum of true grads up to one-step residual
    total_true = jnp.zeros((64, 64))
    total_dec = jnp.zeros((64, 64))
    for i in range(10):
        _, dec, res = compress_int8(g, res)
        total_true += g["w"]
        total_dec += dec["w"]
    err = jnp.abs(total_true - (total_dec + res["w"])).max()
    assert float(err) < 1e-4


def test_topk_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(1000,)), jnp.float32)}
    res = init_residual(g)
    total_true = jnp.zeros(1000)
    total_dec = jnp.zeros(1000)
    for _ in range(20):
        wire, dec, res = compress_topk(g, res, frac=0.05)
        total_true += g["w"]
        total_dec += dec["w"]
    # every coordinate eventually transmitted via error feedback
    err = jnp.abs(total_true - (total_dec + res["w"])).max()
    assert float(err) < 1e-4
    assert wire["w"][0].shape == (50,)


def test_compressed_training_converges(tmp_path):
    params = {"w": jnp.array([5.0, -3.0, 2.0])}

    def loss_fn(p, _):
        return jnp.sum(p["w"] ** 2)

    def data():
        while True:
            yield 0

    tc = TrainerConfig(total_steps=120, log_every=40, checkpoint_dir=None,
                       opt=AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0),
                       compression=CompressionConfig(codec="int8"))
    t = Trainer(loss_fn, params, tc)
    t.run(data())
    assert float(loss_fn(t.params, 0)) < 1e-2


# ---------------------------------------------------------------- ft units
def test_straggler_detector():
    d = StragglerDetector(threshold=2.0, warmup_steps=3)
    for _ in range(10):
        assert not d.observe(0, 1.0)
    assert d.observe(1, 5.0)  # 5x the EWMA
    assert d.flagged and d.flagged[0][0] == 1


def test_heartbeat_monitor():
    h = HeartbeatMonitor(timeout_s=10)
    h.beat(0, now=0.0)
    h.beat(1, now=0.0)
    h.beat(0, now=8.0)
    assert h.dead_workers(now=12.0) == [1]


def test_elastic_plan():
    assert ElasticPlan(n_devices=240, model_axis=16).new_mesh_shape() == (15, 16)
    with pytest.raises(RuntimeError):
        ElasticPlan(n_devices=8, model_axis=16).new_mesh_shape()


# ---------------------------------------------------------------- serving
def test_serve_engine_greedy_matches_forward():
    cfg = get_arch("qwen2-1.5b").reduced()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(3))
    eng = ServeEngine(params, cfg, max_len=48)
    prompts = [[5, 6, 7], [8, 9, 10, 11]]
    res = eng.generate(prompts, max_new_tokens=4, temperature=0.0)
    assert res.tokens.shape == (2, 4)
    assert res.n_generated.min() >= 1
    # greedy decode is deterministic
    res2 = eng.generate(prompts, max_new_tokens=4, temperature=0.0)
    np.testing.assert_array_equal(res.tokens, res2.tokens)


def test_serve_engine_eos_stops():
    cfg = get_arch("qwen2-1.5b").reduced()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(4))
    eng = ServeEngine(params, cfg, max_len=32, eos_id=1)
    res = eng.generate([[3, 4]], max_new_tokens=8, temperature=0.0)
    assert res.tokens.shape[1] <= 8
