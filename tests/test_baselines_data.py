"""Baselines (k2-triples, HDT-BT) parity + data layer (synthetic, rdf,
GraphStore, sampler)."""
import numpy as np
import pytest

from repro.baselines import HDTBitmapTriples, K2Triples, ntriples_size_bytes
from repro.core import Hypergraph, LabelTable, query_oracle
from repro.data import (
    GraphStore,
    NeighborSampler,
    parse_ntriples,
    rdf_like,
    version_graph,
    web_graph,
    write_ntriples,
)

PATTERNS = ["spo", "sp?", "s?o", "s??", "?po", "?p?", "??o", "???"]


def _bind(pattern, s, p, o):
    return (
        s if pattern[0] == "s" else None,
        p if pattern[1] == "p" else None,
        o if pattern[2] == "o" else None,
    )


@pytest.fixture(scope="module")
def small_rdf():
    ds = rdf_like(n_nodes=80, n_edges=300, n_preds=5, seed=1)
    return ds


def test_baseline_query_parity(small_rdf):
    ds = small_rdf
    table = LabelTable.terminals([2] * ds.n_preds)
    g = Hypergraph.from_triples(ds.triples, ds.n_nodes)
    k2 = K2Triples(ds.triples, ds.n_nodes, ds.n_preds)
    hdt = HDTBitmapTriples(ds.triples, ds.n_nodes, ds.n_preds)
    rng = np.random.default_rng(0)
    for _ in range(5):
        t = ds.triples[rng.integers(0, len(ds.triples))]
        s, p, o = int(t[0]), int(t[1]), int(t[2])
        for pattern in PATTERNS:
            qs, qp, qo = _bind(pattern, s, p, o)
            want = sorted(query_oracle(g, qs, qp, qo))
            assert sorted(k2.query(qs, qp, qo)) == want, f"k2 {pattern}"
            assert sorted(hdt.query(qs, qp, qo)) == want, f"hdt {pattern}"


def test_baseline_sizes_positive(small_rdf):
    ds = small_rdf
    k2 = K2Triples(ds.triples, ds.n_nodes, ds.n_preds)
    hdt = HDTBitmapTriples(ds.triples, ds.n_nodes, ds.n_preds)
    raw = ntriples_size_bytes(ds.triples)
    assert 0 < k2.size_in_bytes() < raw
    assert 0 < hdt.size_in_bytes() < raw


def test_synthetic_generators_shapes():
    for ds in [rdf_like(seed=2), web_graph(seed=2), version_graph(seed=2)]:
        assert ds.n_triples > 0
        assert ds.triples.shape[1] == 3
        assert ds.triples[:, 0].max() < ds.n_nodes
        assert ds.triples[:, 1].max() < ds.n_preds
        assert ds.triples[:, 2].max() < ds.n_nodes
        # deduplicated
        assert len(np.unique(ds.triples, axis=0)) == ds.n_triples
    vg = version_graph(seed=3)
    assert vg.node_labels is not None and (vg.node_labels >= 0).any()


def test_ntriples_roundtrip(tmp_path):
    ds = rdf_like(n_nodes=40, n_edges=100, n_preds=3, seed=5)
    path = tmp_path / "g.nt"
    write_ntriples(str(path), ds.triples)
    triples, node_names, pred_names, report = parse_ntriples(str(path))
    assert len(triples) == ds.n_triples
    assert report.malformed == 0 and report.statements == ds.n_triples
    # ids are assigned in file order; compare as string triple sets
    orig = {(f"<http://ex.org/n{s}>", f"<http://ex.org/p{p}>", f"<http://ex.org/n{o}>")
            for s, p, o in ds.triples}
    got = {(node_names[s], pred_names[p], node_names[o]) for s, p, o in triples}
    assert got == orig


def test_graph_store_roundtrip_and_queries():
    ds = rdf_like(n_nodes=60, n_edges=200, n_preds=4, seed=7)
    store = GraphStore.from_triples(ds.triples, ds.n_nodes, ds.n_preds)
    g = Hypergraph.from_triples(ds.triples, ds.n_nodes)
    # compressed neighborhood queries match a scan
    for v in np.unique(ds.triples[:, 0])[:10]:
        want = np.unique(ds.triples[ds.triples[:, 0] == v, 2])
        assert np.array_equal(store.neighbors_out(int(v)), want)
    # CSR view matches the triple multiset
    indptr, indices = store.csr()
    assert indptr[-1] == ds.n_triples
    senders, receivers = store.edge_index()
    got = sorted(zip(senders.tolist(), receivers.tolist()))
    want = sorted(zip(ds.triples[:, 0].tolist(), ds.triples[:, 2].tolist()))
    assert got == want


def test_neighbor_sampler_fanout():
    ds = web_graph(n_nodes=500, n_edges=3000, seed=9)
    store = GraphStore.from_triples(ds.triples, ds.n_nodes, ds.n_preds)
    indptr, indices = store.csc()  # sample in-neighbors
    sampler = NeighborSampler(indptr, indices, fanouts=(15, 10))
    rng = np.random.default_rng(0)
    seeds = rng.choice(ds.n_nodes, 32, replace=False)
    batch = sampler.sample(seeds, rng)
    assert len(batch.blocks) == 2
    assert len(batch.node_ids) >= len(seeds)
    for blk, fan in zip(batch.blocks, (15, 10)):
        assert len(blk.senders) == len(blk.receivers)
        # every sampled edge is a real edge of the graph
    # fanout bound: per receiver at most `fanout` sampled in-neighbors
    blk = batch.blocks[0]
    if len(blk.receivers):
        _, counts = np.unique(blk.receivers, return_counts=True)
        assert counts.max() <= 15
