"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.segment_matmul import build_csr_blocks


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d",
    [
        (1, 4, 4, 128, 128, 64),     # MHA square
        (2, 8, 2, 128, 128, 64),     # GQA 4:1
        (1, 4, 1, 64, 256, 32),      # MQA decode-ish (Sq < Sk)
        (1, 2, 2, 256, 256, 128),
    ],
)
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [None, 64, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_window_softcap(window, softcap):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window, softcap=softcap,
                              block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- spmm
@pytest.mark.parametrize("n,e,d", [(200, 1000, 64), (777, 3000, 128), (64, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_csr_spmm_sweep(n, e, d, dtype):
    rng = np.random.default_rng(3)
    senders = rng.integers(0, n, e)
    receivers = rng.integers(0, n, e)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    src_idx, local_dst = build_csr_blocks(senders, receivers, n, block_n=128)
    got = ops.csr_spmm(x, jnp.asarray(src_idx), jnp.asarray(local_dst), n)
    # kernel accumulates in fp32 (MXU preferred type); compare against an
    # fp32-accumulated oracle, cast back to the kernel's output dtype
    want = ref.spmm_ref(x.astype(jnp.float32), jnp.asarray(senders), jnp.asarray(receivers), n).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **(_tol(dtype) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)),
    )


def test_csr_spmm_isolated_nodes():
    n = 300
    senders = np.array([0, 1, 2])
    receivers = np.array([5, 5, 7])
    x = jnp.ones((n, 128), jnp.float32)
    src_idx, local_dst = build_csr_blocks(senders, receivers, n)
    got = ops.csr_spmm(x, jnp.asarray(src_idx), jnp.asarray(local_dst), n)
    assert float(got[5, 0]) == 2.0 and float(got[7, 0]) == 1.0
    assert float(jnp.abs(got).sum()) == 3 * 128


# ---------------------------------------------------------------- embedding bag
@pytest.mark.parametrize("v,d,b,l", [(1000, 64, 256, 1), (5000, 128, 128, 8), (64, 256, 256, 3)])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_sweep(v, d, b, l, combiner):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    idx = rng.integers(0, v, (b, l))
    idx[rng.random((b, l)) < 0.2] = -1  # ragged bags
    idx = jnp.asarray(idx, jnp.int32)
    got = ops.embedding_bag(table, idx, combiner=combiner)
    want = ref.embedding_bag_ref(table, idx, combiner=combiner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- dot interaction
@pytest.mark.parametrize("b,f,d", [(128, 27, 128), (256, 8, 64), (128, 4, 16)])
def test_dot_interaction_sweep(b, f, d):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(b, f, d)), jnp.float32)
    got = ops.dot_interaction(x)
    want = ref.dot_interaction_ref(x)
    assert got.shape == (b, f * (f - 1) // 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- digram count
@pytest.mark.parametrize("n,k", [(256, 4), (512, 8), (256, 16)])
def test_digram_pair_counts_sweep(n, k):
    rng = np.random.default_rng(6)
    its = rng.integers(0, 50, (n, k)).astype(np.int32)
    cnts = rng.integers(1, 10, (n, k)).astype(np.int32)
    pad = rng.random((n, k)) < 0.3
    its[pad] = -1
    cnts[pad] = 0
    got_lo, got_hi, got_c = ops.digram_pair_counts(jnp.asarray(its), jnp.asarray(cnts))
    want_lo, want_hi, want_c = ref.digram_pair_counts_ref(jnp.asarray(its), jnp.asarray(cnts))
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    valid = np.asarray(got_c) > 0
    np.testing.assert_array_equal(np.asarray(got_lo)[valid], np.asarray(want_lo)[valid])
    np.testing.assert_array_equal(np.asarray(got_hi)[valid], np.asarray(want_hi)[valid])


def test_digram_kernel_matches_host_counter():
    """Kernel output aggregated over nodes == repro.core.digram counts."""
    from repro.core import digram_counts
    from repro.core.digram import node_it_counts
    from tests.test_itr_core import random_hypergraph

    rng = np.random.default_rng(7)
    g, table = random_hypergraph(rng, n_nodes=30, n_edges=100)
    v, it, c = node_it_counts(g, table)
    # build padded per-node (K) arrays
    k = 16
    uniq, inv = np.unique(v, return_inverse=True)
    its = np.full((len(uniq), k), -1, np.int32)
    cs = np.zeros((len(uniq), k), np.int32)
    slot = np.zeros(len(uniq), np.int64)
    for row, (node_i, it_i, c_i) in enumerate(zip(inv, it, c)):
        its[node_i, slot[node_i]] = it_i
        cs[node_i, slot[node_i]] = c_i
        slot[node_i] += 1
    n_pad = ((len(uniq) + 255) // 256) * 256
    its = np.pad(its, ((0, n_pad - len(uniq)), (0, 0)), constant_values=-1)
    cs = np.pad(cs, ((0, n_pad - len(uniq)), (0, 0)))
    lo, hi, cnt = ops.digram_pair_counts(jnp.asarray(its), jnp.asarray(cs))
    lo, hi, cnt = np.asarray(lo), np.asarray(hi), np.asarray(cnt)
    sel = cnt > 0
    keys = (lo[sel].astype(np.int64) << 32) | hi[sel].astype(np.int64)
    agg = {}
    for kk, cc in zip(keys.tolist(), cnt[sel].tolist()):
        agg[kk] = agg.get(kk, 0) + cc
    want_keys, want_cnts = digram_counts(g, table, cap=None)
    assert agg == dict(zip(want_keys.tolist(), want_cnts.tolist()))


# ---------------------------------------------------------------- bitvec rank
@pytest.mark.parametrize("nbits,q", [(4096, 1024), (100_000, 2048)])
def test_bitvec_rank_sweep(nbits, q):
    from repro.core.succinct import BitVector

    rng = np.random.default_rng(8)
    bits = rng.integers(0, 2, nbits).astype(np.uint8)
    bv = BitVector(bits)
    pos = rng.integers(0, nbits, q).astype(np.int32)
    words = jnp.asarray(bv.words)
    ranks = jnp.asarray(bv.word_ranks[:-1].astype(np.int32))
    got = ops.bitvec_rank(words, ranks, jnp.asarray(pos))
    want = bv.rank1(pos.astype(np.int64))
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.int32))
    # and the jnp ref oracle agrees too
    want2 = ref.bitvec_rank_ref(words, ranks, jnp.asarray(pos))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want2))
