"""Minimal stand-in for `hypothesis` when the real package is absent.

The container this repo targets does not ship hypothesis, and the tier-1
suite must still collect and run. `conftest.py` registers this module as
`hypothesis` ONLY when the real library fails to import, so environments
with hypothesis installed are untouched.

Scope: exactly the API surface the test-suite uses — `given`, `settings`,
and the `integers` / `booleans` / `sampled_from` / `lists` / `tuples`
strategies. Examples are drawn from a per-test deterministic RNG (seeded by
the test name) so failures are reproducible; there is no shrinking.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # noqa: N801 — mirrors `hypothesis.strategies` module
    @staticmethod
    def integers(min_value=0, max_value=1 << 31):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)

    @staticmethod
    def tuples(*elems: _Strategy):
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator: record the example budget on the (given-wrapped) test."""

    def apply(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return apply


def given(*strats: _Strategy):
    """Decorator: run the test once per drawn example, deterministically."""

    def apply(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                args = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: args={args!r}"
                    ) from e

        functools.update_wrapper(wrapper, fn, updated=())
        del wrapper.__wrapped__  # keep inspect.signature() arity at zero args
        wrapper.__dict__.pop("_fallback_max_examples", None)
        return wrapper

    return apply
