"""Randomized BGP state machine, cross-checked against the brute-force
join oracle (_bgp_oracle.py).

Each example builds a random graph, then interleaves mutations /
rebuilds / forced rebalances with randomly generated 1–4 pattern BGPs —
chains, stars, cycles, cartesian products, and deliberately
unsatisfiable patterns — asserting binding-set equality against nested
loops over the plain triple set, for both partition strategies and
1/2/4 shards. Join planning, bind-vs-hash step modes, shard routing,
and the whole-BGP cache (including its generation-vector invalidation)
are all on the execution side of the comparison; the reference side is
pure Python over `set` semantics.

The tier-1 run keeps a small example budget; the nightly lane
(``pytest -m slow``, .github/workflows/nightly.yml) re-runs the machine
with a bigger budget via ``ITR_BGP_EXAMPLES``.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from _bgp_oracle import OracleBlowup, oracle_bgp
from repro.distributed.partition import STRATEGIES
from repro.serve.sharded import ShardedTripleService

# nightly lane budget for the @slow machine (tier-1 uses the small ones)
SLOW_EXAMPLES = int(os.environ.get("ITR_BGP_EXAMPLES", "40"))

# binding-set ceiling for the nested-loop oracle: a random BGP whose
# intermediate relation exceeds this is skipped (cartesian chains of
# all-variable patterns are honest but quadratic-to-quartic in Python)
_ORACLE_CAP = 30_000


def _rand_rows(rng, k, n_nodes, n_preds):
    return np.stack([rng.integers(0, n_nodes, k),
                     rng.integers(0, n_preds, k),
                     rng.integers(0, n_nodes, k)], axis=1)


def _rand_term(rng, n_vals, var_pool, p_const=0.45):
    """A constant (mostly in-range, sometimes absent-by-construction) or a
    variable drawn from / extending the pool."""
    if rng.random() < p_const:
        hi = n_vals + (3 if rng.random() < 0.15 else 0)  # some unsatisfiable
        return int(rng.integers(0, hi))
    if var_pool and rng.random() < 0.7:
        return var_pool[int(rng.integers(0, len(var_pool)))]
    var = f"?v{len(var_pool)}"
    var_pool.append(var)
    return var


def _rand_bgp(rng, n_nodes, n_preds):
    """1–4 patterns biased toward shared variables (chains/stars/cycles)
    with occasional disconnected patterns (cartesian products)."""
    n_pats = int(rng.integers(1, 5))
    var_pool: list[str] = []
    patterns = []
    for i in range(n_pats):
        # after the first pattern, mostly reuse variables so joins connect
        s = _rand_term(rng, n_nodes, var_pool)
        p = _rand_term(rng, n_preds, var_pool, p_const=0.75)
        o = _rand_term(rng, n_nodes, var_pool)
        patterns.append((s, p, o))
    if not any(isinstance(t, str) for pat in patterns for t in pat):
        patterns[-1] = (patterns[-1][0], patterns[-1][1], "?v_tail")
    return patterns


def _check_bgps(svc, oracle_set, rng, n_nodes, n_preds, n_bgps=2):
    for _ in range(n_bgps):
        bgp = _rand_bgp(rng, n_nodes, n_preds)
        try:
            want_vars, want = oracle_bgp(sorted(oracle_set), bgp,
                                         max_bindings=_ORACLE_CAP)
        except OracleBlowup:
            continue  # too big to verify in Python; draw another next round
        res = svc.query_bgp(bgp)
        assert list(res.vars) == list(want_vars), bgp
        assert res.tuples() == want, (
            bgp, svc.plan.strategy, svc.n_shards, len(want))


def _run_machine(seed: int, strategy: str, n_shards: int, *, n_ops=6,
                 n_nodes=14, n_preds=4, n_edges=45) -> None:
    rng = np.random.default_rng(seed)
    base = np.unique(_rand_rows(rng, n_edges, n_nodes, n_preds), axis=0)
    oracle = {tuple(map(int, r)) for r in base}
    svc = ShardedTripleService.build(
        base, n_nodes, n_preds, n_shards=n_shards, strategy=strategy,
        rebalance_skew=None)
    try:
        _check_bgps(svc, oracle, rng, n_nodes, n_preds)
        for _ in range(n_ops):
            op = int(rng.integers(0, 100))
            if op < 30:  # insert fresh + duplicate rows
                ins = _rand_rows(rng, int(rng.integers(1, 7)),
                                 n_nodes, n_preds)
                svc.insert_triples(ins)
                oracle |= {tuple(map(int, r)) for r in ins}
            elif op < 55:  # delete live + absent rows
                pool = sorted(oracle)
                picks = [list(pool[int(rng.integers(0, len(pool)))])
                         for _ in range(int(rng.integers(1, 6)))] if pool else []
                picks += _rand_rows(rng, 2, n_nodes, n_preds).tolist()
                dels = np.asarray(picks, dtype=np.int64)
                svc.delete_triples(dels)
                oracle -= {tuple(map(int, r)) for r in dels}
            elif op < 80:  # random BGPs vs the oracle (cache warm + cold)
                _check_bgps(svc, oracle, rng, n_nodes, n_preds)
            elif op < 90:
                svc.rebalance(force=True)
            else:
                svc.rebuild(force=True)
        # quiesced closing checks, repeated so the second pass exercises
        # warm whole-BGP cache entries against the same oracle
        _check_bgps(svc, oracle, rng, n_nodes, n_preds, n_bgps=3)
    finally:
        svc.close()


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10**9))
def test_bgp_oracle_state_machine(seed):
    """Random BGPs interleaved with mutations/rebuilds/rebalances: exact
    bindings for every strategy and shard count."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            _run_machine(int(rng.integers(0, 2**31)), strategy, n_shards)


@pytest.mark.slow
@settings(max_examples=SLOW_EXAMPLES, deadline=None)
@given(st.integers(0, 10**9))
def test_bgp_oracle_state_machine_slow(seed):
    """Nightly-budget version: more ops and bigger graphs
    (ITR_BGP_EXAMPLES; see the nightly workflow lane)."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            _run_machine(int(rng.integers(0, 2**31)), strategy, n_shards,
                         n_ops=12, n_nodes=20, n_edges=90)
