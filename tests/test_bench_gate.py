"""CI benchmark-regression gate: metric flattening, tolerance directions,
baseline recording (`benchmarks.run --smoke --check`)."""
import json

from benchmarks.run import (
    check_regressions,
    conservative_envelope,
    gate_metrics,
    update_baseline,
    update_baseline_from,
)


def _mini_bench(speedup=10.0, dispatch=1.0, warm=5.0, view=4.0, sg=2.0,
                skew=0.5, full_mig=3.0):
    return {
        "patterns": {"s??": {"speedup_vs_scalar": speedup}},
        "warm_cache": {
            "patterns": {"?p?": {"warm_speedup_vs_uncached": warm}},
            "point_lookup": {"warm_speedup": 20.0},
        },
        "crossover_dispatch": {
            "patterns": {"spo": {"dispatched_vs_scalar": dispatch}}},
        "sharded": {
            "warm_view": {"speedup_vs_materialized": view},
            "scatter_gather": {"?p?": {"sharded_vs_single": sg}},
        },
        "rebalance": {
            "skew_after_vs_before": skew,
            "full_vs_migration": full_mig,
        },
    }


def _write(tmp_path, smoke, baseline_metrics):
    smoke_p = tmp_path / "smoke.json"
    base_p = tmp_path / "baseline.json"
    smoke_p.write_text(json.dumps(smoke))
    base_p.write_text(json.dumps(
        {"smoke_baseline": {"metrics": baseline_metrics}}))
    return str(smoke_p), str(base_p)


def test_gate_metrics_flattening():
    m = gate_metrics(_mini_bench())
    assert m["patterns.s??.speedup_vs_scalar"] == 10.0
    assert m["warm_cache.?p?.warm_speedup_vs_uncached"] == 5.0
    assert m["warm_cache.point_lookup.warm_speedup"] == 20.0
    assert m["crossover_dispatch.spo.dispatched_vs_scalar"] == 1.0
    assert m["sharded.warm_view.speedup_vs_materialized"] == 4.0
    assert m["sharded.scatter_gather.?p?.sharded_vs_single"] == 2.0
    assert m["rebalance.skew_after_vs_before"] == 0.5
    assert m["rebalance.full_vs_migration"] == 3.0
    assert gate_metrics({}) == {}  # sections all optional


def test_gate_rebalance_metric_directions(tmp_path):
    # skew ratio is lower-is-better: 0.5 -> 2.0 exceeds the 3x ceiling
    # (bound is 0.5 * 3 = 1.5); full_vs_migration is higher-is-better:
    # 3.0 -> 0.5 falls through the 3.0 / 3 floor
    smoke = _mini_bench(skew=2.0, full_mig=0.5)
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 2
    smoke = _mini_bench(skew=1.4, full_mig=1.1)  # inside tolerance both ways
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 0


def test_gate_passes_within_tolerance(tmp_path):
    # everything drifted by 2x in the bad direction — inside 3x tolerance
    smoke = _mini_bench(speedup=5.0, dispatch=2.0, warm=2.5, view=2.0, sg=4.0)
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 0


def test_gate_fails_on_higher_is_better_collapse(tmp_path):
    smoke = _mini_bench(speedup=2.0)  # 10 -> 2 is past the 10/3 floor
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 1


def test_gate_fails_on_lower_is_better_blowup(tmp_path):
    # dispatch ratio 1.0 -> 4.0 exceeds the 3x ceiling; scatter 2.0 -> 7.0 too
    smoke = _mini_bench(dispatch=4.0, sg=7.0)
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 2


def test_gate_skips_new_smoke_metrics_without_baseline(tmp_path):
    base = gate_metrics(_mini_bench())
    for k in list(base):  # baseline predates the sharded section
        if k.startswith("sharded."):
            del base[k]
    sp, bp = _write(tmp_path, _mini_bench(), base)
    assert check_regressions(sp, bp, tolerance=3.0) == 0


def test_gate_fails_when_baseline_metric_vanishes_from_smoke(tmp_path):
    """A gated section disappearing from the smoke output (renamed/dropped
    key) must FAIL, not silently skip — that's a coverage loss."""
    smoke = _mini_bench()
    del smoke["sharded"]  # 2 baseline metrics no longer emitted
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 2


def test_gate_uses_recorded_tolerance_by_default(tmp_path):
    smoke = _mini_bench(speedup=2.5)  # 4x collapse: outside 3x, inside 5x
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(smoke))
    bp.write_text(json.dumps({"smoke_baseline": {
        "tolerance": 5.0, "metrics": gate_metrics(_mini_bench())}}))
    assert check_regressions(str(sp), str(bp)) == 0       # recorded 5x wins
    assert check_regressions(str(sp), str(bp), tolerance=3.0) == 1  # override


def test_gate_errors_without_baseline_section(tmp_path):
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(_mini_bench()))
    bp.write_text(json.dumps({"patterns": {}}))  # no smoke_baseline
    assert check_regressions(str(sp), str(bp), tolerance=3.0) == 1


def test_update_baseline_roundtrip(tmp_path):
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(_mini_bench()))
    bp.write_text(json.dumps({"patterns": {"keep": {"speedup_vs_scalar": 1.0}}}))
    update_baseline(str(sp), str(bp), tolerance=3.0)
    doc = json.loads(bp.read_text())
    assert doc["patterns"] == {"keep": {"speedup_vs_scalar": 1.0}}  # merged, not replaced
    assert doc["smoke_baseline"]["metrics"] == gate_metrics(_mini_bench())
    # a freshly recorded baseline always gates green against itself
    assert check_regressions(str(sp), str(bp), tolerance=3.0) == 0


def test_conservative_envelope_takes_worst_side():
    runs = [gate_metrics(_mini_bench(speedup=10.0, dispatch=1.0)),
            gate_metrics(_mini_bench(speedup=4.0, dispatch=2.5)),
            gate_metrics(_mini_bench(speedup=7.0, dispatch=1.5))]
    env = conservative_envelope(runs)
    assert env["patterns.s??.speedup_vs_scalar"] == 4.0       # min: higher-better
    assert env["crossover_dispatch.spo.dispatched_vs_scalar"] == 2.5  # max
    # a metric missing from some runs still lands in the envelope
    partial = dict(runs[0])
    del partial["patterns.s??.speedup_vs_scalar"]
    assert "patterns.s??.speedup_vs_scalar" in conservative_envelope([partial, runs[1]])


def test_update_baseline_from_envelope_gates_noise_green(tmp_path):
    """Every run that contributed to the envelope must gate green against
    it — the envelope is exactly the worst side seen."""
    noisy = [_mini_bench(speedup=9.0, warm=1.8), _mini_bench(speedup=3.5, warm=6.0)]
    bp = tmp_path / "baseline.json"
    bp.write_text(json.dumps({}))
    update_baseline_from(noisy, str(bp), tolerance=3.0)
    assert json.loads(bp.read_text())["smoke_baseline"]["runs"] == 2
    for bench in noisy:
        sp = tmp_path / "smoke.json"
        sp.write_text(json.dumps(bench))
        assert check_regressions(str(sp), str(bp)) == 0


def test_update_baseline_keeps_custom_tolerance(tmp_path):
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(_mini_bench()))
    bp.write_text(json.dumps({}))
    update_baseline(str(sp), str(bp), tolerance=5.0)
    update_baseline(str(sp), str(bp))  # refresh without --tolerance
    assert json.loads(bp.read_text())["smoke_baseline"]["tolerance"] == 5.0
    update_baseline(str(sp), str(bp), tolerance=2.0)  # explicit override
    assert json.loads(bp.read_text())["smoke_baseline"]["tolerance"] == 2.0
