"""CI benchmark-regression gate: metric flattening, tolerance directions,
baseline recording (`benchmarks.run --smoke --check`)."""
import json

from benchmarks.run import (
    check_regressions,
    conservative_envelope,
    gate_metrics,
    update_baseline,
    update_baseline_from,
)


def _mini_bench(speedup=10.0, dispatch=1.0, warm=5.0, view=4.0, sg=2.0,
                skew=0.5, full_mig=3.0, cold=6.0):
    return {
        "patterns": {"s??": {"speedup_vs_scalar": speedup}},
        "recovery": {"cold_start_speedup": cold,
                     "wal_replay_records_per_s": 1000.0},
        "warm_cache": {
            "patterns": {"?p?": {"warm_speedup_vs_uncached": warm}},
            "point_lookup": {"warm_speedup": 20.0},
        },
        "crossover_dispatch": {
            "patterns": {"spo": {"dispatched_vs_scalar": dispatch}}},
        "sharded": {
            "warm_view": {"speedup_vs_materialized": view},
            "scatter_gather": {"?p?": {"sharded_vs_single": sg}},
        },
        "rebalance": {
            "skew_after_vs_before": skew,
            "full_vs_migration": full_mig,
        },
    }


def _write(tmp_path, smoke, baseline_metrics):
    smoke_p = tmp_path / "smoke.json"
    base_p = tmp_path / "baseline.json"
    smoke_p.write_text(json.dumps(smoke))
    base_p.write_text(json.dumps(
        {"smoke_baseline": {"metrics": baseline_metrics}}))
    return str(smoke_p), str(base_p)


def test_gate_metrics_flattening():
    m = gate_metrics(_mini_bench())
    assert m["patterns.s??.speedup_vs_scalar"] == 10.0
    assert m["warm_cache.?p?.warm_speedup_vs_uncached"] == 5.0
    assert m["warm_cache.point_lookup.warm_speedup"] == 20.0
    assert m["crossover_dispatch.spo.dispatched_vs_scalar"] == 1.0
    assert m["sharded.warm_view.speedup_vs_materialized"] == 4.0
    assert m["sharded.scatter_gather.?p?.sharded_vs_single"] == 2.0
    assert m["rebalance.skew_after_vs_before"] == 0.5
    assert m["rebalance.full_vs_migration"] == 3.0
    # cold-start speedup is gated; the absolute replay rate is not
    assert m["recovery.cold_start_speedup"] == 6.0
    assert "recovery.wal_replay_records_per_s" not in m
    assert gate_metrics({}) == {}  # sections all optional


def test_gate_rebalance_metric_directions(tmp_path):
    # skew ratio is lower-is-better: 0.5 -> 2.0 exceeds the 3x ceiling
    # (bound is 0.5 * 3 = 1.5); full_vs_migration is higher-is-better:
    # 3.0 -> 0.5 falls through the 3.0 / 3 floor
    smoke = _mini_bench(skew=2.0, full_mig=0.5)
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 2
    smoke = _mini_bench(skew=1.4, full_mig=1.1)  # inside tolerance both ways
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 0


def test_gate_passes_within_tolerance(tmp_path):
    # everything drifted by 2x in the bad direction — inside 3x tolerance
    smoke = _mini_bench(speedup=5.0, dispatch=2.0, warm=2.5, view=2.0, sg=4.0)
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 0


def test_gate_fails_on_higher_is_better_collapse(tmp_path):
    smoke = _mini_bench(speedup=2.0)  # 10 -> 2 is past the 10/3 floor
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 1


def test_gate_fails_on_lower_is_better_blowup(tmp_path):
    # dispatch ratio 1.0 -> 4.0 exceeds the 3x ceiling; scatter 2.0 -> 7.0 too
    smoke = _mini_bench(dispatch=4.0, sg=7.0)
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 2


def test_gate_skips_new_smoke_metrics_without_baseline(tmp_path):
    base = gate_metrics(_mini_bench())
    for k in list(base):  # baseline predates the sharded section
        if k.startswith("sharded."):
            del base[k]
    sp, bp = _write(tmp_path, _mini_bench(), base)
    assert check_regressions(sp, bp, tolerance=3.0) == 0


def test_gate_fails_when_baseline_metric_vanishes_from_smoke(tmp_path):
    """A gated section disappearing from the smoke output (renamed/dropped
    key) must FAIL, not silently skip — that's a coverage loss."""
    smoke = _mini_bench()
    del smoke["sharded"]  # 2 baseline metrics no longer emitted
    sp, bp = _write(tmp_path, smoke, gate_metrics(_mini_bench()))
    assert check_regressions(sp, bp, tolerance=3.0) == 2


def test_gate_uses_recorded_tolerance_by_default(tmp_path):
    smoke = _mini_bench(speedup=2.5)  # 4x collapse: outside 3x, inside 5x
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(smoke))
    bp.write_text(json.dumps({"smoke_baseline": {
        "tolerance": 5.0, "metrics": gate_metrics(_mini_bench())}}))
    assert check_regressions(str(sp), str(bp)) == 0       # recorded 5x wins
    assert check_regressions(str(sp), str(bp), tolerance=3.0) == 1  # override


def test_gate_errors_without_baseline_section(tmp_path):
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(_mini_bench()))
    bp.write_text(json.dumps({"patterns": {}}))  # no smoke_baseline
    assert check_regressions(str(sp), str(bp), tolerance=3.0) == 1


def test_gate_errors_are_actionable_not_tracebacks(tmp_path, capsys):
    """Every malformed input fails with one `gate ERROR` line telling the
    operator what to run — never a KeyError/JSONDecodeError traceback."""
    good_smoke = tmp_path / "smoke.json"
    good_smoke.write_text(json.dumps(_mini_bench()))
    good_base = tmp_path / "baseline.json"
    good_base.write_text(json.dumps(
        {"smoke_baseline": {"metrics": gate_metrics(_mini_bench())}}))

    def expect_error(sp, bp, needle):
        assert check_regressions(str(sp), str(bp), tolerance=3.0) == 1
        err = capsys.readouterr().err
        assert "gate ERROR" in err and needle in err, err

    # missing / corrupt files on either side
    expect_error(tmp_path / "absent.json", good_base, "not found")
    expect_error(good_smoke, tmp_path / "absent.json", "not found")
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    expect_error(bad, good_base, "not valid JSON")
    expect_error(good_smoke, bad, "not valid JSON")
    listdoc = tmp_path / "list.json"
    listdoc.write_text("[1, 2]")
    expect_error(listdoc, good_base, "JSON object")

    # baseline section damage: absent, metrics missing, metrics non-numeric
    bp = tmp_path / "b2.json"
    bp.write_text(json.dumps({"smoke_baseline": {"tolerance": 3.0}}))
    expect_error(good_smoke, bp, "no ")
    bp.write_text(json.dumps({"smoke_baseline": {"metrics": {
        "patterns.s??.speedup_vs_scalar": "fast"}}}))
    expect_error(good_smoke, bp, "must be numbers")

    # a smoke bench section that lost its expected metric key
    broken = _mini_bench()
    del broken["patterns"]["s??"]["speedup_vs_scalar"]
    broken["patterns"]["s??"]["latency_us"] = 3.0
    sp = tmp_path / "s2.json"
    sp.write_text(json.dumps(broken))
    expect_error(sp, good_base, "missing its")


def test_update_baseline_roundtrip(tmp_path):
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(_mini_bench()))
    bp.write_text(json.dumps({"patterns": {"keep": {"speedup_vs_scalar": 1.0}}}))
    update_baseline(str(sp), str(bp), tolerance=3.0)
    doc = json.loads(bp.read_text())
    assert doc["patterns"] == {"keep": {"speedup_vs_scalar": 1.0}}  # merged, not replaced
    assert doc["smoke_baseline"]["metrics"] == gate_metrics(_mini_bench())
    # a freshly recorded baseline always gates green against itself
    assert check_regressions(str(sp), str(bp), tolerance=3.0) == 0


def test_conservative_envelope_takes_worst_side():
    runs = [gate_metrics(_mini_bench(speedup=10.0, dispatch=1.0)),
            gate_metrics(_mini_bench(speedup=4.0, dispatch=2.5)),
            gate_metrics(_mini_bench(speedup=7.0, dispatch=1.5))]
    env = conservative_envelope(runs)
    assert env["patterns.s??.speedup_vs_scalar"] == 4.0       # min: higher-better
    assert env["crossover_dispatch.spo.dispatched_vs_scalar"] == 2.5  # max
    # a metric missing from some runs still lands in the envelope
    partial = dict(runs[0])
    del partial["patterns.s??.speedup_vs_scalar"]
    assert "patterns.s??.speedup_vs_scalar" in conservative_envelope([partial, runs[1]])


def test_update_baseline_from_envelope_gates_noise_green(tmp_path):
    """Every run that contributed to the envelope must gate green against
    it — the envelope is exactly the worst side seen."""
    noisy = [_mini_bench(speedup=9.0, warm=1.8), _mini_bench(speedup=3.5, warm=6.0)]
    bp = tmp_path / "baseline.json"
    bp.write_text(json.dumps({}))
    update_baseline_from(noisy, str(bp), tolerance=3.0)
    assert json.loads(bp.read_text())["smoke_baseline"]["runs"] == 2
    for bench in noisy:
        sp = tmp_path / "smoke.json"
        sp.write_text(json.dumps(bench))
        assert check_regressions(str(sp), str(bp)) == 0


def test_update_baseline_keeps_custom_tolerance(tmp_path):
    sp = tmp_path / "smoke.json"
    bp = tmp_path / "baseline.json"
    sp.write_text(json.dumps(_mini_bench()))
    bp.write_text(json.dumps({}))
    update_baseline(str(sp), str(bp), tolerance=5.0)
    update_baseline(str(sp), str(bp))  # refresh without --tolerance
    assert json.loads(bp.read_text())["smoke_baseline"]["tolerance"] == 5.0
    update_baseline(str(sp), str(bp), tolerance=2.0)  # explicit override
    assert json.loads(bp.read_text())["smoke_baseline"]["tolerance"] == 2.0
