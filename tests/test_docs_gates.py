"""The docs gates themselves: the real repo must pass them, and the
link checker must actually catch dead references (a gate that can't
fail guards nothing)."""

import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load(script):
    spec = importlib.util.spec_from_file_location(
        script.stem, script)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[script.stem] = mod
    spec.loader.exec_module(mod)
    return mod


links = _load(ROOT / "scripts" / "check_docs_links.py")
config = _load(ROOT / "scripts" / "check_docs_config.py")


def test_repo_has_no_dead_doc_links():
    assert links.dead_links(ROOT) == []


def test_repo_config_docs_cover_all_referenced_knobs():
    refs = config.referenced_vars(*(ROOT / d for d in config.SCAN_DIRS))
    documented = config.documented_vars(ROOT / "docs" / "CONFIG.md")
    assert set(refs) - documented == set()


def test_repo_config_docs_cover_all_workflow_knobs():
    # knobs only CI lanes set (nightly oracle budgets) are operational
    # surface too: every ITR_* in .github/workflows must be in CONFIG.md
    refs = config.workflow_vars(ROOT)
    assert refs  # the workflows do set ITR_* knobs — the scan sees them
    documented = config.documented_vars(ROOT / "docs" / "CONFIG.md")
    assert set(refs) - documented == set()


def test_config_gate_catches_workflow_only_undocumented_knob(tmp_path):
    root = _fake_repo(tmp_path, "readme\n")
    (root / "docs" / "CONFIG.md").write_text(
        "| `ITR_DOCUMENTED` | `1` | on |\n")
    wf = root / ".github" / "workflows"
    wf.mkdir(parents=True)
    (wf / "nightly.yml").write_text(
        "env:\n  ITR_DOCUMENTED: '1'\n  ITR_WORKFLOW_ONLY: '9'\n")
    refs = config.workflow_vars(root)
    assert sorted(refs) == ["ITR_DOCUMENTED", "ITR_WORKFLOW_ONLY"]
    assert refs["ITR_WORKFLOW_ONLY"] == [str(wf / "nightly.yml")]
    documented = config.documented_vars(root / "docs" / "CONFIG.md")
    assert set(refs) - documented == {"ITR_WORKFLOW_ONLY"}


def test_workflow_scan_tolerates_missing_workflows_dir(tmp_path):
    root = _fake_repo(tmp_path, "readme\n")
    assert config.workflow_vars(root) == {}


def _fake_repo(tmp_path, readme):
    (tmp_path / "docs").mkdir()
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "real.py").write_text("x = 1\n")
    (tmp_path / "README.md").write_text(readme)
    return tmp_path


def test_link_gate_catches_dead_markdown_link(tmp_path):
    root = _fake_repo(tmp_path, "see [docs](docs/MISSING.md) please\n")
    errs = links.dead_links(root)
    assert len(errs) == 1 and "docs/MISSING.md" in errs[0]


def test_link_gate_catches_missing_backtick_path(tmp_path):
    root = _fake_repo(
        tmp_path, "run `src/real.py` then `src/gone.py` and `state/out`\n")
    errs = links.dead_links(root)
    # `src/real.py` exists, `state/out` is not a scanned root, only
    # `src/gone.py` is a dead reference
    assert len(errs) == 1 and "src/gone.py" in errs[0]


def test_link_gate_accepts_anchors_urls_and_dirs(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "A.md").write_text(
        "[b](B.md#section) [self](#here) [web](https://x.invalid/y) `docs/`\n")
    (tmp_path / "docs" / "B.md").write_text("# b\n")
    (tmp_path / "README.md").write_text("[a](docs/A.md)\n")
    assert links.dead_links(tmp_path) == []


def test_link_gate_resolves_relative_to_containing_file(tmp_path):
    # docs/A.md linking CONFIG.md must resolve inside docs/, not the root
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "A.md").write_text("[c](CONFIG.md)\n")
    (tmp_path / "README.md").write_text("ok\n")
    errs = links.dead_links(tmp_path)
    assert len(errs) == 1 and "CONFIG.md" in errs[0]
    (tmp_path / "docs" / "CONFIG.md").write_text("# c\n")
    assert links.dead_links(tmp_path) == []


def test_link_gate_ignores_pytest_node_ids(tmp_path):
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_x.py").write_text("def test_a(): pass\n")
    _fake_repo(tmp_path, "pinned by `tests/test_x.py::test_a`\n")
    assert links.dead_links(tmp_path) == []
