"""Crash-point-injected recovery oracle for the durable sharded tier.

Two layers over `repro.persist`:

* a deterministic sweep that kills the service at EVERY injection point
  (WAL append/torn/post-append, snapshot write/pre-commit/post-commit,
  migration pre/mid-batch, engine rebuild), recovers from disk, and
  asserts the exact durability contract for that point — an operation
  acknowledged before the kill is fully recovered, one never
  acknowledged either fully recovered (its record was durable) or never
  happened, with no third state;
* a randomized state machine (the crash-point extension of
  `tests/test_rebalance_oracle.py`): random interleavings of durable
  mutations, queries, rebalances, snapshots, and rebuilds, with random
  crash schedules armed per op. Whenever a kill fires, the live instance
  is discarded, the service recovers via ``DurableShardedService.open``,
  the plain-Python set oracle re-synchronizes by probing one marker row
  (each mutation batch is one atomic WAL record, so one probe decides
  the whole batch), and all 8 query patterns must match the oracle.

The tier-1 run keeps a small example budget; the nightly crash lane
(``pytest -m slow``, see .github/workflows/nightly.yml) re-runs the
machine with a bigger budget via ``ITR_CRASH_EXAMPLES``.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.partition import STRATEGIES
from repro.persist.crash import CrashPoint, inject_crashes
from repro.persist.service import DurableShardedService

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]

# every injection point threaded through the durability paths
CRASH_POINTS = [
    "wal.append", "wal.torn", "wal.post_append",
    "snapshot.write_arrays", "snapshot.pre_commit", "snapshot.post_commit",
    "migrate.pre_apply", "migrate.mid_apply",
    "engine.rebuild",
]

# nightly crash-lane budget (tier-1 uses the small settings below)
SLOW_EXAMPLES = int(os.environ.get("ITR_CRASH_EXAMPLES", "40"))

N_NODES, N_PREDS = 16, 4


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


def _oracle_query(triples: set, s, p, o) -> list[tuple]:
    return sorted(
        (tp, (ts, to)) for ts, tp, to in triples
        if (s is None or ts == s) and (p is None or tp == p)
        and (o is None or to == o))


def _check_all_patterns(svc, oracle: set, probe) -> None:
    s, p, o = (int(v) for v in probe)
    for pattern in PATTERN_NAMES:
        qs, qp, qo = _bind(pattern, s, p, o)
        got = sorted(svc.query(qs, qp, qo))
        want = _oracle_query(oracle, qs, qp, qo)
        assert got == want, (pattern, (s, p, o),
                             svc.plan.strategy, svc.n_shards,
                             svc.migration_active)


def _rand_rows(rng, k) -> np.ndarray:
    return np.stack([rng.integers(0, N_NODES, k),
                     rng.integers(0, N_PREDS, k),
                     rng.integers(0, N_NODES, k)], axis=1)


def _probe(rng, oracle: set):
    if oracle and rng.integers(0, 4) > 0:
        rows = sorted(oracle)
        return rows[int(rng.integers(0, len(rows)))]
    return tuple(int(v) for v in _rand_rows(rng, 1)[0])


def _contains(svc, row) -> bool:
    s, p, o = (int(v) for v in row)
    return len(svc.query(s, p, o)) > 0


def _recover(svc, root):
    """Simulate the kill: abandon the live instance, reopen from disk."""
    svc.wal.close()
    recovered = DurableShardedService.open(root, rebalance_skew=None)
    assert recovered.last_recovery is not None
    assert recovered.last_recovery.failed_shards == []
    return recovered


def _spread_base() -> np.ndarray:
    return np.array([[s, s % N_PREDS, (s * 5) % N_NODES]
                     for s in range(N_NODES)], dtype=np.int64)


def _hot_rows() -> np.ndarray:
    """Rows piled onto one subject: inserted AFTER build they skew the
    tier, so a node_range re-cut must move something — guarantees the
    migration crash points are reachable."""
    return np.array([[0, p, o] for p in range(N_PREDS)
                     for o in range(12)], dtype=np.int64)


# -- deterministic sweep: every injection point, exact contract ------------

@pytest.mark.parametrize("point", CRASH_POINTS)
def test_every_injection_point_recovers(point, tmp_path):
    root = str(tmp_path / "svc")
    base = _spread_base()
    oracle = {tuple(map(int, r)) for r in base}
    svc = DurableShardedService.build(
        base, N_NODES, N_PREDS, root=root, n_shards=2,
        strategy="node_range", rebalance_skew=None)

    fresh = np.array([[3, 1, 14], [7, 2, 11]], dtype=np.int64)
    try:
        if point.startswith("wal."):
            with pytest.raises(CrashPoint):
                with inject_crashes({point: 1}):
                    svc.insert_triples(fresh)
            svc = _recover(svc, root)
            landed = _contains(svc, fresh[0])
            if point == "wal.post_append":
                # the record was durable before the kill: must replay
                assert landed, point
            else:
                # no durable record: the operation never happened
                assert not landed, point
            if landed:
                oracle |= {tuple(map(int, r)) for r in fresh}
            if point == "wal.torn":
                assert svc.last_recovery.torn_tail
        elif point.startswith("snapshot."):
            svc.insert_triples(fresh)
            oracle |= {tuple(map(int, r)) for r in fresh}
            with pytest.raises(CrashPoint):
                with inject_crashes({point: 1}):
                    svc.snapshot()
            svc = _recover(svc, root)
            if point == "snapshot.post_commit":
                # committed: recovery must come off the NEW snapshot and
                # replay the stale (untruncated) log idempotently
                assert svc.last_recovery.snapshot_step == 2
            else:
                assert svc.last_recovery.snapshot_step == 1
        elif point.startswith("migrate."):
            hot = _hot_rows()
            svc.insert_triples(hot)
            oracle |= {tuple(map(int, r)) for r in hot}
            with pytest.raises(CrashPoint):
                with inject_crashes({point: 1}):
                    svc.rebalance(force=True)
            svc = _recover(svc, root)
            assert svc.migration_active  # resumed, not lost
            svc.rebalance()  # drain the remaining moves
            assert not svc.migration_active
        else:  # engine.rebuild — needs a non-empty overlay to run
            svc.insert_triples(fresh)
            oracle |= {tuple(map(int, r)) for r in fresh}
            with pytest.raises(CrashPoint):
                with inject_crashes({point: 1}):
                    svc.rebuild(force=True)
            svc = _recover(svc, root)

        if svc.migration_active:
            svc.rebalance()
        _check_all_patterns(svc, oracle, (0, 1, 5))
        _check_all_patterns(svc, oracle, (3, 1, 14))
        assert sum(svc.live_edges()) == len(oracle)
    finally:
        svc.close()


# -- randomized kill-anywhere state machine --------------------------------

def _run_crash_machine(seed: int, strategy: str, n_shards: int, *,
                       n_ops=8, n_edges=45) -> None:
    rng = np.random.default_rng(seed)
    base = np.unique(_rand_rows(rng, n_edges), axis=0)
    oracle = {tuple(map(int, r)) for r in base}
    with tempfile.TemporaryDirectory() as root:
        delta_budget = None if rng.integers(0, 2) else int(rng.integers(4, 16))
        svc = DurableShardedService.build(
            base, N_NODES, N_PREDS, root=root, n_shards=n_shards,
            strategy=strategy, delta_budget=delta_budget,
            rebalance_skew=None)
        try:
            for _ in range(n_ops):
                op = int(rng.integers(0, 100))
                # arm a kill at a point the chosen op can actually reach
                # (hit > occurrences is fine: the op just completes)
                points = _points_for(op)
                schedule = {}
                if points and rng.integers(0, 4) > 0:
                    name = points[int(rng.integers(0, len(points)))]
                    schedule = {name: int(rng.integers(1, 3))}
                try:
                    with inject_crashes(schedule):
                        oracle = _one_op(rng, svc, oracle, op)
                except CrashPoint:
                    svc = _recover(svc, root)
                    oracle = _sync_oracle(svc, oracle, op)
                    _check_all_patterns(svc, oracle, _probe(rng, oracle))
                if rng.integers(0, 8) == 0:  # clean restart, no crash
                    svc.close()
                    svc = DurableShardedService.open(
                        root, rebalance_skew=None)
                    _check_all_patterns(svc, oracle, _probe(rng, oracle))

            if svc.migration_active:
                svc.rebalance()  # drain
            assert not svc.migration_active
            for _ in range(2):
                _check_all_patterns(svc, oracle, _probe(rng, oracle))
            assert sum(svc.live_edges()) == len(oracle)
            for k, engine in enumerate(svc.engines):
                rows = engine.current_triples()
                assert {tuple(map(int, r)) for r in rows} <= oracle
                if len(rows):
                    assert (svc.plan.triple_shards(rows) == k).all()
        finally:
            svc.close()


def _points_for(op: int) -> list[str]:
    """Injection points reachable by the op code `_one_op` maps to."""
    if op < 55:   # mutations: the WAL path + budget-driven auto-rebuild
        return ["wal.append", "wal.torn", "wal.post_append",
                "engine.rebuild"]
    if op < 75:   # queries touch no durability path
        return []
    if op < 87:   # rebalance: journal appends + migration batches
        return ["migrate.pre_apply", "migrate.mid_apply",
                "wal.append", "wal.post_append"]
    if op < 95:   # snapshot
        return ["snapshot.write_arrays", "snapshot.pre_commit",
                "snapshot.post_commit"]
    return ["engine.rebuild"]


_PENDING: dict = {}  # op payload, for post-crash oracle resync


def _one_op(rng, svc, oracle: set, op: int) -> set:
    _PENDING.clear()
    if op < 30:  # durable insert
        rows = _rand_rows(rng, int(rng.integers(1, 8)))
        want = {tuple(map(int, r)) for r in rows}
        _PENDING.update(kind="insert", want=want, new=want - oracle)
        assert svc.insert_triples(rows) == len(want - oracle)
        return oracle | want
    if op < 55:  # durable delete
        k = int(rng.integers(1, 8))
        pool = [list(r) for r in sorted(oracle)]
        picks = [pool[int(rng.integers(0, len(pool)))]
                 for _ in range(k)] if pool else []
        picks += _rand_rows(rng, max(1, k // 2)).tolist()
        rows = np.asarray(picks, dtype=np.int64)
        want = {tuple(map(int, r)) for r in rows}
        _PENDING.update(kind="delete", want=want, gone=want & oracle)
        assert svc.delete_triples(rows) == len(want & oracle)
        return oracle - want
    if op < 75:  # query parity (no state change)
        _check_all_patterns(svc, oracle, _probe(rng, oracle))
        return oracle
    if op < 87:  # rebalance, sometimes partial
        if rng.integers(0, 2):
            svc.rebalance(force=True, max_moves=int(rng.integers(1, 12)))
        else:
            svc.rebalance(force=True)
        return oracle
    if op < 95:  # snapshot + compaction
        svc.snapshot()
        return oracle
    svc.rebuild(force=bool(rng.integers(0, 2)))
    return oracle


def _sync_oracle(svc, oracle: set, op: int) -> set:
    """Re-derive the oracle after a kill mid-mutation: the batch is one
    atomic WAL record, so probing one marker row decides all of it."""
    kind = _PENDING.get("kind")
    if kind == "insert" and _PENDING["new"]:
        marker = sorted(_PENDING["new"])[0]
        if _contains(svc, marker):
            return oracle | _PENDING["want"]
    elif kind == "delete" and _PENDING["gone"]:
        marker = sorted(_PENDING["gone"])[0]
        if not _contains(svc, marker):
            return oracle - _PENDING["want"]
    return oracle


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 10**9))
def test_crash_oracle_state_machine(seed):
    """Kill-anywhere recovery parity for every strategy and shard count."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            _run_crash_machine(int(rng.integers(0, 2**31)),
                               strategy, n_shards)


@pytest.mark.slow
@settings(max_examples=SLOW_EXAMPLES, deadline=None)
@given(st.integers(0, 10**9))
def test_crash_oracle_state_machine_slow(seed):
    """Nightly crash lane: more ops and examples (ITR_CRASH_EXAMPLES)."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            _run_crash_machine(int(rng.integers(0, 2**31)),
                               strategy, n_shards, n_ops=14, n_edges=80)
