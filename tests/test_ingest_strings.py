"""Streaming ingestion + string-term query surfaces, end to end.

Parity oracle: the committed fixture parsed into a plain Python set of
(s, p, o) term-string triples; every string query on every tier (engine,
sharded, durable, replica) must answer exactly what set comprehension
over that oracle answers — for all 8 bound/unbound patterns.
"""
import os
import tempfile

import numpy as np
import pytest

from repro.core.grammar import Hypergraph, LabelTable
from repro.core.query import TripleQueryEngine
from repro.core.repair import compress
from repro.core.term_dict import TermDict
from repro.data.ingest import (
    IngestStats,
    ingest_file,
    ingest_rows,
    iter_tsv,
    resolve_ingest_batch,
    scan_predicates,
)
from repro.data.rdf import ParseReport, parse_ntriples
from repro.persist.service import DurableShardedService
from repro.serve.sharded import ShardedTripleService

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "small.nt")
PATTERNS = ["spo", "sp?", "s?o", "s??", "?po", "?p?", "??o", "???"]


def _oracle():
    """The fixture as a plain-Python set of term-string triples."""
    triples, nodes, preds, report = parse_ntriples(FIXTURE)
    assert report.malformed == 1  # the fixture commits one junk line
    return {(nodes[s], preds[p], nodes[o]) for s, p, o in triples}


def _oracle_answer(oracle, s, p, o):
    return {t for t in oracle
            if (s is None or t[0] == s)
            and (p is None or t[1] == p)
            and (o is None or t[2] == o)}


def _assert_string_parity(query_strings, oracle):
    """All 8 patterns, bound from every oracle triple, must match."""
    for s, p, o in sorted(oracle):
        for pat in PATTERNS:
            qs = s if pat[0] == "s" else None
            qp = p if pat[1] == "p" else None
            qo = o if pat[2] == "o" else None
            got = set(query_strings(qs, qp, qo))
            assert got == _oracle_answer(oracle, qs, qp, qo), (pat, s, p, o)


def _empty_sharded(n_preds=8, n_shards=2):
    return ShardedTripleService.build(
        np.zeros((0, 3), dtype=np.int64), n_nodes=1, n_preds=n_preds,
        n_shards=n_shards, cache=None)


# ---------------- source scanning ----------------
def test_scan_predicates_first_seen_order():
    preds, statements = scan_predicates(FIXTURE)
    assert statements == 13
    assert len(preds) == len(set(preds)) == 8
    assert preds[0] == "<http://ex.org/knows>"  # first-seen order


def test_iter_tsv_counts_malformed():
    lines = ["<http://a>\t<http://p>\t<http://b>",
             "only\ttwo",
             "",
             '<http://a>\t<http://p>\t"lit with spaces"']
    report = ParseReport()
    rows = list(iter_tsv(lines, report))
    assert rows == [("<http://a>", "<http://p>", "<http://b>"),
                    ("<http://a>", "<http://p>", '"lit with spaces"')]
    assert report.malformed == 1  # blank lines are not statements or errors


def test_resolve_ingest_batch(monkeypatch):
    assert resolve_ingest_batch(7) == 7
    assert resolve_ingest_batch(0) == 1  # clamp
    monkeypatch.setenv("ITR_INGEST_BATCH", "64")
    assert resolve_ingest_batch(None) == 64
    monkeypatch.setenv("ITR_INGEST_BATCH", "junk")
    assert resolve_ingest_batch(None) == 4096


# ---------------- engine-level surface ----------------
def _empty_engine(n_preds=8):
    table = LabelTable.terminals([2] * n_preds)
    grammar, _ = compress(
        Hypergraph.from_triples(np.zeros((0, 3), dtype=np.int64), 1), table)
    return TripleQueryEngine(grammar, cache=None, crossover=0, delta_budget=None)


def test_engine_requires_dict():
    eng = _empty_engine()
    with pytest.raises(ValueError, match="no term dictionary"):
        eng.query_strings("<http://x>", None, None)
    with pytest.raises(ValueError, match="no term dictionary"):
        eng.query_bgp_strings([("?x", "<http://p>", "?y")])


def test_engine_ingest_and_parity():
    eng = _empty_engine()
    stats = ingest_file(eng, FIXTURE, batch_size=4)
    assert (stats.rows, stats.inserted, stats.statements) == (13, 13, 13)
    assert stats.malformed == 1 and len(stats.malformed_samples) == 1
    assert (stats.new_nodes, stats.new_preds, stats.batches) == (11, 8, 4)
    assert stats.rows_per_s > 0
    _assert_string_parity(eng.query_strings, _oracle())


def test_engine_rebuild_preserves_dict():
    eng = _empty_engine()
    ingest_file(eng, FIXTURE)
    td = eng.term_dict
    assert eng.rebuild() is True
    assert eng.term_dict is td
    _assert_string_parity(eng.query_strings, _oracle())


def test_ingest_rows_into_bare_target_requires_attach():
    class Bare:
        def insert_triples(self, t):
            return len(t)

    with pytest.raises(ValueError, match="attach"):
        ingest_rows(Bare(), [("<http://a>", "<http://p>", "<http://b>")])


# ---------------- sharded tier ----------------
def test_sharded_ingest_parity_all_patterns():
    svc = _empty_sharded()
    stats = ingest_file(svc, FIXTURE, batch_size=5)
    assert stats.batches == 3 and stats.inserted == 13
    oracle = _oracle()
    _assert_string_parity(svc.query_strings, oracle)
    # ingest is idempotent at the triple level: same file again dedups
    stats2 = ingest_file(svc, FIXTURE)
    assert stats2.inserted == 0 and stats2.new_nodes == 0 and stats2.new_preds == 0
    _assert_string_parity(svc.query_strings, oracle)


def test_sharded_unknown_term_short_circuits():
    svc = _empty_sharded()
    ingest_file(svc, FIXTURE)
    flushes_before = svc.stats.flushes
    assert svc.query_strings("<http://ex.org/nobody>", None, None) == []
    assert svc.query_bgp_strings([("?x", "<http://no.such/pred>", "?y")]) == []
    assert svc.stats.flushes == flushes_before  # no shard was touched
    assert svc.stats.string_queries >= 2
    assert svc.stats.unknown_term_empties == 2


def test_sharded_bgp_strings_parity_and_pred_var():
    svc = _empty_sharded()
    ingest_file(svc, FIXTURE)
    oracle = _oracle()
    knows = "<http://ex.org/knows>"
    rows = svc.query_bgp_strings([("?x", knows, "?y"), ("?y", knows, "?z")])
    want = {(a[0], a[2], b[2]) for a in oracle if a[1] == knows
            for b in oracle if b[1] == knows and b[0] == a[2]}
    assert {(r["?x"], r["?y"], r["?z"]) for r in rows} == want and rows
    # predicate-position variable decodes through the predicate space
    rows = svc.query_bgp_strings([("<http://ex.org/alice>", "?p", "?o")])
    assert {(r["?p"], r["?o"]) for r in rows} == \
        {(p, o) for s, p, o in oracle if s == "<http://ex.org/alice>"}
    with pytest.raises(ValueError, match="both predicate and"):
        svc.query_bgp_strings([("?x", "?x", "?y")])


def test_sharded_tsv_ingest(tmp_path):
    path = tmp_path / "g.tsv"
    path.write_text("<http://a>\t<http://p>\t<http://b>\n"
                    "broken line without tabs\n"
                    "<http://b>\t<http://p>\t<http://c>\n")
    svc = _empty_sharded(n_preds=1)
    stats = ingest_file(svc, str(path))  # format inferred from extension
    assert stats.inserted == 2 and stats.malformed == 1
    assert svc.query_strings(None, "<http://p>", None) == [
        ("<http://a>", "<http://p>", "<http://b>"),
        ("<http://b>", "<http://p>", "<http://c>")]


def test_sharded_pred_capacity_exhausted():
    svc = _empty_sharded(n_preds=2)  # fixture needs 8
    with pytest.raises(ValueError, match="predicate capacity"):
        ingest_file(svc, FIXTURE)


def test_ingest_rows_with_progress_and_stats_reuse():
    svc = _empty_sharded(n_preds=1)
    seen = []
    stats = IngestStats()
    rows = [("<http://a>", "<http://p>", "<http://b>"),
            ("<http://b>", "<http://p>", "<http://c>"),
            ("<http://c>", "<http://p>", "<http://a>")]
    out = ingest_rows(svc, rows, batch_size=2, stats=stats,
                      progress=lambda s: seen.append(s.rows))
    assert out is stats and stats.batches == 2 and seen == [2, 3]


# ---------------- durable tier: WAL, snapshot, replicas ----------------
def test_durable_ingest_survives_reopen_and_replicates():
    with tempfile.TemporaryDirectory() as root:
        svc = DurableShardedService.build(
            np.zeros((0, 3), dtype=np.int64), n_nodes=1, n_preds=8,
            root=root, n_shards=2, cache=None)
        svc.attach_term_dict(TermDict.empty())
        ingest_file(svc, FIXTURE, batch_size=5)
        oracle = _oracle()
        _assert_string_parity(svc.query_strings, oracle)
        node_order = svc.term_dict.nodes.terms_in_id_order()
        svc.close()

        # reopen #1: dict rebuilt purely from the WAL term records
        svc = DurableShardedService.open(root=root, cache=None)
        assert svc.term_dict.nodes.terms_in_id_order() == node_order
        _assert_string_parity(svc.query_strings, oracle)

        # snapshot folds the dict in; post-snapshot mints ride the new WAL
        svc.snapshot()
        svc.add_node_terms(["<http://ex.org/late>"])
        svc.close()
        svc = DurableShardedService.open(root=root, cache=None)
        assert svc.term_dict.node_id("<http://ex.org/late>") is not None
        _assert_string_parity(svc.query_strings, oracle)

        # replicas seed the dict from the snapshot + WAL tail
        svc.enable_replication(1)
        svc.sync_replicas()
        rep_svc = svc.replicas.groups[0].service
        assert rep_svc.term_dict.nodes.terms_in_id_order() == \
            svc.term_dict.nodes.terms_in_id_order()
        _assert_string_parity(rep_svc.query_strings, oracle)
        svc.close()


def test_durable_pred_capacity_does_not_touch_wal():
    with tempfile.TemporaryDirectory() as root:
        svc = DurableShardedService.build(
            np.zeros((0, 3), dtype=np.int64), n_nodes=1, n_preds=1,
            root=root, n_shards=1, cache=None)
        svc.attach_term_dict(TermDict.empty())
        svc.add_pred_terms(["<http://p0>"])
        offset = svc.wal.offset
        with pytest.raises(ValueError, match="predicate capacity"):
            svc.add_pred_terms(["<http://p1>"])
        # the rejected mint must not have been logged: replay would
        # otherwise rebuild an over-capacity dictionary
        assert svc.wal.offset == offset
        svc.close()
        svc = DurableShardedService.open(root=root, cache=None)
        assert svc.term_dict.n_preds == 1
        svc.close()
