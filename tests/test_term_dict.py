"""Unit + property tests for the compressed term dictionary
(`repro.core.term_dict`) and its snapshot persistence."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.term_dict import (
    StringSpace,
    TermDict,
    bgp_result_to_terms,
    resolve_dict_block,
    resolve_string_bgp,
    resolve_string_triple,
)
from repro.persist.snapshot import SnapshotError, load_term_dict, save_term_dict

_TERMS = ([f"<http://ex.org/node/{i:04d}>" for i in range(60)]
          + [f"_:b{i}" for i in range(10)]
          + ['"plain lit"', '"inner "quotes""@en', '"line\nbreak"',
             '"tab\there"^^<http://t>', '"1.5"^^<http://xsd#double>', '""'])


def _space(terms, block=8):
    return StringSpace.from_terms(list(terms), block=block)


# ---------------- base round-trip ----------------
def test_bidirectional_lookup_unsorted_input():
    rng = np.random.default_rng(0)
    terms = list(_TERMS)
    rng.shuffle(terms)
    sp = _space(terms)
    assert len(sp) == len(terms)
    for i, t in enumerate(terms):
        assert sp.term_to_id(t) == i
        assert sp.id_to_term(i) == t


def test_bidirectional_lookup_sorted_input_elides_permutation():
    terms = sorted(_TERMS)
    sp = _space(terms)
    assert sp._ids is None  # identity permutation is not materialized
    for i, t in enumerate(terms):
        assert sp.term_to_id(t) == i
        assert sp.id_to_term(i) == t


def test_unknown_term_and_bad_id():
    sp = _space(_TERMS)
    assert sp.term_to_id("<http://ex.org/absent>") is None
    assert sp.term_to_id("") is None
    with pytest.raises(IndexError):
        sp.id_to_term(len(sp))
    with pytest.raises(IndexError):
        sp.id_to_term(-1)


def test_empty_space():
    sp = StringSpace()
    assert len(sp) == 0
    assert sp.term_to_id("x") is None
    ids = sp.add_terms(["a", "b", "a"])
    assert ids.tolist() == [0, 1, 0]


def test_duplicate_terms_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        _space(["a", "b", "a"])


def test_front_coding_compresses_shared_prefixes():
    # sorted input -> no permutation arrays, so the measurement is the
    # front-coded payload itself
    terms = sorted(f"<http://example.org/very/long/common/prefix/{i}>"
                   for i in range(512))
    sp = _space(terms, block=16)
    plain = sum(len(t.encode()) for t in terms)
    assert sp.size_in_bytes() < 0.5 * plain


# ---------------- append tail + compaction ----------------
def test_append_tail_and_compaction_preserve_ids():
    sp = _space(_TERMS[:20])
    ids = sp.add_terms(["zzz", _TERMS[3], "aaa", "zzz"])
    assert ids.tolist() == [20, 3, 21, 20]
    assert sp.n_extra == 2
    assert sp.id_to_term(21) == "aaa"
    comp = sp.compacted()
    assert comp.n_extra == 0 and len(comp) == 22
    for i in range(len(sp)):
        assert comp.id_to_term(i) == sp.id_to_term(i)
        assert comp.term_to_id(sp.id_to_term(i)) == i


# ---------------- persistence ----------------
def test_to_from_arrays_roundtrip():
    rng = np.random.default_rng(1)
    terms = list(_TERMS)
    rng.shuffle(terms)
    sp = _space(terms)
    sp.add_terms(["tail-1", "tail-2"])
    sp2 = StringSpace.from_arrays(*sp.to_arrays())
    assert len(sp2) == len(sp)
    for i in range(len(sp)):
        assert sp2.id_to_term(i) == sp.id_to_term(i)
    assert sp2.term_to_id("tail-2") == sp.term_to_id("tail-2")


def test_save_load_term_dict(tmp_path):
    td = TermDict.from_terms(_TERMS, ["<http://p0>", "<http://p1>"])
    td.add_node_terms(["<http://late>"])
    d = save_term_dict(td, tmp_path / "td")
    td2 = load_term_dict(d)
    assert td2.nodes.terms_in_id_order() == td.nodes.terms_in_id_order()
    assert td2.preds.terms_in_id_order() == td.preds.terms_in_id_order()
    assert td2.node_id("<http://late>") == td.node_id("<http://late>")


def test_load_term_dict_rejects_corruption(tmp_path):
    td = TermDict.from_terms(["a", "b"], ["p"])
    d = save_term_dict(td, tmp_path / "td")
    blob = tmp_path / "td" / "nodes_blob.npy"
    raw = bytearray(blob.read_bytes())
    raw[-1] ^= 0xFF
    blob.write_bytes(bytes(raw))
    with pytest.raises(SnapshotError, match="checksum"):
        load_term_dict(d)
    with pytest.raises(SnapshotError):
        load_term_dict(tmp_path / "missing")


def test_load_term_dict_rejects_wrong_kind(tmp_path):
    import json

    d = tmp_path / "notdict"
    d.mkdir()
    (d / "manifest.json").write_text(json.dumps({"format": 1, "checksums": {}}))
    with pytest.raises(SnapshotError, match="not a term-dict"):
        load_term_dict(d)


# ---------------- TermDict two-space semantics ----------------
def test_term_dict_spaces_are_disjoint():
    td = TermDict.empty()
    n = td.add_node_terms(["<http://x>", "<http://p>"])
    p = td.add_pred_terms(["<http://p>"])
    assert n.tolist() == [0, 1] and p.tolist() == [0]
    assert td.node_term(1) == "<http://p>" and td.pred_term(0) == "<http://p>"
    assert td.n_nodes == 2 and td.n_preds == 1
    assert td.bytes_per_term() > 0
    comp = td.compacted()
    assert comp.node_id("<http://x>") == 0 and comp.pred_id("<http://p>") == 0


def test_resolve_dict_block(monkeypatch):
    assert resolve_dict_block(4) == 4
    assert resolve_dict_block(0) == 2  # clamp
    monkeypatch.setenv("ITR_DICT_BLOCK", "32")
    assert resolve_dict_block() == 32
    monkeypatch.setenv("ITR_DICT_BLOCK", "junk")
    assert resolve_dict_block() == 16
    monkeypatch.delenv("ITR_DICT_BLOCK")
    assert resolve_dict_block() == 16


# ---------------- string-pattern resolution helpers ----------------
def _td():
    return TermDict.from_terms(["<http://a>", "<http://b>"], ["<http://p>"])


def test_resolve_string_triple():
    td = _td()
    assert resolve_string_triple(td, "<http://a>", None, "<http://b>") == (0, None, 1, True)
    assert resolve_string_triple(td, None, "<http://p>", None) == (None, 0, None, True)
    assert resolve_string_triple(td, "<http://absent>", None, None)[3] is False
    with pytest.raises(TypeError):
        resolve_string_triple(td, 3, None, None)


def test_resolve_string_bgp():
    td = _td()
    pats, pred_vars, known = resolve_string_bgp(
        td, [("?x", "<http://p>", "?y"), ("?y", "?p", "<http://b>")])
    assert known
    assert pats == [("?x", 0, "?y"), ("?y", "?p", 1)]
    assert pred_vars == {"?p"}
    # single-pattern convenience form
    pats1, _, _ = resolve_string_bgp(td, ("?x", "<http://p>", "?y"))
    assert pats1 == [("?x", 0, "?y")]
    # unknown constant -> known=False
    _, _, known = resolve_string_bgp(td, [("?x", "<http://nope>", "?y")])
    assert known is False
    # a var cannot straddle the two id spaces
    with pytest.raises(ValueError, match="both predicate and"):
        resolve_string_bgp(td, [("?x", "?x", "?y")])
    with pytest.raises(ValueError, match="triples"):
        resolve_string_bgp(td, [("?x", "<http://p>")])
    with pytest.raises(TypeError):
        resolve_string_bgp(td, [(None, "<http://p>", "?y")])


def test_bgp_result_to_terms():
    from repro.core.bgp import BGPResult

    td = _td()
    res = BGPResult(("?x", "?p"), np.array([[0, 0], [1, 0]], dtype=np.int64))
    rows = bgp_result_to_terms(td, res, {"?p"})
    assert rows == [{"?x": "<http://a>", "?p": "<http://p>"},
                    {"?x": "<http://b>", "?p": "<http://p>"}]


# ---------------- property: random pools, random blocks ----------------
@settings(max_examples=15)
@given(st.integers(2, 40), st.integers(1, 120), st.booleans())
def test_property_bijection(block, n_terms, shuffle):
    rng = np.random.default_rng(block * 1000 + n_terms)
    terms = [f"<http://t/{i}/{'x' * int(rng.integers(0, 20))}>"
             for i in range(n_terms)]
    if shuffle:
        rng.shuffle(terms)
    sp = StringSpace.from_terms(terms, block=block)
    for i, t in enumerate(terms):
        assert sp.term_to_id(t) == i
        assert sp.id_to_term(i) == t
    assert sp.term_to_id("<absent>") is None
    sp2 = StringSpace.from_arrays(*sp.to_arrays())
    assert sp2.terms_in_id_order() == terms
