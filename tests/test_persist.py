"""Deterministic durability tests: snapshot format, WAL framing, recovery.

The randomized crash oracle lives in `tests/test_crash_oracle.py`; this
file pins the deterministic contracts it builds on — byte-level WAL
torn-tail tolerance, snapshot checksum verification, mmap cold start,
migration-batch replay idempotency, and degraded serving around a shard
whose snapshot is gone.
"""
import json
import os

import numpy as np
import pytest

from repro.core.hypergraph import Hypergraph, LabelTable
from repro.core.query import TripleQueryEngine
from repro.core.repair import RepairConfig, compress
from repro.distributed.rebalance import migration_moves, plan_rebalance
from repro.persist.crash import (
    CrashInjector,
    CrashPoint,
    crash_point,
    inject_crashes,
    parse_crash_points,
)
from repro.persist.service import DurableShardedService, RecoveryReport
from repro.persist.snapshot import (
    MANIFEST,
    SnapshotError,
    load_snapshot,
    save_snapshot,
)
from repro.persist.wal import (
    MAGIC,
    WriteAheadLog,
    read_wal_records,
    resolve_wal_fsync,
)
from repro.serve.sharded import ShardedTripleService

ALL_PATTERNS = [(-1, -1, -1), (1, -1, -1), (-1, 1, -1), (-1, -1, 1),
                (1, 1, -1), (1, -1, 1), (-1, 1, 1), (1, 1, 1)]


def _rand_triples(seed, n, n_nodes=24, n_preds=4):
    rng = np.random.default_rng(seed)
    return np.unique(np.stack([rng.integers(0, n_nodes, n),
                               rng.integers(0, n_preds, n),
                               rng.integers(0, n_nodes, n)], axis=1), axis=0)


def _build_engine(rows, n_nodes=24, n_preds=4, config=None):
    graph = Hypergraph.from_triples(rows, n_nodes)
    table = LabelTable.terminals(np.full(n_preds, 2, dtype=np.int64))
    grammar, _ = compress(graph, table, config)
    engine = TripleQueryEngine(grammar, config=config)
    engine._base_edges = len(rows)
    return engine


def _answers(engine):
    return {pat: sorted(engine.query(*pat)) for pat in ALL_PATTERNS}


def _svc_answers(svc):
    return {pat: sorted(svc.query(*(v if v >= 0 else None for v in pat)))
            for pat in ALL_PATTERNS}


# -- crash injection harness ----------------------------------------------

class TestCrashInjection:
    def test_schedule_fires_on_exact_hit(self):
        inj = CrashInjector({"pt": 3})
        inj.visit("pt")
        inj.visit("pt")
        with pytest.raises(CrashPoint) as exc:
            inj.visit("pt")
        assert exc.value.name == "pt"
        assert inj.hits["pt"] == 3
        inj.visit("pt")  # past the scheduled hit: disarmed again

    def test_crash_point_is_not_an_exception(self):
        # defensive `except Exception` must not swallow a simulated kill
        assert not issubclass(CrashPoint, Exception)
        with pytest.raises(CrashPoint):
            with inject_crashes({"x": 1}):
                try:
                    crash_point("x")
                except Exception:  # noqa: BLE001 - the point of the test
                    pytest.fail("CrashPoint caught by `except Exception`")

    def test_inject_crashes_restores_previous(self):
        with inject_crashes({"a": 1}) as outer:
            with inject_crashes({"b": 1}) as inner:
                crash_point("a")  # counts against the INNER schedule only
            with pytest.raises(CrashPoint):
                crash_point("a")
        assert inner.hits == {"a": 1}
        assert outer.hits == {"a": 1}
        crash_point("a")  # disarmed outside all blocks

    def test_parse_crash_points(self):
        assert parse_crash_points("wal.append:2, snapshot.pre_commit") == \
            {"wal.append": 2, "snapshot.pre_commit": 1}
        assert parse_crash_points("") == {}
        with pytest.raises(ValueError):
            parse_crash_points("wal.append:two")
        with pytest.raises(ValueError):
            parse_crash_points(":3")

    def test_resolve_wal_fsync(self, monkeypatch):
        assert resolve_wal_fsync(True) is True
        assert resolve_wal_fsync(False) is False
        monkeypatch.delenv("ITR_WAL_FSYNC", raising=False)
        assert resolve_wal_fsync() is True  # durable by default
        monkeypatch.setenv("ITR_WAL_FSYNC", "0")
        assert resolve_wal_fsync() is False
        monkeypatch.setenv("ITR_WAL_FSYNC", "1")
        assert resolve_wal_fsync() is True


# -- write-ahead log -------------------------------------------------------

class TestWal:
    def test_roundtrip(self, tmp_path):
        path = tmp_path / "wal.log"
        payloads = [b"alpha", b"", b"x" * 1000, bytes(range(256))]
        with WriteAheadLog(path) as wal:
            for p in payloads:
                wal.append(p)
        records, report = read_wal_records(path)
        assert records == payloads
        assert report.n_records == 4 and not report.torn_tail

    def test_append_survives_reopen(self, tmp_path):
        path = tmp_path / "wal.log"
        with WriteAheadLog(path) as wal:
            wal.append(b"one")
        with WriteAheadLog(path) as wal:  # reopen appends, never clobbers
            wal.append(b"two")
        records, _ = read_wal_records(path)
        assert records == [b"one", b"two"]

    def test_reset_compacts(self, tmp_path):
        path = tmp_path / "wal.log"
        with WriteAheadLog(path) as wal:
            wal.append(b"old")
            wal.reset()
            wal.append(b"new")
        records, _ = read_wal_records(path)
        assert records == [b"new"]

    def test_missing_file_is_empty_log(self, tmp_path):
        records, report = read_wal_records(tmp_path / "absent.log")
        assert records == [] and not report.torn_tail

    def test_bad_magic_raises(self, tmp_path):
        path = tmp_path / "wal.log"
        path.write_bytes(b"NOTAWAL!rest")
        with pytest.raises(ValueError, match="magic"):
            read_wal_records(path)

    def test_torn_tail_every_byte_offset(self, tmp_path):
        """Truncating anywhere inside the final record loses exactly that
        record — recovery keeps every earlier one and reports the tear."""
        path = tmp_path / "wal.log"
        with WriteAheadLog(path) as wal:
            wal.append(b"first")
            wal.append(b"second")
        full = path.read_bytes()
        keep_upto = len(MAGIC) + 8 + len(b"first")  # end of record 1
        for cut in range(keep_upto + 1, len(full)):
            path.write_bytes(full[:cut])
            records, report = read_wal_records(path)
            assert records == [b"first"], cut
            assert report.torn_tail and report.n_records == 1, cut
        # the header itself torn: empty log, still no exception
        for cut in range(1, len(MAGIC)):
            path.write_bytes(full[:cut])
            records, report = read_wal_records(path)
            assert records == [] and report.torn_tail

    def test_corrupt_tail_crc_dropped(self, tmp_path):
        path = tmp_path / "wal.log"
        with WriteAheadLog(path) as wal:
            wal.append(b"good")
            wal.append(b"evil")
        data = bytearray(path.read_bytes())
        data[-1] ^= 0xFF  # flip a payload byte of the LAST record
        path.write_bytes(bytes(data))
        records, report = read_wal_records(path)
        assert records == [b"good"]
        assert report.torn_tail and "crc" in report.torn_reason

    def test_reopen_truncates_torn_tail_before_appending(self, tmp_path):
        """Appending after a torn tail would bury the new records behind
        garbage; reopening must cut the tear first."""
        path = tmp_path / "wal.log"
        with WriteAheadLog(path) as wal:
            wal.append(b"first")
            wal.append(b"second")
        full = path.read_bytes()
        path.write_bytes(full[:-3])  # tear the last record
        with WriteAheadLog(path) as wal:
            assert wal.recovery is not None and wal.recovery.torn_tail
            wal.append(b"third")
        records, report = read_wal_records(path)
        assert records == [b"first", b"third"]
        assert not report.torn_tail

    def test_torn_crash_point_leaves_recoverable_tail(self, tmp_path):
        path = tmp_path / "wal.log"
        wal = WriteAheadLog(path)
        wal.append(b"committed")
        with pytest.raises(CrashPoint):
            with inject_crashes({"wal.torn": 1}):
                wal.append(b"torn-away")
        records, report = read_wal_records(path)
        assert records == [b"committed"]
        assert report.torn_tail


# -- engine snapshots ------------------------------------------------------

class TestEngineSnapshot:
    def test_roundtrip_parity_mmap_and_copy(self, tmp_path):
        rows = _rand_triples(0, 220)
        engine = _build_engine(rows, config=RepairConfig(max_rank=8))
        engine.insert_triples([[1, 2, 3], [5, 0, 9]])
        engine.delete_triples(rows[:4])
        want = _answers(engine)
        path = str(tmp_path / "snap")
        save_snapshot(engine, path)
        for mmap in (True, False):
            loaded = load_snapshot(path, mmap=mmap)
            assert _answers(loaded) == want
            assert loaded.delta.n_inserts == 2
            assert loaded.delta.n_tombstones == 4
            assert loaded.base_edges == len(rows)
            assert loaded.crossover == engine.crossover
            assert loaded.config == RepairConfig(max_rank=8)
            assert loaded.rebuild_count == engine.rebuild_count

    def test_loaded_engine_stays_mutable(self, tmp_path):
        rows = _rand_triples(1, 150)
        path = str(tmp_path / "snap")
        save_snapshot(_build_engine(rows), path)
        loaded = load_snapshot(path)  # mmap-backed arrays
        assert loaded.insert_triples([[0, 1, 2]]) == 1
        assert loaded.delete_triples(rows[:3]) == 3
        assert loaded.rebuild() is True  # recompress over mmap views
        got = {tuple(map(int, r)) for r in loaded.current_triples()}
        want = {tuple(map(int, r)) for r in rows[3:]} | {(0, 1, 2)}
        assert got == want

    def test_empty_engine_roundtrip(self, tmp_path):
        engine = _build_engine(np.zeros((0, 3), dtype=np.int64))
        path = str(tmp_path / "snap")
        save_snapshot(engine, path)
        loaded = load_snapshot(path)
        assert loaded.query(-1, -1, -1) == []

    def test_checksum_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "snap")
        save_snapshot(_build_engine(_rand_triples(2, 100)), path)
        target = os.path.join(path, "flat_params.npy")
        data = bytearray(open(target, "rb").read())
        data[-1] ^= 0x01
        open(target, "wb").write(bytes(data))
        with pytest.raises(SnapshotError, match="checksum"):
            load_snapshot(path)
        # opting out of verification loads the (corrupt) bytes silently
        load_snapshot(path, verify=False)

    def test_missing_array_raises(self, tmp_path):
        path = str(tmp_path / "snap")
        save_snapshot(_build_engine(_rand_triples(3, 80)), path)
        os.remove(os.path.join(path, "start_labels.npy"))
        with pytest.raises(SnapshotError, match="missing"):
            load_snapshot(path)

    def test_manifestless_dir_raises(self, tmp_path):
        path = str(tmp_path / "snap")
        save_snapshot(_build_engine(_rand_triples(4, 80)), path)
        os.remove(os.path.join(path, MANIFEST))
        with pytest.raises(SnapshotError, match="manifest"):
            load_snapshot(path)

    def test_format_version_gate(self, tmp_path):
        path = str(tmp_path / "snap")
        save_snapshot(_build_engine(_rand_triples(5, 80)), path)
        mpath = os.path.join(path, MANIFEST)
        manifest = json.load(open(mpath))
        manifest["format"] = 999
        json.dump(manifest, open(mpath, "w"))
        with pytest.raises(SnapshotError, match="format"):
            load_snapshot(path)

    def test_atomic_overwrite_keeps_previous_on_crash(self, tmp_path):
        rows = _rand_triples(6, 120)
        engine = _build_engine(rows)
        path = str(tmp_path / "snap")
        save_snapshot(engine, path)
        engine.insert_triples([[2, 2, 2]])
        with pytest.raises(CrashPoint):
            with inject_crashes({"snapshot.write_arrays": 3}):
                save_snapshot(engine, path)
        # the committed snapshot is intact; the aborted write is a .tmp
        loaded = load_snapshot(path)
        assert not loaded.contains_triples([[2, 2, 2]])[0]
        assert os.path.isdir(path + ".tmp")
        save_snapshot(engine, path)  # retry cleans the leftover .tmp
        assert not os.path.exists(path + ".tmp")
        assert load_snapshot(path).contains_triples([[2, 2, 2]])[0]


# -- durable sharded service ----------------------------------------------

def _build_durable(tmp_path, seed=7, n_shards=3, strategy="predicate_hash",
                   **kwargs):
    rows = _rand_triples(seed, 260)
    root = str(tmp_path / "svc")
    svc = DurableShardedService.build(
        rows, 24, 4, root=root, n_shards=n_shards, strategy=strategy,
        rebalance_skew=None, **kwargs)
    return svc, rows, root


class TestDurableService:
    def test_recover_replays_mutations(self, tmp_path):
        svc, rows, root = _build_durable(tmp_path)
        svc.insert_triples([[9, 3, 9], [0, 0, 1]])
        svc.delete_triples(rows[:6])
        want = _svc_answers(svc)
        svc.close()
        recovered = DurableShardedService.open(root)
        assert _svc_answers(recovered) == want
        rep = recovered.last_recovery
        assert isinstance(rep, RecoveryReport)
        assert rep.replayed_records == 2 and not rep.torn_tail
        recovered.close()

    def test_snapshot_compacts_wal_and_gc(self, tmp_path):
        svc, rows, root = _build_durable(tmp_path)
        svc.insert_triples([[1, 1, 1]])
        svc.snapshot()
        _, report = read_wal_records(os.path.join(root, "wal.log"))
        assert report.n_records == 0  # compacted
        svc.insert_triples([[2, 2, 2]])
        want = _svc_answers(svc)
        svc.close()
        recovered = DurableShardedService.open(root)
        assert recovered.last_recovery.snapshot_step == 2
        assert recovered.last_recovery.replayed_records == 1
        assert _svc_answers(recovered) == want
        # gc keeps a bounded number of versioned dirs
        snaps = [d for d in os.listdir(root) if d.startswith("snap_")
                 and not d.endswith(".tmp")]
        assert len(snaps) <= 2
        recovered.close()

    def test_crash_between_commit_and_truncate_is_idempotent(self, tmp_path):
        """The whole old WAL replayed onto the NEW snapshot (kill after
        the rename, before the truncation) must be a no-op."""
        svc, rows, root = _build_durable(tmp_path)
        svc.insert_triples([[3, 3, 3]])
        svc.delete_triples(rows[:5])
        svc.insert_triples(rows[:2])  # delete-then-reinsert interleaving
        want = _svc_answers(svc)
        with pytest.raises(CrashPoint):
            with inject_crashes({"snapshot.post_commit": 1}):
                svc.snapshot()
        _, report = read_wal_records(os.path.join(root, "wal.log"))
        assert report.n_records == 3  # truncation never happened
        recovered = DurableShardedService.open(root)
        assert recovered.last_recovery.snapshot_step == 2
        assert recovered.last_recovery.replayed_records == 3
        assert _svc_answers(recovered) == want
        recovered.close()

    def test_mid_migration_snapshot_resumes(self, tmp_path):
        svc, rows, root = _build_durable(tmp_path, strategy="node_range",
                                         n_shards=2)
        svc.insert_triples(  # hot subjects: skews the node_range cut
            np.stack([np.arange(24) % 5, np.full(24, 3),
                      np.arange(24)], axis=1))
        svc.rebalance(force=True, max_moves=5)
        assert svc.migration_active
        svc.snapshot()  # migration plan persisted, pending rows are a diff
        want = _svc_answers(svc)
        svc.close()
        recovered = DurableShardedService.open(root)
        assert recovered.last_recovery.migration_resumed
        assert recovered.migration_active
        assert _svc_answers(recovered) == want
        recovered.rebalance()  # drain to completion
        assert not recovered.migration_active
        assert _svc_answers(recovered) == want
        recovered.close()

    def test_migration_batch_replay_is_idempotent(self, tmp_path):
        """Satellite pin: re-applying a logged migration batch must not
        duplicate rows at dst or resurrect a row deleted post-append."""
        rows = _rand_triples(11, 200)
        svc = ShardedTripleService.build(rows, 24, 4, n_shards=2,
                                         strategy="node_range",
                                         rebalance_skew=None)
        # pile rows onto one hot subject: the re-quantiled boundary then
        # moves every other low-subject row off shard 0
        hot = np.array([[0, p, o] for p in range(4) for o in range(15)])
        svc.insert_triples(hot)
        mig = plan_rebalance(svc.plan, svc.engines)
        moves = mig.pending_moves()
        assert moves, "re-cut must move something for this pin to bite"
        src, dst, batch = moves[0]
        svc._migration = mig
        mig.take(None)  # drain the bookkeeping; apply the batch by hand
        applied = svc._apply_migration_batch(src, dst, batch)
        assert applied == len(batch)
        before = {tuple(map(int, r))
                  for r in svc.engines[dst].current_triples()}
        # replay 1: full batch again -> no row is still at src -> no-op
        assert svc._apply_migration_batch(src, dst, batch) == 0
        after = {tuple(map(int, r))
                 for r in svc.engines[dst].current_triples()}
        assert after == before, "replay duplicated migrated rows"
        # replay 2: a row deleted after the move (through the in-flight
        # dual-shard delete path) must stay dead when the batch re-applies
        dead = batch[0].reshape(1, 3)
        assert svc.delete_triples(dead) == 1
        assert not svc.engines[dst].contains_triples(dead)[0]
        assert svc._apply_migration_batch(src, dst, batch) == 0
        assert not svc.engines[dst].contains_triples(dead)[0], \
            "replay resurrected a deleted row"

    def test_degraded_shard_serves_and_reingests(self, tmp_path):
        svc, rows, root = _build_durable(tmp_path, n_shards=3)
        full = _svc_answers(svc)
        svc.close()
        # nuke one shard's snapshot payload (build wrote snap_000001)
        victim = os.path.join(root, "snap_000001", "shard_1",
                              "flat_params.npy")
        data = bytearray(open(victim, "rb").read())
        data[-1] ^= 0xFF
        open(victim, "wb").write(bytes(data))
        recovered = DurableShardedService.open(root)
        assert recovered.last_recovery.failed_shards == [1]
        assert recovered.failed_shards == {1}
        # the tier still answers: surviving shards' rows only
        got = _svc_answers(recovered)
        lost = {tuple(map(int, r)) for r in rows
                if int(recovered.plan.route_triples(
                    r.reshape(1, 3))[0]) == 1}
        assert lost, "test needs the victim shard to own rows"
        survivors = {tuple(map(int, r)) for r in rows} - lost
        assert set(
            (s, p, o) for p, (s, o) in got[(-1, -1, -1)]
        ) == {(s, p, o) for s, p, o in survivors}
        assert recovered.stats.degraded_patterns > 0
        # writes to the hole and rebalancing are refused
        bad_row = next(iter(lost))
        with pytest.raises(RuntimeError, match="failed shards"):
            recovered.insert_triples([list(bad_row)])
        with pytest.raises(RuntimeError, match="failed shards"):
            recovered.rebalance(force=True)
        with pytest.raises(RuntimeError, match="failed shards"):
            recovered.snapshot()
        # re-ingest restores exact parity with the pre-failure answers
        recovered.reingest_shard(1, rows)
        assert recovered.failed_shards == set()
        assert _svc_answers(recovered) == full
        recovered.snapshot()  # snapshotting is legal again
        recovered.close()

    def test_open_without_snapshot_raises(self, tmp_path):
        with pytest.raises(SnapshotError, match="no snapshot root"):
            DurableShardedService.open(str(tmp_path / "empty"))
        empty = tmp_path / "present-but-empty"
        empty.mkdir()
        with pytest.raises(SnapshotError, match="no complete snapshot"):
            DurableShardedService.open(str(empty))

    def test_snapshot_dir_env_knob(self, tmp_path, monkeypatch):
        from repro.persist.service import resolve_snapshot_dir
        monkeypatch.delenv("ITR_SNAPSHOT_DIR", raising=False)
        with pytest.raises(ValueError, match="ITR_SNAPSHOT_DIR"):
            resolve_snapshot_dir()
        monkeypatch.setenv("ITR_SNAPSHOT_DIR", str(tmp_path / "via-env"))
        assert resolve_snapshot_dir() == str(tmp_path / "via-env")
        assert resolve_snapshot_dir(str(tmp_path / "arg")) == \
            str(tmp_path / "arg")
