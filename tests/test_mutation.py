"""Mutation subsystem: delta overlay set semantics, engine insert/delete
parity against a from-scratch engine on the mutated triple set (all 8
patterns), incremental per-shard rebuild, budget-driven auto-rebuild, and
cache-generation hygiene (only mutated shards bumped)."""
import numpy as np
import pytest

from repro.core import (
    DeltaOverlay,
    Hypergraph,
    LabelTable,
    TripleQueryEngine,
    compress,
    resolve_delta_budget,
)
from repro.data.graph_store import GraphStore
from repro.serve.sharded import _MERGED_SHARD, ShardedTripleService
from repro.serve.triple_service import TripleQueryService

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]

N_NODES, N_PREDS = 15, 3


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


def _unique_triples(seed, n_edges=60, n_nodes=N_NODES, n_preds=N_PREDS):
    rng = np.random.default_rng(seed)
    t = np.stack([rng.integers(0, n_nodes, n_edges),
                  rng.integers(0, n_preds, n_edges),
                  rng.integers(0, n_nodes, n_edges)], axis=1)
    return np.unique(t, axis=0)


def _engine(triples, n_nodes=N_NODES, n_preds=N_PREDS, **kwargs):
    table = LabelTable.terminals([2] * n_preds)
    grammar, _ = compress(Hypergraph.from_triples(triples, n_nodes), table)
    kwargs.setdefault("cache", None)
    kwargs.setdefault("crossover", 0)
    kwargs.setdefault("delta_budget", None)
    return TripleQueryEngine(grammar, **kwargs)


def _assert_parity(query_fn, oracle_engine, probe_rows):
    """Every pattern bound from every probe row must match the oracle."""
    for row in probe_rows:
        s, p, o = map(int, row)
        for pattern in PATTERN_NAMES:
            qs, qp, qo = _bind(pattern, s, p, o)
            got = sorted(query_fn(qs, qp, qo))
            want = sorted(oracle_engine.query_scalar(qs, qp, qo))
            assert got == want, (pattern, (s, p, o))


def _mutate_and_logical(target, base):
    """Apply a fixed insert/delete interleaving to `target` (engine-like
    mutation surface); returns (logical rows, probe rows). The expected
    set is tracked in plain Python, independent of the delta code."""
    base_set = {tuple(map(int, r)) for r in base}
    ins1 = np.array([[1, 0, 14], [2, 1, 3], [13, 2, 0], [0, 0, 0]])
    del1 = base[:5]
    ins2 = np.concatenate([del1[:2], ins1[:1]])  # resurrect 2, re-insert 1
    del2 = ins1[1:2]                             # un-buffer one overlay insert
    logical = set(base_set)
    for rows, op in ((ins1, "i"), (del1, "d"), (ins2, "i"), (del2, "d")):
        applied = target.insert_triples(rows) if op == "i" \
            else target.delete_triples(rows)
        want = {tuple(map(int, r)) for r in rows}
        expected = len(want - logical) if op == "i" else len(want & logical)
        assert applied == expected
        logical = logical | want if op == "i" else logical - want
    probes = np.concatenate([base[5:7], ins1[:2], del1[:2], del2])
    return np.array(sorted(logical)), probes


# ------------------------------------------------------------- delta unit
def test_delta_overlay_set_semantics():
    d = DeltaOverlay()
    assert d.is_empty and d.size == 0
    rows = np.array([[1, 0, 2], [3, 1, 4]])
    assert d.insert_rows(rows) == 2
    assert d.n_inserts == 2 and d.n_tombstones == 0
    # deleting an overlay insert un-buffers it
    assert d.delete_rows(rows[:1]) == 1
    assert d.n_inserts == 1 and d.n_tombstones == 0
    # deleting a base row tombstones it
    base_row = np.array([[9, 2, 9]])
    assert d.delete_rows(base_row) == 1
    assert d.n_tombstones == 1
    # re-inserting a tombstoned row resurrects (tombstone dropped)
    assert d.insert_rows(base_row) == 1
    assert d.n_tombstones == 0 and d.n_inserts == 1
    assert d.size == 1
    d.clear()
    assert d.is_empty


def test_delta_apply_keeps_base_duplicates():
    d = DeltaOverlay()
    base = np.array([[1, 0, 2], [1, 0, 2], [3, 0, 4]])
    d.insert_rows(np.array([[5, 1, 6]]))
    d.delete_rows(np.array([[3, 0, 4]]))
    out = {tuple(r) for r in d.apply(base)}
    assert out == {(1, 0, 2), (5, 1, 6)}
    # both duplicate copies of a surviving base row are kept
    assert len(d.apply(base)) == 3


def test_resolve_delta_budget_spellings(monkeypatch):
    monkeypatch.delenv("ITR_DELTA_BUDGET", raising=False)
    from repro.core.delta import DEFAULT_DELTA_BUDGET

    assert resolve_delta_budget() == DEFAULT_DELTA_BUDGET
    for spelling in ("off", "NONE", " never "):
        monkeypatch.setenv("ITR_DELTA_BUDGET", spelling)
        assert resolve_delta_budget() is None
    monkeypatch.setenv("ITR_DELTA_BUDGET", "128")
    assert resolve_delta_budget() == 128
    monkeypatch.setenv("ITR_DELTA_BUDGET", "0")
    assert resolve_delta_budget() == 0
    monkeypatch.setenv("ITR_DELTA_BUDGET", "-5")
    assert resolve_delta_budget() is None
    monkeypatch.setenv("ITR_DELTA_BUDGET", "not-a-number")
    assert resolve_delta_budget() == DEFAULT_DELTA_BUDGET
    # explicit values bypass the environment entirely
    assert resolve_delta_budget(7) == 7
    assert resolve_delta_budget(-1) is None


def test_mutation_batch_validation():
    eng = _engine(_unique_triples(0))
    with pytest.raises(ValueError):
        eng.insert_triples(np.array([[1, 2]]))        # wrong shape
    with pytest.raises(ValueError):
        eng.insert_triples(np.array([[-1, 0, 2]]))    # negative id
    with pytest.raises(ValueError):
        eng.insert_triples(np.array([[1, N_PREDS, 2]]))  # unknown predicate
    assert eng.insert_triples(np.zeros((0, 3), dtype=np.int64)) == 0
    assert eng.delta.is_empty
    # a rank-1 terminal (ITR+ node-label style) is not a triple predicate
    table = LabelTable.terminals([2] * N_PREDS + [1])
    grammar, _ = compress(
        Hypergraph.from_triples(_unique_triples(0), N_NODES), table)
    eng1 = TripleQueryEngine(grammar, cache=None, crossover=0,
                             delta_budget=None)
    with pytest.raises(ValueError):
        eng1.insert_triples(np.array([[1, N_PREDS, 2]]))


# ------------------------------------------------------------ engine level
def test_engine_overlay_parity_and_rebuild():
    base = _unique_triples(1)
    eng = _engine(base)
    logical, probes = _mutate_and_logical(eng, base)
    assert not eng.delta.is_empty
    assert {tuple(r) for r in eng.current_triples()} == \
        {tuple(map(int, r)) for r in logical}
    oracle = _engine(logical)
    _assert_parity(eng.query, oracle, probes)
    # rebuild recompresses base+delta; results must not change
    assert eng.rebuild() is True
    assert eng.delta.is_empty and eng.rebuild_count == 1
    assert eng.rebuild() is False  # empty overlay: no-op
    _assert_parity(eng.query, oracle, probes)


def test_engine_insert_grows_node_universe_on_rebuild():
    base = _unique_triples(2)
    eng = _engine(base)
    eng.insert_triples(np.array([[1, 0, 99]]))
    assert (0, (1, 99)) in eng.query(1, 0, None)      # overlay answers
    assert eng.query(99, None, None) == []            # 99 has no out-edges
    eng.rebuild()
    assert eng.grammar.start.n_nodes >= 100
    assert (0, (1, 99)) in eng.query(1, 0, None)      # compressed answers


def test_engine_auto_rebuild_at_budget():
    base = _unique_triples(3)
    eng = _engine(base, delta_budget=0)  # recompress after every mutation
    assert eng.insert_triples(np.array([[2, 1, 5]])) in (0, 1)
    assert eng.delta.is_empty  # either a no-op or immediately rebuilt
    eng2 = _engine(base, delta_budget=2)
    new_rows = np.array([[0, 0, 14], [14, 1, 0], [7, 2, 8]])
    new_rows = new_rows[~np.array(
        [tuple(r) in {tuple(b) for b in base} for r in new_rows.tolist()])]
    eng2.insert_triples(new_rows[:1])
    assert eng2.rebuild_count == 0                    # within budget
    eng2.insert_triples(new_rows[1:])
    assert eng2.rebuild_count == 1 and eng2.delta.is_empty


def test_rebuild_reuses_build_config():
    """Budget-triggered auto-rebuilds must recompress with the config the
    engine/service was built with, not silently fall back to defaults."""
    from repro.core import RepairConfig

    cfg = RepairConfig(max_iters=0)  # distinctive: no rules at all
    base = _unique_triples(13)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=2,
                                     strategy="predicate_hash", config=cfg,
                                     delta_budget=0)
    assert all(e.config is cfg for e in svc.engines)
    rows = np.array([[0, 1, 14], [14, 0, 0]])
    rows = rows[~np.array([tuple(r) in {tuple(b) for b in base}
                           for r in rows.tolist()])]
    svc.insert_triples(rows)  # budget 0 -> auto-rebuild inside the engine
    for e in svc.engines:
        assert e.delta.is_empty
        assert len(e.grammar.rules) == 0  # max_iters=0 config survived
        assert e.config is cfg


def test_query_fast_path_includes_overlay():
    """The cache-less selective fast path must not bypass the overlay."""
    base = _unique_triples(4)
    eng = _engine(base, crossover=4)  # fast path active (crossover >= 1)
    assert eng.cache is None
    eng.insert_triples(np.array([[1, 0, 13]]))
    assert (0, (1, 13)) in eng.query(1, None, None)
    eng.delete_triples(base[:1])
    s, p, o = map(int, base[0])
    assert (p, (s, o)) not in eng.query(s, p, o)


def test_neighbors_include_overlay():
    base = _unique_triples(5)
    eng = _engine(base)
    eng.insert_triples(np.array([[3, 1, 11]]))
    assert 11 in eng.neighbors_out(3)
    assert 3 in eng.neighbors_in(11)


def test_mutation_bumps_engine_cache_generation():
    from repro.core import QueryResultCache

    base = _unique_triples(6)
    cache = QueryResultCache()
    eng = _engine(base, cache=cache)
    s = int(base[0][0])
    warm = eng.query(s, None, None)
    assert eng.query(s, None, None) == warm  # cache hit path
    gen = cache.generation()
    eng.insert_triples(np.array([[s, 0, 12], [s, 0, 13]]))
    assert cache.generation() > gen
    got = eng.query(s, None, None)
    assert (0, (s, 12)) in got and (0, (s, 13)) in got  # no stale entry


# ----------------------------------------------------------- sharded tier
@pytest.mark.parametrize("strategy", ["predicate_hash", "node_range"])
@pytest.mark.parametrize("n_shards", [2, 3])
def test_sharded_mutation_parity(strategy, n_shards):
    base = _unique_triples(7, n_edges=80)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS,
                                     n_shards=n_shards, strategy=strategy,
                                     delta_budget=None)
    logical, probes = _mutate_and_logical(svc, base)
    oracle = _engine(logical)
    assert sum(svc.delta_sizes()) > 0
    _assert_parity(svc.query, oracle, probes)          # overlay path
    rebuilt = svc.rebuild(force=True)                  # forced incremental
    assert rebuilt and all(e.delta.is_empty for e in svc.engines)
    assert svc.rebuild(force=True) == []               # all clean now
    _assert_parity(svc.query, oracle, probes)          # compressed path


def test_sharded_mutation_bumps_only_mutated_shards():
    base = _unique_triples(8, n_edges=80)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=4,
                                     strategy="predicate_hash",
                                     delta_budget=None)
    gens = [svc.cache.generation(k) for k in range(4)]
    # all mutation rows share one predicate -> exactly one owning shard
    rows = np.array([[1, 1, 2], [3, 1, 4], [5, 1, 6]])
    target = int(svc.plan.route_triples(rows)[0])
    assert svc.insert_triples(rows) > 0
    for k in range(4):
        if k == target:
            assert svc.cache.generation(k) > gens[k]
        else:  # unmutated shards keep their warm entries
            assert svc.cache.generation(k) == gens[k]
    # merged cross-shard namespace depends on every shard: always bumped
    assert svc.cache.generation(_MERGED_SHARD) > 0


def test_sharded_budget_rebuilds_only_dirty_shard():
    base = _unique_triples(9, n_edges=80)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=4,
                                     strategy="predicate_hash",
                                     delta_budget=1)
    rows = np.array([[0, 2, 1], [2, 2, 3], [4, 2, 5], [6, 2, 7]])
    rows = rows[~np.array([tuple(r) in {tuple(b) for b in base}
                           for r in rows.tolist()])]
    target = int(svc.plan.route_triples(rows)[0])
    counts_before = [e.rebuild_count for e in svc.engines]
    svc.insert_triples(rows)  # 1 shard exceeds budget -> auto-rebuild
    for k, e in enumerate(svc.engines):
        expect = counts_before[k] + (1 if k == target else 0)
        assert e.rebuild_count == expect
    assert svc.stats.rebuilds == 1
    assert svc.engines[target].delta.is_empty
    for s, p, o in rows:
        assert (int(p), (int(s), int(o))) in svc.query(int(s), int(p), int(o))


def test_sharded_warm_scatter_results_refresh_after_mutation():
    base = _unique_triples(10, n_edges=80)
    svc = ShardedTripleService.build(base, N_NODES, N_PREDS, n_shards=3,
                                     strategy="node_range", delta_budget=None)
    before = svc.query(None, 1, None)    # ?P? scatters; merged entry cached
    assert svc.query(None, 1, None) == before
    row = np.array([[2, 1, 9]])
    if svc.insert_triples(row) == 0:     # already present: delete instead
        svc.delete_triples(row)
        assert (1, (2, 9)) not in svc.query(None, 1, None)
    else:
        assert (1, (2, 9)) in svc.query(None, 1, None)


# ------------------------------------------------------- service fronts
def test_triple_service_mutation_stats():
    base = _unique_triples(11)
    svc = TripleQueryService(_engine(base))
    n = svc.insert_triples(np.array([[1, 0, 11], [2, 1, 12]]))
    assert n == svc.stats.inserted == 2
    assert svc.delete_triples(base[:3]) == svc.stats.deleted == 3
    assert svc.query_many([(1, 0, None)])[0]  # flush sees the overlay
    assert svc.rebuild() is True and svc.stats.rebuilds == 1
    assert svc.rebuild() is False and svc.stats.rebuilds == 1


def test_graph_store_mutation():
    base = _unique_triples(12)
    store = GraphStore.from_triples(base, N_NODES, N_PREDS)
    indptr, _ = store.csr()  # materialize, then mutate
    new = np.array([[1, 0, 13]])
    present = tuple(new[0]) in {tuple(r) for r in base}
    n = store.insert_triples(new)
    assert n == (0 if present else 1)
    store.delete_triples(base[:2])
    # point path and training views both reflect the overlay
    assert (0, (1, 13)) in store.triples(1, 0, None)
    indptr2, indices2 = store.csr()
    s0, _, o0 = map(int, base[0])
    assert o0 not in indices2[indptr2[s0]:indptr2[s0 + 1]] or \
        (base[:2, 0] != s0).all()
    assert 13 in indices2[indptr2[1]:indptr2[2]]
    with pytest.raises(ValueError):  # fixed node universe
        store.insert_triples(np.array([[1, 0, N_NODES]]))
    assert store.rebuild() is True
    assert store.grammar is store.engine.grammar  # refs refreshed
    assert (0, (1, 13)) in store.triples(1, 0, None)


def test_route_triples_validates_shape():
    from repro.distributed.partition import make_plan

    plan = make_plan("predicate_hash", 2, N_NODES, N_PREDS)
    with pytest.raises(ValueError):
        plan.route_triples(np.array([1, 2, 3]))
    shards = plan.route_triples(np.array([[1, 0, 2]]))
    assert shards.shape == (1,) and 0 <= shards[0] < 2
