"""Regression + property tests for the N-Triples reader/writer.

Pins the three parser bugfixes: the blank-node pattern no longer swallows
a statement terminator with no preceding space (`_:b1.`), literal bodies
are escaped on write / unescaped on read (so parse -> write -> parse is
the identity on adversarial literals), and malformed lines are counted
and surfaced instead of silently dropped.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.rdf import (
    ParseReport,
    decode_term,
    encode_term,
    escape_literal,
    iter_ntriples,
    parse_ntriples,
    unescape_literal,
    write_ntriples,
)


# ---------------- blank-node terminator (regression) ----------------
def test_blank_node_does_not_swallow_terminator(tmp_path):
    path = tmp_path / "b.nt"
    path.write_text(
        "<http://a> <http://p> _:b1.\n"       # no space before the '.'
        "<http://a> <http://p2> _:b1 .\n")    # conventional spacing
    triples, nodes, preds, report = parse_ntriples(str(path))
    assert report.malformed == 0 and report.statements == 2
    assert "_:b1" in nodes and "_:b1." not in nodes
    # both spellings must resolve to the SAME node id
    assert triples[0, 2] == triples[1, 2]


def test_blank_node_inner_dots_kept(tmp_path):
    path = tmp_path / "b.nt"
    path.write_text("_:a.b-c <http://p> _:x.\n")
    _, nodes, _, report = parse_ntriples(str(path))
    assert report.malformed == 0
    assert nodes == ["_:a.b-c", "_:x"]


# ---------------- malformed-line reporting (regression) ----------------
def test_malformed_lines_counted_and_sampled(tmp_path):
    path = tmp_path / "m.nt"
    path.write_text(
        "# a comment\n"
        "\n"
        "<http://a> <http://p> <http://b> .\n"
        "this is junk\n"
        "<http://only> <http://two-terms>\n"
        "<http://a> <http://p> <http://c> .\n")
    triples, _, _, report = parse_ntriples(str(path))
    assert len(triples) == 2
    assert report.statements == 2
    assert report.malformed == 2
    assert report.samples == ["this is junk", "<http://only> <http://two-terms>"]
    assert report.lines == 6  # comments/blanks counted as lines, not malformed
    d = report.as_dict()
    assert d["malformed"] == 2 and len(d["samples"]) == 2


def test_malformed_sampling_caps():
    report = ParseReport()
    for i in range(20):
        report.record_malformed(f"junk {i}")
    assert report.malformed == 20
    assert len(report.samples) == ParseReport._MAX_SAMPLES


# ---------------- literal escaping (regression) ----------------
def test_literal_escape_unescape_inverse():
    body = 'he said "hi"\\\n\t\r done'
    assert unescape_literal(escape_literal(body)) == body
    assert unescape_literal(r"A\U00000042") == "AB"
    with pytest.raises(ValueError):
        unescape_literal(r"\q")


def test_term_encode_decode_inverse():
    for term in ('"a\nb"@en', '"q\\"uote"^^<http://t>', "<http://iri>",
                 "_:b7", '"plain"', '"@fake-suffix"@en'):
        assert decode_term(encode_term(decode_term(term))) == decode_term(term)


def test_write_escapes_literals(tmp_path):
    # the canonical decoded form holds the RAW body text
    nodes = ["<http://s>", '"multi\nline "quoted""@en']
    preds = ["<http://p>"]
    triples = np.array([[0, 0, 1]], dtype=np.int64)
    path = tmp_path / "w.nt"
    write_ntriples(str(path), triples, nodes, preds)
    # the file must stay one-line-per-statement (newline escaped on write)
    lines = [ln for ln in path.read_text().splitlines() if ln.strip()]
    assert len(lines) == 1
    t2, n2, p2, report = parse_ntriples(str(path))
    assert report.malformed == 0
    assert n2 == nodes and p2 == preds
    assert np.array_equal(t2, triples)


# ---------------- property round-trip over adversarial terms ----------------
# the hypothesis fallback shim has no `text` strategy, so adversarial terms
# come from a fixed pool covering every spelling class: IRIs, blank nodes
# (incl. dotted labels), plain / lang-tagged / datatyped literals, quotes,
# backslashes, newlines, tabs, and a literal containing " . "
_NODE_POOL = [
    "<http://ex.org/a>",
    "<http://ex.org/b#frag>",
    "_:b1",
    "_:x.y-z",
    '"plain"',
    '"with "inner" quotes"@en',
    '"line\nbreak"@en-GB',
    '"tab\there"^^<http://www.w3.org/2001/XMLSchema#string>',
    '"back\\slash \\ again"',
    '"looks like a terminator . <http://not-a-term>"',
    '"1.5"^^<http://www.w3.org/2001/XMLSchema#double>',
]
_PRED_POOL = ["<http://ex.org/p0>", "<http://ex.org/p1>", "<http://ex.org/p2>"]


@settings(max_examples=25)
@given(st.lists(
    st.tuples(st.integers(0, len(_NODE_POOL) - 1),
              st.integers(0, len(_PRED_POOL) - 1),
              st.integers(0, len(_NODE_POOL) - 1)),
    min_size=1, max_size=30))
def test_roundtrip_adversarial_terms(idx_triples):
    # no tmp_path here: the hypothesis fallback shim cannot mix fixtures
    # with @given, so the test manages its own temp dir
    import tempfile

    rows = np.array(idx_triples, dtype=np.int64)
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/adv.nt"
        write_ntriples(path, rows, _NODE_POOL, _PRED_POOL)
        triples, nodes, preds, report = parse_ntriples(path)
        assert report.malformed == 0
        assert report.statements == len(rows)
        want = {(_NODE_POOL[s], _PRED_POOL[p], _NODE_POOL[o]) for s, p, o in rows}
        got = {(nodes[s], preds[p], nodes[o]) for s, p, o in triples}
        assert got == want
        # and a second write -> parse is byte-identical on the dictionaries
        path2 = f"{d}/adv2.nt"
        write_ntriples(path2, triples, nodes, preds)
        t2, n2, p2, _ = parse_ntriples(path2)
        assert n2 == nodes and p2 == preds and np.array_equal(t2, triples)


def test_iter_ntriples_streams_from_any_line_iterable():
    lines = ['<http://a> <http://p> "x\\ny" .', "junk"]
    report = ParseReport()
    rows = list(iter_ntriples(lines, report))
    assert rows == [("<http://a>", "<http://p>", '"x\ny"')]
    assert report.malformed == 1
