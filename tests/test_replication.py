"""Read-replica tier: WAL tail cursors, snapshot seeding, dispatch, and
the replica-consistency oracle.

Three layers of coverage:

* `repro.persist.wal` incremental tailing — torn final record mid-tail
  (the cursor stops cleanly at the damage and resumes once the append
  completes) and tailing across a ``reset()`` compaction (the cursor
  must see ``truncated`` and NEVER silently rescan from offset 0).
* `repro.serve.replication` mechanics — knob resolution, dispatch
  policies and the lag bound, group-private cache generations, reseed
  failover after a snapshot compacts the log under a lagging group,
  rebalance state propagating through the WAL feed, and idempotent
  close across the whole service hierarchy.
* the replica-consistency oracle (the CI acceptance bar): after a
  quiesce (``sync_replicas`` with no concurrent mutations) every
  replica group answers all 8 (S,P,O) pattern shapes identically to
  the primary, for both partition strategies, including after a forced
  lag-induced reseed. The nightly lane adds a churn variant
  (``@slow``): concurrent mutations + dispatched reads + periodic
  syncs and snapshots, budget via ``ITR_CHURN_SECONDS``.
"""
import os
import threading
import time
import zlib

import numpy as np
import pytest

from repro.distributed.partition import STRATEGIES
from repro.persist.service import DurableShardedService
from repro.persist.wal import (
    _FRAME,
    MAGIC,
    WalCursor,
    WriteAheadLog,
    tail_wal_records,
)
from repro.serve.replication import (
    DEFAULT_MAX_LAG,
    resolve_replica_dispatch,
    resolve_replica_max_lag,
    resolve_replicas,
)

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]

N_NODES, N_PREDS = 24, 4


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


def _oracle_query(triples: set, s, p, o) -> list[tuple]:
    """Reference answer in the service's result shape: (p, (s, o))."""
    return sorted(
        (tp, (ts, to)) for ts, tp, to in triples
        if (s is None or ts == s) and (p is None or tp == p)
        and (o is None or to == o))


def _check_all_patterns(svc, oracle: set, probe, ctx="") -> None:
    s, p, o = (int(v) for v in probe)
    for pattern in PATTERN_NAMES:
        qs, qp, qo = _bind(pattern, s, p, o)
        got = sorted(svc.query(qs, qp, qo))
        want = _oracle_query(oracle, qs, qp, qo)
        assert got == want, (ctx, pattern, (s, p, o))


def _rand_rows(rng, k, n_nodes=N_NODES, n_preds=N_PREDS) -> np.ndarray:
    return np.stack([rng.integers(0, n_nodes, k),
                     rng.integers(0, n_preds, k),
                     rng.integers(0, n_nodes, k)], axis=1)


def _probes(rng, oracle: set, k=3):
    live = sorted(oracle)
    out = [live[int(rng.integers(0, len(live)))] for _ in range(k) if live]
    out.append(tuple(int(v) for v in _rand_rows(rng, 1)[0]))
    return out


def _build(tmp_path, *, strategy="predicate_hash", n_shards=3, seed=0,
           n_edges=60, **kwargs):
    rng = np.random.default_rng(seed)
    base = np.unique(_rand_rows(rng, n_edges), axis=0)
    oracle = {tuple(map(int, r)) for r in base}
    svc = DurableShardedService.build(
        base, N_NODES, N_PREDS, root=str(tmp_path / "store"),
        n_shards=n_shards, strategy=strategy, fsync=False,
        rebalance_skew=None, serve_threads=1, **kwargs)
    return svc, oracle, rng


def _mutate(svc, oracle, rng, n_ins=12, n_del=5):
    ins = _rand_rows(rng, n_ins)
    svc.insert_triples(ins)
    oracle.update(tuple(map(int, r)) for r in ins)
    if oracle and n_del:
        live = sorted(oracle)
        idx = rng.integers(0, len(live), min(n_del, len(live)))
        dele = np.array([live[int(i)] for i in idx], dtype=np.int64)
        svc.delete_triples(dele)
        oracle.difference_update(tuple(map(int, r)) for r in dele)


# -- WAL tailing edge cases ---------------------------------------------


def test_tail_wal_records_incremental(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(b"alpha")
    mid = wal.offset
    wal.append(b"beta")
    wal.append(b"gamma")

    recs, report = tail_wal_records(path, len(MAGIC))
    assert recs == [b"alpha", b"beta", b"gamma"]
    assert report.valid_bytes == wal.offset and not report.truncated

    recs, report = tail_wal_records(path, mid)
    assert recs == [b"beta", b"gamma"] and not report.truncated
    # fully caught up: nothing new, offset parked at the end
    recs, report = tail_wal_records(path, wal.offset)
    assert recs == [] and report.valid_bytes == wal.offset
    wal.close()


def test_wal_cursor_resumes_across_appends(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    cur = WalCursor(path)
    wal.append(b"one")
    recs, _ = cur.tail()
    assert recs == [b"one"] and cur.records == 1
    wal.append(b"two")
    wal.append(b"three")
    recs, _ = cur.tail()
    assert recs == [b"two", b"three"] and cur.records == 3
    assert cur.offset == wal.offset
    recs, _ = cur.tail()
    assert recs == []
    wal.close()


def test_tail_stops_cleanly_at_torn_final_record(tmp_path):
    """A torn final record mid-tail stops the cursor at the damage; once
    the append completes the same cursor resumes and reads it."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(b"committed")
    cur = WalCursor(path)
    recs, _ = cur.tail()
    assert recs == [b"committed"]
    parked = cur.offset

    payload = b"torn-in-half"
    frame = _FRAME.pack(len(payload), zlib.crc32(payload)) + payload
    half = len(frame) // 2
    with open(path, "ab") as f:
        f.write(frame[:half])  # the kill-mid-append simulation

    recs, report = cur.tail()
    assert recs == [] and report.torn_tail and not report.truncated
    assert cur.offset == parked  # parked exactly at the damage

    with open(path, "ab") as f:
        f.write(frame[half:])  # append completes
    recs, report = cur.tail()
    assert recs == [payload] and not report.torn_tail
    assert cur.records == 2
    wal.close()


def test_tail_across_reset_detects_truncation(tmp_path):
    """Compaction under a live cursor must surface ``truncated`` — never
    a silent replay from offset 0 (which would double-apply history)."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    for i in range(3):
        wal.append(b"rec%d" % i)
    cur = WalCursor(path)
    cur.tail()
    assert cur.records == 3
    parked = cur.offset

    assert wal.resets == 0
    wal.reset()  # snapshot() compacts the log exactly like this
    assert wal.resets == 1 and wal.n_records == 0
    assert wal.offset == len(MAGIC)

    recs, report = cur.tail()
    assert report.truncated
    assert recs == []              # NOT the pre-reset records again
    assert cur.offset == parked    # cursor did not move
    assert cur.records == 3

    # even after the log regrows, a shorter-than-cursor file still reads
    # truncated; regrowth PAST the old offset is the resets-counter case
    wal.append(b"fresh")
    assert wal.offset < parked
    recs, report = cur.tail()
    assert report.truncated and recs == []
    wal.close()


def test_wal_bookkeeping_survives_reopen(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(b"a")
    wal.append(b"bb")
    end = wal.offset
    wal.close()
    wal2 = WriteAheadLog(path, fsync=False)
    assert wal2.offset == end and wal2.n_records == 2
    assert wal2.resets == 0  # incarnation counter is per-handle
    wal2.close()


# -- knob resolution ------------------------------------------------------


def test_resolve_replicas(monkeypatch):
    assert resolve_replicas(3) == 3
    assert resolve_replicas(0) == 0
    assert resolve_replicas(-2) == 0
    assert resolve_replicas("off") == 0
    monkeypatch.delenv("ITR_REPLICAS", raising=False)
    assert resolve_replicas() == 0
    monkeypatch.setenv("ITR_REPLICAS", "2")
    assert resolve_replicas() == 2
    monkeypatch.setenv("ITR_REPLICAS", "banana")
    assert resolve_replicas() == 0


def test_resolve_replica_dispatch(monkeypatch):
    assert resolve_replica_dispatch("least_loaded") == "least_loaded"
    assert resolve_replica_dispatch("sideways") == "round_robin"
    monkeypatch.setenv("ITR_REPLICA_DISPATCH", "least_loaded")
    assert resolve_replica_dispatch() == "least_loaded"
    monkeypatch.delenv("ITR_REPLICA_DISPATCH")
    assert resolve_replica_dispatch() == "round_robin"


def test_resolve_replica_max_lag(monkeypatch):
    assert resolve_replica_max_lag(0) == 0
    assert resolve_replica_max_lag(7) == 7
    assert resolve_replica_max_lag(-1) is None
    assert resolve_replica_max_lag("off") is None
    assert resolve_replica_max_lag("unbounded") is None
    monkeypatch.delenv("ITR_REPLICA_MAX_LAG", raising=False)
    assert resolve_replica_max_lag() == DEFAULT_MAX_LAG
    monkeypatch.setenv("ITR_REPLICA_MAX_LAG", "64")
    assert resolve_replica_max_lag() == 64


# -- replica-consistency oracle -------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_replica_parity_after_quiesce(tmp_path, strategy):
    """The acceptance bar: after quiesce every replica group answers all
    8 pattern shapes identically to the primary, on both strategies."""
    svc, oracle, rng = _build(tmp_path, strategy=strategy, replicas=2)
    try:
        mgr = svc.replicas
        assert mgr is not None and len(mgr.groups) == 2
        for _ in range(3):
            _mutate(svc, oracle, rng)
        svc.sync_replicas()
        stats = svc.replica_stats()
        assert stats["max_lag_records"] == 0
        assert stats["stale_groups"] == 0
        for probe in _probes(rng, oracle):
            # through the router's dispatch path (replicas serve)...
            _check_all_patterns(svc, oracle, probe, ctx=f"dispatch/{strategy}")
            # ...and pinned to each group directly
            for g in mgr.groups:
                _check_all_patterns(g.service, oracle, probe,
                                    ctx=f"group{g.index}/{strategy}")
        assert svc.service.stats.replica_flushes > 0
    finally:
        svc.close()


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_forced_lag_reseed_parity(tmp_path, strategy):
    """snapshot() while groups lag compacts the log underneath their
    cursors: sync must reseed (never silently replay) and land at exact
    parity, including records appended after the compaction."""
    svc, oracle, rng = _build(tmp_path, strategy=strategy, replicas=2)
    try:
        mgr = svc.replicas
        _mutate(svc, oracle, rng)         # groups now lag
        svc.snapshot()                     # WAL reset under their cursors
        _mutate(svc, oracle, rng)          # post-compaction history
        post = svc.wal.n_records
        svc.sync_replicas()
        for g in mgr.groups:
            assert g.reseeds == 1
            assert g.records == post       # only post-snapshot records
        stats = svc.replica_stats()
        assert stats["max_lag_records"] == 0 and stats["stale_groups"] == 0
        for probe in _probes(rng, oracle):
            _check_all_patterns(svc, oracle, probe, ctx="post-reseed")
            for g in mgr.groups:
                _check_all_patterns(g.service, oracle, probe,
                                    ctx=f"post-reseed group{g.index}")
    finally:
        svc.close()


def test_open_seeds_replicas_from_disk(tmp_path):
    svc, oracle, rng = _build(tmp_path)
    _mutate(svc, oracle, rng)
    root = svc.root
    svc.close()
    svc2 = DurableShardedService.open(root, fsync=False, replicas=1,
                                      serve_threads=1)
    try:
        assert svc2.replicas is not None
        assert svc2.replica_stats()["max_lag_records"] == 0
        for probe in _probes(rng, oracle):
            _check_all_patterns(svc2, oracle, probe, ctx="open")
            _check_all_patterns(svc2.replicas.groups[0].service, oracle,
                                probe, ctx="open group0")
    finally:
        svc2.close()


# -- dispatch -------------------------------------------------------------


def test_lag_bound_gates_dispatch(tmp_path):
    """max_lag=0: a group one record behind stops serving flushes until
    an explicit sync catches it up."""
    svc, oracle, rng = _build(tmp_path)
    try:
        mgr = svc.enable_replication(1, max_lag=0, auto_sync=False)
        assert svc.query(None, 0, None) is not None
        served = svc.service.stats.replica_flushes
        assert served > 0 and mgr.groups[0].flushes == served

        _mutate(svc, oracle, rng, n_ins=4, n_del=0)  # lag > 0 now
        assert mgr.stats()["groups"][0]["dispatchable"] is False
        svc.query(None, 1, None)
        assert svc.service.stats.replica_flushes == served  # primary served

        svc.sync_replicas()
        svc.query(None, 1, None)
        assert svc.service.stats.replica_flushes == served + 1
    finally:
        svc.close()


def test_round_robin_rotates_groups(tmp_path):
    svc, _, _ = _build(tmp_path, replicas=2, replica_max_lag="off",
                       replica_dispatch="round_robin")
    try:
        mgr = svc.replicas
        for p in range(4):
            svc.query(None, p % N_PREDS, None)
        assert [g.flushes for g in mgr.groups] == [2, 2]
    finally:
        svc.close()


def test_least_loaded_avoids_busy_group(tmp_path):
    svc, _, _ = _build(tmp_path, replicas=2, replica_max_lag="off",
                       replica_dispatch="least_loaded")
    try:
        mgr = svc.replicas
        mgr.groups[0].in_flight = 5  # pretend group 0 is saturated
        for p in range(3):
            svc.query(None, p % N_PREDS, None)
        assert mgr.groups[1].flushes == 3 and mgr.groups[0].flushes == 0
        mgr.groups[0].in_flight = 0
    finally:
        svc.close()


def test_replica_serves_its_own_generation(tmp_path):
    """Cache generations: a lagging group keeps answering from its own
    (older) consistent state — primary mutations neither bleed into its
    results nor purge its warm entries — until it syncs."""
    svc, oracle, rng = _build(tmp_path)
    try:
        mgr = svc.enable_replication(1, max_lag="off", auto_sync=False)
        before = svc.query(None, 0, None)
        assert sorted(before) == _oracle_query(oracle, None, 0, None)

        old_oracle = set(oracle)
        _mutate(svc, oracle, rng, n_ins=10, n_del=3)
        assert _oracle_query(oracle, None, 0, None) != \
            _oracle_query(old_oracle, None, 0, None)

        # unbounded lag: the stale group still serves — at ITS generation
        stale = svc.query(None, 0, None)
        assert sorted(stale) == _oracle_query(old_oracle, None, 0, None)

        # the primary itself sees the new state (bypass dispatch)
        mgr_ref, svc.service._replicas = svc.service._replicas, None
        try:
            fresh = svc.query(None, 0, None)
        finally:
            svc.service._replicas = mgr_ref
        assert sorted(fresh) == _oracle_query(oracle, None, 0, None)

        svc.sync_replicas()
        assert sorted(svc.query(None, 0, None)) == \
            _oracle_query(oracle, None, 0, None)
    finally:
        svc.close()


def test_rebalance_propagates_through_wal_feed(tmp_path):
    """A forced rebalance journals plan/migration records; groups replay
    them on sync and become dispatchable again with the new routing."""
    from repro.distributed.partition import plan_to_dict

    svc, oracle, rng = _build(tmp_path, strategy="node_range", n_shards=3,
                              seed=7, replicas=1, replica_max_lag="off")
    try:
        mgr = svc.replicas
        # pile rows onto a few high subjects AFTER the build-time quantile
        # cut, so a forced re-cut actually changes the boundaries
        skewed = np.stack([
            rng.integers(N_NODES - 3, N_NODES, 120),
            rng.integers(0, N_PREDS, 120),
            rng.integers(0, N_NODES, 120)], axis=1)
        svc.insert_triples(skewed)
        oracle.update(tuple(map(int, r)) for r in skewed)
        svc.rebalance(force=True)
        assert plan_to_dict(svc.plan) != \
            plan_to_dict(mgr.groups[0].service.plan)
        # plan disagreement: the lagging group must stop serving flushes
        assert mgr.stats()["groups"][0]["dispatchable"] is False
        svc.sync_replicas()
        g = mgr.groups[0]
        assert mgr.stats()["groups"][0]["dispatchable"] is True
        assert plan_to_dict(g.service.plan) == plan_to_dict(svc.plan)
        for probe in _probes(rng, oracle):
            _check_all_patterns(svc, oracle, probe, ctx="post-rebalance")
            _check_all_patterns(g.service, oracle, probe,
                                ctx="post-rebalance group0")
    finally:
        svc.close()


# -- introspection + lifecycle --------------------------------------------


def test_replica_set_and_stats_shapes(tmp_path):
    svc, oracle, rng = _build(tmp_path, replicas=2, replica_max_lag="off")
    try:
        mgr = svc.replicas
        _mutate(svc, oracle, rng, n_ins=6, n_del=0)
        stats = svc.replica_stats()
        assert stats["n_replicas"] == 2 and stats["max_lag"] is None
        assert stats["max_lag_records"] > 0  # lag accounting is live
        assert len(stats["groups"]) == 2

        for k in range(svc.service.n_shards):
            rset = mgr.replica_set(k)
            assert len(rset) == 2
            assert rset.max_lag_records == stats["max_lag_records"]
            for rep in rset:
                assert rep.shard == k and rep.engine is not None
                assert rep.cache_ns < -2  # below the reserved namespaces
        with pytest.raises(ValueError):
            mgr.replica_set(svc.service.n_shards)

        svc.sync_replicas()
        assert svc.replica_stats()["max_lag_records"] == 0
    finally:
        svc.close()


def test_close_is_idempotent_across_hierarchy(tmp_path):
    svc, _, _ = _build(tmp_path, replicas=2)
    mgr = svc.replicas
    svc.close()
    assert mgr.closed and mgr.acquire() is None
    svc.close()                 # durable double-close: no-op
    svc.service.close()         # router close after the fact: no-op
    mgr.close()                 # manager close after the fact: no-op

    # and the other entry order: router first, then the durable wrapper
    svc2, _, _ = _build(tmp_path / "b", replicas=1)
    mgr2 = svc2.replicas
    svc2.service.close()
    assert mgr2.closed
    svc2.close()
    svc2.close()


# -- nightly churn oracle -------------------------------------------------


@pytest.mark.slow
def test_replica_churn_under_concurrent_mutations(tmp_path):
    """Nightly lane: mutator + dispatched readers + periodic syncs and
    snapshots (forced reseeds) racing for ITR_CHURN_SECONDS, then a
    quiesce and full pattern parity on every group."""
    budget = float(os.environ.get("ITR_CHURN_SECONDS", "4"))
    svc, oracle, rng = _build(tmp_path, replicas=2, n_edges=80,
                              replica_max_lag="off")
    mgr = svc.replicas
    stop = threading.Event()
    errors: list = []
    lock = threading.Lock()  # guards oracle + rng

    def mutator():
        try:
            while not stop.is_set():
                with lock:
                    _mutate(svc, oracle, rng, n_ins=6, n_del=2)
                time.sleep(0.002)
        except Exception as exc:  # pragma: no cover - surfaced below
            errors.append(exc)
            stop.set()

    def reader(seed):
        r = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                s, p, o = (int(v) for v in _rand_rows(r, 1)[0])
                for pattern in PATTERN_NAMES:
                    res = svc.query(*_bind(pattern, s, p, o))
                    for tp, (ts, to) in res:  # well-formed (p, (s, o))
                        assert 0 <= tp < N_PREDS
        except Exception as exc:  # pragma: no cover - surfaced below
            errors.append(exc)
            stop.set()

    def churner():
        try:
            i = 0
            while not stop.is_set():
                time.sleep(0.05)
                svc.sync_replicas()
                i += 1
                if i % 6 == 0:
                    svc.snapshot()  # compacts the WAL: forces reseeds
        except Exception as exc:  # pragma: no cover - surfaced below
            errors.append(exc)
            stop.set()

    threads = [threading.Thread(target=mutator)]
    threads += [threading.Thread(target=reader, args=(100 + i,))
                for i in range(3)]
    threads.append(threading.Thread(target=churner))
    for t in threads:
        t.start()
    time.sleep(budget)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    try:
        assert not errors, errors
        svc.sync_replicas()  # quiesce
        assert sum(g.reseeds for g in mgr.groups) > 0
        assert svc.replica_stats()["max_lag_records"] == 0
        for probe in _probes(rng, oracle, k=5):
            _check_all_patterns(svc, oracle, probe, ctx="churn quiesce")
            for g in mgr.groups:
                _check_all_patterns(g.service, oracle, probe,
                                    ctx=f"churn group{g.index}")
    finally:
        svc.close()
