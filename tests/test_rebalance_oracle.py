"""Randomized mutate/query/rebalance/rebuild state machine, cross-checked
against a plain-Python set oracle.

Each example drives one `ShardedTripleService` through a random
interleaving of `insert_triples` / `delete_triples` / `rebuild` /
`rebalance` (full and partial, leaving migrations in flight) and
all-8-pattern query checks, for both partition strategies and 1/2/4
shards. The oracle is a bare ``set`` of (s, p, o) tuples mutated by the
same set semantics — no engine code on the reference side — so any
divergence (stale cache entry, resurrected tombstone, row lost or
duplicated by a migration, mis-routed pattern mid-flight) shows up as a
pattern mismatch.

The tier-1 run keeps a small example budget; the nightly lane
(``pytest -m slow``, see .github/workflows/nightly.yml) re-runs the same
machine with a bigger budget and bigger graphs via ``ITR_ORACLE_EXAMPLES``.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.partition import STRATEGIES
from repro.serve.sharded import ShardedTripleService

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]

# nightly lane budget for the @slow machine (tier-1 uses the small ones)
SLOW_EXAMPLES = int(os.environ.get("ITR_ORACLE_EXAMPLES", "60"))


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


def _oracle_query(triples: set, s, p, o) -> list[tuple]:
    """Reference answer in the service's result shape: (p, (s, o))."""
    return sorted(
        (tp, (ts, to)) for ts, tp, to in triples
        if (s is None or ts == s) and (p is None or tp == p)
        and (o is None or to == o))


def _check_all_patterns(svc, oracle: set, probe) -> None:
    s, p, o = (int(v) for v in probe)
    for pattern in PATTERN_NAMES:
        qs, qp, qo = _bind(pattern, s, p, o)
        got = sorted(svc.query(qs, qp, qo))
        want = _oracle_query(oracle, qs, qp, qo)
        assert got == want, (pattern, (s, p, o),
                             svc.plan.strategy, svc.n_shards,
                             svc.migration_active)


def _rand_rows(rng, k, n_nodes, n_preds) -> np.ndarray:
    return np.stack([rng.integers(0, n_nodes, k),
                     rng.integers(0, n_preds, k),
                     rng.integers(0, n_nodes, k)], axis=1)


def _probe(rng, oracle: set, n_nodes, n_preds):
    if oracle and rng.integers(0, 4) > 0:  # mostly probe live rows
        rows = sorted(oracle)
        return rows[int(rng.integers(0, len(rows)))]
    return tuple(int(v) for v in _rand_rows(rng, 1, n_nodes, n_preds)[0])


def _run_machine(seed: int, strategy: str, n_shards: int, *, n_ops=8,
                 n_nodes=16, n_preds=4, n_edges=50, auto=False) -> None:
    rng = np.random.default_rng(seed)
    base = np.unique(_rand_rows(rng, n_edges, n_nodes, n_preds), axis=0)
    oracle = {tuple(map(int, r)) for r in base}
    # small budgets sometimes, so migrations/mutations also exercise the
    # budget-driven per-shard auto-rebuild mid-interleaving
    delta_budget = None if rng.integers(0, 2) else int(rng.integers(4, 16))
    svc = ShardedTripleService.build(
        base, n_nodes, n_preds, n_shards=n_shards, strategy=strategy,
        delta_budget=delta_budget,
        rebalance_skew=(1.0 if auto else None))

    for _ in range(n_ops):
        op = int(rng.integers(0, 100))
        if op < 30:  # insert: fresh rows + occasional live duplicates
            rows = _rand_rows(rng, int(rng.integers(1, 8)), n_nodes, n_preds)
            want = {tuple(map(int, r)) for r in rows}
            assert svc.insert_triples(rows) == len(want - oracle)
            oracle |= want
        elif op < 55:  # delete: mix of live rows and absent ones
            k = int(rng.integers(1, 8))
            pool = [list(r) for r in sorted(oracle)]
            picks = [pool[int(rng.integers(0, len(pool)))]
                     for _ in range(k)] if pool else []
            picks += _rand_rows(rng, max(1, k // 2),
                                n_nodes, n_preds).tolist()
            rows = np.asarray(picks, dtype=np.int64)
            want = {tuple(map(int, r)) for r in rows}
            assert svc.delete_triples(rows) == len(want & oracle)
            oracle -= want
        elif op < 80:  # query: all 8 patterns against the set oracle
            _check_all_patterns(svc, oracle,
                                _probe(rng, oracle, n_nodes, n_preds))
        elif op < 92:  # rebalance, sometimes leaving moves in flight
            if rng.integers(0, 2):
                svc.rebalance(force=True,
                              max_moves=int(rng.integers(1, 12)))
            else:
                svc.rebalance(force=True)
        else:  # incremental rebuild (also legal mid-migration)
            svc.rebuild(force=bool(rng.integers(0, 2)))

    if svc.stats.rebalances == 0:  # the suite's contract: >= 1 rebalance
        svc.rebalance(force=True)
    if svc.migration_active:
        svc.rebalance()  # drain
    assert not svc.migration_active

    for _ in range(2):
        _check_all_patterns(svc, oracle, _probe(rng, oracle, n_nodes, n_preds))
    # tier-level invariants after the dust settles
    assert sum(svc.live_edges()) == len(oracle)
    for k, engine in enumerate(svc.engines):
        rows = engine.current_triples()
        assert {tuple(map(int, r)) for r in rows} <= oracle
        if len(rows):  # adopted plan == physical placement, per shard
            assert (svc.plan.triple_shards(rows) == k).all()


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10**9))
def test_rebalance_oracle_state_machine(seed):
    """Explicit (incl. partial/in-flight) rebalances interleaved with
    mutations and queries: exact for every strategy and shard count."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            _run_machine(int(rng.integers(0, 2**31)), strategy, n_shards)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10**9))
def test_rebalance_oracle_auto_trigger(seed):
    """Same machine with the mutation-path auto-trigger armed at the
    lowest threshold: rebalances fire inside insert/delete calls."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        _run_machine(int(rng.integers(0, 2**31)), strategy, n_shards=2,
                     n_ops=6, auto=True)


@pytest.mark.slow
@settings(max_examples=SLOW_EXAMPLES, deadline=None)
@given(st.integers(0, 10**9))
def test_rebalance_oracle_state_machine_slow(seed):
    """Nightly-budget version: more ops, bigger graphs, more examples
    (ITR_ORACLE_EXAMPLES; see the nightly workflow lane)."""
    rng = np.random.default_rng(seed)
    for strategy in STRATEGIES:
        for n_shards in (1, 2, 4):
            _run_machine(int(rng.integers(0, 2**31)), strategy, n_shards,
                         n_ops=16, n_nodes=24, n_edges=110)
    for strategy in STRATEGIES:
        _run_machine(int(rng.integers(0, 2**31)), strategy, n_shards=4,
                     n_ops=10, auto=True)
