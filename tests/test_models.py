"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finite checks) plus model-level correctness properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf_mod

LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in ARCHS.items() if s.family == "gnn"]


def _gnn_batch(rng, n=40, e=160, d_feat=8, d_edge=4):
    return dict(
        x=jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32),
        ef=jnp.asarray(rng.normal(size=(e, d_edge)), jnp.float32),
        senders=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        receivers=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        species=jnp.asarray(rng.integers(0, 4, n), jnp.int32),
        pos=jnp.asarray(rng.normal(size=(n, 3)) * 2, jnp.float32),
        n=n,
    )


# ------------------------------------------------------------ LM smoke
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).reduced()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    loss, grads = jax.value_and_grad(lambda p: tf_mod.forward_loss(p, tokens, targets, cfg))(params)
    assert jnp.isfinite(loss), arch
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads)), arch
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2 = tf_mod.forward_loss(params2, tokens, targets, cfg)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_shapes(arch):
    cfg = get_arch(arch).reduced()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    cache = tf_mod.init_cache(cfg, batch=2, max_len=16)
    logits, cache2 = tf_mod.decode_step(params, cache, jnp.array([1, 2], jnp.int32), 0, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert jnp.isfinite(logits).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_decode_matches_teacher_forcing():
    """Greedy decode logits == teacher-forced forward logits, step by step."""
    cfg = get_arch("gemma2-9b").reduced()  # exercises local/global + softcaps
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    S = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    full = tf_mod.forward_logits(params, tokens, cfg)  # (2, S, V)
    cache = tf_mod.init_cache(cfg, batch=2, max_len=S)
    for t in range(S):
        step_logits, cache = tf_mod.decode_step(params, cache, tokens[:, t], t, cfg)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3,
        )


def test_moe_decode_matches_teacher_forcing():
    # capacity_factor high enough that no token is dropped in either the
    # grouped (teacher-forced) or per-token (decode) dispatch — capacity
    # dropping is group-size dependent by construction, so parity is only
    # defined drop-free
    import dataclasses

    cfg = dataclasses.replace(get_arch("olmoe-1b-7b").reduced(), capacity_factor=16.0)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    S = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    full = tf_mod.forward_logits(params, tokens, cfg)
    cache = tf_mod.init_cache(cfg, batch=2, max_len=S)
    for t in range(S):
        step_logits, cache = tf_mod.decode_step(params, cache, tokens[:, t], t, cfg)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3,
        )


# ------------------------------------------------------------ GNN smoke
@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(0)
    b = _gnn_batch(rng)
    key = jax.random.PRNGKey(0)
    if arch == "gcn-cora":
        params = gnn_mod.gcn_init(cfg, key, 8, 7)
        out = gnn_mod.gcn_apply(params, b["x"], b["senders"], b["receivers"], b["n"], cfg)
        assert out.shape == (b["n"], 7)
    elif arch == "gatedgcn":
        params = gnn_mod.gatedgcn_init(cfg, key, 8, 4, 7)
        out = gnn_mod.gatedgcn_apply(params, b["x"], b["ef"], b["senders"], b["receivers"], b["n"], cfg)
        assert out.shape == (b["n"], 7)
    elif arch == "meshgraphnet":
        params = gnn_mod.meshgraphnet_init(cfg, key, 8, 4, 3)
        out = gnn_mod.meshgraphnet_apply(params, b["x"], b["ef"], b["senders"], b["receivers"], b["n"], cfg)
        assert out.shape == (b["n"], 3)
    else:  # nequip
        params = gnn_mod.nequip_init(cfg, key, n_species=4)
        out = gnn_mod.nequip_apply(params, b["species"], b["pos"], b["senders"], b["receivers"], b["n"], cfg)
        assert out.shape == (b["n"], 1)
    assert jnp.isfinite(out).all()


def test_nequip_equivariance_property():
    """Scalar outputs invariant under random E(3) transforms (rotation +
    translation); this is the irrep-correctness test for the tensor product."""
    cfg = get_arch("nequip").reduced()
    rng = np.random.default_rng(3)
    b = _gnn_batch(rng)
    params = gnn_mod.nequip_init(cfg, jax.random.PRNGKey(3), n_species=4)
    f = lambda pos: gnn_mod.nequip_apply(params, b["species"], pos, b["senders"], b["receivers"], b["n"], cfg)
    base = f(b["pos"])
    for seed in range(3):
        r = np.random.default_rng(seed)
        q, _ = np.linalg.qr(r.normal(size=(3, 3)))
        if np.linalg.det(q) < 0:
            q[:, 0] *= -1
        shift = jnp.asarray(r.normal(size=(3,)), jnp.float32)
        got = f(b["pos"] @ jnp.asarray(q.T, jnp.float32) + shift)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-4, atol=1e-4)


def test_gnn_gradients_flow():
    cfg = get_arch("gatedgcn").reduced()
    rng = np.random.default_rng(4)
    b = _gnn_batch(rng)
    params = gnn_mod.gatedgcn_init(cfg, jax.random.PRNGKey(4), 8, 4, 7)
    labels = jnp.asarray(rng.integers(0, 7, b["n"]), jnp.int32)

    def loss_fn(p):
        logits = gnn_mod.gatedgcn_apply(p, b["x"], b["ef"], b["senders"], b["receivers"], b["n"], cfg)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(b["n"]), labels])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gn > 0


# ------------------------------------------------------------ DLRM smoke
def test_dlrm_smoke_train_step():
    cfg = get_arch("dlrm-mlperf").reduced()
    params = dlrm_mod.dlrm_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B = 32
    dense = jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32)
    sparse = jnp.asarray(
        np.stack([rng.integers(0, r, B) for r in cfg.row_counts], axis=1), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, B), jnp.float32)
    loss, grads = jax.value_and_grad(
        lambda p: dlrm_mod.dlrm_loss(p, dense, sparse, labels, cfg))(params)
    assert jnp.isfinite(loss)
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads))
    logits = dlrm_mod.dlrm_apply(params, dense, sparse, cfg)
    assert logits.shape == (B,)


def test_dlrm_retrieval():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    cands = jnp.asarray(rng.normal(size=(1000, 16)), jnp.float32)
    scores, idx = dlrm_mod.retrieval_scores(q, cands, k=10)
    want = np.argsort(-np.asarray(cands @ q))[:10]
    np.testing.assert_array_equal(np.asarray(idx), want)


def test_full_configs_param_counts():
    """Published parameter counts sanity: yi ~34B, gemma2 ~9B, qwen2 ~1.5B,
    phi3.5 ~42B total, olmoe ~7B total; DLRM ~22.8B (91GB/4)."""
    approx = {
        "yi-34b": (34e9, 0.10),
        "gemma2-9b": (9e9, 0.35),       # counts include the 256k-vocab embeddings
        "qwen2-1.5b": (1.5e9, 0.30),
        "phi3.5-moe-42b-a6.6b": (42e9, 0.10),
        "olmoe-1b-7b": (7e9, 0.10),
    }
    for arch, (want, tol) in approx.items():
        cfg = get_arch(arch).config()
        got = cfg.n_params()
        assert abs(got - want) / want < tol, f"{arch}: {got:.3e} vs {want:.3e}"
    # MoE active params < total
    phi = get_arch("phi3.5-moe-42b-a6.6b").config()
    assert phi.n_active_params() < phi.n_params()
    assert 5e9 < phi.n_active_params() < 9e9
