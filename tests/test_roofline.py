"""Roofline extraction: trip-count-aware HLO costs, collective parsing,
term computation."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import model_flops, parse_collectives, roofline_terms
from repro.roofline.hlo_cost import hlo_cost, parse_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_matches_unrolled_flops():
    W = jnp.ones((8, 64, 32), jnp.float32)
    x = jnp.ones((4, 64), jnp.float32)

    def body(c, w):
        return jnp.tanh((c @ w) @ w.T), None

    def scanned(x, W):
        return jax.lax.scan(body, x, W)[0]

    def unrolled(x, W):
        for i in range(8):
            x, _ = body(x, W[i])
        return x

    c_scan = hlo_cost(_compile_text(scanned, x, W), 1)
    c_unroll = hlo_cost(_compile_text(unrolled, x, W), 1)
    # dots: 8 * (2*4*64*32 + 2*4*32*64) = 262144; elementwise adds a little
    assert c_scan.flops == pytest.approx(c_unroll.flops, rel=0.02)
    assert c_scan.flops > 262144 * 0.95
    # bytes: same order (loop-carry copies vs static-slice layouts differ);
    # both far below the naive full-stack-per-iteration overcount (~2 MB)
    assert c_scan.bytes == pytest.approx(c_unroll.bytes, rel=0.5)
    assert max(c_scan.bytes, c_unroll.bytes) < 1_000_000


def test_nested_scan_trip_counts_multiply():
    W = jnp.ones((4, 3, 16, 16), jnp.float32)
    x = jnp.ones((2, 16), jnp.float32)

    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        return jax.lax.scan(inner, c, ws)[0], None

    def fn(x, W):
        return jax.lax.scan(outer, x, W)[0]

    c = hlo_cost(_compile_text(fn, x, W), 1)
    # 12 dots of 2*2*16*16 = 12288 dot flops; elementwise loop overhead on
    # top, but the nested trip multiplication (4×3) must be present
    assert 12 * 2 * 2 * 16 * 16 <= c.flops < 2 * 12 * 2 * 2 * 16 * 16


def test_dynamic_slice_of_weight_stack_charged_slice_sized():
    W = jnp.ones((100, 64, 64), jnp.float32)  # 100-layer stack
    x = jnp.ones((4, 64), jnp.float32)

    def fn(x, W):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, W)[0]

    c = hlo_cost(_compile_text(fn, x, W), 1)
    # per-iteration traffic ~ one (64,64) slice + small carry, NOT the full
    # (100,64,64) stack per iteration (which would be >160 MB)
    assert c.bytes < 100 * (64 * 64 * 4 * 4 + 4 * 64 * 4 * 8)


def test_parse_collectives_wire_model():
    hlo = """
ENTRY %main (p0: f32[16,16]) -> f32[16,16] {
  %p0 = f32[16,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%p0), channel_id=1, replica_groups=[4,4]<=[16], dimensions={0}
  %ar = f32[16,16]{1,0} all-reduce(%p0), channel_id=2, replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %cp = f32[16,16]{1,0} collective-permute(%ar), channel_id=3
}
"""
    parsed = parse_collectives(hlo, 16)
    assert parsed["all-gather"]["count"] == 1
    # all-gather result 64*16*4 = 4096B, group 4 -> wire 4096*3/4
    assert parsed["all-gather"]["wire_bytes"] == pytest.approx(4096 * 3 / 4)
    # all-reduce 1024B result, group 8 -> 2*1024*7/8
    assert parsed["all-reduce"]["wire_bytes"] == pytest.approx(2 * 1024 * 7 / 8)
    assert parsed["collective-permute"]["wire_bytes"] == 1024


def test_roofline_terms_dominance():
    t = roofline_terms(197e12 * 0.5, 819e9 * 0.1, 50e9 * 0.05)
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(0.5)
    t2 = roofline_terms(0, 819e9, 50e9 * 3)
    assert t2["dominant"] == "collective"


def test_model_flops_lm_train():
    meta = dict(family="lm", kind="train", n_active_params=1e9, global_batch=256,
                seq_len=4096, n_layers=32, n_heads=32, head_dim=128)
    f = model_flops("qwen2-1.5b", "train_4k", meta)
    assert f > 6 * 1e9 * 256 * 4096  # at least 6·N·T


def test_parse_hlo_computations():
    hlo = _compile_text(lambda x: jnp.tanh(x) @ x, jnp.ones((8, 8)))
    comps, entry = parse_hlo(hlo)
    assert entry is not None and entry in comps
    assert any(op.kind == "dot" for op in comps[entry].ops) or len(comps) > 1
