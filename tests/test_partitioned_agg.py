"""Correctness of the §Perf-D partitioned aggregation.

The shard_map path needs >1 device; the XLA host-device count is locked at
import, so the multi-device check runs in a subprocess. The host-side
helpers (partition_edges / validate_partitioning) are tested in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.distributed.collectives import partition_edges, validate_partitioning

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_partition_edges_properties():
    rng = np.random.default_rng(0)
    n, e, shards = 64, 500, 8
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    ps, pr, mask = partition_edges(s, r, n, shards)
    assert len(ps) % shards == 0
    assert validate_partitioning(pr, n, shards)
    # every real edge survives exactly once
    got = sorted(zip(ps[mask].tolist(), pr[mask].tolist()))
    want = sorted(zip(s.tolist(), r.tolist()))
    assert got == want
    # pads are in-shard rows with sender -1
    assert (ps[~mask] == -1).all()


def test_partitioned_segment_sum_single_device_fallback():
    import jax.numpy as jnp

    from repro.distributed.collectives import partitioned_segment_sum

    msgs = jnp.asarray(np.random.default_rng(1).normal(size=(16, 4)), jnp.float32)
    recv = jnp.asarray(np.random.default_rng(2).integers(0, 8, 16), jnp.int32)
    out = partitioned_segment_sum(msgs, recv, 8)
    import jax

    want = jax.ops.segment_sum(msgs, recv, num_segments=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_partitioned_segment_sum_multidevice_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import (partition_edges,
            partitioned_segment_sum, validate_partitioning)
        from repro.launch.mesh import auto_mesh, set_global_mesh

        mesh = auto_mesh((4, 2), ("data", "model"))
        set_global_mesh(mesh)
        rng = np.random.default_rng(0)
        n, e = 64, 248
        s = rng.integers(0, n, e); r = rng.integers(0, n, e)
        ps, pr, mask = partition_edges(s, r, n, 8)
        assert validate_partitioning(pr, n, 8)
        d = 16
        x = rng.normal(size=(n, d)).astype(np.float32)
        msgs = np.where(mask[:, None], x[np.maximum(ps, 0)], 0.0)

        def agg(m, rr):
            return partitioned_segment_sum(m, rr, n)

        out = jax.jit(agg)(jnp.asarray(msgs), jnp.asarray(pr.astype(np.int32)))
        want = np.zeros((n, d), np.float32)
        np.add.at(want, r, x[s])
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)

        # gradients flow through the shard_map
        g = jax.jit(jax.grad(lambda m: (agg(m, jnp.asarray(pr.astype(np.int32))) ** 2).sum()))(
            jnp.asarray(msgs))
        assert np.isfinite(np.asarray(g)).all()
        # and the compiled HLO contains NO all-reduce for the aggregation
        txt = jax.jit(agg).lower(jnp.asarray(msgs), jnp.asarray(pr.astype(np.int32))).compile().as_text()
        assert "all-reduce(" not in txt, "partitioned agg must not all-reduce"
        print("MULTIDEVICE_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=300, env=env, cwd=ROOT)
    assert "MULTIDEVICE_OK" in res.stdout, res.stdout + res.stderr
