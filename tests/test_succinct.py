"""Unit + property tests for the succinct layer (bitvector/EF/delta/k2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.succinct import (
    BitVector,
    EliasFano,
    K2Tree,
    delta_decode,
    delta_encode,
    gamma_decode,
    gamma_encode,
    pack_bits,
    unpack_bits,
)


# ---------------- bitvector ----------------
def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    for n in [0, 1, 31, 32, 33, 100, 1024, 4097]:
        bits = rng.integers(0, 2, n).astype(np.uint8)
        assert np.array_equal(unpack_bits(pack_bits(bits), n), bits)


def test_rank_select_against_naive():
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, 1000).astype(np.uint8)
    bv = BitVector(bits)
    cum = np.concatenate([[0], np.cumsum(bits)])
    for i in [0, 1, 31, 32, 33, 500, 999, 1000]:
        assert int(bv.rank1(i)) == cum[i]
        assert int(bv.rank0(i)) == i - cum[i]
    ones = np.flatnonzero(bits)
    got = bv.select1(np.arange(len(ones)))
    assert np.array_equal(got, ones)


def test_rank_batched():
    bits = np.array([1, 0, 1, 1, 0, 0, 1], dtype=np.uint8)
    bv = BitVector(bits)
    idx = np.arange(8)
    expect = np.concatenate([[0], np.cumsum(bits)])
    assert np.array_equal(bv.rank1(idx), expect)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=300))
def test_bitvector_properties(bools):
    bits = np.array(bools, dtype=np.uint8)
    bv = BitVector(bits)
    assert np.array_equal(bv.to_numpy(), bits)
    n_ones = int(bits.sum())
    assert bv.n_ones == n_ones
    if n_ones:
        sel = bv.select1(np.arange(n_ones))
        # rank(select(j)) == j and bit at select(j) is 1
        assert np.array_equal(bv.rank1(sel), np.arange(n_ones))
        assert np.all(bv.access(sel) == 1)


# ---------------- elias-fano ----------------
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000), min_size=0, max_size=200))
def test_elias_fano_roundtrip(vals):
    vals = np.sort(np.array(vals, dtype=np.int64))
    ef = EliasFano(vals)
    assert np.array_equal(ef.to_numpy(), vals)


def test_elias_fano_access_and_rank():
    vals = np.array([2, 3, 5, 7, 11, 13, 24, 24, 60], dtype=np.int64)
    ef = EliasFano(vals)
    assert int(ef.access(4)) == 11
    assert np.array_equal(ef.access(np.array([0, 8])), np.array([2, 60]))
    assert ef.rank_leq(24) == 8
    assert ef.rank_leq(1) == 0
    assert ef.rank_leq(100) == 9


def test_elias_fano_rejects_too_small_universe():
    vals = np.array([2, 5, 9], dtype=np.int64)
    # universe must exceed the max value: == max and < max both mis-split
    for bad in (9, 4, 0):
        with pytest.raises(ValueError, match="universe"):
            EliasFano(vals, universe=bad)
    with pytest.raises(ValueError, match="non-negative"):
        EliasFano(np.array([-1, 3], dtype=np.int64))
    # boundary: universe == max + 1 is the tightest legal value
    ef = EliasFano(vals, universe=10)
    assert np.array_equal(ef.to_numpy(), vals)
    # an explicit universe on an empty sequence is always fine
    assert EliasFano(np.array([], dtype=np.int64), universe=0).n == 0


def test_elias_fano_compresses_dense_runs():
    vals = np.repeat(np.arange(100), 50)  # 5000 values, universe 100
    ef = EliasFano(vals)
    assert ef.size_in_bytes() < 5000 * 4  # far smaller than raw int32


# ---------------- gamma / delta ----------------
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=2**40), min_size=0, max_size=200))
def test_delta_roundtrip(vals):
    vals = np.array(vals, dtype=np.uint64)
    words, nbits = delta_encode(vals)
    out = delta_decode(words, nbits, len(vals))
    assert np.array_equal(out, vals)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=2**30), min_size=1, max_size=100))
def test_gamma_roundtrip(vals):
    vals = np.array(vals, dtype=np.uint64)
    words, nbits = gamma_encode(vals)
    assert np.array_equal(gamma_decode(words, nbits, len(vals)), vals)


def test_delta_is_compact_for_small_values():
    vals = np.ones(1000, dtype=np.uint64)  # delta(1) = 1 bit
    words, nbits = delta_encode(vals)
    assert nbits == 1000


# ---------------- k2 tree ----------------
def _random_matrix(rng, n, m, density):
    nnz = max(1, int(n * m * density))
    r = rng.integers(0, n, nnz)
    c = rng.integers(0, m, nnz)
    return r, c


@pytest.mark.parametrize("k", [2, 4])
@pytest.mark.parametrize("shape", [(8, 8), (10, 17), (64, 3), (1, 1), (100, 100)])
def test_k2_dense_roundtrip(k, shape):
    rng = np.random.default_rng(42)
    n, m = shape
    r, c = _random_matrix(rng, n, m, 0.05)
    t = K2Tree(r, c, n, m, k=k)
    dense = np.zeros((n, m), dtype=np.uint8)
    dense[r, c] = 1
    assert np.array_equal(t.to_dense(), dense)


def test_k2_row_col_queries():
    rng = np.random.default_rng(7)
    n, m = 50, 70
    r, c = _random_matrix(rng, n, m, 0.03)
    t = K2Tree(r, c, n, m)
    dense = np.zeros((n, m), dtype=np.uint8)
    dense[r, c] = 1
    for i in range(n):
        assert np.array_equal(t.row(i), np.flatnonzero(dense[i]))
    for j in range(m):
        assert np.array_equal(t.col(j), np.flatnonzero(dense[:, j]))
    for i in range(0, n, 7):
        for j in range(0, m, 11):
            assert t.access(i, j) == dense[i, j]


def test_k2_empty():
    t = K2Tree(np.zeros(0), np.zeros(0), 16, 16)
    assert t.n_points == 0
    assert len(t.row(3)) == 0
    assert t.access(0, 0) == 0


def test_k2_batched_rows_cols_vs_dense():
    """rows_many/cols_many: one traversal for many lines == dense oracle,
    including out-of-range and duplicate queries."""
    rng = np.random.default_rng(3)
    n, m = 37, 61
    r, c = _random_matrix(rng, n, m, 0.06)
    t = K2Tree(r, c, n, m)
    dense = np.zeros((n, m), dtype=np.uint8)
    dense[r, c] = 1

    qs = np.array([0, 5, 5, -1, 36, 200, 12], dtype=np.int64)
    idx, cols = t.rows_many(qs)
    for qi in range(len(qs)):
        got = cols[idx == qi]
        want = np.flatnonzero(dense[qs[qi]]) if 0 <= qs[qi] < n else np.zeros(0)
        assert np.array_equal(got, want), f"row query {qi} ({qs[qi]})"

    qs = np.array([60, 0, 3, 3, -5], dtype=np.int64)
    idx, rows_ = t.cols_many(qs)
    for qi in range(len(qs)):
        got = rows_[idx == qi]
        want = np.flatnonzero(dense[:, qs[qi]]) if 0 <= qs[qi] < m else np.zeros(0)
        assert np.array_equal(got, want), f"col query {qi} ({qs[qi]})"

    # full-matrix batched expansion == to_dense == dense
    assert np.array_equal(t.to_dense(), dense)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_k2_batched_matches_scalar(seed):
    rng = np.random.default_rng(seed)
    n, m = 26, 19
    r, c = _random_matrix(rng, n, m, 0.08)
    t = K2Tree(r, c, n, m, k=int(rng.integers(2, 4)))
    qs = rng.integers(0, n, 8).astype(np.int64)
    idx, cols = t.rows_many(qs)
    for qi in range(len(qs)):
        assert np.array_equal(cols[idx == qi], t.row(int(qs[qi])))


def test_pallas_rank_backend_parity():
    """The Pallas bitvec_rank route must agree with the numpy rank path
    (numpy is the parity oracle), including i == n and odd batch sizes."""
    from repro.core.succinct import set_rank_backend

    rng = np.random.default_rng(11)
    bits = rng.integers(0, 2, 4097).astype(np.uint8)
    bv = BitVector(bits)
    # odd-sized batch (not a multiple of the kernel block) + boundary values
    pos = np.concatenate([rng.integers(0, bv.n + 1, 997), [0, bv.n]]).astype(np.int64)
    want = bv._rank1_numpy(pos)
    old = set_rank_backend("pallas")
    try:
        got = bv.rank1(pos)
    finally:
        set_rank_backend(old)
    assert np.array_equal(got, want)


def test_rank_backend_env_unknown_warns_then_falls_back():
    """ITR_RANK_BACKEND with an unknown value must warn once at import and
    fall back to numpy — never crash, never silently pick pallas. The knob
    is read at module import, so probe it in a fresh interpreter."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    code = (
        "import warnings\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    from repro.core.succinct import bitvector\n"
        "assert bitvector.get_rank_backend() == 'numpy', bitvector.get_rank_backend()\n"
        "msgs = [str(x.message) for x in w]\n"
        "assert any('ITR_RANK_BACKEND' in m and 'bogus' in m for m in msgs), msgs\n"
        "print('OK')\n"
    )
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = {**os.environ, "ITR_RANK_BACKEND": "bogus", "PYTHONPATH": src}
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_set_rank_backend_rejects_unknown():
    from repro.core.succinct import set_rank_backend

    with pytest.raises(ValueError):
        set_rank_backend("bogus")


def test_kernel_bitvec_rank_arbitrary_batch_sizes():
    """The kernel itself pads non-multiple-of-block position batches."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.kernels.bitvec_rank import bitvec_rank

    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, 2048).astype(np.uint8)
    bv = BitVector(bits)
    words = jnp.asarray(np.concatenate([bv.words, np.zeros(1, np.uint32)]))
    ranks = jnp.asarray(bv.word_ranks.astype(np.int32))
    for q in [1, 7, 64, 100, 1023]:
        pos = rng.integers(0, bv.n, q).astype(np.int32)
        out = bitvec_rank(words, ranks, jnp.asarray(pos), block_q=64, interpret=True)
        assert np.array_equal(np.asarray(out), bv._rank1_numpy(pos.astype(np.int64)))


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)), min_size=0, max_size=60),
    st.sampled_from([2, 3, 4]),
)
def test_k2_property(points, k):
    n = m = 31
    r = np.array([p[0] for p in points], dtype=np.int64)
    c = np.array([p[1] for p in points], dtype=np.int64)
    t = K2Tree(r, c, n, m, k=k)
    dense = np.zeros((n, m), dtype=np.uint8)
    if len(points):
        dense[r, c] = 1
    assert np.array_equal(t.to_dense(), dense)
    assert t.n_points == int(dense.sum())
