"""ITR core: digram counting, RePair, grammar expansion, encode/decode,
triple-query parity. Includes the paper's Figure 1 worked example."""
from collections import Counter

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DigramCounter,
    Grammar,
    Hypergraph,
    LabelTable,
    RepairConfig,
    TripleQueryEngine,
    attach_node_labels,
    compress,
    digram_counts,
    encode,
    query_oracle,
    strip_node_labels,
)
from repro.core.digram import digram_key, split_digram, split_it


# ---------------------------------------------------------------- helpers
def brute_force_counts(graph, table):
    """Paper's count formula, computed naively."""
    it_offsets = table.it_offsets()
    c = Counter()
    for e in range(graph.n_edges):
        lbl = int(graph.labels[e])
        for m, v in enumerate(graph.edge_nodes(e)):
            c[(int(v), int(it_offsets[lbl]) + m)] += 1
    per_node = {}
    for (v, it), cnt in c.items():
        per_node.setdefault(v, {})[it] = cnt
    out = Counter()
    for v, hist in per_node.items():
        its = sorted(hist)
        for i, i1 in enumerate(its):
            for i2 in its[i:]:
                cv = hist[i1] // 2 if i1 == i2 else min(hist[i1], hist[i2])
                if cv:
                    out[digram_key(i1, i2)] += cv
    return out


def random_hypergraph(rng, n_nodes=12, n_labels=3, n_edges=30, max_rank=3):
    ranks = rng.integers(1, max_rank + 1, n_labels)
    table = LabelTable.terminals(ranks)
    edges = []
    for _ in range(n_edges):
        lbl = int(rng.integers(0, n_labels))
        edges.append((lbl, rng.integers(0, n_nodes, ranks[lbl]).tolist()))
    return Hypergraph.from_edges(n_nodes, edges), table


def fig1_graph():
    """Paper Figure 1(a): nodes 10..13 -> 0..3; labels f=0, g=1 (rank 2)."""
    table = LabelTable.terminals([2, 2], names=["f", "g"])
    g = Hypergraph.from_edges(
        4,
        [
            (1, [1, 2]),  # g(11,12)
            (0, [2, 3]),  # f(12,13)
            (1, [0, 0]),  # g(10,10)
            (0, [0, 1]),  # f(10,11)
            (0, [0, 2]),  # f(10,12)
        ],
    )
    return g, table


# ---------------------------------------------------------------- counting
def test_counts_match_brute_force_fig1():
    g, table = fig1_graph()
    keys, cnts = digram_counts(g, table, cap=None)
    oracle = brute_force_counts(g, table)
    got = dict(zip(keys.tolist(), cnts.tolist()))
    assert got == dict(oracle)
    # paper: c(10,(f,0)) = 2 -> digram ((f,0),(f,0)) has one occurrence at 10
    it_f0 = 0
    assert got[digram_key(it_f0, it_f0)] == 1
    # digram ((g,1),(f,0)) has occurrences at node 10 and node 12
    it_g1 = table.it_offsets()[1] + 1
    assert got[digram_key(it_f0, it_g1)] == 2


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_counts_match_brute_force_random(seed):
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng)
    keys, cnts = digram_counts(g, table, cap=None)
    got = dict(zip(keys.tolist(), cnts.tolist()))
    assert got == dict(brute_force_counts(g, table))


def test_incremental_counter_matches_recount_during_compression():
    rng = np.random.default_rng(0)
    g, table = random_hypergraph(rng, n_nodes=20, n_edges=80)
    # compress with instrumentation: after each iteration the counter's
    # table must equal a from-scratch recount
    from repro.core import repair as rp

    table2 = table.copy()
    graph = g.copy()
    counter = DigramCounter(graph, table2, cap=None)
    it_offsets = table2.it_offsets()
    for _ in range(6):
        best = counter.pop_best()
        if best is None:
            break
        key, cnt = best
        it1, it2 = split_digram(key)
        a1, m1 = split_it(it1, it_offsets)
        a2, m2 = split_it(it2, it_offsets)
        r1, r2 = int(table2.ranks[a1]), int(table2.ranks[a2])
        e1s, e2s = rp._find_occurrences(graph, a1, m1, a2, m2, it1 == it2)
        if len(e1s) == 0:
            break
        new_label = table2.add_label(r1 + r2 - 1)
        it_offsets = table2.it_offsets()
        graph, rem, add = rp._replace(graph, table2, e1s, e2s, a1, m1, r1, a2, m2, r2, new_label)
        counter.apply_delta(rem, add)
        keys, cnts = digram_counts(graph, table2, cap=None)
        inc_keys, inc_cnts = counter.as_arrays()
        assert np.array_equal(keys, inc_keys), "incremental keys diverge from recount"
        assert np.array_equal(cnts, inc_cnts), "incremental counts diverge from recount"


# ---------------------------------------------------------------- replacement
def test_fig1_replacement():
    g, table = fig1_graph()
    cfg = RepairConfig(max_iters=1, prune=False, cap=None, min_count=2)
    grammar, stats = compress(g, table, cfg)
    # mfd is ((f,0),(g,1)) with count 2: both occurrences replaced
    assert stats.replaced_occurrences == 2
    assert stats.rules_created == 1
    # start graph: 5 - 4 + 2 = 3 edges, one rule of 2 edges
    assert grammar.start.n_edges == 3
    (rule,) = grammar.rules.values()
    assert rule.rank == 3
    assert rule.rhs.n_edges == 2
    # decompression restores the original
    assert sorted(grammar.decompress().edge_tuples()) == sorted(g.edge_tuples())


def test_loop_edges_never_self_pair():
    # single edge f(0,0): digram ((f,0),(f,1)) has count 0 by formula?
    # c(0,(f,0)) = 1, c(0,(f,1)) = 1 -> count 1, but only pair is (e,e).
    table = LabelTable.terminals([2])
    g = Hypergraph.from_edges(1, [(0, [0, 0])])
    grammar, stats = compress(g, table, RepairConfig(cap=None))
    assert stats.replaced_occurrences == 0
    assert sorted(grammar.decompress().edge_tuples()) == sorted(g.edge_tuples())


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["count", "savings"]))
def test_compress_decompress_identity(seed, selection):
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng, n_nodes=15, n_edges=60)
    grammar, _ = compress(g, table, RepairConfig(cap=None, selection=selection))
    grammar.validate()
    assert sorted(grammar.decompress().edge_tuples()) == sorted(g.edge_tuples())


def test_compression_shrinks_repetitive_graph():
    # a long path colored alternately: digrams abound
    n = 400
    table = LabelTable.terminals([2, 2])
    edges = [(i % 2, [i, i + 1]) for i in range(n - 1)]
    g = Hypergraph.from_edges(n, edges)
    grammar, stats = compress(g, table)
    assert stats.final_size_units < stats.initial_size_units * 0.8
    assert sorted(grammar.decompress().edge_tuples()) == sorted(g.edge_tuples())


# ---------------------------------------------------------------- encode/decode
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_encode_decode_roundtrip(seed):
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng, n_nodes=14, n_edges=50)
    grammar, _ = compress(g, table)
    enc = encode(grammar)
    dec = enc.decode()
    dec.validate()
    assert sorted(dec.decompress().edge_tuples()) == sorted(g.edge_tuples())
    assert enc.size_in_bytes() > 0


def test_index_functions_absorb_loops():
    # B(10,10,11)-style loop edge: index fn (0,0,1); zeta = [10,11]
    table = LabelTable.terminals([3])
    g = Hypergraph.from_edges(12, [(0, [10, 10, 11])])
    grammar = Grammar(table, g, {})
    dec = encode(grammar).decode()
    assert sorted(dec.decompress().edge_tuples()) == sorted(g.edge_tuples())


# ---------------------------------------------------------------- queries
PATTERNS = ["spo", "sp?", "s?o", "s??", "?po", "?p?", "??o", "???"]


def _bind(pattern, s, p, o):
    return (
        s if pattern[0] == "s" else None,
        p if pattern[1] == "p" else None,
        o if pattern[2] == "o" else None,
    )


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_query_parity_all_patterns(seed):
    rng = np.random.default_rng(seed)
    n_nodes, n_preds = 20, 4
    triples = np.stack(
        [
            rng.integers(0, n_nodes, 120),
            rng.integers(0, n_preds, 120),
            rng.integers(0, n_nodes, 120),
        ],
        axis=1,
    )
    table = LabelTable.terminals([2] * n_preds)
    g = Hypergraph.from_triples(triples, n_nodes)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar)
    t = triples[rng.integers(0, len(triples))]
    s, p, o = int(t[0]), int(t[1]), int(t[2])
    for pattern in PATTERNS:
        qs, qp, qo = _bind(pattern, s, p, o)
        got = sorted(engine.query(qs, qp, qo))
        want = sorted(query_oracle(g, qs, qp, qo))
        assert got == want, f"pattern {pattern}: {got} != {want}"
        assert len(got) >= 1  # the probe triple itself always matches


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_query_batch_parity_all_patterns_random_hypergraph(seed):
    """query_batch == query_oracle per query, all 8 patterns in ONE batch,
    on mixed-rank random hypergraphs (not just triples)."""
    rng = np.random.default_rng(seed)
    g, table = random_hypergraph(rng, n_nodes=14, n_edges=50)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar)
    s = int(rng.integers(0, 14))
    p = int(rng.integers(0, 3))
    o = int(rng.integers(0, 14))
    bound = [_bind(pattern, s, p, o) for pattern in PATTERNS]
    ss, pp, oo = (list(col) for col in zip(*bound))
    batch = engine.query_batch(ss, pp, oo)
    for i, pattern in enumerate(PATTERNS):
        qs, qp, qo = bound[i]
        want = sorted(query_oracle(g, qs, qp, qo))
        assert sorted(batch[i]) == want, f"pattern {pattern} diverges from oracle"
        # the scalar reference path must agree too
        assert sorted(engine.query_scalar(qs, qp, qo)) == want


def test_query_batch_duplicate_queries_replicate():
    """Deduped execution must hand every duplicate its full result set."""
    rng = np.random.default_rng(5)
    triples = np.stack(
        [rng.integers(0, 15, 80), rng.integers(0, 3, 80), rng.integers(0, 15, 80)],
        axis=1,
    )
    table = LabelTable.terminals([2] * 3)
    g = Hypergraph.from_triples(triples, 15)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar)
    p = int(triples[0, 1])
    batch = engine.query_batch([None] * 4, [p, p, None, p], [None] * 4)
    want_p = sorted(query_oracle(g, None, p, None))
    want_all = sorted(query_oracle(g, None, None, None))
    assert sorted(batch[0]) == want_p
    assert sorted(batch[1]) == want_p
    assert sorted(batch[2]) == want_all
    assert sorted(batch[3]) == want_p


def test_query_batch_all_none_is_an_error():
    triples = np.array([[0, 0, 1]])
    table = LabelTable.terminals([2])
    grammar, _ = compress(Hypergraph.from_triples(triples, 2), table)
    engine = TripleQueryEngine(grammar)
    with pytest.raises(ValueError, match="batch size"):
        engine.query_batch(None, None, None)
    # the documented spelling of an all-unbound batch works
    assert len(engine.query_batch([None], None, None)[0]) == 1


def test_query_batch_arrays_layout():
    triples = np.array([[0, 0, 1], [1, 0, 2], [0, 1, 2]])
    table = LabelTable.terminals([2, 2])
    g = Hypergraph.from_triples(triples, 3)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar)
    r_q, r_l, r_n, r_o = engine.query_batch_arrays([0, None], [None, 0], [None, None])
    assert len(r_o) == len(r_l) + 1
    # query 0 (s=0): edges 0(0,1) and 1(0,2); query 1 (p=0): edges 0(0,1), 0(1,2)
    got0 = sorted((int(r_l[i]), tuple(r_n[r_o[i]:r_o[i + 1]].tolist()))
                  for i in np.flatnonzero(r_q == 0))
    got1 = sorted((int(r_l[i]), tuple(r_n[r_o[i]:r_o[i + 1]].tolist()))
                  for i in np.flatnonzero(r_q == 1))
    assert got0 == [(0, (0, 1)), (1, (0, 2))]
    assert got1 == [(0, (0, 1)), (0, (1, 2))]


def test_triple_query_service_micro_batching():
    from repro.serve.triple_service import TripleQueryService

    rng = np.random.default_rng(9)
    triples = np.stack(
        [rng.integers(0, 12, 60), rng.integers(0, 2, 60), rng.integers(0, 12, 60)],
        axis=1,
    )
    table = LabelTable.terminals([2, 2])
    g = Hypergraph.from_triples(triples, 12)
    grammar, _ = compress(g, table)
    service = TripleQueryService(TripleQueryEngine(grammar), max_batch=3)
    patterns = [(int(s), None, None) for s, _, _ in triples[:7]]
    patterns.append((None, 0, None))
    out = service.query_many(patterns)
    assert len(out) == 8
    for res, (s, p, o) in zip(out, patterns):
        assert sorted(res) == sorted(query_oracle(g, s, p, o))
    assert service.stats.queries == 8
    assert service.stats.batches == 3  # ceil(8 / max_batch=3)
    assert service.pending == 0


def test_neighborhood_queries():
    triples = np.array([[0, 0, 1], [0, 1, 2], [3, 0, 0], [2, 1, 0]])
    table = LabelTable.terminals([2, 2])
    g = Hypergraph.from_triples(triples, 4)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar)
    assert np.array_equal(engine.neighbors_out(0), [1, 2])
    assert np.array_equal(engine.neighbors_in(0), [2, 3])


# ---------------------------------------------------------------- ITR+
def test_itr_plus_roundtrip_and_dictionary_gain():
    rng = np.random.default_rng(3)
    n_nodes = 60
    triples = np.stack(
        [rng.integers(0, n_nodes, 150), rng.integers(0, 2, 150), rng.integers(0, n_nodes, 150)],
        axis=1,
    )
    table = LabelTable.terminals([2, 2])
    g = Hypergraph.from_triples(triples, n_nodes)
    node_labels = rng.integers(0, 3, n_nodes)  # x / o / b, ttt-style
    g_plus, table_plus, base = attach_node_labels(g, table, node_labels)
    assert g_plus.n_edges == g.n_edges + n_nodes
    grammar, _ = compress(g_plus, table_plus)
    decomp = grammar.decompress()
    stripped, labels_back = strip_node_labels(decomp, base, 3)
    assert np.array_equal(labels_back, node_labels)
    assert sorted(stripped.edge_tuples()) == sorted(g.edge_tuples())


def test_itr_plus_rank1_edges_join_digrams():
    # star of nodes all labeled 'x' with edges to a hub: digram of
    # (label-edge, graph-edge) should be replaced
    n = 50
    table = LabelTable.terminals([2])
    edges = [(0, [i, 0]) for i in range(1, n)]
    g = Hypergraph.from_edges(n, edges)
    node_labels = np.zeros(n, dtype=np.int64)
    g_plus, table_plus, base = attach_node_labels(g, table, node_labels)
    grammar, stats = compress(g_plus, table_plus, RepairConfig(cap=None))
    assert stats.replaced_occurrences > 0
    # some rule must contain the rank-1 label edge
    assert any((r.rhs.ranks() == 1).any() for r in grammar.rules.values())


# ---------------------------------------------------------------- ablations
def test_loop_rule_transform_roundtrip():
    """§Handling loops ablation: the loop-rule grammar decompresses to the
    same graph, and (per the paper) does not beat the index-functions."""
    from repro.core.ablations import loop_rule_transform
    from repro.core import encode as enc_fn

    rng = np.random.default_rng(11)
    # graph with plenty of loops
    table = LabelTable.terminals([2, 3])
    edges = []
    for _ in range(60):
        lbl = int(rng.integers(0, 2))
        rank = 2 if lbl == 0 else 3
        nodes = rng.integers(0, 8, rank).tolist()
        edges.append((lbl, nodes))
    g = Hypergraph.from_edges(8, edges)
    grammar, _ = compress(g, table)
    transformed = loop_rule_transform(grammar)
    transformed.validate()
    # no loop edges remain in the start graph
    for e in range(transformed.start.n_edges):
        nodes = transformed.start.edge_nodes(e)
        assert len(np.unique(nodes)) == len(nodes)
    assert sorted(transformed.decompress().edge_tuples()) == sorted(g.edge_tuples())
    # paper's claim on this instance: extra rules don't shrink the encoding
    assert enc_fn(transformed).size_in_bytes() >= enc_fn(grammar).size_in_bytes() * 0.95
