"""Sharded serving tier: partition plans, scatter-gather routing parity vs
the single-engine oracle, the shared (shard, S, P, O) cache tier with
generation invalidation, and the view-based result API."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Hypergraph,
    LabelTable,
    QueryResultCache,
    QueryResultView,
    TripleQueryEngine,
    compress,
    concat_ragged,
    query_oracle,
)
from repro.distributed.partition import make_plan, partition_triples
from repro.serve.sharded import ShardedTripleService
from repro.serve.triple_service import TripleQueryService

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]


def _random_triples(seed, n_nodes=15, n_preds=3, n_edges=80):
    rng = np.random.default_rng(seed)
    return np.stack(
        [rng.integers(0, n_nodes, n_edges), rng.integers(0, n_preds, n_edges),
         rng.integers(0, n_nodes, n_edges)], axis=1)


def _single_engine(triples, n_nodes, n_preds):
    table = LabelTable.terminals([2] * n_preds)
    g = Hypergraph.from_triples(triples, n_nodes)
    grammar, _ = compress(g, table)
    return TripleQueryEngine(grammar, cache=None, crossover=0), g


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


# ---------------------------------------------------------------- partition
def test_partition_covers_disjointly():
    triples = _random_triples(0, n_nodes=20, n_preds=5, n_edges=120)
    for strategy in ("predicate_hash", "node_range"):
        for n_shards in (1, 3, 7):
            plan = make_plan(strategy, n_shards, 20, 5)
            parts = partition_triples(triples, plan)
            assert len(parts) == n_shards
            merged = np.concatenate(parts)
            # disjoint cover: same multiset of rows
            assert sorted(map(tuple, merged)) == sorted(map(tuple, triples))


def test_partition_owning_axis():
    triples = _random_triples(1, n_nodes=20, n_preds=5, n_edges=120)
    plan = make_plan("predicate_hash", 3, 20, 5)
    for k, part in enumerate(partition_triples(triples, plan)):
        for _, p, _ in part:  # every triple's predicate routes to its shard
            assert plan.route(-1, int(p), -1) == k
    plan = make_plan("node_range", 3, 20, 5)
    for k, part in enumerate(partition_triples(triples, plan)):
        for s, _, _ in part:
            assert plan.route(int(s), -1, -1) == k


def test_partition_routing_scatter_rules():
    ph = make_plan("predicate_hash", 4, 100, 8)
    assert ph.route(5, -1, -1) == -1      # S?? scatters under predicate hash
    assert ph.route(-1, -1, 7) == -1      # ??O scatters
    assert ph.route(-1, -1, -1) == -1     # ??? always scatters
    assert ph.route(5, 3, 7) == ph.route(-1, 3, -1)  # P owns regardless of S/O
    nr = make_plan("node_range", 4, 100, 8)
    assert nr.route(-1, 3, -1) == -1      # ?P? scatters under node range
    assert nr.route(-1, -1, 7) == -1      # ??O scatters (O is not the axis)
    assert nr.route(5, 3, 7) == nr.route(5, -1, -1)  # S owns regardless of P/O
    rb = nr.route_batch(np.array([5, -1]), np.array([3, 3]), np.array([7, -1]))
    assert rb[0] == nr.route(5, 3, 7) and rb[1] == -1


def test_partition_rejects_bad_config():
    from repro.distributed.partition import PartitionPlan

    with pytest.raises(ValueError):
        make_plan("by-vibes", 2, 10, 3)
    with pytest.raises(ValueError):
        make_plan("node_range", 0, 10, 3)
    with pytest.raises(ValueError):  # node_range without boundaries
        PartitionPlan("node_range", 4, 10, 3)
    with pytest.raises(ValueError):  # wrong boundary count
        PartitionPlan("node_range", 4, 10, 3,
                      boundaries=np.array([0, 5, 10]))
    with pytest.raises(ValueError):  # non-monotonic boundaries
        PartitionPlan("node_range", 2, 10, 3,
                      boundaries=np.array([0, 7, 5]))


def test_node_range_quantile_boundaries_balance_skewed_subjects():
    """Subjects concentrated in a prefix of the id space (the RDF-typical
    shape) must still spread across shards: boundaries follow the subject
    distribution, not even id ranges."""
    rng = np.random.default_rng(2)
    n_nodes = 1000
    subs = rng.integers(0, 40, 400)  # subjects live in [0, 40) of [0, 1000)
    triples = np.stack([subs, rng.integers(0, 3, 400),
                        rng.integers(0, n_nodes, 400)], axis=1)
    plan = make_plan("node_range", 4, n_nodes, 3, triples=triples)
    parts = partition_triples(triples, plan)
    sizes = [len(p) for p in parts]
    assert sum(sizes) == 400
    assert max(sizes) <= 2 * (400 // 4 + 40)  # no shard holds ~everything
    assert sum(1 for s in sizes if s > 0) >= 3
    # routing agrees with placement for every triple
    for k, part in enumerate(parts):
        for s, _, _ in part:
            assert plan.route(int(s), -1, -1) == k


def test_node_range_more_shards_than_nodes():
    triples = np.array([[0, 0, 1], [1, 1, 0], [2, 2, 2]], dtype=np.int64)
    plan = make_plan("node_range", 8, 3, 3)
    parts = partition_triples(triples, plan)
    assert sum(len(p) for p in parts) == 3
    svc = ShardedTripleService.build(triples, 3, 3, n_shards=8,
                                     strategy="node_range")
    assert sorted(svc.query(None, None, None)) == \
        sorted((int(p), (int(s), int(o))) for s, p, o in triples)


# ---------------------------------------------------------------- parity
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_sharded_parity_all_patterns_random_grammars(seed):
    """ShardedTripleService == single-engine query_scalar oracle for every
    (S,P,O) binding pattern, both strategies, several shard counts —
    including a second pass served from the shared cache."""
    rng = np.random.default_rng(seed)
    n_nodes, n_preds = 14, 3
    triples = _random_triples(seed, n_nodes, n_preds, n_edges=60)
    oracle, _ = _single_engine(triples, n_nodes, n_preds)
    s0, p0, o0 = (int(v) for v in triples[rng.integers(0, len(triples))])
    # a miss row too: bindings that may match nothing
    s1, p1, o1 = n_nodes - 1, n_preds - 1, 0
    patterns = [_bind(pat, s0, p0, o0) for pat in PATTERN_NAMES] + \
               [_bind(pat, s1, p1, o1) for pat in PATTERN_NAMES]
    want = [sorted(oracle.query_scalar(qs, qp, qo)) for qs, qp, qo in patterns]
    for strategy in ("predicate_hash", "node_range"):
        for n_shards in (1, 2, 4):
            svc = ShardedTripleService.build(
                triples, n_nodes, n_preds, n_shards=n_shards, strategy=strategy)
            got = svc.query_many(patterns)
            assert [sorted(r) for r in got] == want, (strategy, n_shards)
            replay = svc.query_many(patterns)  # warm: served from shared tier
            assert [sorted(r) for r in replay] == want, (strategy, n_shards)
            assert svc.cache.stats.hits > 0


def test_sharded_duplicate_tickets_share_entries():
    triples = _random_triples(3)
    svc = ShardedTripleService.build(triples, 15, 3, n_shards=3,
                                     strategy="node_range")
    p0 = int(triples[0, 1])
    for _ in range(3):
        svc.submit(None, p0, None)  # scattered, duplicated
    view = svc.flush_view()
    assert view.n_queries == 3 and len(view.entries) == 1
    assert view.entry(0) is view.entry(1) is view.entry(2)
    # merged scatter entries are shared -> mutation must fail loudly
    labels, nodes, _ = view.entry(0)
    for arr in (labels, nodes):
        if len(arr):
            with pytest.raises(ValueError):
                arr[0] = -1
    # flush() shares one IMMUTABLE result tuple per unique pattern —
    # mutation fails loudly instead of corrupting sibling tickets
    for _ in range(3):
        svc.submit(None, p0, None)
    out = svc.flush()
    assert out[0] is out[1] is out[2]
    assert isinstance(out[0], tuple)
    with pytest.raises((TypeError, AttributeError)):
        out[0][0] = None


def test_sharded_chunked_flush_matches_and_counts_batches():
    triples = _random_triples(4)
    oracle, _ = _single_engine(triples, 15, 3)
    svc = ShardedTripleService.build(triples, 15, 3, n_shards=2, max_batch=2)
    subjects = [int(s) for s in triples[:5, 0]]
    got = svc.query_many([(s, None, None) for s in subjects])
    for r, s in zip(got, subjects):
        assert sorted(r) == sorted(oracle.query_scalar(s, None, None))
    assert svc.stats.shard_batches >= 2  # max_batch forced chunking
    assert svc.stats.queries == 5 and svc.stats.flushes == 1


def test_sharded_empty_flush_and_stats():
    svc = ShardedTripleService.build(_random_triples(5), 15, 3, n_shards=2)
    assert svc.flush() == []
    assert svc.stats.flushes == 0 and svc.stats.queries == 0
    svc.submit(int(svc.engines[0].grammar.start.nodes_flat[0]), None, None)
    svc.flush()
    assert svc.stats.flushes == 1 and svc.stats.unique_patterns == 1
    assert svc.stats.owned + svc.stats.scattered == 1


def test_sharded_query_returns_own_ticket_with_pending_queue():
    """Regression: query() must return the pattern it submitted, not
    ticket 0, when other submissions are already pending."""
    triples = _random_triples(13)
    oracle, _ = _single_engine(triples, 15, 3)
    svc = ShardedTripleService.build(triples, 15, 3, n_shards=2)
    s0, s1 = int(triples[0, 0]), int(triples[1, 0])
    svc.submit(s0, None, None)  # someone else's pending ticket
    got = svc.query(s1, None, None)
    assert sorted(got) == sorted(oracle.query_scalar(s1, None, None))
    assert svc.pending == 0  # the pending ticket was flushed alongside


def test_neighbors_batch_duplicates_share_readonly_arrays():
    """Duplicate vs share one result array; in-place mutation must raise
    instead of silently corrupting the sibling duplicate's answer."""
    triples = _random_triples(14)
    table = LabelTable.terminals([2] * 3)
    g = Hypergraph.from_triples(triples, 15)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=0)
    v = int(triples[0, 0])
    outs = engine.neighbors_out_batch([v, v])
    assert outs[0] is outs[1]
    if len(outs[0]):
        with pytest.raises(ValueError):
            outs[0][0] = -1


def test_sharded_without_cache_still_exact():
    triples = _random_triples(6)
    oracle, _ = _single_engine(triples, 15, 3)
    svc = ShardedTripleService.build(triples, 15, 3, n_shards=3, cache=None)
    assert svc.cache is None and svc.cache_stats() is None
    s0 = int(triples[0, 0])
    assert sorted(svc.query(s0, None, None)) == \
        sorted(oracle.query_scalar(s0, None, None))


# ---------------------------------------------------------------- shared tier
def test_shared_cache_keys_do_not_collide_across_shards():
    """Two shards answer the same ?P? pattern with different results; the
    shared tier must keep both (shard-qualified keys) plus the merged
    cross-shard entry, and a warm replay must serve the exact union from
    the merged namespace without re-executing anything."""
    triples = _random_triples(7, n_preds=4)
    oracle, _ = _single_engine(triples, 15, 4)
    svc = ShardedTripleService.build(triples, 15, 4, n_shards=2,
                                     strategy="node_range")
    p0 = int(triples[0, 1])
    want = sorted(oracle.query_scalar(None, p0, None))
    assert sorted(svc.query(None, p0, None)) == want
    inserts = svc.cache.stats.inserts
    assert inserts >= 3  # one entry per shard + the merged entry
    hits_before = svc.cache.stats.hits
    assert sorted(svc.query(None, p0, None)) == want
    assert svc.cache.stats.hits > hits_before   # merged-tier hit
    assert svc.cache.stats.inserts == inserts   # nothing re-executed
    assert svc.stats.merged_hits >= 1


def test_warm_scattered_pattern_skips_fanout():
    """A warm scattered pattern is one merged-tier lookup: no engine
    micro-batches are issued on the replay flush."""
    triples = _random_triples(15)
    oracle, _ = _single_engine(triples, 15, 3)
    svc = ShardedTripleService.build(triples, 15, 3, n_shards=3,
                                     strategy="node_range")
    p0 = int(triples[0, 1])
    want = sorted(oracle.query_scalar(None, p0, None))
    assert sorted(svc.query(None, p0, None)) == want  # cold: fans out
    sb = svc.stats.shard_batches
    assert sorted(svc.query(None, p0, None)) == want  # warm: merged hit
    assert svc.stats.shard_batches == sb
    assert svc.stats.merged_hits == 1
    # invalidating ANY shard also invalidates the merged entry
    svc.invalidate(1)
    assert sorted(svc.query(None, p0, None)) == want
    assert svc.stats.shard_batches > sb  # had to fan out again


def test_generation_bump_invalidates_one_shard_only():
    cache = QueryResultCache()
    v0, v1 = cache.shard_view(0), cache.shard_view(1)
    e = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
    v0.insert(3, -1, -1, e)
    v1.insert(3, -1, -1, e)
    v0.insert(-1, 2, -1, e)
    assert len(cache) == 3
    gen = v0.bump_generation()
    assert gen == 1 and cache.generation(0) == 1 and cache.generation(1) == 0
    # shard 0's entries are gone — eagerly, so budgets reflect live data
    assert v0.lookup(3, -1, -1) is None and v0.lookup(-1, 2, -1) is None
    assert len(cache) == 1 and cache.cached_edges == 1
    # shard 1 stays warm
    assert v1.lookup(3, -1, -1) is not None
    # re-inserts under the new generation are served again
    v0.insert(3, -1, -1, e)
    assert v0.lookup(3, -1, -1) is not None


def test_sharded_invalidate_then_exact():
    triples = _random_triples(8)
    oracle, _ = _single_engine(triples, 15, 3)
    svc = ShardedTripleService.build(triples, 15, 3, n_shards=3)
    s0 = int(triples[0, 0])
    want = sorted(oracle.query_scalar(s0, None, None))
    assert sorted(svc.query(s0, None, None)) == want
    misses = svc.cache.stats.misses
    svc.invalidate(0)  # one shard cold, others warm
    assert sorted(svc.query(s0, None, None)) == want
    assert svc.cache.stats.misses > misses
    svc.invalidate()   # everything cold
    assert sorted(svc.query(s0, None, None)) == want


# ------------------------------------------------- ?P? segment floor (bugfix)
def test_point_lookup_burst_never_evicts_predicate_segment():
    """Regression: the dedicated ?P? segment must hold its entries through
    an arbitrarily long burst of selective point-lookup inserts — plain
    keys and shard-qualified keys alike — and the budget accounting must
    stay exact."""
    for use_shards in (False, True):
        cache = QueryResultCache(max_entries=32, max_edges=64,
                                 predicate_entries=8, predicate_edges=200)
        faces = [cache.shard_view(k) for k in range(3)] if use_shards \
            else [cache]
        pe = (np.arange(30), np.arange(60), np.arange(0, 62, 2))
        for i, f in enumerate(faces):
            f.insert(-1, i, -1, pe)  # ?P? entries, one per face
        pred_entries = len(cache._predicate.entries)
        pred_edges = cache._predicate.edges
        assert pred_entries == len(faces) and pred_edges == 30 * len(faces)
        point = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
        for s in range(300):  # burst of spo point lookups across all faces
            faces[s % len(faces)].insert(s, 0, s + 1, point)
        # the predicate segment is untouched: same entries, same budget
        assert len(cache._predicate.entries) == pred_entries
        assert cache._predicate.edges == pred_edges
        for i, f in enumerate(faces):
            assert f.lookup(-1, i, -1) is not None
        # general segment respected its own budgets
        assert cache._general.edges <= 64
        assert len(cache._general.entries) <= 32
        # accounting is exact: tracked edges == sum over live entries
        for seg in (cache._general, cache._predicate):
            assert seg.edges == sum(len(v[0]) for v in seg.entries.values())


def test_predicate_segment_evicts_only_under_own_pressure():
    cache = QueryResultCache(predicate_entries=2, predicate_edges=1 << 20)
    e = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
    for p in range(4):  # ?P? churn beyond its own entry budget
        cache.insert(-1, p, -1, e)
    assert len(cache._predicate.entries) == 2
    assert cache.lookup(-1, 3, -1) is not None
    assert cache.lookup(-1, 0, -1) is None  # its own LRU, its own pressure


# ---------------------------------------------------------------- view API
def test_view_materialize_matches_arrays_path():
    triples = _random_triples(9)
    table = LabelTable.terminals([2] * 3)
    g = Hypergraph.from_triples(triples, 15)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=0)
    s0, p0 = int(triples[0, 0]), int(triples[0, 1])
    ss = [s0, None, s0, None]
    pp = [None, p0, None, p0]
    oo = [None, None, None, None]
    view = engine.query_batch_view(ss, pp, oo)
    assert len(view.entries) == 2  # duplicates share entries
    assert view.entry(0) is view.entry(2) and view.entry(1) is view.entry(3)
    got = view.materialize()
    fresh = TripleQueryEngine(grammar, cache=None, crossover=0)
    want = fresh.query_batch_arrays(ss, pp, oo)

    def norm(res):
        r_q, r_l, r_n, r_o = res
        return sorted((int(r_q[i]), int(r_l[i]),
                       tuple(r_n[r_o[i]:r_o[i + 1]].tolist()))
                      for i in range(len(r_l)))

    assert norm(got) == norm(want)
    assert view.total_results() == len(want[1])
    np.testing.assert_array_equal(
        view.result_counts(), np.bincount(want[0], minlength=4))


def test_view_tuples_match_query_batch():
    triples = _random_triples(10)
    table = LabelTable.terminals([2] * 3)
    g = Hypergraph.from_triples(triples, 15)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=0)
    s0 = int(triples[0, 0])
    view = engine.query_batch_view([s0, None], [None, None], [None, s0])
    for qid, (qs, qo) in enumerate([(s0, None), (None, s0)]):
        assert sorted(view.tuples(qid)) == sorted(query_oracle(g, qs, None, qo))


def test_view_concat_and_empty():
    empty = QueryResultView([], np.zeros(0, dtype=np.int64))
    assert empty.n_queries == 0 and empty.total_results() == 0
    r_q, r_l, r_n, r_o = empty.materialize()
    assert len(r_l) == 0 and r_o.tolist() == [0]
    e1 = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
    e2 = (np.array([2, 3]), np.array([4, 5, 6, 7]), np.array([0, 2, 4]))
    v = QueryResultView.concat([
        QueryResultView([e1], np.zeros(2, dtype=np.int64)),
        QueryResultView([e2], np.zeros(1, dtype=np.int64))])
    assert v.n_queries == 3 and len(v.entries) == 2
    assert v.entry(0) is e1 and v.entry(2) is e2
    assert v.total_results() == 1 + 1 + 2


def test_concat_ragged_merges_and_skips_empty():
    e1 = (np.array([1]), np.array([0, 1]), np.array([0, 2]))
    e0 = (np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(1, np.int64))
    e2 = (np.array([2]), np.array([4, 5, 6]), np.array([0, 3]))
    labels, nodes, offsets = concat_ragged([e1, e0, e2])
    assert labels.tolist() == [1, 2]
    assert nodes.tolist() == [0, 1, 4, 5, 6]
    assert offsets.tolist() == [0, 2, 5]
    labels, _, offsets = concat_ragged([])
    assert len(labels) == 0 and offsets.tolist() == [0]


def test_uncached_view_entries_are_read_only():
    """The view's read-only contract must hold with the cache disabled too
    (cache.insert is not the only freeze point)."""
    triples = _random_triples(12)
    table = LabelTable.terminals([2] * 3)
    g = Hypergraph.from_triples(triples, 15)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=None, crossover=0)
    s0 = int(triples[0, 0])
    for view in (engine.query_batch_view([s0, s0], None, None),
                 engine.query_batch_view([s0], None, None)):
        assert view.entry(0) is view.entry(view.n_queries - 1)
        labels, nodes, _ = view.entry(0)
        for arr in (labels, nodes):
            if len(arr):
                with pytest.raises(ValueError):
                    arr[0] = -1


def test_service_flush_view_shares_entries():
    triples = _random_triples(11)
    table = LabelTable.terminals([2] * 3)
    g = Hypergraph.from_triples(triples, 15)
    grammar, _ = compress(g, table)
    engine = TripleQueryEngine(grammar, cache=QueryResultCache(), crossover=0)
    service = TripleQueryService(engine)
    s0 = int(triples[0, 0])
    for _ in range(4):
        service.submit(s0, None, None)
    view = service.flush_view()
    assert view.n_queries == 4 and len(view.entries) == 1
    assert view.entry(0) is view.entry(3)
    assert sorted(view.tuples(0)) == sorted(query_oracle(g, s0, None, None))
    # flush shares one immutable result tuple per unique pattern
    for _ in range(3):
        service.submit(s0, None, None)
    out = service.flush()
    assert out[0] is out[1] is out[2] and isinstance(out[0], tuple)
    assert sorted(out[0]) == sorted(query_oracle(g, s0, None, None))
