"""Concurrent serving: RWLock semantics, thread-safe shared state, threaded
scatter-gather parity, and a multi-threaded mutate/query/rebalance stress
oracle cross-checked against a plain-Python set reference.

The stress machines split the subject space: *stable* rows (never mutated)
answer exactly under any interleaving, while *churn* rows (the only ones
background mutators touch) bound what an unselective pattern may
additionally return mid-flight. After the threads join, all 8 patterns
must match the final set oracle exactly — on both partition strategies.

The tier-1 run keeps the stress short; the nightly lane (``pytest -m
slow``) re-runs it longer via ``ITR_STRESS_SECONDS``/``ITR_STRESS_THREADS``.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.result_cache import QueryResultCache
from repro.persist.service import DurableShardedService
from repro.serve.concurrency import RWLock, resolve_serve_threads
from repro.serve.sharded import ShardedTripleService

PATTERN_NAMES = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]

# nightly lane budget (tier-1 uses the short defaults)
SLOW_SECONDS = float(os.environ.get("ITR_STRESS_SECONDS", "6"))
SLOW_THREADS = int(os.environ.get("ITR_STRESS_THREADS", "8"))


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


def _oracle_query(triples: set, s, p, o) -> list[tuple]:
    """Reference answer in the service's result shape: (p, (s, o))."""
    return sorted(
        (tp, (ts, to)) for ts, tp, to in triples
        if (s is None or ts == s) and (p is None or tp == p)
        and (o is None or to == o))


def _rows(rng, k, n_nodes, n_preds, lo_node=0) -> np.ndarray:
    return np.stack([rng.integers(lo_node, n_nodes, k),
                     rng.integers(0, n_preds, k),
                     rng.integers(0, n_nodes, k)], axis=1)


def _join_all(threads, timeout=60.0):
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(max(0.1, deadline - time.monotonic()))
        assert not t.is_alive(), f"thread {t.name} did not finish"


# ------------------------------------------------------------------ RWLock
def test_rwlock_readers_share():
    lock = RWLock()
    inside = threading.Barrier(3, timeout=10)

    def reader():
        with lock.read():
            inside.wait()  # all 3 readers inside simultaneously

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    _join_all(threads)
    assert lock.active_readers == 0 and not lock.write_held


def test_rwlock_writer_excludes_readers_and_writers():
    lock = RWLock()
    log: list[str] = []
    entered = threading.Event()
    release = threading.Event()

    def writer():
        with lock.write():
            log.append("w-in")
            entered.set()
            release.wait(10)
            log.append("w-out")

    def reader():
        with lock.read():
            log.append("r-in")

    w = threading.Thread(target=writer)
    w.start()
    assert entered.wait(10)
    r = threading.Thread(target=reader)
    r.start()
    time.sleep(0.05)
    assert log == ["w-in"]  # reader is parked behind the writer
    release.set()
    _join_all([w, r])
    assert log == ["w-in", "w-out", "r-in"]


def test_rwlock_write_preferring():
    """A waiting writer bars NEW readers, so it runs as soon as the
    current readers drain — a steady reader stream cannot starve it."""
    lock = RWLock()
    order: list[str] = []
    r1_in = threading.Event()
    w_started = threading.Event()
    r1_release = threading.Event()

    def first_reader():
        with lock.read():
            r1_in.set()
            r1_release.wait(10)
        order.append("r1-out")

    def writer():
        w_started.set()
        with lock.write():
            order.append("w")

    def late_reader():
        w_started.wait(10)
        time.sleep(0.05)  # let the writer reach its wait loop
        with lock.read():
            order.append("r2")

    threads = [threading.Thread(target=f)
               for f in (first_reader, writer, late_reader)]
    for t in threads:
        t.start()
    assert r1_in.wait(10)
    time.sleep(0.15)  # writer waiting on r1; r2 parked behind the writer
    assert order == []
    r1_release.set()
    _join_all(threads)
    assert order[0] == "r1-out" and order[1] == "w" and order[2] == "r2"


def test_rwlock_writer_reentrant_and_read_under_write():
    lock = RWLock()
    with lock.write():
        with lock.write():  # reentrant write
            with lock.read():  # read granted to the write owner
                assert lock.write_held
        assert lock.write_held
    assert not lock.write_held and lock.active_readers == 0


def test_rwlock_read_reentrant():
    lock = RWLock()
    with lock.read():
        with lock.read():
            assert lock.active_readers == 1  # depth, not a second reader
    assert lock.active_readers == 0


def test_rwlock_upgrade_refused():
    lock = RWLock()
    with lock.read():
        with pytest.raises(RuntimeError, match="upgrade"):
            lock.acquire_write()
    assert not lock.write_held and lock.active_readers == 0


def test_rwlock_release_errors():
    lock = RWLock()
    with pytest.raises(RuntimeError):
        lock.release_read()
    with pytest.raises(RuntimeError):
        lock.release_write()


# ------------------------------------------------- ITR_SERVE_THREADS knob
def test_resolve_serve_threads_spellings(monkeypatch):
    ncpu = os.cpu_count() or 1
    assert resolve_serve_threads(4) == 4
    assert resolve_serve_threads(1) == 1
    assert resolve_serve_threads(0) == 1
    assert resolve_serve_threads(-3) == 1
    for word in ("off", "OFF", "none", "never"):
        assert resolve_serve_threads(word) == 1
    monkeypatch.delenv("ITR_SERVE_THREADS", raising=False)
    assert resolve_serve_threads() == ncpu
    monkeypatch.setenv("ITR_SERVE_THREADS", "3")
    assert resolve_serve_threads() == 3
    assert resolve_serve_threads(2) == 2  # explicit beats env
    monkeypatch.setenv("ITR_SERVE_THREADS", "nonsense")
    assert resolve_serve_threads() == ncpu
    monkeypatch.setenv("ITR_SERVE_THREADS", "off")
    assert resolve_serve_threads() == 1


# ------------------------------------------------------ shared-tier cache
def test_cache_concurrent_hammer():
    """lookup/insert/bump/clear from many threads: no exception, and the
    budget accounting stays consistent afterwards."""
    cache = QueryResultCache(max_entries=64, max_edges=1 << 12)
    errors: list = []
    stop = threading.Event()

    def entry(n):
        arr = np.arange(n, dtype=np.int64)
        return (arr, arr.copy(), np.arange(n + 1, dtype=np.int64))

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                op = int(rng.integers(0, 100))
                s, p, o = (int(v) for v in rng.integers(0, 8, 3))
                shard = int(rng.integers(0, 4))
                if op < 45:
                    cache.lookup(s, p, o, shard=shard)
                elif op < 85:
                    cache.insert(s, p, o, entry(int(rng.integers(0, 16))),
                                 shard=shard)
                elif op < 95:
                    cache.bump_generation(shard)
                elif op < 98:
                    len(cache), cache.cached_edges
                else:
                    cache.clear()
        except Exception as exc:  # pragma: no cover - only on regression
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.4)
    stop.set()
    _join_all(threads)
    assert not errors
    assert len(cache) <= 64 * 2  # per-segment caps hold
    assert cache.cached_edges >= 0
    assert cache.stats.lookups == cache.stats.hits + cache.stats.misses


# ------------------------------------------- threaded fan-out parity
def _build_pair(seed, strategy, serve_threads, n_shards=4):
    rng = np.random.default_rng(seed)
    triples = np.unique(_rows(rng, 300, 40, 6), axis=0)
    svc = ShardedTripleService.build(
        triples, 40, 6, n_shards=n_shards, strategy=strategy,
        rebalance_skew=None, serve_threads=serve_threads)
    return triples, svc


@pytest.mark.parametrize("strategy", ["predicate_hash", "node_range"])
def test_threaded_scatter_matches_sequential(strategy):
    """serve_threads>1 and serve_threads=1 produce identical results and
    identical per-shard batch accounting for the same flush."""
    triples, seq = _build_pair(7, strategy, serve_threads=1)
    _, par = _build_pair(7, strategy, serve_threads=4)
    oracle = {tuple(map(int, r)) for r in triples}
    patterns = [(None, 2, None), (None, None, None), (5, None, None),
                (None, None, 3), (None, 1, 7), (2, 0, None)]
    got_seq = seq.query_many(patterns)
    got_par = par.query_many(patterns)
    for (s, p, o), a, b in zip(patterns, got_seq, got_par):
        assert sorted(a) == sorted(b) == _oracle_query(oracle, s, p, o)
    assert par.stats.shard_batches == seq.stats.shard_batches
    par.close()
    seq.close()


def test_set_serve_threads_swaps_pool():
    _, svc = _build_pair(8, "predicate_hash", serve_threads=1)
    assert svc.set_serve_threads(3) == 3
    before = svc.query(None, 1, None)
    assert svc.set_serve_threads("off") == 1
    assert svc.query(None, 1, None) == before
    svc.close()
    svc.close()  # idempotent


# --------------------------------------------- concurrent request plane
def test_concurrent_query_threads_get_their_own_results():
    triples, svc = _build_pair(9, "node_range", serve_threads=2)
    oracle = {tuple(map(int, r)) for r in triples}
    errors: list = []

    def client(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                s, p, o = (int(v) for v in rng.integers(0, 12, 3))
                qs, qp, qo = _bind(
                    PATTERN_NAMES[int(rng.integers(0, 8))], s, p, o)
                got = sorted(svc.query(qs, qp, qo))
                want = _oracle_query(oracle, qs, qp, qo)
                assert got == want, (qs, qp, qo)
        except Exception as exc:
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    _join_all(threads)
    assert not errors, errors[0]
    svc.close()


def test_query_many_skips_foreign_pending_tickets():
    """query_many returns exactly its own patterns' results (in order)
    even when another caller's submission is already pending — the
    foreign ticket is flushed alongside but never leaks into the
    returned list."""
    triples, svc = _build_pair(10, "predicate_hash", serve_threads=1)
    oracle = {tuple(map(int, r)) for r in triples}
    svc.submit(None, None, None)  # someone else's pending ticket
    patterns = [(None, 1, None), (3, None, None)]
    got = svc.query_many(patterns)
    assert len(got) == len(patterns)
    for (s, p, o), res in zip(patterns, got):
        assert sorted(res) == _oracle_query(oracle, s, p, o)
    assert svc.pending == 0  # the foreign ticket was flushed alongside
    assert svc.query_many([]) == []
    svc.close()


# --------------------------------------------------- stress oracle
class _Churn(threading.Thread):
    """Background mutator: inserts/deletes only churn-pool rows, tracking
    its own applied set (it is the only writer of those rows)."""

    def __init__(self, svc, pool, stop, errors, seed):
        super().__init__(name="churn")
        self.svc, self.stop, self.errors = svc, stop, errors
        self.pool = pool  # np.ndarray of candidate rows
        self.live: set = set()
        self.rng = np.random.default_rng(seed)

    def run(self):
        try:
            while not self.stop.is_set():
                k = int(self.rng.integers(1, 6))
                picks = self.pool[self.rng.integers(0, len(self.pool), k)]
                want = {tuple(map(int, r)) for r in picks}
                if self.rng.integers(0, 2):
                    assert self.svc.insert_triples(picks) == \
                        len(want - self.live)
                    self.live |= want
                else:
                    assert self.svc.delete_triples(picks) == \
                        len(want & self.live)
                    self.live -= want
        except Exception as exc:
            self.errors.append(exc)


class _Rebalancer(threading.Thread):
    def __init__(self, svc, stop, errors, seed):
        super().__init__(name="rebalance")
        self.svc, self.stop, self.errors = svc, stop, errors
        self.rng = np.random.default_rng(seed)

    def run(self):
        try:
            while not self.stop.is_set():
                self.svc.rebalance(
                    force=True, max_moves=int(self.rng.integers(1, 64)))
                time.sleep(0.005)
        except Exception as exc:
            self.errors.append(exc)


def _stress_machine(strategy: str, *, seconds: float, n_query_threads: int,
                    seed: int = 0, serve_threads: int = 2) -> None:
    rng = np.random.default_rng(seed)
    n_preds, stable_nodes, n_nodes = 4, 12, 24
    stable = np.unique(_rows(rng, 60, stable_nodes, n_preds), axis=0)
    stable_set = {tuple(map(int, r)) for r in stable}
    # churn subjects live in [stable_nodes, n_nodes): disjoint from every
    # stable subject, so stable-subject queries answer exactly mid-churn
    churn_pool = np.unique(
        np.stack([rng.integers(stable_nodes, n_nodes, 80),
                  rng.integers(0, n_preds, 80),
                  rng.integers(0, n_nodes, 80)], axis=1), axis=0)
    churn_universe = {tuple(map(int, r)) for r in churn_pool}
    svc = ShardedTripleService.build(
        stable, n_nodes, n_preds, n_shards=3, strategy=strategy,
        rebalance_skew=None, serve_threads=serve_threads,
        delta_budget=32)

    stop = threading.Event()
    errors: list = []
    churn = _Churn(svc, churn_pool, stop, errors, seed + 1)
    reb = _Rebalancer(svc, stop, errors, seed + 2)

    def reader(rseed):
        rrng = np.random.default_rng(rseed)
        try:
            while not stop.is_set():
                s = int(rrng.integers(0, stable_nodes))
                p = int(rrng.integers(0, n_preds))
                o = int(rrng.integers(0, n_nodes))
                for pattern in PATTERN_NAMES:
                    qs, qp, qo = _bind(pattern, s, p, o)
                    got = sorted(svc.query(qs, qp, qo))
                    want = _oracle_query(stable_set, qs, qp, qo)
                    if qs is not None:
                        # stable subject: churn can never contribute rows
                        assert got == want, (pattern, qs, qp, qo)
                    else:
                        # unselective: exactly the stable answer plus some
                        # matching subset of the churn universe
                        extra = [r for r in got if r not in want]
                        assert [r for r in got if r in want] == want, \
                            (pattern, qs, qp, qo)
                        for tp, (ts, to) in extra:
                            assert (ts, tp, to) in churn_universe
                            assert ts >= stable_nodes
                            assert qp is None or tp == qp
                            assert qo is None or to == qo
        except Exception as exc:
            errors.append(exc)

    readers = [threading.Thread(target=reader, args=(seed + 10 + i,),
                                name=f"reader-{i}")
               for i in range(n_query_threads)]
    for t in [churn, reb, *readers]:
        t.start()
    time.sleep(seconds)
    stop.set()
    _join_all([churn, reb, *readers])
    assert not errors, errors[0]

    # quiesced: drain any in-flight migration, then exact 8-pattern parity
    svc.rebalance(force=True)
    assert not svc.migration_active
    final = stable_set | churn.live
    for probe in list(sorted(final))[:5] or [(0, 0, 0)]:
        s, p, o = probe
        for pattern in PATTERN_NAMES:
            qs, qp, qo = _bind(pattern, s, p, o)
            assert sorted(svc.query(qs, qp, qo)) == \
                _oracle_query(final, qs, qp, qo), (pattern, probe)
    svc.close()


@pytest.mark.parametrize("strategy", ["predicate_hash", "node_range"])
def test_stress_queries_vs_mutation_and_rebalance(strategy):
    _stress_machine(strategy, seconds=1.2, n_query_threads=3,
                    seed=hash(strategy) % 1000)


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["predicate_hash", "node_range"])
def test_stress_queries_vs_mutation_and_rebalance_slow(strategy):
    _stress_machine(strategy, seconds=SLOW_SECONDS,
                    n_query_threads=SLOW_THREADS,
                    seed=hash(strategy) % 1000, serve_threads=4)


# --------------------------------------------------- durable interleave
def test_durable_concurrent_mutations_snapshot_reopen(tmp_path):
    """Two mutator threads + query threads + a mid-run snapshot: WAL order
    equals apply order, so reopening replays to exactly the final state."""
    rng = np.random.default_rng(3)
    n_preds, n_nodes = 3, 20
    base = np.unique(_rows(rng, 40, 10, n_preds), axis=0)
    base_set = {tuple(map(int, r)) for r in base}
    dur = DurableShardedService.build(
        base, n_nodes, n_preds, root=tmp_path, n_shards=2,
        strategy="predicate_hash", rebalance_skew=None, serve_threads=2)

    # disjoint churn pools per mutator (subjects 10..14 vs 15..19), so the
    # final oracle is just the union of what each thread last held
    pools = [np.unique(np.stack([rng.integers(10, 15, 40),
                                 rng.integers(0, n_preds, 40),
                                 rng.integers(0, n_nodes, 40)], axis=1),
                       axis=0),
             np.unique(np.stack([rng.integers(15, 20, 40),
                                 rng.integers(0, n_preds, 40),
                                 rng.integers(0, n_nodes, 40)], axis=1),
                       axis=0)]
    stop = threading.Event()
    errors: list = []
    churns = [_Churn(dur, pool, stop, errors, 50 + i)
              for i, pool in enumerate(pools)]

    def reader(rseed):
        rrng = np.random.default_rng(rseed)
        try:
            while not stop.is_set():
                s = int(rrng.integers(0, 10))
                got = sorted(dur.query(s, None, None))
                assert got == _oracle_query(base_set, s, None, None)
        except Exception as exc:
            errors.append(exc)

    readers = [threading.Thread(target=reader, args=(70 + i,))
               for i in range(2)]
    for t in [*churns, *readers]:
        t.start()
    time.sleep(0.3)
    dur.snapshot()  # exclusive: captures one instant, compacts the WAL
    time.sleep(0.3)
    stop.set()
    _join_all([*churns, *readers])
    assert not errors, errors[0]

    final = base_set | churns[0].live | churns[1].live
    assert sorted(dur.query(None, None, None)) == \
        _oracle_query(final, None, None, None)
    dur.close()

    reopened = DurableShardedService.open(root=tmp_path)
    assert sorted(reopened.query(None, None, None)) == \
        _oracle_query(final, None, None, None)
    for pattern in PATTERN_NAMES:
        qs, qp, qo = _bind(pattern, 5, 1, 7)
        assert sorted(reopened.query(qs, qp, qo)) == \
            _oracle_query(final, qs, qp, qo), pattern
    reopened.close()
