"""§Roofline source: aggregates results/dryrun/*.json into the per-cell
roofline table (3 terms, dominant bottleneck, useful-FLOPs ratio)."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(mesh="1pod"):
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, "results", "dryrun", f"*__{mesh}.json"))):
        with open(path) as fh:
            r = json.load(fh)
        if isinstance(r, list):
            rows.extend(r)
        else:
            rows.append(r)
    return [r for r in rows if isinstance(r, dict)]


def run(mesh="1pod", quiet=False):
    rows = load(mesh)
    out = []
    for r in rows:
        if not r.get("ok"):
            out.append({"arch": r.get("arch"), "shape": r.get("shape"), "ok": False,
                        "error": r.get("error", "?")})
            continue
        t = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "ok": True,
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": t["dominant"],
            "useful_ratio": r.get("useful_flops_ratio"),
            "hbm_gb": r["memory"]["peak_est_bytes"] / 1e9,
            "fits": r["memory"]["peak_est_bytes"] < 16e9,
            "compile_s": r.get("compile_s"),
        })
    if not quiet:
        print(f"roofline table ({mesh}): {sum(o['ok'] for o in out)}/{len(out)} cells")
        hdr = f"{'arch':<24}{'shape':<15}{'compute':>10}{'memory':>10}{'collect':>10}  {'dom':<10}{'useful':>7}{'HBM GB':>8} fit"
        print(hdr)
        for o in sorted(out, key=lambda x: (x["arch"], x["shape"])):
            if not o["ok"]:
                print(f"{o['arch']:<24}{o['shape']:<15} FAILED: {o['error'][:60]}")
                continue
            ur = f"{o['useful_ratio']:.3f}" if o["useful_ratio"] else "-"
            print(f"{o['arch']:<24}{o['shape']:<15}{o['compute_ms']:>9.1f}ms{o['memory_ms']:>9.1f}ms"
                  f"{o['collective_ms']:>9.1f}ms  {o['dominant']:<10}{ur:>7}{o['hbm_gb']:>8.2f} {'Y' if o['fits'] else 'N'}")
    return out


if __name__ == "__main__":
    import sys

    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "1pod")
