"""Paper Figure 3: compression ratio (compressed / uncompressed N-Triples)
per dataset per compressor, on the synthetic stand-ins of Table 1b."""
from __future__ import annotations

from benchmarks.common import build_all
from repro.data.synthetic import PAPER_DATASETS

DATASETS = ["homepages-en", "geo-coordinates-en", "jamendo", "archiveshub",
            "chess-legal", "ttt-win", "WikiTalk", "NotreDame", "CA-AstroPh"]


def run(datasets=DATASETS, quiet=False):
    from repro.core.itr_plus import dictionary_cost_itr, dictionary_cost_itr_plus

    rows = []
    for name in datasets:
        ds = PAPER_DATASETS[name]()
        built = build_all(ds)
        raw = built.pop("raw_bytes")
        row = {"dataset": name, "V": ds.n_nodes, "E": ds.n_triples, "T": ds.n_preds}
        for method, b in built.items():
            size = b["size"]
            # labeled datasets: ITR pays |labeled nodes| dictionary entries,
            # ITR+ only the distinct label strings (paper §ITR+)
            if ds.node_labels is not None and method in ("ITR", "ITR+"):
                n_labeled = int((ds.node_labels >= 0).sum())
                size += (dictionary_cost_itr_plus(ds.node_label_names)
                         if method == "ITR+"
                         else dictionary_cost_itr(ds.node_label_names, n_labeled))
            row[method] = size / raw
        rows.append(row)
        if not quiet:
            ratios = " ".join(f"{m}={row[m]:.4f}" for m in built)
            print(f"fig3 {name:<20} V={ds.n_nodes:<7} E={ds.n_triples:<8} {ratios}")
    return rows


if __name__ == "__main__":
    run()
