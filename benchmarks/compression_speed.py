"""Compression throughput (paper reports build feasibility — RDFRePair was
stopped after 6 days on wikidata; ITR's count/replace must scale): edges/s
on growing synthetic inputs, plus the Pallas digram-count kernel stage."""
from __future__ import annotations

import time


from repro.core import Hypergraph, LabelTable, compress
from repro.data.synthetic import rdf_like


def run(sizes=(2000, 8000, 32000), quiet=False):
    rows = []
    for n_edges in sizes:
        ds = rdf_like(n_nodes=n_edges // 3, n_edges=n_edges, n_preds=20, seed=1)
        table = LabelTable.terminals([2] * ds.n_preds)
        g = Hypergraph.from_triples(ds.triples, ds.n_nodes)
        t0 = time.perf_counter()
        grammar, stats = compress(g, table)
        dt = time.perf_counter() - t0
        rows.append({"edges": ds.n_triples, "seconds": dt,
                     "edges_per_s": ds.n_triples / dt,
                     "iterations": stats.iterations,
                     "replaced": stats.replaced_occurrences})
        if not quiet:
            print(f"speed E={ds.n_triples:<7} {dt:6.2f}s  {ds.n_triples/dt:9.0f} edges/s "
                  f"iters={stats.iterations} replaced={stats.replaced_occurrences}")
    return rows


if __name__ == "__main__":
    run()
