"""Paper §ITR+: node labels as rank-1 hyperedges on the ttt-win stand-in.

Measures (a) structure bytes, (b) dictionary bytes (ITR: one RDF repr per
labeled node; ITR+: one entry per distinct label), (c) compression with the
loop-rule ablation (§Handling loops: extra rules do NOT beat index-functions).
"""
from __future__ import annotations

from benchmarks.common import build_itr
from repro.core.itr_plus import dictionary_cost_itr, dictionary_cost_itr_plus
from repro.data.synthetic import PAPER_DATASETS


def run(quiet=False):
    ds = PAPER_DATASETS["ttt-win"]()
    n_labeled = int((ds.node_labels >= 0).sum())
    label_names = ds.node_label_names

    plain = build_itr(ds, plus=False)
    plus = build_itr(ds, plus=True)
    dict_plain = dictionary_cost_itr(label_names, n_labeled)
    dict_plus = dictionary_cost_itr_plus(label_names)
    total_plain = plain["size"] + dict_plain
    total_plus = plus["size"] + dict_plus
    rows = [{
        "dataset": "ttt-win",
        "itr_structure": plain["size"], "itr_dict": dict_plain, "itr_total": total_plain,
        "itr_plus_structure": plus["size"], "itr_plus_dict": dict_plus,
        "itr_plus_total": total_plus,
        "plus_gain": 1 - total_plus / total_plain,
    }]
    if not quiet:
        r = rows[0]
        print(f"itr+ ttt-win: ITR total={r['itr_total']}B (dict {r['itr_dict']}B) | "
              f"ITR+ total={r['itr_plus_total']}B (dict {r['itr_plus_dict']}B) | "
              f"gain={r['plus_gain']:.1%}")
    return rows


if __name__ == "__main__":
    run()
