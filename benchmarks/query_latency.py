"""Paper Figure 4: average runtime of 500 queries per triple pattern on the
geo-coordinates-en stand-in, per engine (ITR vs k²-triples vs HDT-BT).

The paper's claim under test: ITR answers every pattern except ?P? faster
than (or comparable to) the baselines, in milliseconds.

Beyond the paper, `BENCH_query_latency.json` tracks the serving-perf
trajectory from PR 1 onward:

* per-pattern µs for the batched engine (`query_batch_arrays`) vs the seed
  per-query worklist (`query_scalar`), plus `batch_throughput_qps`;
* a `warm_cache` section — cold (cache-miss + insert) vs warm (all-hit)
  batch runs against the uncached baseline, exercising the cross-request
  result cache incl. its ?P? segment;
* a `crossover_dispatch` section — single-query latency of the dispatched
  `engine.query` vs the scalar worklist vs a forced frontier-of-one, per
  selective pattern, at the engine's calibrated crossover width;
* a `sharded` section (PR 3) — per-shard-count mixed-workload throughput
  for both partition strategies, scatter-gather latency vs the single
  engine on the unselective patterns, and the warm repeated-``?P?``
  micro-batch workload through the view path (`query_batch_view`): shared
  entries instead of per-duplicate replication, which is the PR 2
  `warm_cache` cost floor the view is built to beat;
* a `mutation` section (PR 4) — overlay query overhead vs delta size
  (the same mixed workload on one engine at increasing insert+tombstone
  counts, relative to the clean engine) and incremental per-shard
  rebuild vs a full recompress of the mutated triple set (the
  amortization the delta budget buys);
* a `rebalance` section (PR 5) — a skewed mutation burst concentrates
  rows on one `node_range` shard, then `rebalance()` re-cuts the
  boundaries online: mixed-workload latency before/after, live skew
  before/after, and the cost of the incremental tombstone/insert
  migration vs a full re-partition (fresh `ShardedTripleService.build`)
  of the same logical triples;
* a `recovery` section (PR 6) — durable-tier cold start: reopening the
  service from its mmap-able snapshot (`DurableShardedService.open`) vs
  recompressing the same triples through RePair from scratch, gated as
  ``cold_start_speedup``; plus the WAL replay rate (records/s through
  recovery) and the first-query-after-restore latency (the page-fault
  cost mmap defers out of the open path).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import (
    BATCH_QUERIES_PER_PATTERN,
    PATTERNS,
    QUERIES_PER_PATTERN,
    bind_pattern,
    build_all,
    engine_cache_disabled,
    sample_rows,
    time_queries,
    time_query_batch,
)
from repro.data.synthetic import PAPER_DATASETS

# selective patterns: S or O bound — the ones eligible for scalar dispatch
DISPATCH_PATTERNS = ["s??", "sp?", "s?o", "??o", "spo"]
WARM_CACHE_PATTERNS = ["s??", "?p?", "sp?", "??o"]
# sharded-tier sweep: shard counts per strategy + the mixed routing workload
SHARD_COUNTS = (1, 2, 4)
SHARDED_MIXED_CYCLE = ["s??", "sp?", "?p?", "??o"]


def run(dataset="geo-coordinates-en", n_queries=500, quiet=False,
        json_path="BENCH_query_latency.json", scale=None):
    ds = PAPER_DATASETS[dataset]() if scale is None else PAPER_DATASETS[dataset](scale=scale)
    built = build_all(ds)
    built.pop("raw_bytes")
    itr = built["ITR"]["engine"]
    rows = []
    bench = {"dataset": dataset, "n_queries": n_queries, "patterns": {}}
    for pattern in PATTERNS:
        row = {"pattern": pattern}
        checks = {}
        for method, b in built.items():
            us, n_res = time_queries(b["engine"], ds, pattern, n_queries)
            row[method] = us
            checks[method] = n_res
        # seed per-query reference path (pre-batching worklist)
        scalar_us, scalar_n = time_queries(
            itr, ds, pattern, n_queries, query_fn=itr.query_scalar)
        checks["ITR-scalar"] = scalar_n
        # batched throughput on the full workload
        bat_us, bat_n, qps = time_query_batch(itr, ds, pattern, n_queries)
        # batched parity on the same capped sample as the per-query engines
        # (the timing run above already IS that sample unless caps differ)
        n_par = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries))
        n_bat = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pattern, n_queries))
        if n_par == n_bat:
            checks["ITR-batched"] = bat_n
        else:
            _, par_n, _ = time_query_batch(itr, ds, pattern, n_par)
            checks["ITR-batched"] = par_n
        # engines must agree on result counts (correctness guard)
        assert len(set(checks.values())) == 1, f"{pattern}: result mismatch {checks}"
        row["ITR-batched"] = bat_us
        speedup = scalar_us / bat_us if bat_us > 0 else float("inf")
        bench["patterns"][pattern] = {
            "scalar_us": scalar_us,
            "batched_us": bat_us,
            "speedup_vs_scalar": speedup,
            "batch_qps": qps,
            "n_results_batched": bat_n,
            "baseline_us": {m: row[m] for m in built},
        }
        rows.append(row)
        if not quiet:
            times = " ".join(f"{m}={row[m]:9.1f}us" for m in built)
            print(f"fig4 {pattern} {times} batched={bat_us:9.1f}us "
                  f"({speedup:5.1f}x vs scalar)  (n={checks['ITR']})")
    _bench_warm_cache(itr, ds, bench, n_queries, quiet)
    _bench_crossover(itr, ds, bench, n_queries, quiet)
    _bench_sharded(itr, ds, bench, n_queries, quiet)
    _bench_mutation(itr, ds, bench, n_queries, quiet)
    _bench_rebalance(itr, ds, bench, n_queries, quiet)
    _bench_bgp(itr, ds, bench, n_queries, quiet)
    _bench_recovery(ds, bench, quiet)
    _bench_ingestion(ds, bench, quiet)
    _finalize_throughput(bench, n_queries)
    if json_path:
        try:  # a full rewrite must not erase the committed CI gate baseline
            prior = json.loads(Path(json_path).read_text())
            if "smoke_baseline" in prior:
                bench["smoke_baseline"] = prior["smoke_baseline"]
        except (OSError, ValueError):
            pass
        Path(json_path).write_text(json.dumps(bench, indent=2))
    if not quiet:
        print(f"batch_throughput_qps={bench['batch_throughput_qps']:.0f}"
              + (f" -> {json_path}" if json_path else " (not written)"))
    return rows


def _bench_warm_cache(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Streaming repeated-pattern serving: a hot set of patterns queried in
    micro-batches. In-batch dedup collapses repeats *within* one flush; only
    the cross-request cache collapses them *across* flushes — so the
    uncached baseline re-executes every micro-batch's unique patterns while
    the warm pass answers them all from the LRU. The acceptance bar is warm
    throughput >= 5x the uncached batch path on this workload.
    """
    if itr.cache is None:
        return
    hot, micro = 32, 32
    n_flushes = max(2, min(16, n_queries // micro))
    rng = np.random.default_rng(1)
    out = {}
    for pattern in WARM_CACHE_PATTERNS:
        pool = np.unique(sample_rows(ds, 4 * hot), axis=0)[:hot]
        batches = []
        for _ in range(n_flushes):
            picks = pool[rng.integers(0, len(pool), micro)]
            batches.append(bind_pattern(pattern, picks))
        total_q = n_flushes * micro

        def run_workload():
            t0 = time.perf_counter()
            for s_arr, p_arr, o_arr in batches:
                itr.query_batch_arrays(s_arr, p_arr, o_arr)
            return (time.perf_counter() - t0) / total_q * 1e6

        # min over reps: the CI gate compares warm/uncached ratios, and a
        # load spike hitting one side of a single-shot measurement skews
        # the ratio by several x (same rationale as the dispatch section)
        with engine_cache_disabled(itr):
            uncached_us = min(run_workload() for _ in range(2))
        itr.cache.clear()
        cold_us = run_workload()  # first flush misses, later flushes hit
        warm_us = min(run_workload() for _ in range(2))  # all-hit steady state
        out[pattern] = {
            "uncached_us": uncached_us,
            "cold_us": cold_us,
            "warm_us": warm_us,
            "warm_speedup_vs_uncached": uncached_us / warm_us if warm_us > 0 else float("inf"),
            "warm_qps": 1e6 / warm_us if warm_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"cache {pattern} uncached={uncached_us:9.1f}us cold={cold_us:9.1f}us "
                  f"warm={warm_us:9.1f}us ({out[pattern]['warm_speedup_vs_uncached']:5.1f}x"
                  f" vs uncached batch)")
    # single-query point lookups: the purest repeated-pattern serving case
    s0, p0, o0 = (int(v) for v in sample_rows(ds, 1)[0])
    reps = 50
    with engine_cache_disabled(itr):
        t0 = time.perf_counter()
        for _ in range(reps):
            itr.query(s0, None, None)
        point_uncached_us = (time.perf_counter() - t0) / reps * 1e6
    itr.cache.clear()
    itr.query(s0, None, None)  # populate
    t0 = time.perf_counter()
    for _ in range(reps):
        itr.query(s0, None, None)
    point_warm_us = (time.perf_counter() - t0) / reps * 1e6
    agg_uncached = sum(p["uncached_us"] for p in out.values())
    agg_warm = sum(p["warm_us"] for p in out.values())
    st = itr.cache.stats
    bench["warm_cache"] = {
        "hot_patterns": hot,
        "micro_batch": micro,
        "n_flushes": n_flushes,
        "patterns": out,
        "aggregate_warm_speedup_vs_uncached":
            agg_uncached / agg_warm if agg_warm > 0 else float("inf"),
        "point_lookup": {
            "uncached_us": point_uncached_us,
            "warm_us": point_warm_us,
            "warm_speedup": point_uncached_us / point_warm_us if point_warm_us > 0 else float("inf"),
        },
        "cache_stats": {"hits": st.hits, "misses": st.misses,
                        "evictions": st.evictions, "inserts": st.inserts,
                        "predicate_hits": st.predicate_hits,
                        "hit_rate": st.hit_rate},
    }
    if not quiet:
        print(f"cache point-lookup uncached={point_uncached_us:9.1f}us "
              f"warm={point_warm_us:9.1f}us "
              f"({bench['warm_cache']['point_lookup']['warm_speedup']:5.1f}x)")


def _bench_crossover(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Single-query latency per selective pattern: the dispatched engine
    entry (`query`) — timed on the real serving path, cache attached and
    cold (unique patterns, so every call is a miss + insert) — must be no
    worse than the seed scalar worklist; the forced frontier-of-one
    documents the gap the dispatch closes."""

    def _cold_dispatched_us(pattern: str, nq: int) -> float:
        if itr.cache is None:  # cache-less engine: query() IS the worklist
            return time_queries(itr, ds, pattern, nq)[0]
        rows = np.unique(sample_rows(ds, 2 * nq), axis=0)[:nq]  # no repeats:
        itr.cache.clear()                                       # all misses
        t0 = time.perf_counter()
        for s, p, o in rows:
            itr.query(int(s) if pattern[0] == "s" else None,
                      int(p) if pattern[1] == "p" else None,
                      int(o) if pattern[2] == "o" else None)
        return (time.perf_counter() - t0) / len(rows) * 1e6

    out = {}
    for pattern in DISPATCH_PATTERNS:
        nq = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries), 100)
        # min over reps: single-run wall timings jitter more than the
        # dispatch overhead being measured
        dispatched_us = min(_cold_dispatched_us(pattern, nq) for _ in range(2))
        scalar_us = min(time_queries(itr, ds, pattern, nq,
                                     query_fn=itr.query_scalar)[0] for _ in range(2))
        crossover = itr.crossover
        itr.crossover = 0  # force the frontier path (time_queries detaches the cache)
        try:
            frontier_us, _ = time_queries(itr, ds, pattern, nq)
        finally:
            itr.crossover = crossover
        out[pattern] = {
            "dispatched_us": dispatched_us,
            "scalar_us": scalar_us,
            "frontier_single_us": frontier_us,
            "dispatched_vs_scalar": dispatched_us / scalar_us if scalar_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"dispatch {pattern} dispatched={dispatched_us:9.1f}us "
                  f"scalar={scalar_us:9.1f}us frontier1={frontier_us:9.1f}us")
    bench["crossover_dispatch"] = {"crossover_width": itr.crossover, "patterns": out}


def _bench_sharded(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Sharded serving tier: partitioned engines + scatter-gather router +
    shared cache, plus the view-based warm path.

    Three measurements land in ``bench["sharded"]``:

    * per-shard-count cold/warm throughput of a mixed selective/unselective
      workload through `ShardedTripleService`, both partition strategies;
    * scatter-gather overhead: the unselective patterns on a 4-shard
      service (caches detached) vs the single engine's uncached batch;
    * the warm repeated-``?P?`` micro-batch workload through
      `query_batch_view` vs the materializing `query_batch_arrays` — the
      view must beat the PR 2 `warm_cache` warm number because it skips
      the per-duplicate replication entirely.
    """
    from repro.serve.sharded import ShardedTripleService

    section: dict = {"shard_counts": list(SHARD_COUNTS), "strategies": {}}

    # mixed workload: rows bound through a rotating pattern cycle
    nq = min(n_queries, 200)
    rows = sample_rows(ds, nq, seed=3)
    mixed = [bind_pattern(SHARDED_MIXED_CYCLE[i % len(SHARDED_MIXED_CYCLE)],
                          rows[i:i + 1]) for i in range(nq)]
    mixed = [(s[0], p[0], o[0]) for s, p, o in mixed]

    def run_mixed(svc) -> float:
        t0 = time.perf_counter()
        svc.query_many(mixed)
        return (time.perf_counter() - t0) / nq * 1e6

    widest: dict = {}  # strategy -> max-shard-count service, reused below
    for strategy in ("predicate_hash", "node_range"):
        per = {}
        for n_shards in SHARD_COUNTS:
            svc = ShardedTripleService.build(
                ds.triples, ds.n_nodes, ds.n_preds,
                n_shards=n_shards, strategy=strategy)
            cold_us = run_mixed(svc)   # cache misses + inserts
            st = svc.stats
            routing = (st.owned, st.scattered, st.shard_batches)
            warm_us = run_mixed(svc)   # shared-tier hits
            per[str(n_shards)] = {
                "cold_us_per_query": cold_us,
                "warm_us_per_query": warm_us,
                "warm_qps": 1e6 / warm_us if warm_us > 0 else float("inf"),
                # routing counts from the cold pass only (one workload's worth)
                "owned_unique": routing[0],
                "scattered_unique": routing[1],
                "shard_batches": routing[2],
                "shard_edges": svc.shard_sizes(),
            }
            if n_shards == max(SHARD_COUNTS):
                widest[strategy] = svc
            if not quiet:
                print(f"sharded {strategy} P={n_shards} cold={cold_us:9.1f}us "
                      f"warm={warm_us:9.1f}us owned={routing[0]} "
                      f"scattered={routing[1]}")
        section["strategies"][strategy] = per

    # scatter-gather vs single engine, caches detached on both sides.
    # Each pattern runs on a strategy where it genuinely scatters: ?P? is
    # OWNED under predicate_hash (that axis exists to own it), so its
    # scatter cost shows only under node_range; ??O scatters under both.
    sg = {}
    for pattern, strategy in (("?p?", "node_range"), ("??o", "predicate_hash")):
        svc = widest[strategy]
        nqp = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries))
        # min over reps on both sides: these ratios feed the CI gate
        single_us = min(time_query_batch(itr, ds, pattern, nqp)[0]
                        for _ in range(2))
        s_arr, p_arr, o_arr = bind_pattern(pattern, sample_rows(ds, nqp, seed=0))
        # detach engine caches AND the shared tier (merged-entry namespace)
        # so every rep measures the execution fan-out, not a cache hit
        caches = [e.cache for e in svc.engines]
        svc_cache, svc.cache = svc.cache, None
        for e in svc.engines:
            e.cache = None
        try:
            def run_scatter() -> float:
                t0 = time.perf_counter()
                for s, p, o in zip(s_arr, p_arr, o_arr):
                    svc.submit(s, p, o)
                svc.flush_view()
                return (time.perf_counter() - t0) / nqp * 1e6

            sharded_us = min(run_scatter() for _ in range(2))
        finally:
            svc.cache = svc_cache
            for e, c in zip(svc.engines, caches):
                e.cache = c
        sg[pattern] = {
            "strategy": strategy,
            "single_engine_us": single_us,
            "sharded_us": sharded_us,
            "sharded_vs_single": sharded_us / single_us if single_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"sharded scatter {pattern} [{strategy}] single={single_us:9.1f}us "
                  f"sharded(P={max(SHARD_COUNTS)})={sharded_us:9.1f}us")
    section["scatter_gather"] = sg

    # warm ?P? through the view path: the PR 2 warm_cache workload shape
    # (hot pattern pool, micro-batches), materialized vs view-based
    if itr.cache is not None:
        hot, micro = 32, 32
        n_flushes = max(2, min(16, n_queries // micro))
        rng = np.random.default_rng(1)
        pool = np.unique(sample_rows(ds, 4 * hot), axis=0)[:hot]
        batches = []
        for _ in range(n_flushes):
            picks = pool[rng.integers(0, len(pool), micro)]
            batches.append(bind_pattern("?p?", picks))
        total_q = n_flushes * micro

        def run_flushes(fn) -> float:
            t0 = time.perf_counter()
            for s_arr, p_arr, o_arr in batches:
                fn(s_arr, p_arr, o_arr)
            return (time.perf_counter() - t0) / total_q * 1e6

        itr.cache.clear()
        run_flushes(itr.query_batch_arrays)            # populate
        # min over reps: speedup_vs_materialized feeds the CI gate
        warm_mat_us = min(run_flushes(itr.query_batch_arrays) for _ in range(2))
        view_warm_us = min(run_flushes(itr.query_batch_view) for _ in range(2))

        # the same workload through the warm scatter-gather tier, on the
        # strategy where ?P? actually fans out (node_range)
        svc_nr = widest["node_range"]

        def sharded_flush(s_arr, p_arr, o_arr):
            for s, p, o in zip(s_arr, p_arr, o_arr):
                svc_nr.submit(s, p, o)
            svc_nr.flush_view()

        run_flushes(sharded_flush)                     # populate shared tier
        sharded_view_warm_us = min(run_flushes(sharded_flush) for _ in range(2))
        section["warm_view"] = {
            "materialized_warm_us": warm_mat_us,
            "view_warm_us": view_warm_us,
            "speedup_vs_materialized":
                warm_mat_us / view_warm_us if view_warm_us > 0 else float("inf"),
            "sharded_view_warm_us": sharded_view_warm_us,
            "view_warm_qps": 1e6 / view_warm_us if view_warm_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"sharded warm-view ?p? materialized={warm_mat_us:9.1f}us "
                  f"view={view_warm_us:9.1f}us "
                  f"({section['warm_view']['speedup_vs_materialized']:5.1f}x) "
                  f"sharded-view={sharded_view_warm_us:9.1f}us")
    bench["sharded"] = section


def _bench_mutation(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Mutation subsystem: what writes cost the read path, and what the
    delta budget buys at rebuild time.

    * *Overlay overhead*: a cache-less engine runs the mixed batch
      workload after its delta overlay is grown to a small and a large
      tier (half inserts, half tombstones — both merge steps exercised),
      timed against a from-scratch engine compressed from the SAME
      logical triple set. Same logical set -> same result volume, so the
      gated ratio ``us(overlay) / us(recompressed)`` isolates pure
      overlay cost instead of confounding it with tombstones shrinking
      (or inserts growing) the results being materialized.
    * *Incremental rebuild*: mutations targeting ONE predicate land on
      one shard of a 4-shard predicate-hash service; `rebuild(force=True)`
      recompresses just that shard, timed against a from-scratch
      `ShardedTripleService.build` on the mutated triple set. The gated
      ratio is ``full_s / incremental_s`` (the amortization factor).
    """
    from repro.core import (
        Hypergraph,
        LabelTable,
        TripleQueryEngine,
        compress,
    )
    from repro.serve.sharded import ShardedTripleService

    rng = np.random.default_rng(7)
    nq = min(n_queries, 100)
    rows = sample_rows(ds, nq, seed=5)
    batches = [bind_pattern(pat, rows) for pat in SHARDED_MIXED_CYCLE]

    engine = TripleQueryEngine(itr.grammar, itr.encoded, cache=None,
                               crossover=0, delta_budget=None)

    def run_workload(e) -> float:
        t0 = time.perf_counter()
        for s_arr, p_arr, o_arr in batches:
            e.query_batch_arrays(s_arr, p_arr, o_arr)
        return (time.perf_counter() - t0) / (nq * len(batches)) * 1e6

    def recompressed() -> TripleQueryEngine:
        """From-scratch engine on the overlay engine's logical triples —
        the tier's fair baseline (identical results, no overlay)."""
        logical = engine.current_triples()
        n_nodes = ds.n_nodes
        if len(logical):
            n_nodes = max(n_nodes, int(logical[:, [0, 2]].max()) + 1)
        grammar, _ = compress(
            Hypergraph.from_triples(logical, n_nodes),
            LabelTable.terminals([2] * ds.n_preds))
        return TripleQueryEngine(grammar, cache=None, crossover=0,
                                 delta_budget=None)

    del_pool = np.unique(np.asarray(ds.triples, dtype=np.int64), axis=0)
    rng.shuffle(del_pool)
    del_cursor = [0]

    def grow_delta(target: int) -> None:
        """Half inserts / half tombstones, re-drawing until the overlay
        reaches `target` (random inserts colliding with base rows are
        filtered out by set semantics, so one draw may fall short)."""
        for _ in range(8):
            need = target - engine.delta.size
            if need <= 0:
                return
            n_ins = (need + 1) // 2
            fresh = np.stack([rng.integers(0, ds.n_nodes, n_ins),
                              rng.integers(0, ds.n_preds, n_ins),
                              rng.integers(0, ds.n_nodes, n_ins)], axis=1)
            engine.insert_triples(fresh)
            n_del = min(target - engine.delta.size,
                        len(del_pool) - del_cursor[0])
            if n_del > 0:
                engine.delete_triples(
                    del_pool[del_cursor[0]:del_cursor[0] + n_del])
                del_cursor[0] += n_del

    # min over reps: overhead_vs_clean feeds the CI gate
    pristine_us = min(run_workload(engine) for _ in range(2))
    tiers = {}
    for tier, target in (("small", 64), ("large", 512)):
        grow_delta(target)
        tier_us = min(run_workload(engine) for _ in range(2))
        clean_us = min(run_workload(recompressed()) for _ in range(2))
        tiers[tier] = {
            "delta_rows": engine.delta.size,
            "us_per_query": tier_us,
            "recompressed_us_per_query": clean_us,
            "overhead_vs_clean": tier_us / clean_us if clean_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"mutation overlay {tier} delta={engine.delta.size} "
                  f"recompressed={clean_us:9.1f}us overlaid={tier_us:9.1f}us "
                  f"({tiers[tier]['overhead_vs_clean']:5.2f}x)")

    # incremental per-shard rebuild vs full recompress of the mutated set
    n_shards = 4
    svc = ShardedTripleService.build(ds.triples, ds.n_nodes, ds.n_preds,
                                     n_shards=n_shards, cache=None,
                                     strategy="predicate_hash", crossover=0,
                                     delta_budget=None, rebalance_skew=None)
    p0 = int(ds.triples[0, 1])  # one predicate -> one owning shard
    n_mut = max(16, len(ds.triples) // 50)
    fresh = np.stack([rng.integers(0, ds.n_nodes, n_mut),
                      np.full(n_mut, p0, dtype=np.int64),
                      rng.integers(0, ds.n_nodes, n_mut)], axis=1)
    svc.insert_triples(fresh)
    dirty = [k for k, d in enumerate(svc.delta_sizes()) if d]
    delta_rows = int(sum(svc.delta_sizes()))
    mutated = np.concatenate([t.current_triples() for t in svc.engines])
    t0 = time.perf_counter()
    rebuilt = svc.rebuild(force=True)
    incr_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    ShardedTripleService.build(mutated, ds.n_nodes, ds.n_preds,
                               n_shards=n_shards, cache=None,
                               strategy="predicate_hash", crossover=0,
                               delta_budget=None)
    full_s = time.perf_counter() - t0
    bench["mutation"] = {
        "overlay": {"pristine_us_per_query": pristine_us, "tiers": tiers},
        "rebuild": {
            "n_shards": n_shards,
            "dirty_shards": len(dirty),
            "rebuilt_shards": rebuilt,
            "delta_rows": delta_rows,
            "incremental_s": incr_s,
            "full_s": full_s,
            "full_vs_incremental": full_s / incr_s if incr_s > 0 else float("inf"),
        },
    }
    if not quiet:
        print(f"mutation rebuild dirty={dirty} incremental={incr_s * 1e3:9.1f}ms "
              f"full={full_s * 1e3:9.1f}ms "
              f"({bench['mutation']['rebuild']['full_vs_incremental']:5.1f}x)")


def _bench_rebalance(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Online rebalancing under a skewed write burst.

    A 4-shard `node_range` tier takes a burst of inserts whose subjects
    all fall inside shard 0's range — the hot-shard shape mutation
    produces in practice — then `rebalance(force=True)` re-quantiles the
    boundaries and migrates the diff. Recorded (caches detached so shard
    balance is the only variable):

    * mixed-workload latency on the skewed tier, right after the
      migration (moved rows still in destination overlays), and at
      steady state once the dirty shards rebuild;
    * live `max/mean` skew before/after (deterministic, gated);
    * migration cost (plan + tombstone/insert moves) vs a full
      re-partition (`ShardedTripleService.build` on the same logical
      triples) — the amortization online re-cutting buys, gated as
      ``full_vs_migration``.
    """
    from repro.serve.sharded import ShardedTripleService

    n_shards = 4
    svc = ShardedTripleService.build(ds.triples, ds.n_nodes, ds.n_preds,
                                     n_shards=n_shards, cache=None,
                                     strategy="node_range", crossover=0,
                                     delta_budget=None, rebalance_skew=None)
    # hot burst: subjects packed into shard 0's range, distinct enough
    # that a quantile re-cut CAN split them across shards
    rng = np.random.default_rng(11)
    lo = int(svc.plan.boundaries[0])
    hi = max(int(svc.plan.boundaries[1]), lo + 1)
    n_burst = max(64, len(ds.triples) // 4)
    burst = np.stack([rng.integers(lo, hi, n_burst),
                      rng.integers(0, ds.n_preds, n_burst),
                      rng.integers(0, ds.n_nodes, n_burst)], axis=1)
    inserted = svc.insert_triples(burst)
    skew_before = svc.skew()

    nq = min(n_queries, 100)
    rows = sample_rows(ds, nq, seed=9)
    hot = burst[rng.integers(0, len(burst), nq)]
    rows[::2] = hot[::2]  # half the probes target the hot range
    mixed = [bind_pattern(SHARDED_MIXED_CYCLE[i % len(SHARDED_MIXED_CYCLE)],
                          rows[i:i + 1]) for i in range(nq)]
    mixed = [(s[0], p[0], o[0]) for s, p, o in mixed]

    def run_mixed() -> float:
        t0 = time.perf_counter()
        svc.query_many(mixed)
        return (time.perf_counter() - t0) / nq * 1e6

    before_us = min(run_mixed() for _ in range(2))
    logical = np.concatenate([e.current_triples() for e in svc.engines])

    t0 = time.perf_counter()
    res = svc.rebalance(force=True)
    migration_s = time.perf_counter() - t0
    skew_after = svc.skew()
    after_us = min(run_mixed() for _ in range(2))
    # steady state: fold the migration overlays into fresh grammars
    svc.rebuild(force=True)
    after_rebuild_us = min(run_mixed() for _ in range(2))

    n_nodes = max(ds.n_nodes, int(logical[:, [0, 2]].max()) + 1) \
        if len(logical) else ds.n_nodes
    t0 = time.perf_counter()
    ShardedTripleService.build(logical, n_nodes, ds.n_preds,
                               n_shards=n_shards, cache=None,
                               strategy="node_range", crossover=0,
                               delta_budget=None, rebalance_skew=None)
    full_s = time.perf_counter() - t0

    bench["rebalance"] = {
        "n_shards": n_shards,
        "burst_rows": int(inserted),
        "migrated_rows": svc.stats.migrated_rows,
        "skew_before": skew_before,
        "skew_after": skew_after,
        "skew_after_vs_before": skew_after / skew_before
        if skew_before > 0 else float("inf"),
        "mixed_before_us": before_us,
        "mixed_after_us": after_us,
        "mixed_after_rebuild_us": after_rebuild_us,
        "migration_s": migration_s,
        "full_repartition_s": full_s,
        "full_vs_migration": full_s / migration_s
        if migration_s > 0 else float("inf"),
    }
    if not quiet:
        print(f"rebalance skew {skew_before:5.2f}->{skew_after:5.2f} "
              f"moved={svc.stats.migrated_rows} "
              f"mixed {before_us:9.1f}us->{after_us:9.1f}us"
              f"->{after_rebuild_us:9.1f}us(rebuilt) "
              f"migration={migration_s * 1e3:9.1f}ms "
              f"full={full_s * 1e3:9.1f}ms "
              f"({bench['rebalance']['full_vs_migration']:5.1f}x), "
              f"pending={res['pending']}")


def _naive_bgp_join(query_fn, patterns) -> list[tuple]:
    """The baseline `query_bgp` must beat: fetch each pattern's full
    result through the ordinary per-pattern query surface, then join the
    Python way — a dict index on the shared variables, patterns in the
    order given (no planning, no id-array joins). Returns sorted binding
    tuples, the `BGPResult.tuples()` comparison shape."""
    from repro.core.bgp import bgp_variables, parse_bgp

    patterns = parse_bgp(patterns)
    out_vars = bgp_variables(patterns)
    bindings: list[dict] = [{}]
    for pat in patterns:
        terms = pat.terms
        res = query_fn(*(None if isinstance(t, str) else t for t in terms))
        solved = set(bindings[0]) if bindings else set()
        shared = [v for v in pat.variables() if v in solved]
        index: dict = {}
        for label, (s, o) in res:
            vals: dict = {}
            ok = True
            for slot, val in enumerate((s, label, o)):
                term = terms[slot]
                if isinstance(term, str):
                    if term in vals and vals[term] != val:
                        ok = False
                        break
                    vals[term] = val
            if ok:
                index.setdefault(
                    tuple(vals[v] for v in shared), []).append(vals)
        nxt = []
        for b in bindings:
            for vals in index.get(tuple(b[v] for v in shared), []):
                nb = dict(b)
                nb.update(vals)
                nxt.append(nb)
        bindings = nxt
        if not bindings:
            break
    return sorted(tuple(b[v] for v in out_vars) for b in bindings)


def _chain_predicates(triples, k: int, n_preds: int) -> list[int]:
    """Predicates (p1, .., pk) such that `?a p1 ?b . ?b p2 ?c ...` is
    satisfiable, found by walking actual rows subject-to-object; falls
    back to the most frequent predicates when no k-hop walk exists (a
    0-binding chain still measures the join machinery, just less of it)."""
    by_subj: dict = {}
    for s, p, o in triples.tolist():
        by_subj.setdefault(s, []).append((p, o))
    for s, p, o in triples.tolist():
        chain, node = [p], o
        while len(chain) < k and by_subj.get(node):
            p2, node = by_subj[node][0]
            chain.append(p2)
        if len(chain) == k:
            return chain
    freq = np.argsort(-np.bincount(triples[:, 1], minlength=n_preds))
    return [int(freq[i % len(freq)]) for i in range(k)]


def _bench_bgp(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """BGP joins over the sharded tier (PR 9).

    Three shapes derived from the dataset's most frequent predicates — a
    2-pattern chain, a 3-pattern chain, and a 2-pattern star — each
    measured three ways on a 2-shard `predicate_hash` tier:

    * ``cold_us``: `query_bgp` with every cache namespace invalidated
      first (planner + bind/hash joins + sub-pattern fetches, all cold);
    * ``warm_us``: the identical BGP again — a whole-BGP hit in the
      merged cache namespace;
    * ``naive_us``: the per-pattern-then-Python-join baseline
      (`_naive_bgp_join`), also from a cold cache, same fetch surface.

    Gated: ``chain3.planned_vs_naive`` (naive/cold, higher is better) —
    the planned id-array join path must keep beating materialize-and-loop
    Python joins; ``chain3.warm_speedup`` (cold/warm) — the whole-BGP
    cache must keep short-circuiting repeat analytical queries.
    """
    from repro.serve.sharded import ShardedTripleService

    svc = ShardedTripleService.build(ds.triples, ds.n_nodes, ds.n_preds,
                                     n_shards=2, crossover=0,
                                     delta_budget=None, rebalance_skew=None)
    p1, p2, p3 = _chain_predicates(ds.triples, 3, ds.n_preds)
    shapes = {
        "chain2": f"?a {p1} ?b . ?b {p2} ?c",
        "chain3": f"?a {p1} ?b . ?b {p2} ?c . ?c {p3} ?d",
        "star2": f"?h {p1} ?a . ?h {p2} ?b",
    }
    section: dict = {"n_shards": 2, "predicates": [p1, p2, p3]}
    reps = 3
    for name, bgp in shapes.items():
        cold_s = warm_s = naive_s = float("inf")
        res = None
        for _ in range(reps):
            svc.invalidate()  # sub-pattern AND whole-BGP namespaces
            t0 = time.perf_counter()
            res = svc.query_bgp(bgp)
            cold_s = min(cold_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            svc.query_bgp(bgp)
            warm_s = min(warm_s, time.perf_counter() - t0)
        for _ in range(reps):
            svc.invalidate()  # same cold start the planned path gets
            t0 = time.perf_counter()
            naive = _naive_bgp_join(svc.query, bgp)
            naive_s = min(naive_s, time.perf_counter() - t0)
        assert naive == res.tuples(), f"bgp {name}: naive/planned mismatch"
        section[name] = {
            "bgp": bgp,
            "n_bindings": len(res),
            "cold_us": cold_s * 1e6,
            "warm_us": warm_s * 1e6,
            "naive_us": naive_s * 1e6,
            "planned_vs_naive": naive_s / cold_s if cold_s > 0 else float("inf"),
            "warm_speedup": cold_s / warm_s if warm_s > 0 else float("inf"),
        }
        if not quiet:
            r = section[name]
            print(f"bgp {name} n={len(res)} cold={r['cold_us']:9.1f}us "
                  f"warm={r['warm_us']:9.1f}us naive={r['naive_us']:9.1f}us "
                  f"({r['planned_vs_naive']:5.1f}x vs naive, "
                  f"{r['warm_speedup']:5.1f}x warm)")
    bench["bgp"] = section


def _bench_recovery(ds, bench: dict, quiet: bool) -> None:
    """Durable-tier cold start and WAL replay (PR 6).

    Three measurements land in ``bench["recovery"]``:

    * ``cold_start_speedup`` (gated): reopening the service from its
      snapshot (`DurableShardedService.open`, mmap-backed arrays, no
      RePair) vs compressing the same triples from scratch — the whole
      point of persisting engine state;
    * ``first_query_after_open_us``: the first query on the reopened
      tier, i.e. the page-fault cost mmap defers out of the open path;
    * ``wal_replay_records_per_s``: recovery throughput with a log of
      mutation records to replay over the snapshot (recorded, not gated
      — an absolute rate, machine-dependent).
    """
    import shutil
    import tempfile

    from repro.persist.service import DurableShardedService
    from repro.serve.sharded import ShardedTripleService

    n_shards = 2
    kwargs = dict(n_shards=n_shards, cache=None, crossover=0,
                  delta_budget=None, rebalance_skew=None)
    root = tempfile.mkdtemp(prefix="itr-bench-recovery-")
    try:
        svc = DurableShardedService.build(
            ds.triples, ds.n_nodes, ds.n_preds, root=root, **kwargs)
        svc.close()
        # min over reps: cold_start_speedup feeds the CI gate
        def timed_open():
            t0 = time.perf_counter()
            opened = DurableShardedService.open(
                root, cache=None, rebalance_skew=None)
            return time.perf_counter() - t0, opened

        cold_start_s, svc = timed_open()
        for _ in range(1):
            svc.close()
            again_s, svc = timed_open()
            cold_start_s = min(cold_start_s, again_s)
        s0 = int(ds.triples[0, 0])
        t0 = time.perf_counter()
        svc.query(s0, None, None)
        first_query_us = (time.perf_counter() - t0) * 1e6

        repair_s = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            ShardedTripleService.build(
                ds.triples, ds.n_nodes, ds.n_preds, **kwargs)
            repair_s = min(repair_s, time.perf_counter() - t0)

        # a log's worth of mutation records to replay over the snapshot
        rng = np.random.default_rng(13)
        n_records, per_record = 32, 8
        for _ in range(n_records):
            svc.insert_triples(np.stack(
                [rng.integers(0, ds.n_nodes, per_record),
                 rng.integers(0, ds.n_preds, per_record),
                 rng.integers(0, ds.n_nodes, per_record)], axis=1))
        svc.close()
        t0 = time.perf_counter()
        svc = DurableShardedService.open(
            root, cache=None, rebalance_skew=None)
        replay_open_s = time.perf_counter() - t0
        replayed = svc.last_recovery.replayed_records
        svc.close()

        bench["recovery"] = {
            "n_shards": n_shards,
            "cold_start_s": cold_start_s,
            "repair_rebuild_s": repair_s,
            "cold_start_speedup": repair_s / cold_start_s
            if cold_start_s > 0 else float("inf"),
            "first_query_after_open_us": first_query_us,
            "wal_records_replayed": int(replayed),
            "replay_open_s": replay_open_s,
            "wal_replay_records_per_s": replayed / replay_open_s
            if replay_open_s > 0 else float("inf"),
        }
        if not quiet:
            r = bench["recovery"]
            print(f"recovery cold-start={cold_start_s * 1e3:9.1f}ms "
                  f"repair-rebuild={repair_s * 1e3:9.1f}ms "
                  f"({r['cold_start_speedup']:5.1f}x) "
                  f"first-query={first_query_us:9.1f}us "
                  f"replay={replayed}rec/{replay_open_s * 1e3:.1f}ms "
                  f"({r['wal_replay_records_per_s']:.0f}rec/s)")
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _bench_ingestion(ds, bench: dict, quiet: bool) -> None:
    """Streaming RDF ingestion + term-dictionary footprint (PR 10).

    The dataset is serialized to N-Triples, then streamed back through
    :func:`repro.data.ingest.ingest_file` into an empty sharded tier.
    ``bench["ingestion"]`` records:

    * ``dict_vs_plain_bytes`` (gated, lower = better): the front-coded
      term dictionary's bytes vs a plain-Python forward+reverse mapping
      (raw term bytes stored twice + 8-byte id and pointer slots) —
      deterministic for a given dataset, so it gates tightly;
    * ``terms_per_s`` / ``rows_per_s``: mint and ingest throughput
      (recorded, not gated — absolute rates are machine-dependent);
    * ``dict_bytes_per_term`` vs ``hdt_model_bytes_per_term``: footprint
      against the IRI-length model the N-Triples size baseline assumes
      (:func:`repro.baselines.ntriples.ntriples_size_bytes`).
    """
    import shutil
    import tempfile

    from repro.data.ingest import ingest_file
    from repro.data.rdf import write_ntriples
    from repro.serve.sharded import ShardedTripleService

    tmp = tempfile.mkdtemp(prefix="itr-bench-ingest-")
    try:
        path = f"{tmp}/graph.nt"
        write_ntriples(path, ds.triples)
        svc = ShardedTripleService.build(
            np.zeros((0, 3), dtype=np.int64), n_nodes=1, n_preds=ds.n_preds,
            n_shards=2, cache=None, crossover=0, delta_budget=None,
            rebalance_skew=None)
        stats = ingest_file(svc, path)
        td = svc.term_dict
        n_terms = td.n_nodes + td.n_preds
        raw = sum(len(t.encode()) for t in td.nodes.terms_in_id_order()) \
            + sum(len(t.encode()) for t in td.preds.terms_in_id_order())
        plain_bytes = 2 * raw + 16 * n_terms
        dict_bytes = td.size_in_bytes()
        hdt_per_term = (24 * td.n_nodes + 28 * td.n_preds) / max(n_terms, 1)
        bench["ingestion"] = {
            "rows": stats.rows,
            "batches": stats.batches,
            "rows_per_s": stats.rows_per_s,
            "terms_minted": stats.new_nodes + stats.new_preds,
            "terms_per_s": (stats.new_nodes + stats.new_preds) / stats.seconds
            if stats.seconds > 0 else float("inf"),
            "dict_bytes": int(dict_bytes),
            "plain_dict_bytes": int(plain_bytes),
            "dict_vs_plain_bytes": dict_bytes / plain_bytes
            if plain_bytes > 0 else float("inf"),
            "dict_bytes_per_term": td.bytes_per_term(),
            "hdt_model_bytes_per_term": hdt_per_term,
        }
        if not quiet:
            b = bench["ingestion"]
            print(f"ingestion rows={b['rows']} "
                  f"({b['rows_per_s']:,.0f}rows/s, "
                  f"{b['terms_per_s']:,.0f}terms/s) "
                  f"dict={b['dict_bytes']}B vs plain={b['plain_dict_bytes']}B "
                  f"({b['dict_vs_plain_bytes']:.3f}x) "
                  f"{b['dict_bytes_per_term']:.1f}B/term "
                  f"(hdt model {b['hdt_model_bytes_per_term']:.1f}B/term)")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _finalize_throughput(bench: dict, n_queries: int) -> None:
    """Aggregate qps = total batched queries / total batched wall time."""
    total_q = 0
    total_s = 0.0
    for pat, p in bench["patterns"].items():
        nq = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pat, n_queries))
        total_q += nq
        total_s += p["batched_us"] * nq / 1e6
    bench["batch_throughput_qps"] = total_q / total_s if total_s > 0 else 0.0


if __name__ == "__main__":
    run()
