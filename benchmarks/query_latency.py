"""Paper Figure 4: average runtime of 500 queries per triple pattern on the
geo-coordinates-en stand-in, per engine (ITR vs k²-triples vs HDT-BT).

The paper's claim under test: ITR answers every pattern except ?P? faster
than (or comparable to) the baselines, in milliseconds.
"""
from __future__ import annotations

from benchmarks.common import PATTERNS, build_all, time_queries
from repro.data.synthetic import PAPER_DATASETS


def run(dataset="geo-coordinates-en", n_queries=500, quiet=False):
    ds = PAPER_DATASETS[dataset]()
    built = build_all(ds)
    built.pop("raw_bytes")
    rows = []
    for pattern in PATTERNS:
        row = {"pattern": pattern}
        checks = {}
        for method, b in built.items():
            us, n_res = time_queries(b["engine"], ds, pattern, n_queries)
            row[method] = us
            checks[method] = n_res
        # engines must agree on result counts (correctness guard)
        assert len(set(checks.values())) == 1, f"{pattern}: result mismatch {checks}"
        rows.append(row)
        if not quiet:
            times = " ".join(f"{m}={row[m]:9.1f}us" for m in built)
            print(f"fig4 {pattern} {times}  (n={checks['ITR']})")
    return rows


if __name__ == "__main__":
    run()
